"""Prefill path: batch-chunked prefill must equal unchunked exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "dbrx-132b"])
def test_chunked_prefill_matches_unchunked(arch):
    cfg = get_config(arch).reduced().replace(remat="nothing")
    if cfg.moe is not None:
        # capacity-based MoE drops are batch-size-dependent; with ample
        # capacity (no drops) chunked == unchunked must hold exactly
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": tokens}

    logits1, cache1 = jax.jit(model.prefill)(params, batch)
    model.cfg = cfg.replace(prefill_chunks=2)
    logits2, cache2 = jax.jit(model.prefill)(params, batch)

    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(cache1),
                    jax.tree_util.tree_leaves(cache2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-4, atol=1e-4)


def test_prefill_last_logits_match_forward():
    cfg = get_config("internlm2-1.8b").reduced().replace(remat="nothing")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    tokens = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    logits_fwd, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    logits_pre, cache = jax.jit(model.prefill)(params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(logits_pre[:, 0]),
                               np.asarray(logits_fwd[:, -1]),
                               rtol=1e-3, atol=1e-3)
    # cache covers the prompt
    k = jax.tree_util.tree_leaves(cache)[0]
    assert k.shape[2] == 12   # [L, B, S, ...]
