"""Approximate pre-filter indexes (`kernels/index.py`): exact re-rank
semantics (dedup, masking, tie-breaks), LSH/k-means recall on matching
workloads, and the `match_pair(mode="approx")` wiring — including the
ISSUE gate: LSH recall >= 0.95 at default probes on synthetic_scene
pairs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matching
from repro.kernels import index as kindex
from repro.kernels import ref


def packed(n, seed, words=8):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 2 ** 32, size=(n, words),
                                   dtype=np.uint64).astype(np.uint32))


# ---- exact re-rank ---------------------------------------------------------

def test_rerank_duplicate_candidate_cannot_fake_second_best():
    """The same row surfaced by two tables must not count twice: a
    duplicated best masquerading as second-best would zero the Lowe
    ratio and reject every real match."""
    db = packed(8, 0)
    q = db[2:3]                            # query equals db row 2: dist 0
    valid = jnp.ones(8, jnp.bool_)
    cand = jnp.asarray([[2, 2, 2, 5, -1, -1]], jnp.int32)
    best, second, idx = kindex.rerank_exact(q, db, valid, cand,
                                            metric="hamming")
    assert int(best[0]) == 0 and int(idx[0]) == 2
    # second-best is row 5's real distance, not the duplicated zero
    d5 = int(ref.match_best2(q, db[5:6], jnp.ones(1, jnp.bool_),
                             metric="hamming")[0][0])
    assert int(second[0]) == d5 > 0


def test_rerank_matches_oracle_on_full_candidate_set():
    """Candidates = every row -> rerank must equal the exact matcher,
    including db_valid masking and smallest-index tie-breaks."""
    nq, nk = 33, 210
    q, db = packed(nq, 1), packed(nk, 2)
    valid = jnp.asarray(np.random.RandomState(3).rand(nk) > 0.2)
    cand = jnp.tile(jnp.arange(nk, dtype=jnp.int32)[None], (nq, 1))
    got = kindex.rerank_exact(q, db, valid, cand, metric="hamming")
    want = ref.match_best2(q, db, valid, metric="hamming")
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rerank_empty_candidate_rows_yield_big():
    db = packed(4, 0)
    q = packed(2, 1)
    cand = jnp.full((2, 5), -1, jnp.int32)
    best, second, idx = kindex.rerank_exact(q, db, jnp.ones(4, jnp.bool_),
                                            cand, metric="hamming")
    assert (np.asarray(best) >= 1 << 30).all()
    assert (np.asarray(second) >= 1 << 30).all()


# ---- index construction ----------------------------------------------------

def test_build_index_factory_routes_by_dtype():
    assert isinstance(kindex.build_index(np.asarray(packed(64, 0))),
                      kindex.LshIndex)
    assert isinstance(
        kindex.build_index(np.random.RandomState(0).randn(64, 16)
                           .astype(np.float32)), kindex.KMeansIndex)
    with pytest.raises(ValueError, match="unknown metric"):
        kindex.build_index(np.zeros((4, 4), np.float32), metric="cosine")
    with pytest.raises(TypeError, match="bit-packed"):
        kindex.LshIndex(np.zeros((4, 4), np.float32))


def test_lsh_invalid_rows_never_surface():
    db = packed(128, 0)
    valid = np.zeros(128, bool)
    valid[:64] = True
    idx = kindex.LshIndex(np.asarray(db), valid, seed=1)
    cand = np.asarray(idx.candidates(db))      # query with every row
    surfaced = np.unique(cand[cand >= 0])
    assert surfaced.size and (surfaced < 64).all()


def test_kmeans_lists_are_disjoint_and_complete():
    rng = np.random.RandomState(0)
    db = rng.randn(300, 16).astype(np.float32)
    idx = kindex.KMeansIndex(db, n_clusters=8, bucket_cap=300)
    lists = np.asarray(idx._lists)
    rows = lists[lists >= 0]
    assert idx.overflow == 0
    assert len(rows) == 300 and len(np.unique(rows)) == 300


def test_lsh_self_query_recall_with_noise():
    """Near-duplicate queries (3% flipped bits — far tighter than the
    matching ratio test needs) find their counterpart at default knobs."""
    rng = np.random.RandomState(4)
    bits = rng.randint(0, 2, size=(400, 256)).astype(np.uint8)
    noisy = bits ^ (rng.rand(400, 256) < 0.03)

    def pack_bits(b):
        w = b.reshape(b.shape[0], -1, 32).astype(np.uint32)
        return (w << np.arange(32, dtype=np.uint32)).sum(-1).astype(np.uint32)

    db = pack_bits(bits)
    q = jnp.asarray(pack_bits(noisy.astype(np.uint8)))
    idx = kindex.LshIndex(db, seed=2)
    _, _, got = idx.search(q)
    recall = float((np.asarray(got) == np.arange(400)).mean())
    assert recall >= 0.95, recall


def test_kmeans_self_query_recall_with_noise():
    rng = np.random.RandomState(5)
    base = rng.randn(400, 32).astype(np.float32)
    idx = kindex.KMeansIndex(base, seed=3)
    q = jnp.asarray(base + 0.05 * rng.randn(400, 32).astype(np.float32))
    _, _, got = idx.search(q)
    recall = float((np.asarray(got) == np.arange(400)).mean())
    assert recall >= 0.95, recall


# ---- match_pair(mode="approx") on real extracted descriptors ---------------

def _scene_features(scene, alg):
    from repro.configs.difet_paper import DifetConfig
    from repro.core.bundle import tile_scene
    from repro.core.engine import extract_features
    cfg = DifetConfig(tile=64, halo=24, max_keypoints_per_tile=256,
                      fast_threshold=0.08)
    b = tile_scene(scene, cfg)
    r = jax.jit(lambda t, h: extract_features(t, h, alg, cfg))(
        b.tiles, b.headers)
    return (jnp.asarray(r["top_desc"]), jnp.asarray(r["top_valid"]))


def test_lsh_recall_on_synthetic_scene_pair():
    """The ISSUE gate: approx (multi-probe LSH) keeps >= 0.95 of the exact
    pipeline's accepted matches at default probes on an overlapping
    synthetic_scene pair."""
    from repro.data.landsat import synthetic_scene
    base = synthetic_scene(200, 320, seed=9, density=4.0)
    da, va = _scene_features(base[:, :220], "brief")
    db_, vb = _scene_features(base[:, 100:], "brief")
    exact = matching.match_pair(da, va, db_, vb)
    approx = matching.match_pair(da, va, db_, vb, mode="approx")
    acc = np.asarray(exact.ok)
    assert acc.any(), "no exact-accepted matches — scene too sparse"
    agree = np.asarray(approx.idx_b)[acc] == np.asarray(exact.idx_b)[acc]
    assert float(agree.mean()) >= 0.95, float(agree.mean())
    # approx-accepted matches carry true (re-ranked) distances
    both = acc & np.asarray(approx.ok) \
        & (np.asarray(approx.idx_b) == np.asarray(exact.idx_b))
    assert both.any()
    np.testing.assert_array_equal(np.asarray(approx.dist)[both],
                                  np.asarray(exact.dist)[both])


def test_match_pair_approx_accepts_prebuilt_indexes_and_probe_knob():
    rng = np.random.RandomState(6)
    base = rng.randn(200, 32).astype(np.float32)
    da = jnp.asarray(base)
    db_ = jnp.asarray(base + 0.03 * rng.randn(200, 32).astype(np.float32))
    va = vb = jnp.ones(200, bool)
    ia = kindex.build_index(np.asarray(da))
    ib = kindex.build_index(np.asarray(db_))
    m1 = matching.match_pair(da, va, db_, vb, mode="approx",
                             index_a=ia, index_b=ib)
    m2 = matching.match_pair(da, va, db_, vb, mode="approx",
                             index_a=ia, index_b=ib,
                             probes=ib.probes)
    np.testing.assert_array_equal(np.asarray(m1.idx_b), np.asarray(m2.idx_b))
    assert np.asarray(m1.ok).mean() > 0.9
    with pytest.raises(ValueError, match="unknown mode"):
        matching.match_pair(da, va, db_, vb, mode="fuzzy")
