"""Fleet serving subsystem: trace generator, consistent-hash routing,
admission control, tiered cache, replica lifecycle, lease liveness,
autoscaling, and chaos (kill mid-flight with bit-parity)."""
import dataclasses
import functools
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs.difet_paper import DifetConfig
from repro.core import engine
from repro.data.landsat import synthetic_scene
from repro.serve import (DiskCacheTier, FeatureService, Fleet, FleetConfig,
                         HashRing, Router, RouterConfig, ServeConfig, Shed,
                         TieredResultCache, TokenBucket, TraceConfig,
                         make_trace, scene_key, tile_pool)
from repro.serve.fleet import DEAD, DRAINING, READY, RETIRED
from repro.serve.router import (SHED_CLOSED, SHED_FLEET_SATURATED,
                                SHED_NO_REPLICA, SHED_TENANT_THROTTLED)

BASE = DifetConfig(tile=32, halo=8, max_keypoints_per_tile=16)


def fleet_cfg(n, *, cache_dir=None, lease_dir=None, lease_ttl_s=5.0,
              max_batch=4, max_pending=1024, cache_entries=0,
              max_batch_delay_s=0.005, min_replicas=1, max_replicas=None,
              scale_up=16.0, scale_down=2.0, grace=3, slo_p99_s=0.5,
              router=None) -> FleetConfig:
    return FleetConfig(
        serve=ServeConfig(base=BASE, buckets=(32,), max_batch=max_batch,
                          max_batch_delay_s=max_batch_delay_s,
                          max_pending=max_pending,
                          cache_entries=cache_entries),
        router=router or RouterConfig(),
        initial_replicas=n, min_replicas=min_replicas,
        max_replicas=max_replicas or max(n, 2),
        warm_algorithm_sets=(("harris",),),
        cache_dir=str(cache_dir) if cache_dir else None,
        lease_dir=str(lease_dir) if lease_dir else None,
        lease_ttl_s=lease_ttl_s,
        slo_p99_s=slo_p99_s,
        scale_up_queue_per_replica=scale_up,
        scale_down_queue_per_replica=scale_down,
        scale_down_grace_ticks=grace)


def direct(gray, algs=("harris",)):
    """Unrouted reference: jitted extract_features_multi on the padded
    tile (the parity oracle every served result must match bitwise)."""
    svc = FeatureService(ServeConfig(base=BASE, buckets=(32,)))
    try:
        bucket = svc.table.bucket_for(*gray.shape)
        tile, header = svc.table.pad_to_bucket(gray, bucket)
        fn = jax.jit(functools.partial(engine.extract_features_multi,
                                       algorithms=tuple(sorted(algs)),
                                       cfg=svc.table.cfg_for(bucket)))
        return {alg: {k: np.asarray(v) for k, v in res.items()}
                for alg, res in fn(tile[None], header[None]).items()}
    finally:
        svc.close()


def assert_results_equal(a, b):
    assert set(a) == set(b)
    for alg in a:
        assert set(a[alg]) == set(b[alg])
        for k in a[alg]:
            x, y = np.asarray(a[alg][k]), np.asarray(b[alg][k])
            assert x.shape == y.shape and x.dtype == y.dtype, (alg, k)
            assert np.array_equal(x, y), (alg, k)


# ---- trace generator -------------------------------------------------------

def test_trace_deterministic_and_skewed():
    cfg = TraceConfig(n_requests=600, seed=7, arrival="poisson", rate=500.0,
                      unique_scenes=16, hot_fraction=0.125, hot_weight=0.7,
                      tenants=("a", "b"), tenant_weights=(0.75, 0.25))
    t1, t2 = make_trace(cfg), make_trace(cfg)
    assert t1 == t2                       # byte-identical replays
    ts = [ev.t for ev in t1]
    assert all(b >= a for a, b in zip(ts, ts[1:]))   # arrivals ordered
    # mean rate within 2x of nominal (poisson, 600 samples)
    assert 0.5 * 600 / 500.0 < ts[-1] < 2.0 * 600 / 500.0
    # hot set (2 of 16 scenes) draws ~70% of the mass
    hot_frac = np.mean([ev.scene < 2 for ev in t1])
    assert 0.55 < hot_frac < 0.85
    tenant_a = np.mean([ev.tenant == "a" for ev in t1])
    assert 0.6 < tenant_a < 0.9


def test_trace_burst_arrivals_cluster():
    cfg = TraceConfig(n_requests=400, seed=1, arrival="burst", rate=200.0,
                      burst_factor=4.0, burst_fraction=0.25)
    gaps = np.diff([0.0] + [ev.t for ev in make_trace(cfg)])
    mean_gap = 1.0 / 200.0
    assert gaps.min() < 0.5 * mean_gap    # spikes are genuinely faster
    assert gaps.max() > mean_gap          # calm segments slower than mean
    # long-run mean stays near the nominal rate
    assert 0.3 * mean_gap < gaps.mean() < 3.0 * mean_gap


def test_tile_pool_shared_across_same_seed():
    a = tile_pool(TraceConfig(n_requests=1, seed=5, unique_scenes=3))
    b = tile_pool(TraceConfig(n_requests=99, seed=5, unique_scenes=3))
    for k in a:
        assert np.array_equal(a[k], b[k])   # parity checks depend on this


# ---- consistent hashing ----------------------------------------------------

def test_hash_ring_minimal_remap_and_balance():
    ring = HashRing(vnodes=64)
    for name in ("r1", "r2", "r3", "r4"):
        ring.add(name)
    keys = [f"scene-{i}" for i in range(400)]
    before = {k: ring.lookup(k) for k in keys}
    share = {n: sum(1 for v in before.values() if v == n)
             for n in ring.names}
    assert all(s > 0.05 * len(keys) for s in share.values())   # balanced-ish
    ring.remove("r3")
    after = {k: ring.lookup(k) for k in keys}
    for k in keys:
        if before[k] != "r3":
            assert after[k] == before[k]     # only r3's keys remapped
        else:
            assert after[k] != "r3"
    ring.add("r3")
    assert {k: ring.lookup(k) for k in keys} == before   # and they return


def test_token_bucket_throttles_and_refills():
    tb = TokenBucket(rate=50.0, burst=3)
    takes = [tb.take()[0] for _ in range(4)]
    assert takes == [True, True, True, False]
    ok, retry = tb.take()
    assert not ok and retry > 0
    time.sleep(retry + 0.05)
    assert tb.take()[0]                   # refilled
    assert TokenBucket(float("inf"), 1).take() == (True, 0.0)


# ---- router admission: typed sheds ----------------------------------------

def test_router_typed_sheds():
    img = np.zeros((8, 8), np.float32)
    r = Router(RouterConfig(tenant_limits={"limited": (0.001, 1.0)}))
    with pytest.raises(Shed) as e:        # empty pool
        r.submit(img, ("harris",))
    assert e.value.reason == SHED_NO_REPLICA
    r._bucket("limited").take()           # burn the only token (burst=1)
    with pytest.raises(Shed) as e:
        r.submit(img, ("harris",), tenant="limited")
    assert e.value.reason == SHED_TENANT_THROTTLED
    assert e.value.tenant == "limited" and e.value.retry_after_s > 0
    assert isinstance(e.value, Shed)      # and a ServiceOverloaded subclass
    from repro.serve import ServiceOverloaded
    assert isinstance(e.value, ServiceOverloaded)

    r2 = Router(RouterConfig(max_global_pending=0))
    with pytest.raises(Shed) as e:
        r2.submit(img, ("harris",))
    assert e.value.reason == SHED_FLEET_SATURATED

    r.close()
    with pytest.raises(Shed) as e:
        r.submit(img, ("harris",))
    assert e.value.reason == SHED_CLOSED
    s = r.stats()
    assert s["shed_total"] == sum(s["shed"].values()) >= 3


# ---- tiered cache ----------------------------------------------------------

def test_disk_tier_roundtrip_bit_exact(tmp_path):
    tier = DiskCacheTier(tmp_path)
    key = ("digest:0:0", "harris", "cfg")
    val = {"top_scores": np.arange(6, dtype=np.float32).reshape(2, 3),
           "total_count": np.array(7, np.int32),          # 0-d leaf
           "top_valid": np.array([True, False])}
    tier.put(key, val)
    out = tier.get(key)
    assert set(out) == set(val)
    for k in val:
        assert out[k].shape == np.asarray(val[k]).shape
        assert out[k].dtype == np.asarray(val[k]).dtype
        assert np.array_equal(out[k], val[k])
        assert not out[k].flags.writeable
    assert tier.get(("other",)) is None and tier.misses == 1


def test_disk_tier_torn_entry_is_a_miss(tmp_path):
    tier = DiskCacheTier(tmp_path)
    key = ("k", "harris", "cfg")
    path = tier.path_for(key)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(b"not an npz: crashed writer")
    assert tier.get(key) is None          # torn entry reads as a miss
    assert not path.exists()              # and is removed
    tier.put(key, {"a": np.ones((2,), np.float32)})
    assert tier.get(key) is not None      # slot is reusable


def test_tiered_cache_warms_a_fresh_local(tmp_path):
    c1 = TieredResultCache(8, tmp_path)
    c2 = TieredResultCache(8, tmp_path)   # fresh LRU, same disk tier
    key = ("d", "harris", "cfg")
    c1.put(key, {"x": np.full((3,), 2.5, np.float32)})
    hit = c2.get(key)                     # served off disk
    assert hit is not None and c2.disk.hits == 1
    assert np.array_equal(hit["x"], np.full((3,), 2.5, np.float32))
    c2.get(key)
    assert c2.local.hits == 1             # promoted: second probe is local
    assert c2.hits == 2 and c2.misses == 0


# ---- fleet routing + lifecycle --------------------------------------------

def test_affinity_routes_same_scene_to_one_replica():
    fleet = Fleet(fleet_cfg(2, cache_entries=128))
    try:
        tile = synthetic_scene(32, 32, 42)
        for _ in range(6):
            fleet.submit(tile, ("harris",), scene_key="scene-X").result(60)
        s = fleet.stats()
        assert s["routed_affinity"] == 6 and s["routed_spill"] == 0
        per = [r["submitted"] for r in s["replicas"].values()]
        assert sorted(per) == [0, 6]      # all six on the affinity replica
    finally:
        fleet.close()


def test_same_digest_in_flight_on_two_replicas_is_consistent(tmp_path):
    """The same tile computed concurrently on two replicas (forced routing)
    must yield bit-identical results on both, and the shared disk tier
    must converge to one well-formed entry either writer could have
    produced."""
    step_lock = threading.Lock()
    fleet = Fleet(fleet_cfg(2, cache_entries=128, cache_dir=tmp_path),
                  step_lock=step_lock)
    try:
        tile = synthetic_scene(32, 32, 77)
        names = fleet.ready_replicas()
        with step_lock:                   # both in flight simultaneously
            handles = [
                fleet.router._slots[n].service.submit(tile, ("harris",))
                for n in names]
        r = [h.result(60).results for h in handles]
        assert_results_equal(r[0], r[1])
        assert_results_equal(r[0], direct(tile))
        # the tier holds exactly the per-algorithm entries for this tile,
        # whichever replica won the (benign) write race
        assert len(fleet.router._slots[names[0]].service.cache.disk) >= 1
        rerouted = fleet.extract(tile, ("harris",), timeout=60).results
        assert_results_equal(rerouted, r[0])
    finally:
        fleet.close()


def test_drain_then_retire_drops_nothing():
    step_lock = threading.Lock()
    fleet = Fleet(fleet_cfg(2, max_batch=4), step_lock=step_lock)
    try:
        tiles = [synthetic_scene(32, 32, 600 + i) for i in range(12)]
        with step_lock:                   # keep every request in flight
            handles = [fleet.submit(t, ("harris",),
                                    scene_key=f"scene-{i}")
                       for i, t in enumerate(tiles)]
            victim = max(fleet.ready_replicas(),
                         key=lambda n: fleet.router._slots[n]
                         .service.scheduler.queue_depth)
            drainer = threading.Thread(
                target=fleet.drain_replica, args=(victim,))
            drainer.start()
            time.sleep(0.1)               # drain starts while work queued
        drainer.join(60)
        assert not drainer.is_alive()
        results = [h.result(60) for h in handles]   # zero dropped responses
        assert len(results) == len(tiles)
        for t, r in zip(tiles, results):
            assert_results_equal(r.results, direct(t))
        assert fleet.replicas[victim].state == RETIRED
        assert victim not in fleet.router.replica_names()
        # retired replica takes no new work; the fleet still serves
        fleet.extract(tiles[0], ("harris",), timeout=60)
    finally:
        fleet.close()


def test_kill_replica_midflight_readmits_bit_identical():
    """Chaos gate: killing a replica with queued + on-device work loses no
    accepted request, and every response matches the direct engine
    bitwise (re-execution is deterministic)."""
    step_lock = threading.Lock()
    fleet = Fleet(fleet_cfg(2, max_batch=4), step_lock=step_lock)
    try:
        tiles = [synthetic_scene(32, 32, 700 + i) for i in range(10)]
        with step_lock:                   # all work pending/in flight
            handles = [fleet.submit(t, ("harris",),
                                    scene_key=f"scene-{i}")
                       for i, t in enumerate(tiles)]
            victim = max(fleet.ready_replicas(),
                         key=lambda n: fleet.router._slots[n]
                         .service.scheduler.queue_depth)
            fleet.kill_replica(victim)    # re-admission happens in here
        results = [h.result(60) for h in handles]
        assert len(results) == len(tiles)
        for t, r in zip(tiles, results):
            assert_results_equal(r.results, direct(t))
        assert fleet.router.readmitted >= 1
        assert fleet.replicas[victim].state == DEAD
        assert victim not in fleet.router.replica_names()
    finally:
        fleet.close()


def test_stale_lease_detects_silent_crash_and_readmits(tmp_path):
    """A replica whose runner dies without telling anyone: heartbeats
    stop, the lease goes stale after one TTL, and the maintenance tick
    declares it dead + re-admits its outstanding work."""
    fleet = Fleet(fleet_cfg(2, lease_dir=tmp_path, lease_ttl_s=0.5,
                            max_batch=64, max_batch_delay_s=10.0))
    try:
        tile = synthetic_scene(32, 32, 801)
        h = fleet.submit(tile, ("harris",), scene_key="scene-crash")
        victim = next(iter(fleet.router._outstanding.values())).replica
        # simulate a silent crash: the runner dies, the fleet is not told
        fleet.router._slots[victim].service.kill()
        assert fleet.maintenance_tick() == []     # lease still fresh
        assert fleet.replicas[victim].state == READY
        time.sleep(0.6)                           # let the lease expire
        died = fleet.maintenance_tick()
        assert victim in died
        assert fleet.replicas[victim].state == DEAD
        r = h.result(60)                          # re-admitted + served
        assert_results_equal(r.results, direct(tile))
    finally:
        fleet.close()


def test_autoscaler_scales_up_on_depth_and_down_after_grace():
    # slo_p99_s=1e9 mutes the latency policy so only the queue-depth
    # triggers are exercised (the SLO path has its own tests)
    step_lock = threading.Lock()
    fleet = Fleet(fleet_cfg(1, min_replicas=1, max_replicas=2,
                            scale_up=4.0, scale_down=2.0, grace=2,
                            slo_p99_s=1e9),
                  step_lock=step_lock)
    try:
        # 12 tiles: the runner holds up to max_batch=4 in flight, so the
        # *queued* depth the policy sees is still 8 > the threshold of 4
        tiles = [synthetic_scene(32, 32, 900 + i) for i in range(12)]
        with step_lock:                   # queue builds past the watermark
            handles = [fleet.submit(t, ("harris",)) for t in tiles]
            action = fleet.autoscale_tick()
        assert action.startswith("scale_up:")
        assert len(fleet.ready_replicas()) == 2
        for h in handles:
            h.result(60)
        # empty queue: two grace ticks, then drain the idle replica
        assert fleet.autoscale_tick() == "hold"
        action = fleet.autoscale_tick()
        assert action.startswith("scale_down:")
        assert len(fleet.ready_replicas()) == 1
        assert fleet.autoscale_tick() == "hold"   # at min_replicas
        # the surviving replica still serves
        fleet.extract(tiles[0], ("harris",), timeout=60)
    finally:
        fleet.close()


def test_fleet_parity_over_trace(tmp_path):
    """Routed results over a mixed hot-scene trace are bit-identical to
    the direct engine — through cache hits, spills and the disk tier."""
    cfg = TraceConfig(n_requests=24, seed=11, unique_scenes=6,
                      tile_sizes=(32,), algorithm_sets=(("harris",),))
    trace, pool = make_trace(cfg), tile_pool(cfg)
    fleet = Fleet(fleet_cfg(2, cache_entries=128, cache_dir=tmp_path))
    try:
        handles = [fleet.submit(pool[ev.pool_key], ev.algorithms,
                                scene_key=scene_key(ev)) for ev in trace]
        oracle = {}
        for ev, h in zip(trace, handles):
            if ev.pool_key not in oracle:
                oracle[ev.pool_key] = direct(pool[ev.pool_key],
                                             ev.algorithms)
            assert_results_equal(h.result(60).results,
                                 oracle[ev.pool_key])
        s = fleet.stats()
        assert s["submitted"] == len(trace) and s["outstanding"] == 0
    finally:
        fleet.close()
