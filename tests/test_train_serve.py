"""Training-loop behaviour + serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamW
from repro.serve.lm import greedy_generate
from repro.train.step import make_train_step, make_init_fn, TrainStepConfig
from repro.data.tokens import synthetic_lm_batch


def setup(arch="smollm-135m", **step_kw):
    cfg = get_config(arch).reduced().replace(remat="nothing")
    model = build_model(cfg)
    opt = AdamW()
    scfg = TrainStepConfig(**step_kw)
    state = jax.jit(make_init_fn(model, opt, scfg))(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt, scfg))
    return cfg, model, state, step


def test_loss_decreases():
    cfg, model, state, step = setup(learning_rate=3e-3)
    losses = []
    for i in range(25):
        batch = synthetic_lm_batch(4, 64, cfg.vocab_size, seed=i)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_microbatch_equivalence():
    """2 microbatches must match the single-batch gradient step closely."""
    cfg, model, state1, step1 = setup(learning_rate=1e-3, microbatches=1)
    _, _, state2, _ = setup(learning_rate=1e-3, microbatches=1)
    opt = AdamW()
    scfg2 = TrainStepConfig(learning_rate=1e-3, microbatches=2)
    step2 = jax.jit(make_train_step(model, opt, scfg2))
    batch = synthetic_lm_batch(4, 32, cfg.vocab_size, seed=0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    s1, m1 = step1(state1, batch)
    s2, m2 = step2(state2, batch)
    # CE is averaged over the same tokens either way
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 0.05
    w1 = jax.tree_util.tree_leaves(s1["params"])[0]
    w2 = jax.tree_util.tree_leaves(s2["params"])[0]
    np.testing.assert_allclose(np.asarray(w1, np.float32),
                               np.asarray(w2, np.float32),
                               rtol=0.1, atol=1e-3)


def test_grad_compression_error_feedback():
    cfg, model, state, step = setup(learning_rate=1e-3,
                                    grad_compression=True)
    assert "err" in state
    batch = synthetic_lm_batch(2, 32, cfg.vocab_size, seed=0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # error buffers are non-zero after one step (feedback captured)
    err_norm = sum(float(jnp.abs(e).sum())
                   for e in jax.tree_util.tree_leaves(state["err"]))
    assert err_norm > 0.0


def test_greedy_generate_deterministic():
    cfg = get_config("smollm-135m").reduced().replace(remat="nothing")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 4)), jnp.int32)
    out1 = greedy_generate(model, params, prompt, n_steps=6)
    out2 = greedy_generate(model, params, prompt, n_steps=6)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 6)


def test_vlm_decode_after_prefix():
    """InternVL: decode continues correctly after an image-prefixed forward."""
    cfg = get_config("internvl2-2b").reduced().replace(remat="nothing")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    s = 6
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, s)), jnp.int32)
    patches = jnp.asarray(rng.randn(2, cfg.n_image_patches, cfg.d_model),
                          jnp.bfloat16)
    logits, _ = jax.jit(model.forward)(
        params, {"tokens": tokens, "patches": patches})
    assert logits.shape[1] == s + cfg.n_image_patches
    assert bool(jnp.isfinite(logits).all())
