"""Sharding rules: divisibility fallback, coverage over every arch's params."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, all_arch_ids
from repro.distributed.sharding import (
    resolve_spec, pspec_for, param_pspec_tree, dp_axes)
from repro.models import build_model


def shapes_tree(arch):
    model = build_model(get_config(arch))
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def test_resolve_spec_divisibility_fallback(mesh_16x16):
    # 9 heads * 64 = 576 divisible by 16 -> shards; 9 alone does not
    assert resolve_spec(("fsdp", "tensor"), (576, 576), mesh_16x16) \
        == P("data", "model")
    assert resolve_spec((None, "tensor"), (4, 9), mesh_16x16) == P(None, None)
    # left-padding for stacked params
    assert resolve_spec(("fsdp", "tensor"), (24, 576, 1536), mesh_16x16) \
        == P(None, "data", "model")


def test_dp_axes(mesh_16x16, mesh_pod):
    assert dp_axes(mesh_16x16) == ("data",)
    assert dp_axes(mesh_pod) == ("pod", "data")


def test_moe_expert_rule(mesh_16x16):
    spec = pspec_for("stack/moe/wi", (58, 256, 7168, 2048), mesh_16x16)
    assert spec == P(None, "model", "data", None)
    spec = pspec_for("stack/moe/wo", (58, 256, 2048, 7168), mesh_16x16)
    assert spec == P(None, "model", None, "data")


@pytest.mark.parametrize("arch", ["smollm-135m", "whisper-large-v3",
                                  "deepseek-v3-671b", "xlstm-350m",
                                  "zamba2-2.7b"])
def test_rules_valid_for_every_param(arch, mesh_16x16, mesh_pod):
    """Every param gets a spec whose sharded dims are divisible — the
    invariant that makes .lower() succeed for every arch."""
    tree = shapes_tree(arch)
    for mesh in (mesh_16x16, mesh_pod):
        specs = param_pspec_tree(tree, mesh)
        leaves_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        leaves_t = jax.tree_util.tree_leaves(tree)
        assert len(leaves_s) == len(leaves_t)
        for spec, leaf in zip(leaves_s, leaves_t):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = (ax,) if isinstance(ax, str) else ax
                prod = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % prod == 0, (arch, spec, leaf.shape)


def test_params_mostly_sharded_for_large_arch(mesh_16x16):
    """FSDP must actually shard the big weights (ZeRO sanity)."""
    tree = shapes_tree("qwen1.5-110b")
    specs = param_pspec_tree(tree, mesh_16x16)
    big_total, big_sharded = 0, 0
    for spec, leaf in zip(
            jax.tree_util.tree_leaves(specs,
                                      is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_leaves(tree)):
        n = int(np.prod(leaf.shape))
        if n < 1e6:
            continue
        big_total += n
        if any(ax is not None for ax in tuple(spec)):
            big_sharded += n
    assert big_sharded / big_total > 0.999
