"""Fault tolerance: job restart, checkpoint integrity, elastic rebalance."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.difet_paper import DifetConfig
from repro.core.bundle import BundleStore, bundle_scenes
from repro.core.job import DifetJob
from repro.data.landsat import synthetic_scene


def make_store(tmp_path, n_bundles=3):
    cfg = DifetConfig(tile=64, halo=16, max_keypoints_per_tile=32)
    store = BundleStore(tmp_path / "store")
    for i in range(n_bundles):
        store.put(f"b{i}", bundle_scenes(
            [synthetic_scene(100, 120, seed=i)], cfg))
    return store


def test_job_restart_after_failure_resumes_and_matches(tmp_path):
    store = make_store(tmp_path)
    # uninterrupted reference
    ref_store = make_store(tmp_path / "ref")
    ref = DifetJob(ref_store, "harris").run()

    job = DifetJob(store, "harris")
    with pytest.raises(RuntimeError, match="simulated worker failure"):
        job.run(simulate_failure_after=1)
    # manifest committed exactly one bundle
    m = json.loads(job.manifest_path.read_text())
    assert sum(m["done"].values()) == 1
    # restart (fresh object, as a new process would)
    job2 = DifetJob(store, "harris")
    summary = job2.run()
    assert summary["bundles_done"] == 3
    assert summary["grand_total"] == ref["grand_total"]
    assert summary["counts"] == {f"b{i}": ref["counts"][f"b{i}"]
                                 for i in range(3)}


def test_job_shard_merge_matches_unsharded(tmp_path):
    store = make_store(tmp_path, n_bundles=1)
    j1 = DifetJob(store, "fast", shards_per_bundle=1,
                  manifest_path=tmp_path / "m1.json")
    j4 = DifetJob(store, "fast", shards_per_bundle=4,
                  manifest_path=tmp_path / "m4.json")
    s1 = j1.run()
    # reset result by re-running with different manifest; results overwrite
    s4 = j4.run()
    assert s1["grand_total"] == s4["grand_total"]


def test_job_rebalance_partitions_everything(tmp_path):
    store = make_store(tmp_path, n_bundles=5)
    job = DifetJob(store, "harris")
    for n in (1, 2, 4):
        parts = job.rebalance(n)
        flat = sorted(b for p in parts for b in p)
        assert flat == sorted(job.manifest.remaining)


def test_checkpoint_corruption_detected(tmp_path):
    cm = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(16, dtype=jnp.float32)}
    cm.save(state, 1)
    # corrupt the tensor file
    d = tmp_path / "step_0000000001"
    z = np.load(d / "tensors.npz")
    data = {k: z[k].copy() for k in z.files}
    data["w"][0] = 999.0
    np.savez(d / "tensors.npz", **data)
    with pytest.raises(IOError, match="corruption"):
        cm.restore(jax.eval_shape(lambda: state))


def test_checkpoint_elastic_restore_changes_sharding(tmp_path):
    """Restore onto a different device layout (the elastic-scaling path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    cm = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((8, 4), jnp.float32)}
    cm.save(state, 1)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = cm.restore(jax.eval_shape(lambda: state), shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)


def test_train_resume_matches_uninterrupted(tmp_path):
    """Checkpoint/restart must reproduce the uninterrupted loss trajectory
    (deterministic data + state capture)."""
    from repro.launch.train import main as train_main
    base = ["--arch", "smollm-135m", "--reduced", "--batch", "2",
            "--seq", "32", "--log-every", "100"]
    full = train_main(base + ["--steps", "8"])
    part = train_main(base + ["--steps", "4", "--ckpt-dir",
                              str(tmp_path / "ck"), "--ckpt-every", "4"])
    resumed = train_main(base + ["--steps", "8", "--ckpt-dir",
                                 str(tmp_path / "ck"), "--resume"])
    np.testing.assert_allclose(full[4:], resumed, rtol=1e-4, atol=1e-5)


# ---- worker leases + elastic pools -----------------------------------------

def test_lease_board_acquire_refresh_steal(tmp_path):
    import time
    from repro.core.job import LeaseBoard
    lb = LeaseBoard(tmp_path / "leases", ttl_s=0.15)
    assert lb.acquire("item", "w0")
    assert not lb.acquire("item", "w1")      # live lease held elsewhere
    assert lb.acquire("item", "w0")          # own lease refreshes
    time.sleep(0.2)
    assert lb.acquire("item", "w1")          # stale lease stolen
    lb.release("item", "w0")                 # non-owner release: no-op
    assert not lb.acquire("item", "w2")
    lb.release("item", "w1")
    assert lb.acquire("item", "w2")


def test_elastic_worker_pool_resumes_after_crash(tmp_path):
    """A worker crash mid-pool + a dead worker's orphaned lease: restart
    with a *different* worker count drains everything, results identical
    to the uninterrupted single-worker job."""
    import time
    store = make_store(tmp_path, n_bundles=4)
    ref = DifetJob(make_store(tmp_path / "ref", n_bundles=4),
                   "harris").run()

    job = DifetJob(store, "harris", lease_ttl_s=0.1)
    with pytest.raises(RuntimeError, match="simulated worker failure"):
        job.run(worker_id="w0", simulate_failure_after=1)
    # a worker that claimed an item and died leaves an orphan lease
    remaining = job.manifest.remaining
    job.leases.acquire(remaining[0], "w_dead")
    time.sleep(0.15)
    # elastic restart: two fresh workers (new processes) share the pool
    s1 = DifetJob(store, "harris", lease_ttl_s=0.1).run(worker_id="w1")
    s2 = DifetJob(store, "harris", lease_ttl_s=0.1).run(worker_id="w2")
    assert s1["bundles_done"] == s2["bundles_done"] == 4
    assert s2["grand_total"] == ref["grand_total"]
    assert s2["counts"] == ref["counts"]


def test_concurrent_workers_partition_without_corruption(tmp_path):
    """Two threads running the same manifest concurrently: leases keep the
    work partitioned; every result lands; a final no-worker pass agrees
    with the uninterrupted reference bit-for-bit."""
    import threading
    store = make_store(tmp_path, n_bundles=6)
    ref = DifetJob(make_store(tmp_path / "ref", n_bundles=6),
                   "fast").run()

    def worker(wid):
        DifetJob(store, "fast").run(worker_id=wid)

    threads = [threading.Thread(target=worker, args=(f"w{i}",))
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every bundle has a committed result regardless of lease races
    assert all(store.has_result(f"b{i}.fast") for i in range(6))
    # the self-healing pass (re-marks any lost done-flags; no-op compute
    # at worst re-runs a deterministic item) matches the reference
    final = DifetJob(store, "fast").run()
    assert final["grand_total"] == ref["grand_total"]
    assert final["counts"] == ref["counts"]


def test_manifest_order_is_restart_deterministic(tmp_path):
    store = make_store(tmp_path, n_bundles=5)
    j1 = DifetJob(store, "harris")
    order1 = list(j1.manifest.bundle_names)
    j2 = DifetJob(store, "harris")       # fresh load from disk
    assert list(j2.manifest.bundle_names) == order1 == sorted(order1)


def test_mesh_sharded_job_bit_identical(tmp_path):
    """DifetJob with a (size-1 CPU) data mesh runs the jitted
    batch-sharded path; results must be bit-identical to the same jitted
    program without input shardings (sharding is a layout change, never a
    numerics change)."""
    import functools
    from repro.core.engine import extract_features_multi
    from repro.distributed.sharding import data_mesh
    store = make_store(tmp_path, n_bundles=2)
    meshed = DifetJob(store, "harris,fast",
                      manifest_path=tmp_path / "mesh.json",
                      shards_per_bundle=1, mesh=data_mesh(1))
    meshed.run()
    for n in ("b0", "b1"):
        b = store.get(n)
        ref = jax.jit(functools.partial(
            extract_features_multi, algorithms=("harris", "fast"),
            cfg=b.cfg))(b.tiles, b.headers)
        for alg in ("harris", "fast"):
            got = store.get_result(f"{n}.{alg}")
            for k in got:
                np.testing.assert_array_equal(
                    got[k], np.asarray(ref[alg][k]),
                    err_msg=f"{n}.{alg}.{k}")


def test_mesh_padding_slice_matches_unpadded(tmp_path):
    """Force the pad path: a fake 3-wide data axis on a 7-tile shard must
    slice back to exactly the unpadded result."""
    from repro.distributed.sharding import data_mesh
    store = make_store(tmp_path, n_bundles=1)
    job = DifetJob(store, "harris", manifest_path=tmp_path / "m.json",
                   shards_per_bundle=1, mesh=data_mesh(1))
    bundle = store.get("b0")
    n = len(bundle)
    ref = job._extract(bundle.tiles, bundle.headers, bundle.cfg)["harris"]
    # pretend the data axis is 3 wide: pad to the next multiple of 3
    job._data_size = lambda: 3
    job._sharded_fns.clear()
    padded = job._extract(bundle.tiles, bundle.headers,
                          bundle.cfg)["harris"]
    assert padded["per_tile_count"].shape[0] == n
    for k in ref:
        np.testing.assert_array_equal(np.asarray(padded[k]),
                                      np.asarray(ref[k]), err_msg=k)
