"""Fault tolerance: job restart, checkpoint integrity, elastic rebalance."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.difet_paper import DifetConfig
from repro.core.bundle import BundleStore, bundle_scenes
from repro.core.job import DifetJob
from repro.data.landsat import synthetic_scene


def make_store(tmp_path, n_bundles=3):
    cfg = DifetConfig(tile=64, halo=16, max_keypoints_per_tile=32)
    store = BundleStore(tmp_path / "store")
    for i in range(n_bundles):
        store.put(f"b{i}", bundle_scenes(
            [synthetic_scene(100, 120, seed=i)], cfg))
    return store


def test_job_restart_after_failure_resumes_and_matches(tmp_path):
    store = make_store(tmp_path)
    # uninterrupted reference
    ref_store = make_store(tmp_path / "ref")
    ref = DifetJob(ref_store, "harris").run()

    job = DifetJob(store, "harris")
    with pytest.raises(RuntimeError, match="simulated worker failure"):
        job.run(simulate_failure_after=1)
    # manifest committed exactly one bundle
    m = json.loads(job.manifest_path.read_text())
    assert sum(m["done"].values()) == 1
    # restart (fresh object, as a new process would)
    job2 = DifetJob(store, "harris")
    summary = job2.run()
    assert summary["bundles_done"] == 3
    assert summary["grand_total"] == ref["grand_total"]
    assert summary["counts"] == {f"b{i}": ref["counts"][f"b{i}"]
                                 for i in range(3)}


def test_job_shard_merge_matches_unsharded(tmp_path):
    store = make_store(tmp_path, n_bundles=1)
    j1 = DifetJob(store, "fast", shards_per_bundle=1,
                  manifest_path=tmp_path / "m1.json")
    j4 = DifetJob(store, "fast", shards_per_bundle=4,
                  manifest_path=tmp_path / "m4.json")
    s1 = j1.run()
    # reset result by re-running with different manifest; results overwrite
    s4 = j4.run()
    assert s1["grand_total"] == s4["grand_total"]


def test_job_rebalance_partitions_everything(tmp_path):
    store = make_store(tmp_path, n_bundles=5)
    job = DifetJob(store, "harris")
    for n in (1, 2, 4):
        parts = job.rebalance(n)
        flat = sorted(b for p in parts for b in p)
        assert flat == sorted(job.manifest.remaining)


def test_checkpoint_corruption_detected(tmp_path):
    cm = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(16, dtype=jnp.float32)}
    cm.save(state, 1)
    # corrupt the tensor file
    d = tmp_path / "step_0000000001"
    z = np.load(d / "tensors.npz")
    data = {k: z[k].copy() for k in z.files}
    data["w"][0] = 999.0
    np.savez(d / "tensors.npz", **data)
    with pytest.raises(IOError, match="corruption"):
        cm.restore(jax.eval_shape(lambda: state))


def test_checkpoint_elastic_restore_changes_sharding(tmp_path):
    """Restore onto a different device layout (the elastic-scaling path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    cm = CheckpointManager(tmp_path)
    state = {"w": jnp.ones((8, 4), jnp.float32)}
    cm.save(state, 1)
    mesh = make_host_mesh()
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = cm.restore(jax.eval_shape(lambda: state), shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), 1.0)


def test_train_resume_matches_uninterrupted(tmp_path):
    """Checkpoint/restart must reproduce the uninterrupted loss trajectory
    (deterministic data + state capture)."""
    from repro.launch.train import main as train_main
    base = ["--arch", "smollm-135m", "--reduced", "--batch", "2",
            "--seq", "32", "--log-every", "100"]
    full = train_main(base + ["--steps", "8"])
    part = train_main(base + ["--steps", "4", "--ckpt-dir",
                              str(tmp_path / "ck"), "--ckpt-every", "4"])
    resumed = train_main(base + ["--steps", "8", "--ckpt-dir",
                                 str(tmp_path / "ck"), "--resume"])
    np.testing.assert_allclose(full[4:], resumed, rtol=1e-4, atol=1e-5)
