"""Fleet telemetry plane: cross-process metrics shipping
(`repro/obs/ship.py`), parent-side aggregation (`repro/obs/agg.py`),
SLO burn-rate monitoring (`repro/obs/slo.py`), the Prometheus exporter,
the torn-snapshot transport fix, and the perf-regression sentry.

The histogram-mergeability property — K workers' shipped bucket deltas
merged parent-side are *indistinguishable* from one histogram that
observed the union stream — runs both as a seeded plain test (always)
and as a hypothesis property test (skipped when hypothesis is absent;
the container does not ship it).
"""
import json
import math
import os
import random
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.agg import TelemetryAggregator, fleet_metric_name
from repro.obs.export import (render_prometheus, spans_to_chrome,
                              validate_chrome_trace)
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.ship import TelemetryShipper, span_from_wire, span_to_wire
from repro.obs.slo import BurnRateMonitor, SloPolicy
from repro.obs.trace import FlightRecorder, Span
from repro.serve.transport import WorkerMailbox, read_message, read_snapshot

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
from regress import diff_snapshots  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                         # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                                     # noqa: D103
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    def settings(*a, **k):                                  # noqa: D103
        return lambda f: f

    class st:                                               # noqa: D101
        @staticmethod
        def lists(*a, **k):
            return None

        @staticmethod
        def floats(*a, **k):
            return None

        @staticmethod
        def integers(*a, **k):
            return None


@pytest.fixture
def fresh_registry():
    reg = MetricsRegistry()
    prev = obs_metrics.set_registry(reg)
    yield reg
    obs_metrics.set_registry(prev)


@pytest.fixture
def flight(tmp_path):
    (tmp_path / "dumps").mkdir(exist_ok=True)
    rec = FlightRecorder(capacity=4096, dump_dir=str(tmp_path / "dumps"))
    prev = obs_trace.set_recorder(rec)
    yield rec
    obs_trace.set_recorder(prev)


# ---- torn-snapshot transport regression ------------------------------------

def test_torn_stats_file_reads_as_not_yet_without_quarantine(tmp_path):
    """A stats snapshot torn at *any* byte length — including the
    0-byte file a crash right after ``open`` leaves — must read as
    "not yet" and must NOT be quarantined: the next periodic publish
    overwrites the same path, so renaming it aside would turn one torn
    write into a permanently missing channel."""
    mbox = WorkerMailbox(tmp_path / "w1")
    mbox.write_stats({"submitted": 7, "name": "w1"})
    raw = (mbox.root / "stats.npz").read_bytes()
    assert len(raw) > 8
    for cut in (0, 1, 8, len(raw) // 2, len(raw) - 1):
        (mbox.root / "stats.npz").write_bytes(raw[:cut])
        assert mbox.read_stats() is None, f"cut={cut}"
        assert (mbox.root / "stats.npz").exists(), \
            f"cut={cut}: torn snapshot was moved aside"
        assert not list(mbox.root.glob("*.corrupt")), \
            f"cut={cut}: snapshot channel was quarantined"
    # the writer's next publish repairs the channel in place
    mbox.write_stats({"submitted": 8, "name": "w1"})
    assert mbox.read_stats() == {"submitted": 8, "name": "w1"}


def test_torn_ready_marker_reads_as_not_yet(tmp_path):
    mbox = WorkerMailbox(tmp_path / "w1")
    mbox.write_ready({"pid": 123})
    raw = (mbox.root / "ready.npz").read_bytes()
    (mbox.root / "ready.npz").write_bytes(raw[: len(raw) // 3])
    assert mbox.read_ready() is None
    assert (mbox.root / "ready.npz").exists()
    mbox.write_ready({"pid": 123})
    assert mbox.read_ready() == {"pid": 123}


def test_queue_channel_still_quarantines_and_empty_file_does_not_raise(
        tmp_path):
    """Queue channels (requests/responses) keep the quarantine
    discipline — and the 0-byte case (np.load raises ``EOFError``, not
    ``ValueError``) must not escape `read_message`."""
    p = tmp_path / "r1.npz"
    p.write_bytes(b"")                                 # the EOFError shape
    assert read_message(p) is None
    assert not p.exists() and p.with_suffix(".npz.corrupt").exists()
    p2 = tmp_path / "r2.npz"
    p2.write_bytes(b"PK\x03\x04 torn")
    assert read_message(p2) is None
    assert p2.with_suffix(".npz.corrupt").exists()
    # read_snapshot on the same garbage: None, file left in place
    p3 = tmp_path / "r3.npz"
    p3.write_bytes(b"PK\x03\x04 torn")
    assert read_snapshot(p3) is None
    assert p3.exists()


# ---- histogram mergeability -------------------------------------------------

BOUNDS = (0.001, 0.01, 0.1, 1.0, 10.0)


def _merged_vs_union(values, n_shards):
    """Split ``values`` round-robin over ``n_shards`` worker histograms,
    merge their counts into a fleet histogram, and return it alongside
    the union-stream oracle."""
    shards = [Histogram(f"w{i}", BOUNDS) for i in range(n_shards)]
    union = Histogram("union", BOUNDS)
    for i, v in enumerate(values):
        shards[i % n_shards].observe(v)
        union.observe(v)
    fleet = Histogram("fleet", BOUNDS)
    for sh in shards:
        fleet.merge_counts(sh.counts(), count=sh.count, sum=sh.sum,
                           min=sh.min, max=sh.max)
    return fleet, union


def test_histogram_merge_equals_union_stream_seeded():
    rng = random.Random(1234)
    values = [rng.lognormvariate(-3, 2.5) for _ in range(500)]
    for k in (1, 2, 3, 7):
        fleet, union = _merged_vs_union(values, k)
        assert fleet.counts() == union.counts()
        assert fleet.count == union.count
        assert fleet.sum == pytest.approx(union.sum)
        assert fleet.min == union.min and fleet.max == union.max
        for q in (0.5, 0.9, 0.99):
            assert fleet.quantile(q) == pytest.approx(union.quantile(q))


@given(st.lists(st.floats(min_value=1e-6, max_value=100.0,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=200),
       st.integers(min_value=1, max_value=8))
@settings(max_examples=50, deadline=None)
def test_histogram_merge_property(values, n_shards):
    fleet, union = _merged_vs_union(values, n_shards)
    assert fleet.counts() == union.counts()
    assert fleet.count == union.count
    assert fleet.quantile(0.99) == pytest.approx(union.quantile(0.99))


def test_histogram_merge_rejects_mismatched_edges():
    a = Histogram("a", (0.1, 1.0))
    b = Histogram("b", (0.1, 1.0, 10.0))
    with pytest.raises(ValueError, match="merge shape mismatch"):
        a.merge_counts(b.counts())


# ---- Prometheus exporter golden ---------------------------------------------

def test_render_prometheus_golden(fresh_registry):
    reg = fresh_registry
    reg.counter("difet.router.admitted").inc(41)
    reg.gauge("difet.fleet.replicas_ready").set(2)
    h = reg.histogram("difet.kernel.step_s", (0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 2.0, 99.0):              # one per region
        h.observe(v)
    golden = "\n".join([
        "# TYPE difet_fleet_replicas_ready gauge",
        "difet_fleet_replicas_ready 2",
        "# TYPE difet_kernel_step_s histogram",
        'difet_kernel_step_s_bucket{le="0.1"} 1',
        'difet_kernel_step_s_bucket{le="1"} 3',
        'difet_kernel_step_s_bucket{le="10"} 4',
        'difet_kernel_step_s_bucket{le="+Inf"} 5',
        "difet_kernel_step_s_sum 102.05",
        "difet_kernel_step_s_count 5",
        "# TYPE difet_router_admitted counter",
        "difet_router_admitted 41",
    ]) + "\n"
    assert render_prometheus(reg) == golden


def test_render_prometheus_empty_registry():
    assert render_prometheus(MetricsRegistry()) == ""


# ---- span wire format -------------------------------------------------------

def test_span_wire_roundtrip_rebase_and_pid():
    s = Span(name="exec", layer="batch", trace_id="t1-abc",
             span_id="s1", parent_id="b0", t0=10.0, t1=10.5,
             thread="runner", attrs=(("bucket", 32), ("obj", object())),
             pid=111)
    wire = span_to_wire(s)
    json.dumps(wire)                      # must be JSON-able (npz meta)
    back = span_from_wire(wire, dt=2.0, pid=222)
    assert back.name == "exec" and back.trace_id == "t1-abc"
    assert back.t0 == pytest.approx(12.0)
    assert back.t1 == pytest.approx(12.5)
    assert back.pid == 222                # aggregator stamp wins
    assert dict(back.attrs)["bucket"] == 32
    assert isinstance(dict(back.attrs)["obj"], str)   # stringified


def test_fleet_metric_name_mapping():
    assert fleet_metric_name("difet.scheduler.queue_s") \
        == "difet.fleet.scheduler.queue_s"
    assert fleet_metric_name("difet.fleet.already") \
        == "difet.fleet.difet.fleet.already"
    assert fleet_metric_name("other.thing") == "difet.fleet.other.thing"


# ---- shipper -> aggregator roundtrip ----------------------------------------

def test_ship_and_aggregate_roundtrip(tmp_path, fresh_registry):
    """Two workers' delta shipments over a real mailbox merge into the
    parent registry: counter deltas accumulate, gauges sum per-worker
    last values, histogram totals equal the per-worker ledger, spans
    arrive pid-stamped, and a replayed payload is dropped by its
    sequence number (never double-counted)."""
    worker_reg = MetricsRegistry()
    (tmp_path / "d").mkdir(exist_ok=True)
    rec = FlightRecorder(capacity=64, dump_dir=str(tmp_path / "d"))
    mbox = WorkerMailbox(tmp_path / "w1")
    shipper = TelemetryShipper(mbox, "w1", registry=worker_reg,
                               recorder=rec, interval_s=0.0)

    worker_reg.counter("difet.cache.disk_hits").inc(3)
    worker_reg.gauge("difet.scheduler.queue_depth").set(5)
    h = worker_reg.histogram("difet.kernel.step_s", BOUNDS)
    h.observe(0.05)
    h.observe(0.5)
    prev = obs_trace.set_recorder(rec)
    try:
        obs_trace.emit_span("exec", "batch", 1.0, 1.5, trace_id="tA")
    finally:
        obs_trace.set_recorder(prev)
    assert shipper.ship() == 1
    worker_reg.counter("difet.cache.disk_hits").inc(2)   # second interval
    h.observe(7.0)
    assert shipper.ship() == 2
    assert shipper.ship() is None                        # nothing new

    payloads = mbox.collect_telemetry()
    assert [p["seq"] for p in payloads] == [1, 2]
    assert not list(mbox.tele.glob("*.npz"))             # queue drained

    parent_reg = MetricsRegistry()
    agg = TelemetryAggregator(parent_reg)
    assert agg.ingest(payloads) == 2
    assert parent_reg.counter("difet.fleet.cache.disk_hits").value == 5
    assert parent_reg.gauge(
        "difet.fleet.scheduler.queue_depth").value == 5
    fleet_h = parent_reg.histogram("difet.fleet.kernel.step_s", BOUNDS)
    assert fleet_h.count == 3 == agg.fleet_counts()["difet.kernel.step_s"]
    assert fleet_h.counts() == h.counts()
    assert fleet_h.min == h.min and fleet_h.max == h.max
    [span] = list(agg.spans)
    assert span.trace_id == "tA" and span.pid == os.getpid()
    # same process -> wall/mono anchors agree -> rebase is an identity
    assert span.t0 == pytest.approx(1.0, abs=0.05)

    # replay: a crash between collect and unlink re-delivers payloads —
    # sequence numbers make ingestion idempotent
    assert agg.ingest(payloads) == 0
    assert agg.dropped == 2
    assert parent_reg.counter("difet.fleet.cache.disk_hits").value == 5
    assert fleet_h.count == 3

    # a second worker's gauge sums with the first's
    agg.ingest([{"worker": "w2", "pid": 999, "seq": 1,
                 "wall_minus_mono": time.time() - time.monotonic(),
                 "gauges": {"difet.scheduler.queue_depth": 7.0},
                 "counters": {}, "hists": {}, "spans": [], "dumps": {}}])
    assert parent_reg.gauge(
        "difet.fleet.scheduler.queue_depth").value == 12


def test_final_flush_always_publishes_and_carries_dumps(tmp_path):
    reg = MetricsRegistry()
    (tmp_path / "d").mkdir(exist_ok=True)
    rec = FlightRecorder(capacity=16, dump_dir=str(tmp_path / "d"))
    rec.dump_on("shed-queue_full")
    mbox = WorkerMailbox(tmp_path / "w1")
    shipper = TelemetryShipper(mbox, "w1", registry=reg, recorder=rec)
    assert shipper.ship(final=True) == 1                 # empty but final
    [p] = mbox.collect_telemetry()
    assert p["final"] is True
    assert "shed-queue_full" in p["dumps"]
    agg = TelemetryAggregator(MetricsRegistry())
    agg.ingest([p])
    assert agg.worker_final["w1"] is True
    assert "shed-queue_full" in agg.worker_dumps["w1"]


# ---- SLO burn-rate monitor --------------------------------------------------

def test_burn_rate_monitor_alerts_once_and_dedupes_dump(tmp_path):
    clock = [0.0]
    hist = Histogram("lat", (0.01, 0.1, 1.0))
    policy = SloPolicy(latency_slo_s=0.1, objective=0.9,
                       fast_window_s=5.0, slow_window_s=60.0,
                       fast_burn=2.0, slow_burn=1.5)
    (tmp_path / "d").mkdir(exist_ok=True)
    rec = FlightRecorder(capacity=32, dump_dir=str(tmp_path / "d"))
    prev = obs_trace.set_recorder(rec)
    try:
        mon = BurnRateMonitor(hist, policy=policy,
                              clock=lambda: clock[0])
        for _ in range(50):                    # healthy: all within SLO
            hist.observe(0.005)
        clock[0] = 10.0
        r = mon.tick()
        assert not r["alerting"] and r["dump"] is None
        assert r["burn_fast"] == pytest.approx(0.0)
        assert r["p99_fast"] is not None and r["p99_fast"] <= 0.1

        for _ in range(50):                    # cliff: everything slow
            hist.observe(0.5)
        clock[0] = 20.0
        r1 = mon.tick()
        assert r1["alerting"] and r1["burn_fast"] > 2.0
        assert r1["dump"] and os.path.exists(r1["dump"])
        clock[0] = 21.0
        r2 = mon.tick()                        # still burning: no 2nd dump
        assert r2["alerting"] and r2["dump"] is None
        assert list(rec.dumps) == [BurnRateMonitor.DUMP_REASON]
        assert mon.alerts == 2
    finally:
        obs_trace.set_recorder(prev)


def test_burn_rate_counts_sheds_as_bad_events(tmp_path):
    """Sheds burn error budget even when every *served* request is
    fast — the SLO is over admission outcomes, not just latencies."""
    clock = [0.0]
    hist = Histogram("lat", (0.01, 0.1, 1.0))
    shed = obs_metrics.Counter("difet.router.shed.queue_full")
    policy = SloPolicy(latency_slo_s=0.1, objective=0.9,
                       fast_window_s=5.0, slow_window_s=60.0,
                       fast_burn=2.0, slow_burn=1.5)
    mon = BurnRateMonitor(hist, shed_counters=[shed], policy=policy,
                          clock=lambda: clock[0])
    for _ in range(10):
        hist.observe(0.005)
    shed.inc(90)                                       # 90% shed rate
    clock[0] = 10.0
    r = mon.tick()
    assert r["alerting"]
    assert r["burn_fast"] == pytest.approx((90 / 100) / 0.1)


# ---- perf-regression sentry -------------------------------------------------

def _snap(rev, rows):
    return {"rev": rev, "quick": True,
            "rows": [{"name": n, "us_per_call": us, "derived": ""}
                     for n, us in rows]}


def test_diff_snapshots_statuses():
    old = _snap("aaa", [("k/a", 100.0), ("k/b", 100.0), ("k/c", 100.0),
                        ("k/gone", 50.0), ("k/err", 0.0)])
    new = _snap("bbb", [("k/a", 110.0), ("k/b", 140.0), ("k/c", 200.0),
                        ("k/new", 10.0), ("k/err", 0.0)])
    res = {r["name"]: r for r in
           diff_snapshots(old, new, warn=1.25, fail=1.5)}
    assert res["k/a"]["status"] == "ok"
    assert res["k/b"]["status"] == "warn"
    assert res["k/c"]["status"] == "fail"
    assert res["k/c"]["ratio"] == pytest.approx(2.0)
    assert res["k/new"]["status"] == "added"
    assert res["k/gone"]["status"] == "removed"
    assert "k/err" not in res                  # zero-timed rows skipped


# ---- cross-process trace stitch (proc fleet, telemetry on) ------------------

def test_proc_fleet_stitched_trace_two_worker_pids(tmp_path, flight,
                                                   fresh_registry):
    """End-to-end over real worker processes: two proc replicas with the
    telemetry plane on serve traced requests; the stitched Chrome trace
    must validate, contain spans from both worker pids (neither being
    the parent's), and >=1 admission-minted trace id must appear in both
    a parent admit span and a worker-side exec span."""
    from repro.data.landsat import synthetic_scene
    from repro.serve import Fleet, FleetConfig, ServeConfig
    from repro.configs.difet_paper import DifetConfig

    base = DifetConfig(tile=32, halo=8, max_keypoints_per_tile=16)
    cfg = FleetConfig(
        serve=ServeConfig(base=base, buckets=(32,), max_batch=4,
                          max_batch_delay_s=0.005, cache_entries=64),
        initial_replicas=2, min_replicas=1, max_replicas=2,
        warm_algorithm_sets=(("harris",),),
        cache_dir=str(tmp_path / "cache"),
        lease_dir=str(tmp_path / "leases"),
        transport_dir=str(tmp_path / "mbox"),
        proc=True, lease_ttl_s=5.0, heartbeat_interval_s=0.1,
        telemetry=True, telemetry_interval_s=0.05)
    fleet = Fleet(cfg)
    try:
        assert fleet.telemetry is not None
        tiles = [synthetic_scene(32, 32, 900 + i) for i in range(8)]
        handles = [fleet.submit(t, ("harris",), scene_key=f"sc-{i}")
                   for i, t in enumerate(tiles)]
        for h in handles:
            h.result(120)
    finally:
        fleet.close()          # drains -> final flush -> last poll

    agg = fleet.telemetry
    worker_pids = {s.pid for s in agg.spans} - {0, os.getpid()}
    assert len(worker_pids) == 2, f"worker pids seen: {worker_pids}"

    stitched = agg.stitched_spans(flight.spans())
    doc = spans_to_chrome(stitched)
    assert validate_chrome_trace(
        doc, required_layers=("router", "scheduler", "batch")) == []
    # one trace id joins the parent's admission to the worker's exec
    admit = {s.trace_id for s in flight.spans()
             if s.name == "admit" and s.trace_id}
    execs = {s.trace_id for s in agg.spans
             if s.name == "exec" and s.trace_id}
    assert admit & execs, (sorted(admit)[:4], sorted(execs)[:4])
    # exact merge: fleet totals == summed worker ledgers
    reg = obs_metrics.registry().metrics()
    ledger = agg.fleet_counts()
    assert ledger, "no worker histograms aggregated"
    for name, total in ledger.items():
        assert reg[fleet_metric_name(name)].count == total, name
