"""DIFET system tests: partition invariance (the paper's core property),
bundle round-trips, and per-algorithm feature extraction."""
import jax
import numpy as np
import pytest

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.core.bundle import BundleStore, bundle_scenes, tile_scene, rgba_to_gray
from repro.core.engine import extract_features
from repro.data.landsat import synthetic_scene, synthetic_scene_rgba


def counts_for(scene, tile, alg="harris", halo=24):
    cfg = DifetConfig(tile=tile, halo=halo, max_keypoints_per_tile=128)
    b = tile_scene(scene, cfg)
    r = jax.jit(lambda t, h: extract_features(t, h, alg, cfg))(
        b.tiles, b.headers)
    return int(r["total_count"]), r


@pytest.mark.parametrize("alg", ["harris", "fast"])
def test_partition_invariance(alg):
    """Feature counts must not depend on the tiling — the TPU analogue of
    'one mapper per image == many mappers per image' (DESIGN.md §2)."""
    scene = synthetic_scene(200, 300, seed=5)
    c64, _ = counts_for(scene, 64, alg)
    c100, _ = counts_for(scene, 100, alg)
    c256, _ = counts_for(scene, 256, alg)
    assert c64 == c100 == c256, (alg, c64, c100, c256)


def test_counts_positive_per_algorithm():
    scene = synthetic_scene(220, 220, seed=1)
    cfg = DifetConfig(tile=128, halo=24, max_keypoints_per_tile=64)
    b = tile_scene(scene, cfg)
    for alg in PAPER_ALGORITHMS:
        r = jax.jit(lambda t, h, a=alg: extract_features(t, h, a, cfg))(
            b.tiles, b.headers)
        assert int(r["total_count"]) > 0, alg
        assert bool(np.isfinite(np.asarray(r["top_scores"])).all()), alg


def test_keypoint_coordinates_in_bounds():
    scene = synthetic_scene(150, 260, seed=2)
    _, r = counts_for(scene, 100, "harris")
    ys = np.asarray(r["top_ys"])[np.asarray(r["top_valid"])]
    xs = np.asarray(r["top_xs"])[np.asarray(r["top_valid"])]
    assert ys.min() >= 0 and ys.max() < 150
    assert xs.min() >= 0 and xs.max() < 260


def test_descriptor_shapes_and_norms():
    scene = synthetic_scene(200, 200, seed=3)
    cfg = DifetConfig(tile=128, halo=24, max_keypoints_per_tile=32)
    b = tile_scene(scene, cfg)
    r = jax.jit(lambda t, h: extract_features(t, h, "sift", cfg))(
        b.tiles, b.headers)
    desc = np.asarray(r["top_desc"])
    valid = np.asarray(r["top_valid"])
    assert desc.shape[-1] == 128
    if valid.any():
        norms = np.linalg.norm(desc[valid], axis=-1)
        assert np.all(norms < 1.5)
        assert np.all(norms > 0.1)
    r2 = jax.jit(lambda t, h: extract_features(t, h, "orb", cfg))(
        b.tiles, b.headers)
    assert np.asarray(r2["top_desc"]).dtype == np.uint32
    assert np.asarray(r2["top_desc"]).shape[-1] == 8   # 256 bits


def test_extract_features_fused_equals_seed():
    """The fused SIFT path and the batched-gather patch extraction must not
    change extraction results: compare `sift`/`brief`/`orb` against the
    seed formulations (level-by-level SIFT response; vmapped dynamic_slice
    patches), field by field."""
    import jax.numpy as jnp
    from repro.core import descriptors as DS
    from repro.core import detectors as D
    from repro.core import engine

    scene = synthetic_scene(220, 220, seed=4)
    cfg = DifetConfig(tile=128, halo=24, max_keypoints_per_tile=64)
    b = tile_scene(scene, cfg)

    def assert_same(ra, rb, tag):
        assert set(ra) == set(rb), tag
        for key in ra:
            a, b = np.asarray(ra[key]), np.asarray(rb[key])
            if a.dtype.kind == "f":
                # float scores/descriptors may differ by ~2 ulp between the
                # two formulations (XLA FMA contraction is shape-dependent)
                np.testing.assert_allclose(a, b, rtol=3e-7, atol=3e-7,
                                           err_msg=f"{tag}/{key}")
            else:
                # counts, positions, validity, packed bits: exact
                np.testing.assert_array_equal(a, b, err_msg=f"{tag}/{key}")

    # --- sift: fused octave path vs seed level-by-level response ----------
    def _sift_resp_seed(img, c, use_pallas):
        return D.sift_dog_response_levelwise(
            img, c.n_octaves, c.scales_per_octave,
            c.sift_contrast_threshold / c.scales_per_octave,
            use_pallas=use_pallas)[0]

    r_fused = extract_features(b.tiles, b.headers, "sift", cfg)
    seed_spec = engine.ALGORITHMS["sift"]._replace(response=_sift_resp_seed)
    orig = engine.ALGORITHMS["sift"]
    try:
        engine.ALGORITHMS["sift"] = seed_spec
        r_seed = extract_features(b.tiles, b.headers, "sift", cfg)
    finally:
        engine.ALGORITHMS["sift"] = orig
    assert_same(r_fused, r_seed, "sift")

    # --- brief/orb: batched-gather patches vs vmapped dynamic_slice -------
    def patches_seed(img, ys, xs, size):
        half = size // 2

        def one(y, x):
            y0 = jnp.clip(y - half, 0, img.shape[0] - size)
            x0 = jnp.clip(x - half, 0, img.shape[1] - size)
            return jax.lax.dynamic_slice(img, (y0, x0), (size, size))
        return jax.vmap(one)(ys, xs)

    img = jnp.asarray(scene)
    rng = np.random.RandomState(0)
    ys = jnp.asarray(rng.randint(0, 220, size=32).astype(np.int32))
    xs = jnp.asarray(rng.randint(0, 220, size=32).astype(np.int32))
    for size in (18, 31, 45):   # covers sift/brief and orb's rotation margin
        np.testing.assert_array_equal(
            np.asarray(DS.extract_patches(img, ys, xs, size)),
            np.asarray(patches_seed(img, ys, xs, size)), err_msg=str(size))

    # --- multi-path (shared FAST response) == per-algorithm extraction ----
    from repro.core.engine import extract_features_multi
    algs = ("sift", "fast", "brief", "orb")
    multi = jax.jit(lambda t, h: extract_features_multi(t, h, algs, cfg))(
        b.tiles, b.headers)
    for alg in algs:
        single = jax.jit(lambda t, h, a=alg: extract_features(t, h, a, cfg))(
            b.tiles, b.headers)
        assert_same(multi[alg], single, alg)


def test_rgba_conversion_and_bundle_roundtrip(tmp_path):
    rgba = synthetic_scene_rgba(120, 140, seed=0)
    gray = rgba_to_gray(rgba)
    assert gray.shape == (120, 140) and gray.dtype == np.float32
    assert 0.0 <= gray.min() and gray.max() <= 1.0
    cfg = DifetConfig(tile=64, halo=16)
    bundle = bundle_scenes([rgba], cfg)
    store = BundleStore(tmp_path)
    store.put("b0", bundle)
    back = store.get("b0")
    np.testing.assert_array_equal(back.tiles, bundle.tiles)
    np.testing.assert_array_equal(back.headers, bundle.headers)
    assert back.cfg.tile == 64


def test_bundle_store_atomic_writes(tmp_path, monkeypatch):
    """A writer crashing mid-write must never surface a truncated npz:
    leftovers are invisible to list()/has_result, and an interrupted
    overwrite leaves the previous committed file intact."""
    import repro.core.bundle as bundle_mod
    cfg = DifetConfig(tile=64, halo=16)
    b0 = tile_scene(synthetic_scene(100, 100, 0), cfg)
    store = BundleStore(tmp_path)
    store.put("b0", b0)
    store.put_result("b0.harris", {"total_count": np.int64(7)})

    # crash leftovers (what a killed writer leaves behind)
    (tmp_path / "junk.npz.tmp").write_bytes(b"\x00" * 64)
    (tmp_path / "junk.result.npz.tmp").write_bytes(b"PK\x03\x04trunc")
    assert store.list() == ["b0"]
    assert not store.has_result("junk")

    # interrupt an overwrite mid-write: the committed b0 must survive
    real_savez = np.savez_compressed

    def dying_savez(f, **arrays):
        real_savez(f, **{k: v[:1] for k, v in arrays.items() if k == "tiles"})
        raise IOError("disk full")

    b1 = tile_scene(synthetic_scene(100, 100, 1), cfg)
    monkeypatch.setattr(bundle_mod.np, "savez_compressed", dying_savez)
    with pytest.raises(IOError):
        store.put("b0", b1)
    monkeypatch.setattr(bundle_mod.np, "savez_compressed", real_savez)
    back = store.get("b0")
    np.testing.assert_array_equal(back.tiles, b0.tiles)   # old data intact
    assert int(store.get_result("b0.harris")["total_count"]) == 7


def test_multi_algorithm_job_matches_single(tmp_path):
    """DifetJob('fast,brief,orb') — the shared-response multi path — must
    store per-algorithm results identical to three single-algorithm jobs."""
    from repro.core.job import DifetJob
    cfg = DifetConfig(tile=64, halo=16, max_keypoints_per_tile=32)
    store = BundleStore(tmp_path / "multi")
    store.put("b0", bundle_scenes([synthetic_scene(100, 120, 3)], cfg))
    multi = DifetJob(store, "fast,brief,orb").run()
    assert multi["bundles_done"] == 1
    assert set(multi["per_algorithm"]) == {"fast", "brief", "orb"}
    for alg in ("fast", "brief", "orb"):
        ref_store = BundleStore(tmp_path / alg)
        ref_store.put("b0", bundle_scenes([synthetic_scene(100, 120, 3)],
                                          cfg))
        single = DifetJob(ref_store, alg).run()
        assert multi["per_algorithm"][alg]["grand_total"] \
            == single["grand_total"]
        rm = store.get_result(f"b0.{alg}")
        rs = ref_store.get_result(f"b0.{alg}")
        assert set(rm) == set(rs)
        for key in rm:
            np.testing.assert_array_equal(rm[key], rs[key], err_msg=key)


def test_pad_to_multiple():
    cfg = DifetConfig(tile=64, halo=16)
    b = tile_scene(synthetic_scene(100, 100, 0), cfg)
    n0 = len(b)
    b2 = b.pad_to(n0 + 3)
    assert len(b2) == n0 + 3
    assert (b2.headers[n0:, 5] == 1).all()   # pad flag set
    r = jax.jit(lambda t, h: extract_features(t, h, "harris", b2.cfg))(
        b2.tiles, b2.headers)
    r0 = jax.jit(lambda t, h: extract_features(t, h, "harris", b.cfg))(
        b.tiles, b.headers)
    assert int(r["total_count"]) == int(r0["total_count"])   # pads emit nothing
