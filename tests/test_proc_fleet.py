"""Cross-process replica fleet + SLO autoscaler + chaos harness.

Fault types exercised (via ``tests/chaos.py`` → `repro/serve/chaos.py`,
the same primitives ``launch/fleet.py --kill-after`` drives):

* real ``kill -9`` (parent-inflicted and worker self-inflicted)
* delayed/stalled heartbeats on a *live* process
* partitioned (unreachable) shared cache directory
* torn ``.npz`` writes (requests, cache entries, dead-writer tmps)
* withheld responses (work finished but not published across a kill)

Every recovery path must be *bit-identical*: re-admitted, re-executed,
or disk-served results all match the direct engine oracle.
"""
import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import numpy as np
import pytest

from chaos import (ChaosPlan, assert_results_equal, cache_partition,
                   clear_plan, direct_extract, read_plan, sigkill,
                   tear_file, wait_until, write_plan)
from repro.configs.difet_paper import DifetConfig
from repro.data.landsat import synthetic_scene
from repro.obs import metrics as obs_metrics
from repro.serve import (DiskCacheTier, Fleet, FleetConfig,
                         ProcReplicaClient, ServeConfig, WorkerMailbox)
from repro.serve.fleet import DEAD, READY, RETIRED
from repro.serve.proc import (serve_config_from_json, serve_config_to_json)
from repro.serve.scheduler import ReplicaDied
from repro.serve.transport import (encode_message, read_message,
                                   write_message)

BASE = DifetConfig(tile=32, halo=8, max_keypoints_per_tile=16)
SRC = Path(__file__).resolve().parents[1] / "src"


def serve_cfg(**kw) -> ServeConfig:
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_batch_delay_s", 0.005)
    kw.setdefault("cache_entries", 64)
    return ServeConfig(base=BASE, buckets=(32,), **kw)


def spawn_worker(tmp_path, name="w1", *, lease_ttl_s=5.0,
                 heartbeat_interval_s=0.1) -> ProcReplicaClient:
    client = ProcReplicaClient.spawn(
        name, tmp_path / "mbox" / name, serve_cfg(), tmp_path / "leases",
        lease_ttl_s=lease_ttl_s, heartbeat_interval_s=heartbeat_interval_s,
        warm_algorithm_sets=(("harris",),))
    client.wait_ready(180.0)
    return client


def proc_fleet_cfg(tmp_path, n, *, lease_ttl_s=0.6, **kw) -> FleetConfig:
    defaults = dict(
        serve=serve_cfg(), initial_replicas=n, min_replicas=1,
        max_replicas=max(n, 2), warm_algorithm_sets=(("harris",),),
        cache_dir=str(tmp_path / "cache"),
        lease_dir=str(tmp_path / "leases"),
        transport_dir=str(tmp_path / "mbox"),
        proc=True, lease_ttl_s=lease_ttl_s, heartbeat_interval_s=0.1)
    defaults.update(kw)
    return FleetConfig(**defaults)


def thread_fleet_cfg(**kw) -> FleetConfig:
    defaults = dict(
        serve=serve_cfg(cache_entries=0), initial_replicas=1,
        min_replicas=1, max_replicas=2,
        warm_algorithm_sets=(("harris",),),
        scale_up_queue_per_replica=1e9,     # isolate the SLO trigger
        scale_down_queue_per_replica=2.0, scale_down_grace_ticks=2)
    defaults.update(kw)
    return FleetConfig(**defaults)


# ---- transport: atomicity + crash discipline (no processes) ---------------

def test_message_roundtrip_bit_exact(tmp_path):
    meta = {"request_id": "r1", "algorithms": ["harris"], "trace_id": "t9"}
    arrays = {"image": np.arange(12, dtype=np.float32).reshape(3, 4),
              "count": np.array(7, np.int32),          # 0-d leaf
              "mask": np.array([True, False])}
    path = tmp_path / "m.npz"
    write_message(path, meta, arrays)
    assert not list(tmp_path.glob("*.tmp.*"))          # tmp committed away
    got_meta, got = read_message(path)
    assert got_meta == meta
    assert set(got) == set(arrays)
    for k in arrays:
        assert got[k].shape == np.asarray(arrays[k]).shape
        assert got[k].dtype == np.asarray(arrays[k]).dtype
        assert np.array_equal(got[k], arrays[k])
        assert not got[k].flags.writeable
    with pytest.raises(ValueError):                    # reserved slot
        encode_message({}, {"__meta__": np.zeros(1)})


def test_torn_request_is_quarantined_never_delivered(tmp_path):
    mbox = WorkerMailbox(tmp_path)
    mbox.send_request("r1", {"algorithms": ["harris"]},
                      {"image": np.zeros((32, 32), np.float32)})
    tear_file(mbox.req / "r1.npz", keep=40)            # torn-write fault
    assert mbox.claim_requests() == []                 # skipped, not served
    assert list(mbox.work.glob("*.corrupt"))           # quarantined
    assert mbox.pending_requests() == []               # never re-admitted
    mbox.send_request("r2", {"algorithms": ["harris"]},
                      {"image": np.zeros((32, 32), np.float32)})
    assert [rid for rid, _, _ in mbox.claim_requests()] == ["r2"]


def test_claimed_but_unanswered_is_enumerable_for_readmission(tmp_path):
    """A worker that dies after claiming leaves its claims visible to
    `pending_requests` — the router's re-admission inventory — while an
    answered claim is retired and its response persists."""
    mbox = WorkerMailbox(tmp_path)
    img = np.zeros((8, 8), np.float32)
    for rid in ("r1", "r2", "r3"):
        mbox.send_request(rid, {"algorithms": ["harris"]}, {"image": img})
    assert [r for r, _, _ in mbox.claim_requests()] == ["r1", "r2", "r3"]
    mbox.send_response("r2", {"status": "ok", "request_id": "r2"}, {})
    assert mbox.pending_requests() == ["r1", "r3"]
    assert mbox.has_response("r2")
    assert not (mbox.work / "r2.npz").exists()
    assert mbox.try_read_response("r2")[0]["status"] == "ok"


def test_serve_config_wire_roundtrip():
    cfg = serve_cfg(max_pending=99, use_pallas=False)
    wire = json.loads(json.dumps(serve_config_to_json(cfg)))
    assert serve_config_from_json(wire) == cfg


def test_chaos_plan_file_lifecycle(tmp_path):
    assert read_plan(tmp_path) == ChaosPlan()          # absent: all off
    write_plan(tmp_path, ChaosPlan(heartbeat_stall_s=2.0,
                                   exit_after_requests=3))
    plan = read_plan(tmp_path)
    assert plan.heartbeat_stall_s == 2.0
    assert plan.exit_after_requests == 3
    assert plan.plan_time > 0                          # stamped from mtime
    assert plan.heartbeat_stalled(plan.plan_time + 1.0)
    assert not plan.heartbeat_stalled(plan.plan_time + 3.0)
    assert not plan.responses_held(plan.plan_time)     # fault not requested
    (tmp_path / "chaos.json").write_text("{not json")  # torn plan write
    assert read_plan(tmp_path) == ChaosPlan()          # never faults a worker
    clear_plan(tmp_path)
    assert read_plan(tmp_path) == ChaosPlan()


# ---- worker process: parity, drain, crash delivery ------------------------

def test_worker_parity_and_clean_drain(tmp_path):
    client = spawn_worker(tmp_path)
    try:
        tiles = [synthetic_scene(32, 32, 100 + i) for i in range(3)]
        client.register_scene("scene-a", tiles[0])     # parent-side registry
        handles = [client.submit("scene-a", ("harris",))]
        handles += [client.submit(t, ("harris",)) for t in tiles[1:]]
        for t, h in zip(tiles, handles):
            assert_results_equal(h.result(60).results, direct_extract(t))
        s = client.stats()
        assert s["alive"] and s["pid"] == client.pid
        assert s["queue_depth"] == 0
    finally:
        client.drain(60.0)
    assert client.proc.returncode == 0                 # clean exit


def test_drain_answers_every_accepted_request(tmp_path):
    client = spawn_worker(tmp_path)
    tiles = [synthetic_scene(32, 32, 200 + i) for i in range(6)]
    handles = [client.submit(t, ("harris",)) for t in tiles]
    client.drain(60.0)                                 # drain with work queued
    assert client.proc.returncode == 0
    for t, h in zip(tiles, handles):                   # zero dropped
        assert_results_equal(h.result(10).results, direct_extract(t))


def test_completed_before_crash_is_delivered_not_recomputed(tmp_path):
    """The response file is the commit point: work the worker finished
    before a ``kill -9`` is still delivered — a persisted response beats
    the dead flag."""
    client = spawn_worker(tmp_path)
    tile = synthetic_scene(32, 32, 300)
    h = client.submit(tile, ("harris",))
    wait_until(lambda: client.mailbox.has_response(h.request_id), 60,
               desc="response published")
    sigkill(client.pid)
    client.proc.wait(10)
    client.mark_dead()
    assert h.done() and not h.failed()                 # deliverable, not lost
    assert_results_equal(h.result(10).results, direct_extract(tile))


def test_exit_after_self_kill_leaves_pending_enumerable(tmp_path):
    """``exit_after_requests``: the worker ``os._exit(137)``s right after
    its N-th response — a deterministic self-``kill -9`` mid-stream.
    Published responses stay deliverable; the rest are enumerable for
    re-admission and their handles report ``failed()``."""
    client = spawn_worker(tmp_path)
    write_plan(client.root, ChaosPlan(exit_after_requests=2))
    tiles = [synthetic_scene(32, 32, 400 + i) for i in range(4)]
    handles = [client.submit(t, ("harris",)) for t in tiles]
    wait_until(lambda: client.proc.poll() is not None, 60,
               desc="worker self kill -9")
    assert client.proc.returncode == 137
    client.mark_dead()
    served = [(t, h) for t, h in zip(tiles, handles)
              if client.mailbox.has_response(h.request_id)]
    lost = [h for _, h in zip(tiles, handles)
            if not client.mailbox.has_response(h.request_id)]
    assert len(served) == 2 and len(lost) == 2
    for t, h in served:                                # still deliverable
        assert_results_equal(h.result(10).results, direct_extract(t))
    for h in lost:                                     # need re-admission
        assert h.failed()
        with pytest.raises(ReplicaDied):
            h.result(1.0)
    assert set(client.mailbox.pending_requests()) == \
        {h.request_id for h in lost}


# ---- fleet-level chaos: SIGKILL, stale leases, heartbeat stalls -----------

def test_proc_fleet_sigkill_stale_lease_readmits_bit_identical(tmp_path):
    """The tentpole chain: raw ``kill -9`` on a replica holding
    outstanding work → the parent learns of the death *only* through the
    stale lease → the victim's requests re-admit to the survivor and
    every accepted request completes bit-identically to the oracle."""
    m0 = obs_metrics.registry().snapshot()
    fleet = Fleet(proc_fleet_cfg(tmp_path, 2))
    try:
        for name in fleet.ready_replicas():            # keep work outstanding
            write_plan(fleet.transport_dir / name,
                       ChaosPlan(hold_responses_s=30.0))
        tiles = [synthetic_scene(32, 32, 500 + i) for i in range(8)]
        handles = [fleet.submit(t, ("harris",), scene_key=f"scene-{i}")
                   for i, t in enumerate(tiles)]
        victim = next(iter(fleet.router._outstanding.values())).replica
        fleet.sigkill_replica(victim)                  # no cooperative path
        for name in fleet.ready_replicas():
            clear_plan(fleet.transport_dir / name)

        def detected():
            fleet.maintenance_tick()
            return fleet.replicas[victim].state == DEAD
        wait_until(detected, 20, desc="stale-lease death detection")

        assert victim not in fleet.router.replica_names()
        results = [h.result(90) for h in handles]      # zero accepted lost
        assert len(results) == len(tiles)
        for t, r in zip(tiles, results):
            assert_results_equal(r.results, direct_extract(t))
        m1 = obs_metrics.registry().snapshot()
        assert (m1.get("difet.fleet.stale_lease_deaths", 0)
                - m0.get("difet.fleet.stale_lease_deaths", 0)) >= 1
        assert fleet.router.readmitted >= 1
    finally:
        fleet.close()


def test_heartbeat_stall_live_worker_declared_dead_and_reaped(tmp_path):
    """Delayed-heartbeat fault: the worker process is alive and well but
    stops refreshing its lease — indistinguishable from a hang to the
    control plane, so the fleet must declare it dead, reap the zombie,
    and keep serving from the survivor."""
    fleet = Fleet(proc_fleet_cfg(tmp_path, 2))
    try:
        victim = sorted(fleet.ready_replicas())[0]
        client = fleet.replicas[victim].service
        assert client.alive()
        write_plan(fleet.transport_dir / victim,
                   ChaosPlan(heartbeat_stall_s=60.0))

        def detected():
            fleet.maintenance_tick()
            return fleet.replicas[victim].state == DEAD
        wait_until(detected, 20, desc="stale lease on a live process")
        wait_until(lambda: not client.alive(), 10, desc="zombie reaped")
        assert victim not in fleet.router.replica_names()
        tile = synthetic_scene(32, 32, 601)            # survivor still serves
        assert_results_equal(
            fleet.extract(tile, ("harris",), timeout=60).results,
            direct_extract(tile))
    finally:
        fleet.close()


# ---- shared disk tier under faults (satellite: concurrent writers) --------

def test_cache_partition_degrades_to_compute(tmp_path):
    root = tmp_path / "tier"
    tier = DiskCacheTier(root)
    key = ("digest", "harris", "cfg")
    val = {"x": np.ones((3,), np.float32)}
    with cache_partition(root):
        tier.put(key, val)                             # absorbed, no raise
        assert tier.get(key) is None                   # miss, no raise
    assert tier.errors >= 1 and tier.stats()["errors"] >= 1
    tier.put(key, val)                                 # partition healed
    assert np.array_equal(tier.get(key)["x"], val["x"])


def test_concurrent_cross_process_put_same_key_one_wins(tmp_path):
    """Two OS processes hammer `DiskCacheTier.put` on the same content
    key with distinguishable values: the atomic-rename discipline means
    the surviving entry is always one writer's *complete* value, never
    an interleaving, and no tmp litter leaks."""
    script = textwrap.dedent("""
        import sys
        import numpy as np
        from repro.serve.cache import DiskCacheTier
        tier = DiskCacheTier(sys.argv[1])
        key = ("tile-digest", "harris", "cfg")
        val = {"x": np.full(256, float(sys.argv[2]), np.float32)}
        for _ in range(40):
            tier.put(key, val)
    """)
    env = dict(os.environ, PYTHONPATH=str(SRC))
    procs = [subprocess.Popen([sys.executable, "-c", script,
                               str(tmp_path), fill], env=env)
             for fill in ("1.0", "2.0")]
    for p in procs:
        assert p.wait(120) == 0
    tier = DiskCacheTier(tmp_path)
    got = tier.get(("tile-digest", "harris", "cfg"))["x"]
    assert got.shape == (256,) and got.dtype == np.float32
    assert np.all(got == got[0]) and got[0] in (1.0, 2.0)   # one writer won
    assert not list(Path(tmp_path).glob("*/*.tmp.*"))       # no torn tmps


def test_torn_cache_writes_read_as_miss(tmp_path):
    """A killed writer's leftover private tmp is never served, and a
    committed entry torn after the fact reads as a miss (and is
    dropped) — the tier always degrades to recompute."""
    tier = DiskCacheTier(tmp_path)
    key = ("digest-torn", "harris", "cfg")
    path = tier.path_for(key)
    path.parent.mkdir(parents=True, exist_ok=True)
    # fault 1: dead writer's tmp (SIGKILL mid-write, before the rename)
    (path.with_suffix(".tmp.99999.1")).write_bytes(b"partial dead write")
    assert tier.get(key) is None
    # fault 2: committed entry truncated in place
    tier.put(key, {"x": np.arange(64, dtype=np.float32)})
    tear_file(path, keep=48)
    assert tier.get(key) is None
    assert not path.exists()                           # torn entry dropped
    tier.put(key, {"x": np.arange(64, dtype=np.float32)})
    assert np.array_equal(tier.get(key)["x"],
                          np.arange(64, dtype=np.float32))


# ---- SLO autoscaler policy -------------------------------------------------

def test_slo_scale_up_on_p99_breach_records_decision():
    m0 = obs_metrics.registry().snapshot()
    fleet = Fleet(thread_fleet_cfg(slo_p99_s=1e-4))    # any latency breaches
    try:
        for i in range(4):
            fleet.extract(synthetic_scene(32, 32, 700 + i), ("harris",),
                          timeout=60)
        action = fleet.autoscale_tick()
        assert action.startswith("scale_up:")
        assert len(fleet.ready_replicas()) == 2
        ev = fleet.scale_events[-1]
        assert ev["action"] == "scale_up"
        assert ev["trigger"] == "p99_latency"          # not the queue path
        assert (ev["before"], ev["after"]) == (1, 2)
        assert ev["value"] > ev["slo_p99_s"] == fleet.cfg.slo_p99_s
        assert fleet.stats()["scale_events"][-1] == ev
        m1 = obs_metrics.registry().snapshot()
        assert (m1.get("difet.fleet.scale_up.p99_latency", 0)
                - m0.get("difet.fleet.scale_up.p99_latency", 0)) >= 1
    finally:
        fleet.close()


def test_slo_scale_down_drains_without_dropping():
    fleet = Fleet(thread_fleet_cfg(initial_replicas=2, slo_p99_s=1e9))
    try:
        tiles = [synthetic_scene(32, 32, 800 + i) for i in range(6)]
        handles = [fleet.submit(t, ("harris",), scene_key=f"s{i}")
                   for i, t in enumerate(tiles)]
        results = [h.result(60) for h in handles]
        assert fleet.autoscale_tick() == "hold"        # grace tick 1 of 2
        action = fleet.autoscale_tick()                # grace met → drain
        assert action.startswith("scale_down:")
        ev = fleet.scale_events[-1]
        assert ev["trigger"] == "slo_satisfied"
        assert (ev["before"], ev["after"]) == (2, 1)
        retired = action.split(":", 1)[1]
        assert fleet.replicas[retired].state == RETIRED
        for t, r in zip(tiles, results):               # nothing dropped
            assert_results_equal(r.results, direct_extract(t))
        assert fleet.autoscale_tick() == "hold"        # at min_replicas
        survivor = fleet.ready_replicas()
        assert len(survivor) == 1
        assert fleet.replicas[survivor[0]].state == READY
        fleet.extract(tiles[0], ("harris",), timeout=60)
    finally:
        fleet.close()
