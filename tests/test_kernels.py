"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c), plus interior-equality with the production detectors."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detectors as D
from repro.data.landsat import synthetic_scene
from repro.kernels import ops, ref

SHAPES = [(32, 128), (61, 200), (96, 96), (128, 257)]


def scenes(h, w, n=2):
    return jnp.asarray(np.stack([synthetic_scene(h, w, seed=i)
                                 for i in range(n)]))


@pytest.mark.parametrize("hw", SHAPES)
@pytest.mark.parametrize("sigma", [1.0, 2.0])
def test_harris_kernel_matches_ref(hw, sigma):
    img = scenes(*hw)
    a = ops.harris(img, k=0.04, sigma=sigma)
    b = ref.harris(img, k=0.04, sigma=sigma)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("hw", SHAPES[:2])
def test_shi_tomasi_kernel_matches_ref(hw):
    img = scenes(*hw)
    a = ops.harris(img, shi_tomasi=True)
    b = ref.harris(img, shi_tomasi=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("hw", SHAPES)
@pytest.mark.parametrize("sigma", [0.8, 1.6, 3.2])
def test_blur_kernel_matches_ref(hw, sigma):
    img = scenes(*hw)
    a = ops.gaussian_blur(img, sigma)
    b = ref.gaussian_blur(img, sigma)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("hw", SHAPES)
@pytest.mark.parametrize("threshold", [0.05, 0.15])
def test_fast_kernel_matches_ref(hw, threshold):
    img = scenes(*hw)
    a = ops.fast_score(img, threshold=threshold)
    b = ref.fast_score(img, threshold=threshold)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(dtype):
    img = scenes(48, 128).astype(dtype)
    a = ops.harris(img)
    assert a.dtype == jnp.float32         # response always fp32
    assert bool(jnp.isfinite(a).all())


def test_single_image_rank():
    img = scenes(40, 130)[0]
    assert ops.harris(img).shape == img.shape
    assert ops.fast_score(img).shape == img.shape


def test_pallas_matches_production_interior():
    """Kernel path vs production jnp detectors agree on the tile interior
    (border band may differ by padding convention — DESIGN.md §5)."""
    img = scenes(96, 160)
    m = 8   # > blur radius + 1
    a = np.asarray(ops.harris(img))[:, m:-m, m:-m]
    b = np.asarray(D.harris_response(img))[:, m:-m, m:-m]
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)
    af = np.asarray(ops.fast_score(img, threshold=0.1))[:, m:-m, m:-m]
    bf = np.asarray(D.fast_score(img, threshold=0.1))[:, m:-m, m:-m]
    np.testing.assert_allclose(af, bf, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused scale-space kernel (kernels/scalespace.py)
# ---------------------------------------------------------------------------
import jax  # noqa: E402

from repro.core.pyramid import (  # noqa: E402
    blur_separable, blur_separable_seed, fused_octave_response)

# odd/even H/W and a lane-unaligned width; H must exceed 2*(cum radius + 1)
SS_SHAPES = [(96, 128), (81, 200), (97, 97), (128, 257)]


@pytest.mark.parametrize("hw", SS_SHAPES)
@pytest.mark.parametrize("spo,sigma0", [(3, 1.6), (2, 1.6), (3, 1.2)])
def test_scalespace_kernel_matches_ref(hw, spo, sigma0):
    """Pallas fused octave vs the independent 26-stack oracle, interpret
    mode (deliverable: atol=1e-5)."""
    base = blur_separable(scenes(*hw), sigma0)
    ra, sa = ops.scalespace_octave(base, scales_per_octave=spo,
                                   contrast_threshold=0.04 / spo,
                                   sigma0=sigma0)
    rb, sb = ref.scalespace_octave(base, scales_per_octave=spo,
                                   contrast_threshold=0.04 / spo,
                                   sigma0=sigma0)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(rb), atol=1e-5)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(sb), atol=1e-5)


def test_scalespace_single_image_rank():
    base = blur_separable(scenes(96, 130)[0], 1.6)
    resp, seed = ops.scalespace_octave(base, scales_per_octave=3,
                                       contrast_threshold=0.0133)
    assert resp.shape == base.shape and seed.shape == base.shape


def test_scalespace_batched_rank():
    imgs = scenes(96, 130, n=3)
    base = blur_separable(imgs, 1.6)
    resp, seed = ops.scalespace_octave(base, scales_per_octave=3,
                                       contrast_threshold=0.0133)
    assert resp.shape == imgs.shape and seed.shape == imgs.shape
    r0, s0 = ops.scalespace_octave(base[0], scales_per_octave=3,
                                   contrast_threshold=0.0133)
    np.testing.assert_array_equal(np.asarray(resp[0]), np.asarray(r0))


def test_scalespace_pallas_matches_production_interior():
    """Fused kernel vs the production jnp path agree beyond the
    cumulative-radius band (padding convention — DESIGN.md §6)."""
    base = blur_separable(scenes(128, 200), 1.6)
    ra, sa = ops.scalespace_octave(base, scales_per_octave=3,
                                   contrast_threshold=0.0133)
    rj, sj = fused_octave_response(base, 3, 0.0133)
    m = ops.scalespace_pad(3) + 2
    np.testing.assert_allclose(np.asarray(ra)[:, m:-m, m:-m],
                               np.asarray(rj)[:, m:-m, m:-m], atol=1e-6)
    np.testing.assert_allclose(np.asarray(sa)[:, m:-m, m:-m],
                               np.asarray(sj)[:, m:-m, m:-m], atol=1e-6)


def test_scalespace_vmem_budget():
    assert ops.scalespace_fits_vmem(176, 176, 3)      # tile 128 + 2*24
    assert not ops.scalespace_fits_vmem(560, 560, 3)  # tile 512: jnp path
    # dispatcher must not crash on an oversized tile (falls back to jnp)
    assert not ops.scalespace_fits_vmem(416, 560, 3)
    base = blur_separable(scenes(416, 560), 1.6)
    resp, seed = fused_octave_response(base, 3, 0.0133, use_pallas=True)
    assert resp.shape == base.shape


# ---------------------------------------------------------------------------
# fused jnp path vs seed formulation (bitwise)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", [0.8, 1.6, 3.2])
@pytest.mark.parametrize("hw", [(61, 200), (96, 96)])
def test_blur_fast_matches_seed(hw, sigma):
    """The no-transpose blur vs the seed's pad-per-pass/transpose
    formulation: the per-pixel arithmetic is the same sequence, but XLA may
    contract mul+add to FMA differently across fusion boundaries, so allow
    ~2 ulp (observed max 1 ulp); the count-relevant invariants are pinned
    by ``test_fused_sift_response_matches_levelwise``."""
    img = scenes(*hw)
    a = np.asarray(jax.jit(lambda x: blur_separable(x, sigma))(img))
    b = np.asarray(jax.jit(lambda x: blur_separable_seed(x, sigma))(img))
    np.testing.assert_allclose(a, b, rtol=3e-7, atol=3e-8)


def test_fused_sift_response_matches_levelwise():
    """Octave-fused streaming path vs the seed's level-by-level
    gaussian_pyramid/26-stack path: values within ~2 ulp (XLA FMA
    contraction), and the thresholded detection mask — what Table-2 counts
    measure — must be IDENTICAL at every octave."""
    img = scenes(120, 176)
    thr = 0.04 / 3
    fused = D.sift_dog_response(img, contrast_threshold=thr)
    seedp = D.sift_dog_response_levelwise(img, contrast_threshold=thr)
    assert len(fused) == len(seedp)
    for a, b in zip(fused, seedp):
        a, b = np.asarray(a), np.asarray(b)
        np.testing.assert_allclose(a, b, atol=3e-7)
        np.testing.assert_array_equal(a > thr, b > thr)
