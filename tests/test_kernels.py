"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c), plus interior-equality with the production detectors."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detectors as D
from repro.data.landsat import synthetic_scene
from repro.kernels import ops, ref

SHAPES = [(32, 128), (61, 200), (96, 96), (128, 257)]


def scenes(h, w, n=2):
    return jnp.asarray(np.stack([synthetic_scene(h, w, seed=i)
                                 for i in range(n)]))


@pytest.mark.parametrize("hw", SHAPES)
@pytest.mark.parametrize("sigma", [1.0, 2.0])
def test_harris_kernel_matches_ref(hw, sigma):
    img = scenes(*hw)
    a = ops.harris(img, k=0.04, sigma=sigma)
    b = ref.harris(img, k=0.04, sigma=sigma)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("hw", SHAPES[:2])
def test_shi_tomasi_kernel_matches_ref(hw):
    img = scenes(*hw)
    a = ops.harris(img, shi_tomasi=True)
    b = ref.harris(img, shi_tomasi=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-7)


@pytest.mark.parametrize("hw", SHAPES)
@pytest.mark.parametrize("sigma", [0.8, 1.6, 3.2])
def test_blur_kernel_matches_ref(hw, sigma):
    img = scenes(*hw)
    a = ops.gaussian_blur(img, sigma)
    b = ref.gaussian_blur(img, sigma)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("hw", SHAPES)
@pytest.mark.parametrize("threshold", [0.05, 0.15])
def test_fast_kernel_matches_ref(hw, threshold):
    img = scenes(*hw)
    a = ops.fast_score(img, threshold=threshold)
    b = ref.fast_score(img, threshold=threshold)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtype_sweep(dtype):
    img = scenes(48, 128).astype(dtype)
    a = ops.harris(img)
    assert a.dtype == jnp.float32         # response always fp32
    assert bool(jnp.isfinite(a).all())


def test_single_image_rank():
    img = scenes(40, 130)[0]
    assert ops.harris(img).shape == img.shape
    assert ops.fast_score(img).shape == img.shape


def test_pallas_matches_production_interior():
    """Kernel path vs production jnp detectors agree on the tile interior
    (border band may differ by padding convention — DESIGN.md §5)."""
    img = scenes(96, 160)
    m = 8   # > blur radius + 1
    a = np.asarray(ops.harris(img))[:, m:-m, m:-m]
    b = np.asarray(D.harris_response(img))[:, m:-m, m:-m]
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)
    af = np.asarray(ops.fast_score(img, threshold=0.1))[:, m:-m, m:-m]
    bf = np.asarray(D.fast_score(img, threshold=0.1))[:, m:-m, m:-m]
    np.testing.assert_allclose(af, bf, rtol=1e-5, atol=1e-6)
