"""Per-arch smoke tests (deliverable f): reduced same-family config, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, all_arch_ids
from repro.models import build_model
from repro.optim import AdamW
from repro.train.step import make_train_step, make_init_fn, TrainStepConfig

B, S = 2, 32


def make_batch(cfg):
    rng = np.random.RandomState(0)
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.n_image_patches:
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.n_image_patches, cfg.d_model), jnp.bfloat16)
    if cfg.is_enc_dec:
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(all_arch_ids()))
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced().replace(remat="nothing")
    model = build_model(cfg)
    batch = make_batch(cfg)

    # forward: logits shape + finite
    params = model.init(jax.random.PRNGKey(0))
    logits, aux = jax.jit(model.forward)(params, batch)
    expect_seq = S + (cfg.n_image_patches or 0)
    assert logits.shape == (B, expect_seq, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN/inf logits"

    # one full train step: loss finite, params updated, no NaN grads
    opt = AdamW()
    scfg = TrainStepConfig(learning_rate=1e-3)
    state = jax.jit(make_init_fn(model, opt, scfg))(jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(model, opt, scfg))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # at least one param changed
    changed = jax.tree_util.tree_map(
        lambda a, b: bool((a != b).any()), state["params"],
        new_state["params"])
    assert any(jax.tree_util.tree_leaves(changed)), f"{arch}: no update"


@pytest.mark.parametrize("arch", sorted(all_arch_ids()))
def test_smoke_decode_consistency(arch):
    """Teacher-forced decode must match the full forward pass: feeding the
    same tokens step-by-step through the KV-cache/state path reproduces the
    forward logits at the final position (the strongest cache-logic test)."""
    cfg = get_config(arch).reduced().replace(remat="nothing")
    model = build_model(cfg)
    rng = np.random.RandomState(2)
    s = 8
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, s)), jnp.int32)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.n_image_patches:
        pytest.skip("vlm prefix handled in test_vlm_decode below")
    if cfg.is_enc_dec:
        batch["frames"] = jnp.zeros((B, cfg.encoder_seq_len, cfg.d_model),
                                    jnp.bfloat16)
    logits_full, _ = jax.jit(model.forward)(params, batch)

    cache = model.init_cache(B, s)
    if cfg.is_enc_dec:
        # populate frozen cross-attn cache exactly as prefill would
        _, cache2 = model.prefill(params, batch)
        cache = dict(cache, xk=cache2["xk"], xv=cache2["xv"])
    decode = jax.jit(model.decode_step)
    logits_step = None
    for i in range(s):
        logits_step, cache = decode(params, cache, tokens[:, i:i + 1],
                                    jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0]), np.asarray(logits_full[:, -1]),
        rtol=0.05, atol=0.15)
