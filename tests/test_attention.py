"""Attention algorithm equivalences + MLA absorption correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as A


@pytest.mark.parametrize("sq,sk,causal", [(16, 16, True), (16, 16, False),
                                          (8, 32, False), (64, 64, True)])
def test_online_matches_einsum(sq, sk, causal):
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(2, sq, 4, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, sk, 4, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, sk, 4, 16), jnp.float32)
    a = A.attention_einsum(q, k, v, causal=causal)
    b = A.attention_online(q, k, v, causal=causal, chunk=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_online_mixed_head_dims():
    """MLA: q/k head dim != v head dim."""
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 8, 2, 24), jnp.float32)
    k = jnp.asarray(rng.randn(1, 8, 2, 24), jnp.float32)
    v = jnp.asarray(rng.randn(1, 8, 2, 16), jnp.float32)
    a = A.attention_einsum(q, k, v, causal=True)
    b = A.attention_online(q, k, v, causal=True, chunk=4)
    assert a.shape == b.shape == (1, 8, 2, 16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_gqa_decode_matches_full():
    cfg = get_config("internlm2-1.8b").reduced()
    p = A.gqa_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.RandomState(2)
    s = 6
    x = jnp.asarray(rng.randn(2, s, cfg.d_model), jnp.float32)
    positions = jnp.arange(s)[None, :]
    full = A.gqa_attention(p, cfg, x, positions, causal=True)

    hd = cfg.resolved_head_dim
    kc = jnp.zeros((2, s, cfg.n_kv_heads, hd), jnp.float32)
    vc = jnp.zeros((2, s, cfg.n_kv_heads, hd), jnp.float32)
    outs = []
    for i in range(s):
        o, kc, vc = A.gqa_decode(p, cfg, x[:, i:i + 1], kc, vc, i)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-3, atol=1e-3)


def test_mla_absorbed_decode_matches_naive():
    """Weight-absorbed latent-space decode == naive expanded attention."""
    cfg = get_config("deepseek-v3-671b").reduced()
    p = A.mla_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.RandomState(3)
    s = 5
    x = jnp.asarray(rng.randn(2, s, cfg.d_model), jnp.float32)
    positions = jnp.arange(s)[None, :]
    full, _, _ = A.mla_attention(p, cfg, x, positions, causal=True)

    m = cfg.mla
    ckv = jnp.zeros((2, s, m.kv_lora_rank), jnp.float32)
    kr = jnp.zeros((2, s, m.qk_rope_head_dim), jnp.float32)
    outs = []
    for i in range(s):
        o, ckv, kr = A.mla_decode_absorbed(p, cfg, x[:, i:i + 1], ckv, kr, i)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-3, atol=1e-3)


def test_rope_rotation_invariance():
    """<rope(q,p), rope(k,p)> depends only on relative position."""
    from repro.models import layers as L
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(1, 1, 2, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 1, 2, 32), jnp.float32)

    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.full((1, 1), pq), 1e4)
        kr = L.apply_rope(k, jnp.full((1, 1), pk), 1e4)
        return float(jnp.einsum("bshd,bshd->", qr, kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-4   # but absolute matters
