"""Property-based tests (hypothesis) on NMS/top-K selection invariants,
plus plain regression tests for the strict-max plateau tie-break.

``hypothesis`` is optional: the property tests skip when it is missing
(the container does not ship it) while the regression tests always run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nms

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                         # pragma: no cover
    HAVE_HYPOTHESIS = False

    def given(*a, **k):                                     # noqa: D103
        return lambda f: pytest.mark.skip("hypothesis not installed")(f)

    def settings(*a, **k):                                  # noqa: D103
        return lambda f: f

    class st:                                               # noqa: D101
        @staticmethod
        def integers(*a, **k):
            return None

        @staticmethod
        def floats(*a, **k):
            return None


if HAVE_HYPOTHESIS:
    arrays = st.integers(0, 10**6).map(
        lambda seed: np.random.RandomState(seed).rand(24, 24)
        .astype(np.float32))
else:
    arrays = None


# ---------------------------------------------------------------------------
# regression tests: strict 3x3 max with deterministic plateau tie-break
# ---------------------------------------------------------------------------
def test_nms_plateau_tiebreak():
    """A constant plateau must emit exactly one keypoint per 3x3 window,
    at the smallest row-major index — the seed's ``resp >= mx`` kept a
    keypoint at EVERY plateau pixel."""
    a = np.zeros((8, 8), np.float32)
    a[2:4, 2:4] = 1.0                       # 2x2 plateau, all within 3x3
    r = np.asarray(nms.nms3x3(jnp.asarray(a)))
    kept = np.argwhere(r > 0)
    assert kept.shape[0] == 1
    np.testing.assert_array_equal(kept[0], [2, 2])   # smallest flat index


def test_nms_constant_field_is_sparse():
    """Fully-constant response: survivors must be spaced >= 2 apart (no two
    survivors share a 3x3 window), deterministic run-to-run."""
    a = np.full((10, 10), 0.5, np.float32)
    r1 = np.asarray(nms.nms3x3(jnp.asarray(a)))
    r2 = np.asarray(nms.nms3x3(jnp.asarray(a)))
    np.testing.assert_array_equal(r1, r2)
    kept = np.argwhere(r1 > 0)
    for i in range(len(kept)):
        d = np.abs(kept - kept[i]).max(axis=1)
        d[i] = 99
        assert (d >= 2).all(), kept


def test_nms_strict_maxima_unchanged():
    """Isolated strict maxima are kept with their value; non-maxima are
    zeroed (the pre-fix behaviour away from plateaus)."""
    rng = np.random.RandomState(0)
    a = rng.rand(24, 24).astype(np.float32)     # ties have measure ~0
    r = np.asarray(nms.nms3x3(jnp.asarray(a)))
    kept = np.argwhere(r > 0)
    assert kept.shape[0] > 0
    for y, x in kept:
        window = a[max(y - 1, 0):y + 2, max(x - 1, 0):x + 2]
        assert a[y, x] == window.max()
        assert (window == a[y, x]).sum() == 1   # strict
        assert r[y, x] == a[y, x]


def test_nms_batched_rank():
    a = np.random.RandomState(1).rand(3, 16, 16).astype(np.float32)
    r = np.asarray(nms.nms3x3(jnp.asarray(a)))
    assert r.shape == a.shape
    for i in range(3):
        np.testing.assert_array_equal(
            r[i], np.asarray(nms.nms3x3(jnp.asarray(a[i]))))


# ---------------------------------------------------------------------------
# property tests (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(arrays)
def test_nms_keeps_local_maxima_only(a):
    r = np.asarray(nms.nms3x3(jnp.asarray(a)))
    kept = np.argwhere(r > 0)
    for y, x in kept:
        window = a[max(y - 1, 0):y + 2, max(x - 1, 0):x + 2]
        assert a[y, x] >= window.max() - 1e-6


@settings(max_examples=25, deadline=None)
@given(arrays, st.floats(0.0, 1.0))
def test_count_monotone_in_threshold(a, t):
    mask = jnp.ones_like(jnp.asarray(a), bool)
    c1 = int(nms.count_above(jnp.asarray(a), t, mask))
    c2 = int(nms.count_above(jnp.asarray(a), min(t + 0.1, 1.0), mask))
    assert c2 <= c1


@settings(max_examples=25, deadline=None)
@given(arrays, st.integers(1, 32))
def test_topk_sorted_valid_above_threshold(a, k):
    mask = jnp.ones_like(jnp.asarray(a), bool)
    ys, xs, scores, valid = nms.topk_keypoints(jnp.asarray(a), k, 0.5, mask)
    s = np.asarray(scores)
    v = np.asarray(valid)
    assert np.all(np.diff(s) <= 1e-6)           # sorted descending
    assert np.all(s[v] > 0.5)                   # above threshold
    assert np.all(s[~v] == 0.0)                 # invalid slots zeroed
    yy, xx = np.asarray(ys)[v], np.asarray(xs)[v]
    np.testing.assert_allclose(a[yy, xx], s[v], rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 16))
def test_merge_topk_equals_global_topk(seed, k):
    rng = np.random.RandomState(seed)
    sa, sb = rng.rand(k).astype(np.float32), rng.rand(k).astype(np.float32)
    sa.sort(); sb.sort()
    sa, sb = sa[::-1].copy(), sb[::-1].copy()
    pa = {"i": np.arange(k, dtype=np.int32)}
    pb = {"i": np.arange(k, 2 * k, dtype=np.int32)}
    top, payload = nms.merge_topk(jnp.asarray(sa), pa, jnp.asarray(sb), pb, k)
    expected = np.sort(np.concatenate([sa, sb]))[::-1][:k]
    np.testing.assert_allclose(np.asarray(top), expected, rtol=1e-6)
