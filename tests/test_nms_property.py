"""Property-based tests (hypothesis) on NMS/top-K selection invariants."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import nms

arrays = st.integers(0, 10**6).map(
    lambda seed: np.random.RandomState(seed).rand(24, 24).astype(np.float32))


@settings(max_examples=25, deadline=None)
@given(arrays)
def test_nms_keeps_local_maxima_only(a):
    r = np.asarray(nms.nms3x3(jnp.asarray(a)))
    kept = np.argwhere(r > 0)
    for y, x in kept:
        window = a[max(y - 1, 0):y + 2, max(x - 1, 0):x + 2]
        assert a[y, x] >= window.max() - 1e-6


@settings(max_examples=25, deadline=None)
@given(arrays, st.floats(0.0, 1.0))
def test_count_monotone_in_threshold(a, t):
    mask = jnp.ones_like(jnp.asarray(a), bool)
    c1 = int(nms.count_above(jnp.asarray(a), t, mask))
    c2 = int(nms.count_above(jnp.asarray(a), min(t + 0.1, 1.0), mask))
    assert c2 <= c1


@settings(max_examples=25, deadline=None)
@given(arrays, st.integers(1, 32))
def test_topk_sorted_valid_above_threshold(a, k):
    mask = jnp.ones_like(jnp.asarray(a), bool)
    ys, xs, scores, valid = nms.topk_keypoints(jnp.asarray(a), k, 0.5, mask)
    s = np.asarray(scores)
    v = np.asarray(valid)
    assert np.all(np.diff(s) <= 1e-6)           # sorted descending
    assert np.all(s[v] > 0.5)                   # above threshold
    assert np.all(s[~v] == 0.0)                 # invalid slots zeroed
    yy, xx = np.asarray(ys)[v], np.asarray(xs)[v]
    np.testing.assert_allclose(a[yy, xx], s[v], rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6), st.integers(1, 16))
def test_merge_topk_equals_global_topk(seed, k):
    rng = np.random.RandomState(seed)
    sa, sb = rng.rand(k).astype(np.float32), rng.rand(k).astype(np.float32)
    sa.sort(); sb.sort()
    sa, sb = sa[::-1].copy(), sb[::-1].copy()
    pa = {"i": np.arange(k, dtype=np.int32)}
    pb = {"i": np.arange(k, 2 * k, dtype=np.int32)}
    top, payload = nms.merge_topk(jnp.asarray(sa), pa, jnp.asarray(sb), pb, k)
    expected = np.sort(np.concatenate([sa, sb]))[::-1][:k]
    np.testing.assert_allclose(np.asarray(top), expected, rtol=1e-6)
