"""Serving subsystem: buckets, LRU cache, scheduler, service parity,
determinism (DESIGN.md §8)."""
import functools
import threading
import time

import numpy as np
import pytest

import jax

from repro.configs.difet_paper import DifetConfig
from repro.core import engine
from repro.core.bundle import tile_scene
from repro.core.job import DifetJob
from repro.data.landsat import synthetic_scene
from repro.serve import (BatchScheduler, BucketTable, FeatureService,
                         ResultCache, ServeConfig, ServiceClosed,
                         ServiceOverloaded, config_digest, encode_tile,
                         tile_digest)

BASE = DifetConfig(tile=32, halo=8, max_keypoints_per_tile=16)
ALGS = ("harris", "shi_tomasi")


def make_service(max_batch=4, cache_entries=128, buckets=(32,),
                 max_pending=1024):
    return FeatureService(ServeConfig(
        base=BASE, buckets=buckets, max_batch=max_batch,
        max_batch_delay_s=0.005, max_pending=max_pending,
        cache_entries=cache_entries))


@pytest.fixture(scope="module")
def service():
    svc = make_service()
    yield svc
    svc.close()


# ---- algorithm normalization (shared with launch/extract.py) --------------

def test_normalize_algorithms_dedupes_preserving_order():
    assert engine.normalize_algorithms("fast, brief,fast,orb") == \
        ("fast", "brief", "orb")
    assert engine.normalize_algorithms(("harris",)) == ("harris",)


def test_normalize_algorithms_rejects_unknown_listing_choices():
    with pytest.raises(ValueError) as e:
        engine.normalize_algorithms("harris,bogus")
    msg = str(e.value)
    assert "bogus" in msg
    for name in engine.ALGORITHMS:
        assert name in msg          # the error spells out valid choices
    with pytest.raises(ValueError):
        engine.normalize_algorithms(" , ")


# ---- buckets ---------------------------------------------------------------

def test_bucket_selection():
    table = BucketTable((32, 64, 128), BASE)
    assert table.bucket_for(20, 31) == 32
    assert table.bucket_for(32, 33) == 64
    assert table.bucket_for(65, 10) == 128
    assert table.bucket_for(129, 5) is None     # oversize → scene split


def test_pad_to_bucket_matches_tile_scene_bitwise(rng):
    table = BucketTable((32, 64), BASE)
    for h, w, bucket in [(32, 32, 32), (30, 25, 32), (33, 20, 64),
                         (9, 64, 64)]:
        gray = rng.rand(h, w).astype(np.float32)
        tile, header = table.pad_to_bucket(gray, bucket)
        ref = tile_scene(gray, table.cfg_for(bucket))
        assert np.array_equal(tile, ref.tiles[0])
        assert np.array_equal(header, ref.headers[0])


def test_pad_to_bucket_sub_halo_tiles_use_multibounce_fallback(rng):
    table = BucketTable((32,), BASE)      # halo 8
    gray = rng.rand(5, 32).astype(np.float32)   # side < halo: np.pad path
    tile, header = table.pad_to_bucket(gray, 32)
    ref = tile_scene(gray, table.cfg_for(32))
    assert np.array_equal(tile, ref.tiles[0])
    assert np.array_equal(header, ref.headers[0])
    with pytest.raises(ValueError, match="too small"):
        table.pad_to_bucket(rng.rand(1, 32).astype(np.float32), 32)


# ---- result cache ----------------------------------------------------------

def _entry(i):
    return {"top_scores": np.full((4,), float(i), np.float32)}


def test_cache_lru_eviction_order():
    c = ResultCache(capacity=3)
    for k in "abc":
        c.put(k, _entry(0))
    assert c.get("a") is not None        # refresh 'a': LRU order b, c, a
    c.put("d", _entry(1))                # evicts 'b'
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None
    assert c.get("d") is not None
    assert c.evictions == 1 and len(c) == 3


def test_cache_entries_are_frozen_copies():
    c = ResultCache(capacity=2)
    src = {"x": np.ones((3,), np.float32)}
    stored = c.put("k", src)
    src["x"][0] = 99.0                   # caller mutation can't reach cache
    assert c.get("k")["x"][0] == 1.0
    with pytest.raises(ValueError):
        stored["x"][0] = 5.0             # read-only
    assert c.get("k")["x"].shape == (3,)
    zero_d = c.put("z", {"n": np.int32(7)})
    assert zero_d["n"].shape == ()       # 0-d leaves stay 0-d


def test_cache_capacity_zero_disables():
    c = ResultCache(capacity=0)
    c.put("k", _entry(0))
    assert c.get("k") is None and len(c) == 0


def test_config_digest_collision_safety():
    d1 = config_digest(BASE, use_pallas=False)
    assert config_digest(BASE, use_pallas=False) == d1
    # any config field change or backend flip must change the key
    import dataclasses
    assert config_digest(dataclasses.replace(BASE, harris_k=0.05)) != d1
    assert config_digest(dataclasses.replace(BASE, tile=64)) != d1
    assert config_digest(BASE, use_pallas=True) != d1
    c = ResultCache(capacity=8)
    c.put((tile_digest(np.zeros((4, 4))), "harris", d1), _entry(0))
    other = config_digest(dataclasses.replace(BASE, harris_k=0.05))
    assert c.get((tile_digest(np.zeros((4, 4))), "harris", other)) is None


# ---- service: parity, cache, partial hits ----------------------------------

def _direct(table, gray, algs):
    bucket = table.bucket_for(*gray.shape)
    tile, header = table.pad_to_bucket(gray, bucket)
    fn = jax.jit(functools.partial(engine.extract_features_multi,
                                   algorithms=algs, cfg=table.cfg_for(bucket)))
    return {alg: {k: np.asarray(v) for k, v in res.items()}
            for alg, res in fn(tile[None], header[None]).items()}


def assert_results_equal(a, b):
    assert set(a) == set(b)
    for alg in a:
        assert set(a[alg]) == set(b[alg])
        for k in a[alg]:
            x, y = np.asarray(a[alg][k]), np.asarray(b[alg][k])
            assert x.shape == y.shape and x.dtype == y.dtype, (alg, k)
            assert np.array_equal(x, y), (alg, k)


def test_served_parity(service):
    """Served results are bit-identical to direct engine calls, whatever
    batch the scheduler rode them in."""
    tiles = [synthetic_scene(32, 32, s) for s in range(6)]
    resps = [h.result(60) for h in
             [service.submit(t, ALGS) for t in tiles]]
    for t, r in zip(tiles, resps):
        assert_results_equal(_direct(service.table, t, ALGS), r.results)
        assert r.n_tiles == 1 and r.bucket == 32
        assert r.timing["latency_s"] >= 0.0
        assert r.timing["batch_sizes"] and r.timing["batch_sizes"][0] >= 1


def test_repeat_requests_served_from_cache(service):
    tile = synthetic_scene(32, 32, 77)
    first = service.extract(tile, ALGS, timeout=60)
    assert not first.fully_cached
    hits_before = service.cache.hits
    again = service.extract(tile, ALGS, timeout=60)
    assert again.fully_cached
    assert again.cached == {a: 1.0 for a in ALGS}
    assert service.cache.hits >= hits_before + len(ALGS)
    assert_results_equal(first.results, again.results)


def test_partial_algorithm_cache_hit(service):
    tile = synthetic_scene(32, 32, 123)
    service.extract(tile, ("harris",), timeout=60)
    r = service.extract(tile, ALGS, timeout=60)   # harris cached, shi fresh
    assert r.cached["harris"] == 1.0 and r.cached["shi_tomasi"] == 0.0
    assert_results_equal(_direct(service.table, tile, ALGS), r.results)


def test_wire_format_and_scene_id(service):
    tile = synthetic_scene(32, 32, 5)
    via_bytes = service.extract(encode_tile(tile), ("harris",), timeout=60)
    service.register_scene("granule-5", tile)
    via_id = service.extract("granule-5", ("harris",), timeout=60)
    assert_results_equal(via_bytes.results, via_id.results)
    with pytest.raises(KeyError):
        service.submit("nope", ("harris",))


def test_scene_request_splits_and_merges(service):
    """Oversize image → largest-bucket tiles, merged with the batch job's
    reduce; bit-identical to the jitted per-tile reference."""
    scene = synthetic_scene(70, 70, 9)
    cfg = service.table.cfg_for(32)
    b = tile_scene(scene, cfg)
    fn = jax.jit(functools.partial(engine.extract_request_features,
                                   algorithms=("harris",), cfg=cfg))
    per = {k: np.asarray(v)
           for k, v in fn(b.tiles, b.headers)["harris"].items()}
    want = DifetJob._merge([{k: v[i] for k, v in per.items()}
                            for i in range(len(b))])
    r = service.submit(scene, "harris").result(60)
    assert r.n_tiles == len(b) == 9
    assert_results_equal({"harris": want}, r.results)


def test_algorithm_order_canonicalized_one_program():
    """Permuted algorithm lists share one compiled program and batch
    group; the response still reports the request's order."""
    svc = make_service(max_batch=4, cache_entries=64)
    try:
        r1 = svc.extract(synthetic_scene(32, 32, 200),
                         ("shi_tomasi", "harris"), timeout=60)
        r2 = svc.extract(synthetic_scene(32, 32, 201),
                         ("harris", "shi_tomasi"), timeout=60)
        assert r1.algorithms == ("shi_tomasi", "harris")
        assert r2.algorithms == ("harris", "shi_tomasi")
        assert svc.compile_cache.keys() == [(32, ("harris", "shi_tomasi"))]
        assert_results_equal(
            _direct(svc.table, synthetic_scene(32, 32, 200),
                    ("shi_tomasi", "harris")), r1.results)
    finally:
        svc.close()


def test_warmup_compiles_each_pair_exactly_once():
    svc = make_service(max_batch=2, cache_entries=0)
    try:
        assert svc.warmup([("harris",)]) == 1
        assert svc.warmup([("harris",)]) == 1     # idempotent
        for s in range(3):
            svc.extract(synthetic_scene(32, 32, s), ("harris",), timeout=60)
        assert svc.compile_cache.programs == 1    # traffic added no programs
        assert svc.compile_cache.keys() == [(32, ("harris",))]
    finally:
        svc.close()


# ---- determinism -----------------------------------------------------------

def test_arrival_order_determinism():
    """The same request set in different arrival orders (different batch
    partitions) yields bit-identical per-request results."""
    tiles = [synthetic_scene(32, 32, 40 + s) for s in range(10)]
    orders = [list(range(10)), [9, 3, 1, 7, 5, 0, 8, 2, 6, 4]]
    outcomes = []
    for order in orders:
        svc = make_service(max_batch=4, cache_entries=0)
        try:
            handles = {i: svc.submit(tiles[i], ("harris",)) for i in order}
            outcomes.append({i: handles[i].result(60).results
                             for i in order})
        finally:
            svc.close()
    for i in range(10):
        assert_results_equal(outcomes[0][i], outcomes[1][i])


# ---- latency accounting -----------------------------------------------------

def test_open_loop_latency_not_inflated_by_drain_order():
    """Reported latency is the batch-completion stamp minus enqueue — not
    when ``result()`` got around to being called.  An open-loop client
    injects everything up front, waits out the whole run, then drains in
    submit order; the first request's latency must reflect its (first,
    fast) batch, not the drain delay."""
    import time

    svc = make_service(max_batch=1, cache_entries=0)
    try:
        svc.warmup([("harris",)])
        delay = 0.08
        orig = svc._run_batch

        def slow(bucket, algs, items):        # fixed per-batch service time
            time.sleep(delay)
            orig(bucket, algs, items)

        svc.scheduler._run_batch = slow
        # inject faster than service: all 4 submitted before batch 1 ends
        tiles = [synthetic_scene(32, 32, 400 + s) for s in range(4)]
        submit_t0 = time.perf_counter()
        handles = [svc.submit(t, ("harris",)) for t in tiles]
        while not all(h.done() for h in handles):
            time.sleep(0.01)
        time.sleep(0.3)                       # the drain wait under test
        lats = [h.result(60).timing["latency_s"] for h in handles]
        drain_wall = time.perf_counter() - submit_t0
        # every request completed long before result() was called...
        assert drain_wall > 0.3
        # ...and the first request's latency is ~one service time, far
        # below the drain wall (pre-fix it equaled drain_wall)
        assert lats[0] < 0.3 < drain_wall
        # later queue positions waited behind earlier batches
        assert lats[-1] >= lats[0]
        for r in [h.result(60) for h in handles]:
            assert r.timing["completed_at"] >= r.timing["enqueued_at"]
    finally:
        svc.close()


def test_fully_cached_response_reports_zero_queue_latency():
    """A request served entirely from the result cache never touched the
    device; its completion stamp is its enqueue stamp."""
    svc = make_service(max_batch=2, cache_entries=64)
    try:
        tile = synthetic_scene(32, 32, 900)
        svc.extract(tile, ("harris",), timeout=60)
        r = svc.extract(tile, ("harris",), timeout=60)
        assert r.fully_cached
        assert r.timing["completed_at"] == r.timing["enqueued_at"]
        assert r.timing["latency_s"] == 0.0
    finally:
        svc.close()


# ---- scheduler: backpressure + coalescing ----------------------------------

def test_scheduler_backpressure():
    release = threading.Event()
    done = []

    def blocking_runner(bucket, algs, items):
        release.wait(30)
        for it in items:
            it.future.set_result(("ok", it.batch_size))
            done.append(it.seq)

    sched = BatchScheduler(blocking_runner, max_batch=1,
                           max_batch_delay_s=0.0, max_pending=2)
    tile = np.zeros((4, 4), np.float32)
    header = np.zeros((6,), np.int32)
    futures, rejected = [], 0
    for _ in range(6):
        try:
            futures.append(sched.submit(tile, header, 4, ("harris",)))
        except ServiceOverloaded:
            rejected += 1
    assert rejected >= 1                      # queue bounded, load shed
    assert sched.stats()["rejected"] == rejected
    release.set()
    for f in futures:
        assert f.result(30)[0] == "ok"        # accepted work still completes
    sched.stop(10)


def test_concurrent_identical_requests_coalesce():
    """Two in-flight requests for the same (tile, algorithms) share one
    device computation."""
    svc = make_service(max_batch=4, cache_entries=128)
    try:
        svc.warmup([("harris",)])
        tile = synthetic_scene(32, 32, 314)
        h1 = svc.submit(tile, ("harris",))
        h2 = svc.submit(tile, ("harris",))
        r1, r2 = h1.result(60), h2.result(60)
        assert_results_equal(r1.results, r2.results)
        assert svc.scheduler.items == 1       # one WorkItem served both
    finally:
        svc.close()


def test_identical_tiles_at_different_positions_never_alias():
    """Results carry scene-global coordinates (ys = ty·tile + local), so
    pixel-identical tiles at different grid positions have different
    correct outputs: the cache/coalescing key must fold the header's
    position, or the second position is served the first one's
    coordinates."""
    svc = make_service(cache_entries=128)
    try:
        svc.warmup([("harris",)])
        gray = synthetic_scene(32, 32, seed=99)
        tile, header0 = svc.table.pad_to_bucket(gray, 32)
        header1 = header0.copy()
        header1[1], header1[2] = 2, 3          # same pixels, grid (2, 3)
        cfgd = svc._cfg_digest(32)

        def run(header):
            part = svc._submit_tile(tile, header, 32, ("harris",), cfgd,
                                    block=True)
            res = dict(part.cached)
            if part.future is not None:
                computed, _, _ = part.future.result(60)
                res.update(computed)
            return res["harris"]

        r0, r1 = run(header0), run(header1)
        valid = np.asarray(r0["top_valid"]).astype(bool)
        assert valid.any()
        t = svc.table.cfg_for(32).tile
        # position must be baked into the coordinates, not aliased away
        np.testing.assert_array_equal(
            np.asarray(r1["top_ys"])[valid],
            np.asarray(r0["top_ys"])[valid] + 2 * t)
        np.testing.assert_array_equal(
            np.asarray(r1["top_xs"])[valid],
            np.asarray(r0["top_xs"])[valid] + 3 * t)
    finally:
        svc.close()


# ---- shutdown + burst-overflow regressions (fleet PR satellites) -----------

def test_stop_wakes_blocked_submitters():
    """A submitter parked on backpressure must be woken by stop() and get
    a clean ServiceClosed — not hang on the condition variable (the
    busy-wait used to re-check only queue room, never closure)."""
    release = threading.Event()

    def runner(bucket, algs, items):
        release.wait(30)
        for it in items:
            it.future.set_result("ok")

    sched = BatchScheduler(runner, max_batch=1, max_batch_delay_s=0.0,
                           max_pending=1)
    tile = np.zeros((4, 4), np.float32)
    header = np.zeros((6,), np.int32)
    f1 = sched.submit(tile, header, 4, ("harris",))
    deadline = time.monotonic() + 10
    while sched.queue_depth and time.monotonic() < deadline:
        time.sleep(0.001)                 # runner took f1 (blocked in step)
    f2 = sched.submit(tile, header, 4, ("harris",))   # queue now full
    woke = []

    def blocked_submitter():
        try:
            sched.submit(tile, header, 4, ("harris",), block=True,
                         timeout=30)
        except ServiceClosed as e:
            woke.append(e)

    t = threading.Thread(target=blocked_submitter)
    t.start()
    time.sleep(0.1)                       # let it park on the cv
    sched.stop(timeout=0.1)               # runner still blocked: just flag
    t.join(5)
    assert not t.is_alive(), "blocked submitter hung across stop()"
    assert len(woke) == 1                 # clean typed wake-up
    with pytest.raises(ServiceClosed):
        sched.submit(tile, header, 4, ("harris",))    # post-stop submit
    release.set()
    assert f1.result(30) == "ok"          # accepted work still completes
    assert f2.result(30) == "ok"
    sched.stop(10)


def test_burst_overflow_sheds_under_concurrent_submitters():
    """A synchronized burst from many client threads against a tiny
    pending bound: overflow is shed (counted per service), every accepted
    request completes, and nothing is double-counted."""
    base = DifetConfig(tile=32, halo=8, max_keypoints_per_tile=16)
    step_lock = threading.Lock()
    svc = FeatureService(ServeConfig(
        base=base, buckets=(32,), max_batch=4, max_batch_delay_s=0.001,
        max_pending=8, cache_entries=0), step_lock=step_lock)
    try:
        svc.warmup([("harris",)])
        tiles = [synthetic_scene(32, 32, 500 + i) for i in range(48)]
        handles, sheds, lock = [], [], threading.Lock()

        def client(chunk):
            for tile in chunk:
                try:
                    h = svc.submit(tile, ("harris",))
                except ServiceOverloaded:
                    with lock:
                        sheds.append(1)
                else:
                    with lock:
                        handles.append(h)

        with step_lock:                   # device stalled: queue must fill
            threads = [threading.Thread(target=client,
                                        args=(tiles[i::8],))
                       for i in range(8)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        assert len(sheds) >= 1            # the burst overflowed the bound
        assert len(handles) + len(sheds) == len(tiles)
        assert svc.shed == len(sheds)
        assert svc.requests == len(handles)
        for h in handles:                 # accepted work all completes
            r = h.result(60)
            assert int(r.results["harris"]["total_count"]) >= 0
    finally:
        svc.close()


def test_service_stats_flat_snapshot():
    """The per-replica counters the fleet router aggregates: flat keys,
    cheap to poll, consistent with the traffic just served."""
    svc = make_service(max_batch=4, cache_entries=64)
    try:
        svc.warmup([("harris",)])
        tile = synthetic_scene(32, 32, 907)
        svc.submit(tile, ("harris",), block=True).result(60)
        svc.submit(tile, ("harris",), block=True).result(60)   # cache hit
        s = svc.stats()
        for key in ("name", "submitted", "shed", "cache_hits",
                    "cache_misses", "queue_depth", "batches",
                    "batch_occupancy", "p50_queue_ms", "p99_queue_ms",
                    "busy_s", "steps"):
            assert key in s, key
        assert s["submitted"] == 2 and s["shed"] == 0
        assert s["cache_hits"] >= 1 and s["cache_misses"] >= 1
        assert s["steps"] >= 1 and s["busy_s"] > 0.0
        assert 0.0 < s["batch_occupancy"] <= 1.0
        assert s["p99_queue_ms"] >= s["p50_queue_ms"] >= 0.0
    finally:
        svc.close()


def test_work_item_settlement_is_idempotent_first_wins():
    """Regression: ``stop()``/``kill()`` racing an in-flight
    ``_run_batch`` used to double-resolve a future through ad-hoc
    ``done()``-then-set guards.  `WorkItem.resolve`/`WorkItem.fail` are
    the only settlement paths now: exactly one caller wins, losers are
    no-ops, and many racing threads agree on the outcome."""
    from concurrent.futures import Future

    from repro.serve import ReplicaDied, WorkItem

    def item():
        return WorkItem(seq=0, tile=np.zeros((32, 32), np.float32),
                        header=np.zeros(6, np.int32), bucket=32,
                        algorithms=("harris",), digest="d",
                        cfg_digest="c", future=Future())

    # sequential: the second settlement (either kind) is a no-op
    it = item()
    assert it.resolve("first") and not it.resolve("second")
    assert not it.fail(ReplicaDied("late kill"))
    assert it.future.result(0) == "first"
    it = item()
    assert it.fail(ReplicaDied("kill won")) and not it.resolve("late batch")
    with pytest.raises(ReplicaDied):
        it.future.result(0)

    # concurrent: N resolvers vs N failers on one item — exactly one
    # winner, the future holds exactly that side's outcome
    for trial in range(20):
        it = item()
        start = threading.Barrier(8)
        wins = []

        def run(op, tag):
            start.wait()
            if op():
                wins.append(tag)
        threads = (
            [threading.Thread(target=run,
                              args=((lambda i=i: it.resolve(f"r{i}")),
                                    "resolve")) for i in range(4)] +
            [threading.Thread(target=run,
                              args=((lambda i=i: it.fail(
                                  ReplicaDied(f"f{i}"))),
                                    "fail")) for i in range(4)])
        for t in threads:
            t.start()
        for t in threads:
            t.join(10)
        assert len(wins) == 1, wins
        if wins[0] == "resolve":
            assert str(it.future.result(0)).startswith("r")
        else:
            with pytest.raises(ReplicaDied):
                it.future.result(0)


def test_scheduler_kill_vs_completion_race_single_outcome():
    """Scheduler-level settle race: ``kill()`` fired while a batch is
    mid-flight.  Whichever side wins, every accepted future settles
    exactly once — a result bit-identical to the direct path, or
    ``ReplicaDied`` — and never hangs or raises InvalidStateError."""
    release = threading.Event()

    def slow_runner(bucket, algorithms, batch):
        release.wait(10)
        for it in batch:
            it.resolve({"ok": it.seq})

    sched = BatchScheduler(slow_runner, max_batch=4,
                           max_batch_delay_s=0.001, max_pending=64,
                           name="settle-race")
    futs = [sched.submit(np.zeros((32, 32), np.float32), np.zeros(6),
                         32, ("harris",)) for _ in range(4)]
    deadline = time.monotonic() + 5.0
    while not sched._active and time.monotonic() < deadline:
        time.sleep(0.002)                 # batch now on-device
    killer = threading.Thread(target=sched.kill)
    killer.start()
    release.set()                         # completion races the kill
    killer.join(10)
    outcomes = []
    for f in futs:
        try:
            outcomes.append(("ok", f.result(10)))
        except Exception as e:  # noqa: BLE001
            outcomes.append(("died", type(e).__name__))
    assert len(outcomes) == 4             # every future settled, none hung
    for kind, val in outcomes:
        assert kind in ("ok", "died")
        if kind == "died":
            assert val == "ReplicaDied"
