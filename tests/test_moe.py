"""MoE dispatch invariants + dense-equivalence oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as M


def make_cfg(e=4, k=2, cf=8.0):
    return ModelConfig(
        arch_id="test-moe", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=64,
        moe=MoEConfig(n_experts=e, n_experts_per_tok=k, d_ff_expert=48,
                      capacity_factor=cf))


def dense_oracle(p, cfg, x):
    """Brute force: every token through every expert, weighted by gates."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    gates, idx, _ = M.route(p, cfg, xt)
    g = jnp.einsum("ecd,edf->ecf", jnp.broadcast_to(
        xt[None], (cfg.moe.n_experts, *xt.shape)), p["wi"])
    u = jnp.einsum("ecd,edf->ecf", jnp.broadcast_to(
        xt[None], (cfg.moe.n_experts, *xt.shape)), p["wu"])
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), p["wo"])  # [E,T,d]
    w = jnp.zeros((xt.shape[0], cfg.moe.n_experts))
    w = w.at[jnp.arange(xt.shape[0])[:, None], idx].set(gates)
    out = jnp.einsum("te,etd->td", w.astype(x.dtype), ye)
    return out.reshape(b, s, d)


def test_moe_matches_dense_oracle_when_no_drops():
    cfg = make_cfg(cf=8.0)   # capacity huge -> nothing dropped
    p = M.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 8, 32), jnp.float32)
    y, aux = M.moe_apply(p, cfg, x)
    y_ref = dense_oracle(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0.0


def test_route_gates_normalized():
    cfg = make_cfg()
    p = M.moe_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(1).randn(16, 32), jnp.float32)
    gates, idx, aux = M.route(p, cfg, x)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.moe.n_experts
    # top-k indices unique per token
    assert all(len(set(row)) == len(row) for row in np.asarray(idx))


def test_capacity_drops_are_bounded():
    cfg = make_cfg(cf=0.25)   # tiny capacity -> drops must not corrupt output
    p = M.moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jnp.asarray(np.random.RandomState(2).randn(2, 32, 32), jnp.float32)
    y, _ = M.moe_apply(p, cfg, x)
    assert bool(jnp.isfinite(y).all())
    # dropped tokens produce zero output, so norm is <= the no-drop output
    cfg_big = make_cfg(cf=8.0)
    y_full, _ = M.moe_apply(p, cfg_big, x)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-3


def test_shared_experts_added():
    cfg = make_cfg()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, n_shared_experts=1))
    p = M.moe_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    assert "shared" in p
    x = jnp.asarray(np.random.RandomState(3).randn(1, 4, 32), jnp.float32)
    y, _ = M.moe_apply(p, cfg, x)
    assert bool(jnp.isfinite(y).all())


def test_capacity_formula():
    cfg = make_cfg(e=8, k=2, cf=1.0)
    c = M.capacity(cfg, 1024)
    assert c >= 1024 * 2 // 8
    assert c % 8 == 0
