"""Reusable chaos/fault-injection harness for the fleet tests.

The fault *primitives* live in the library (`repro/serve/chaos.py`) so
that CI tests and the command-line chaos driver
(``launch/fleet.py --kill-after``) exercise the same code paths; this
module is the pytest-side veneer: it re-exports those primitives and
adds the polling / parity helpers every chaos test needs.

Fault surface (see each primitive's docstring):

* ``sigkill(pid)``            — real ``kill -9``, no cleanup
* ``cache_partition(path)``   — chmod-000 a shared directory for a block
* ``tear_file(path)``         — truncate a committed file in place
* ``ChaosPlan`` + ``write_plan``/``clear_plan`` — in-band worker faults
  (stalled heartbeats, withheld responses, self-``kill -9`` after N
  responses), re-read by the `serve/proc.py` worker every loop

All faults are deterministic: tests pick the exact span where a fault
lands, never a random schedule.
"""
import functools
import time

import numpy as np

import jax

from repro.configs.difet_paper import DifetConfig
from repro.core import engine
from repro.serve.api import FeatureService, ServeConfig
from repro.serve.chaos import (ChaosPlan, cache_partition, clear_plan,  # noqa: F401
                               read_plan, sigkill, tear_file, write_plan)

__all__ = ["ChaosPlan", "write_plan", "read_plan", "clear_plan",
           "sigkill", "cache_partition", "tear_file",
           "wait_until", "direct_extract", "assert_results_equal"]


def wait_until(pred, timeout: float = 10.0, interval: float = 0.02,
               desc: str = "condition"):
    """Poll ``pred`` until truthy; return its value.  Raises
    ``AssertionError`` (not TimeoutError — this is a test harness) with
    ``desc`` if the deadline passes first."""
    deadline = time.monotonic() + timeout
    while True:
        val = pred()
        if val:
            return val
        if time.monotonic() > deadline:
            raise AssertionError(f"timed out after {timeout}s "
                                 f"waiting for {desc}")
        time.sleep(interval)


def direct_extract(gray, algorithms=("harris",), *, base=None):
    """Unrouted, unserved reference extraction: jitted
    ``extract_features_multi`` on the bucket-padded tile.  Every chaos
    test's parity oracle — a served/re-admitted/recovered result must
    match this bitwise."""
    base = base or DifetConfig(tile=32, halo=8, max_keypoints_per_tile=16)
    svc = FeatureService(ServeConfig(base=base, buckets=(gray.shape[0],)))
    try:
        bucket = svc.table.bucket_for(*gray.shape)
        tile, header = svc.table.pad_to_bucket(gray, bucket)
        fn = jax.jit(functools.partial(engine.extract_features_multi,
                                       algorithms=tuple(sorted(algorithms)),
                                       cfg=svc.table.cfg_for(bucket)))
        return {alg: {k: np.asarray(v) for k, v in res.items()}
                for alg, res in fn(tile[None], header[None]).items()}
    finally:
        svc.close()


def assert_results_equal(a, b):
    """Bitwise parity over two per-algorithm feature dicts: same keys,
    same shapes/dtypes, identical values."""
    assert set(a) == set(b)
    for alg in a:
        assert set(a[alg]) == set(b[alg])
        for k in a[alg]:
            x, y = np.asarray(a[alg][k]), np.asarray(b[alg][k])
            assert x.shape == y.shape and x.dtype == y.dtype, (alg, k)
            assert np.array_equal(x, y), (alg, k)
