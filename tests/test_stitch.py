"""Stitching workload: end-to-end registration accuracy, checkpointed
restart of the match phase, and the mosaic layout solve."""
import numpy as np
import pytest

from repro.core import mosaic
from repro.launch import stitch

ARGS = ["--scenes", "3", "--scene-size", "256", "--overlap", "128",
        "--tile", "64", "--algorithm", "brief", "--min-inliers", "8"]


def test_stitch_end_to_end_and_restart(tmp_path):
    """`python -m repro.launch.stitch` on known-shift synthetic scenes must
    recover every pairwise offset to sub-pixel accuracy and place all
    scenes; a second invocation resumes from the store (no recompute) and
    reproduces the layout exactly."""
    args = ARGS + ["--store", str(tmp_path / "s")]
    out = stitch.main(args)
    assert out["max_err"] is not None and out["max_err"] <= 1.0
    assert len(out["positions"]) == 3
    assert not out["dropped"]
    # deterministic resume: results come from committed store artifacts
    out2 = stitch.main(args)
    assert out2["positions"] == out["positions"]
    assert out2["pairs"] == out["pairs"]


def test_stitch_match_phase_restart_after_failure(tmp_path):
    """Kill the match phase after its first chunk; the same command must
    resume and finish (the ManifestJob guarantee, extraction + matching)."""
    args = ARGS + ["--store", str(tmp_path / "s"), "--pairs-per-step", "1"]
    with pytest.raises(SystemExit):
        stitch.main(args + ["--fail-after", "1"])
    out = stitch.main(args)
    assert out["max_err"] is not None and out["max_err"] <= 1.0
    assert len(out["positions"]) == 3


def test_solve_layout_drops_unverified_pairs():
    names = ["a", "b", "c"]
    results = {
        ("a", "b"): {"t": np.array([0.0, -10.0]), "n_inliers": 50},
        ("b", "c"): {"t": np.array([2.0, -20.0]), "n_inliers": 3},  # weak
    }
    pos, dropped = mosaic.solve_layout(names, results, min_inliers=8)
    assert dropped == [("b", "c")]
    assert set(pos) == {"a", "b"}          # c unreachable
    np.testing.assert_allclose(pos["b"], [0.0, 10.0])
    summary = mosaic.mosaic_summary(pos, (100, 100))
    assert summary["n_scenes"] == 2
    assert summary["mosaic_hw"] == (100, 110)


def test_solve_layout_chain_propagation():
    names = [f"s{i}" for i in range(4)]
    results = {(names[i], names[i + 1]):
               {"t": np.array([float(i), -64.0]), "n_inliers": 20}
               for i in range(3)}
    pos, dropped = mosaic.solve_layout(names, results)
    assert not dropped and len(pos) == 4
    np.testing.assert_allclose(pos["s3"], [-(0 + 1 + 2), 3 * 64.0])
