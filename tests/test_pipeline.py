"""Streaming ingest: tiler bit-parity, ragged/odd scenes, band stripes,
prefetcher error/shutdown, and sliced-batch coverage."""
import threading
import time

import numpy as np
import pytest

from repro.configs.difet_paper import DifetConfig
from repro.core.bundle import bundle_scenes, rgba_to_gray, tile_scene
from repro.data.landsat import (ArraySceneReader, BandSceneReader,
                                synthetic_scene, synthetic_scene_rgba,
                                write_scene_bands)
from repro.data.pipeline import (Prefetcher, StreamTiler, batch_slices,
                                 count_batches, iter_scene_tiles,
                                 iter_tile_batches, reflect_indices)

CFG = DifetConfig(tile=64, halo=16, max_keypoints_per_tile=32)


def stream_all(reader, cfg=CFG, scene_id=0, stripe_rows=None):
    pairs = list(iter_scene_tiles(reader, cfg, scene_id, stripe_rows))
    tiles = np.stack([t for t, _ in pairs])
    headers = np.asarray([h for _, h in pairs], np.int32)
    return tiles, headers


def test_reflect_indices_match_np_pad():
    rng = np.random.RandomState(0)
    for n, before, after in [(7, 3, 5), (64, 16, 16), (5, 0, 7),
                             (1, 2, 2), (3, 4, 4), (100, 16, 44)]:
        x = rng.rand(n).astype(np.float32)
        idx = reflect_indices(n, before, after)
        np.testing.assert_array_equal(
            x[idx], np.pad(x, (before, after), mode="reflect"))


@pytest.mark.parametrize("hw", [(128, 128), (100, 120), (97, 131),
                                (64, 200), (30, 30), (65, 63)])
def test_stream_tiler_bit_identical_to_tile_scene(hw):
    """Odd, truncated-to-odd, and sub-tile scene sizes all round-trip
    bit-exactly through the streaming tiler."""
    gray = synthetic_scene(*hw, seed=3)
    eager = tile_scene(gray, CFG, scene_id=5)
    tiles, headers = stream_all(ArraySceneReader(gray), scene_id=5)
    np.testing.assert_array_equal(tiles, eager.tiles)
    np.testing.assert_array_equal(headers, eager.headers)


def test_stream_tiler_stripe_size_invariance():
    gray = synthetic_scene(130, 94, seed=1)
    ref = stream_all(ArraySceneReader(gray))
    for rows in (1, 7, 32, 500):
        got = stream_all(ArraySceneReader(gray), stripe_rows=rows)
        np.testing.assert_array_equal(got[0], ref[0])
        np.testing.assert_array_equal(got[1], ref[1])


def test_stream_tiler_rejects_truncated_and_overrun_scenes():
    tiler = StreamTiler(100, 80, CFG)
    tiler.feed(np.zeros((60, 80), np.float32))
    with pytest.raises(ValueError, match="truncated"):
        tiler.finish()                          # 40 rows never arrived
    with pytest.raises(ValueError, match="overruns"):
        tiler.feed(np.zeros((50, 80), np.float32))
    with pytest.raises(ValueError, match="width"):
        tiler.feed(np.zeros((10, 79), np.float32))


def test_band_reader_matches_eager_gray(tmp_path):
    rgba = synthetic_scene_rgba(90, 110, seed=2)
    d = write_scene_bands(tmp_path, "s0", rgba)
    reader = BandSceneReader(d)
    assert reader.shape == (90, 110)
    np.testing.assert_array_equal(reader.read_rows(0, 90),
                                  rgba_to_gray(rgba))
    # stripe reads agree with whole-scene reads
    np.testing.assert_array_equal(
        np.concatenate(list(reader.stripes(17))), rgba_to_gray(rgba))


def test_band_reader_band_count_and_shape_mismatch(tmp_path):
    import json
    d = write_scene_bands(tmp_path, "s1", synthetic_scene_rgba(40, 40))
    # drop a band: the manifest now names an incomplete set
    (d / "B3.npy").unlink()
    meta = json.loads((d / "scene.json").read_text())
    meta["bands"] = [b for b in meta["bands"] if b != "B3"]
    (d / "scene.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="band set"):
        BandSceneReader(d)
    # wrong-shape band
    d2 = write_scene_bands(tmp_path, "s2", synthetic_scene_rgba(40, 40))
    np.save(d2 / "B3.npy", np.zeros((40, 39), np.uint8))
    with pytest.raises(ValueError, match="shape"):
        BandSceneReader(d2)


def test_band_reader_truncated_file(tmp_path):
    d = write_scene_bands(tmp_path, "s3", synthetic_scene_rgba(64, 64))
    path = d / "B4.npy"
    path.write_bytes(path.read_bytes()[:200])   # cut the data section
    with pytest.raises(IOError, match="truncated or corrupt"):
        BandSceneReader(d)


def test_iter_tile_batches_matches_bundle_scenes(tmp_path):
    scenes = [synthetic_scene(100, 90, seed=i) for i in range(3)]
    eager = bundle_scenes(scenes, CFG)
    readers = [ArraySceneReader(s, f"s{i}") for i, s in enumerate(scenes)]
    batches = list(iter_tile_batches(readers, CFG, batch_tiles=4))
    assert [i for i, _ in batches] == list(range(len(batches)))
    tiles = np.concatenate([b.tiles for _, b in batches])
    headers = np.concatenate([b.headers for _, b in batches])
    # every batch is fixed-shape; the tail is pad-flagged
    assert all(len(b) == 4 for _, b in batches)
    n = len(eager)
    np.testing.assert_array_equal(tiles[:n], eager.tiles)
    np.testing.assert_array_equal(headers[:n], eager.headers)
    assert (headers[n:, 5] == 1).all()          # pad flag on the remainder


def test_batch_slices_cover_exactly():
    for n, w in [(8, 2), (7, 3), (5, 5), (9, 4), (3, 1)]:
        slices = batch_slices(n, w)
        assert len(slices) == w
        covered = [i for lo, hi in slices for i in range(lo, hi)]
        assert covered == list(range(n))


def test_sliced_batches_equal_full_stream():
    scenes = [synthetic_scene(100, 90, seed=i) for i in range(3)]
    readers = [ArraySceneReader(s, f"s{i}") for i, s in enumerate(scenes)]
    full = dict(iter_tile_batches(readers, CFG, batch_tiles=4))
    n = count_batches([r.shape for r in readers], CFG, 4)
    assert len(full) == n
    for w in (2, 3):
        got = {}
        for lo, hi in batch_slices(n, w):
            got.update(iter_tile_batches(readers, CFG, 4,
                                         start=lo, stop=hi))
        assert got.keys() == full.keys()
        for i in full:
            np.testing.assert_array_equal(got[i].tiles, full[i].tiles)
            np.testing.assert_array_equal(got[i].headers, full[i].headers)


def test_sliced_batches_skip_unneeded_scenes():
    class CountingReader(ArraySceneReader):
        reads = 0

        def read_rows(self, y0, y1):
            CountingReader.reads += 1
            return super().read_rows(y0, y1)

    scenes = [synthetic_scene(128, 128, seed=i) for i in range(4)]
    readers = [CountingReader(s, f"s{i}") for i, s in enumerate(scenes)]
    n = count_batches([r.shape for r in readers], CFG, 4)
    # the first worker's slice must not touch the last scene
    lo, hi = batch_slices(n, 2)[0]
    CountingReader.reads = 0
    list(iter_tile_batches(readers, CFG, 4, start=lo, stop=hi))
    reads_slice = CountingReader.reads
    CountingReader.reads = 0
    list(iter_tile_batches(readers, CFG, 4))
    assert reads_slice < CountingReader.reads


def test_prefetcher_yields_everything_in_order():
    with Prefetcher(iter(range(20)), depth=2) as pf:
        assert list(pf) == list(range(20))


def test_prefetcher_propagates_producer_error():
    def boom():
        yield 1
        yield 2
        raise IOError("scene truncated mid-stream")

    pf = Prefetcher(boom(), depth=2)
    got = []
    with pytest.raises(IOError, match="truncated mid-stream"):
        for x in pf:
            got.append(x)
    assert got == [1, 2]
    pf.close()


def test_prefetcher_close_unblocks_producer():
    """A consumer abandoning iteration must not leave the producer thread
    wedged on a full queue."""
    produced = []

    def slow_infinite():
        i = 0
        while True:
            produced.append(i)
            yield i
            i += 1

    pf = Prefetcher(slow_infinite(), depth=2)
    assert next(pf) == 0
    pf.close()
    assert not pf._thread.is_alive()
    # producer stopped near the queue depth, not unboundedly
    assert len(produced) <= 8


def test_prefetcher_error_in_first_item():
    def bad():
        raise ValueError("no scenes")
        yield  # noqa: unreachable — makes this a generator

    with pytest.raises(ValueError, match="no scenes"):
        next(Prefetcher(bad(), depth=1))


def test_pipelined_extraction_bit_identical_to_eager(tmp_path):
    """The acceptance property: streaming + batching + worker slicing
    changes nothing about extraction output."""
    import jax
    from repro.core.engine import extract_features_multi
    scenes = [synthetic_scene_rgba(100, 90, seed=i) for i in range(2)]
    dirs = [write_scene_bands(tmp_path, f"s{i}", s)
            for i, s in enumerate(scenes)]
    readers = [BandSceneReader(d) for d in dirs]
    eager = bundle_scenes(scenes, CFG)
    algs = ("harris", "fast")
    fn = jax.jit(lambda t, h: extract_features_multi(t, h, algs, CFG))
    # eager reference, batch by batch over the same flat order
    n_b = count_batches([r.shape for r in readers], CFG, 4)
    ref = {}
    padded = eager.pad_to(n_b * 4)
    for i in range(n_b):
        s = slice(i * 4, (i + 1) * 4)
        ref[i] = jax.device_get(fn(padded.tiles[s], padded.headers[s]))
    for w in (1, 2):
        got = {}
        for lo, hi in batch_slices(n_b, w):
            with Prefetcher(iter_tile_batches(readers, CFG, 4,
                                              start=lo, stop=hi)) as pf:
                for idx, bundle in pf:
                    got[idx] = jax.device_get(fn(bundle.tiles,
                                                 bundle.headers))
        assert got.keys() == ref.keys()
        for i in ref:
            for alg in algs:
                for k in ref[i][alg]:
                    np.testing.assert_array_equal(
                        np.asarray(got[i][alg][k]),
                        np.asarray(ref[i][alg][k]), err_msg=f"{i}/{alg}/{k}")


def test_prefetcher_device_put_stages_batches():
    """device_put staging with a (tiles, headers) sharding pair handles
    the (index, TileBundle) tuples the batch iterator yields."""
    import jax
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import batch_pspec, data_mesh
    scenes = [synthetic_scene(100, 90, seed=0)]
    readers = [ArraySceneReader(scenes[0], "s0")]
    mesh = data_mesh(1)
    pair = (NamedSharding(mesh, batch_pspec(mesh, 3)),
            NamedSharding(mesh, batch_pspec(mesh, 2)))
    ref = dict(iter_tile_batches(readers, CFG, 4))
    with Prefetcher(iter_tile_batches(readers, CFG, 4), depth=2,
                    device_put=True, sharding=pair) as pf:
        for idx, bundle in pf:
            assert isinstance(bundle.tiles, jax.Array)
            assert isinstance(bundle.headers, jax.Array)
            np.testing.assert_array_equal(np.asarray(bundle.tiles),
                                          ref[idx].tiles)
            np.testing.assert_array_equal(np.asarray(bundle.headers),
                                          ref[idx].headers)


def test_sliced_batches_stop_reading_after_slice():
    """A worker slice ending mid-scene must not stream the boundary
    scene's remaining stripes."""
    class CountingReader(ArraySceneReader):
        reads = 0

        def read_rows(self, y0, y1):
            CountingReader.reads += 1
            return super().read_rows(y0, y1)

    # one tall scene, 1-row stripes: reads past the slice are visible
    reader = CountingReader(synthetic_scene(64 * 6, 64, seed=0), "s0")
    n = count_batches([reader.shape], CFG, 2)
    assert n == 3
    CountingReader.reads = 0
    list(iter_tile_batches([reader], CFG, 2, stripe_rows=1,
                           start=0, stop=1))
    reads_first = CountingReader.reads
    CountingReader.reads = 0
    list(iter_tile_batches([reader], CFG, 2, stripe_rows=1))
    assert reads_first < CountingReader.reads / 2


def test_prefetcher_overlaps_producer_and_consumer():
    """With depth 2 the producer runs ahead while the consumer works."""
    seen_ahead = []

    def producer():
        for i in range(6):
            yield i

    pf = Prefetcher(producer(), depth=2)
    time.sleep(0.2)                 # give the thread time to fill the queue
    seen_ahead.append(pf._q.qsize())
    assert list(pf) == list(range(6))
    assert seen_ahead[0] >= 1       # at least one batch was staged ahead
