"""Matcher subsystem: kernel-vs-oracle parity (interpret mode), mutual-NN
+ ratio filtering, RANSAC recovery, and partition invariance of matching
(the interior-ownership guarantee extended to the new subsystem)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import matching
from repro.kernels import ops, ref

SHAPES = [(37, 53), (64, 128), (130, 300), (257, 511)]


def packed(n, seed, words=8):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randint(0, 2 ** 32, size=(n, words),
                                   dtype=np.uint64).astype(np.uint32))


def floats(n, seed, d=128):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n, d).astype(np.float32))


def mask(n, seed, frac=0.8):
    return jnp.asarray(np.random.RandomState(seed).rand(n) < frac)


@pytest.mark.parametrize("nq,nk", SHAPES)
def test_hamming_kernel_bit_identical_to_oracle(nq, nk):
    """Pallas kernel (interpret), jnp fallback, and the bit-unpacked oracle
    must agree EXACTLY — integer distances leave no tolerance."""
    q, db, v = packed(nq, 0), packed(nk, 1), mask(nk, 2)
    o = ref.match_best2(q, db, v, metric="hamming")
    p = ops.match_best2(q, db, v, metric="hamming", use_pallas=True,
                        interpret=True)
    f = ops.match_best2(q, db, v, metric="hamming")
    for got, name in ((p, "pallas"), (f, "fallback")):
        for a, b in zip(got, o):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


@pytest.mark.parametrize("nq,nk", SHAPES[:3])
@pytest.mark.parametrize("d", [64, 128])
def test_l2_kernel_matches_oracle(nq, nk, d):
    q, db, v = floats(nq, 0, d), floats(nk, 1, d), mask(nk, 2)
    ob, os_, oi = ref.match_best2(q, db, v, metric="l2")
    for use_pallas in (True, False):
        b, s, i = ops.match_best2(q, db, v, metric="l2",
                                  use_pallas=use_pallas, interpret=True)
        np.testing.assert_allclose(np.asarray(b), np.asarray(ob),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s), np.asarray(os_),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(oi))


def test_all_invalid_database_matches_nothing():
    q, db = packed(10, 0), packed(20, 1)
    none = jnp.zeros((20,), jnp.bool_)
    m = matching.match_pair(q, jnp.ones((10,), jnp.bool_), db, none)
    assert not bool(np.asarray(m.ok).any())


def test_match_pair_mutual_and_ratio():
    """db[4] duplicates db[0] -> query 0's best/second tie -> ratio rejects;
    unique counterparts match; queries with no counterpart don't."""
    da = packed(6, 3)
    db = jnp.concatenate([da[:4], da[0:1]], axis=0)
    va = jnp.ones((6,), jnp.bool_)
    vb = jnp.ones((5,), jnp.bool_)
    m = matching.match_pair(da, va, db, vb, 0.9)
    ok = np.asarray(m.ok)
    idx = np.asarray(m.idx_b)
    assert not ok[0]                       # exact duplicate -> tie -> rejected
    assert ok[1] and ok[2] and ok[3]
    assert list(idx[1:4]) == [1, 2, 3]
    assert not ok[4] and not ok[5]         # no counterpart in db


def test_ransac_translation_recovers_shift():
    rng = np.random.RandomState(7)
    k = 400
    pa = rng.rand(k, 2).astype(np.float32) * 500
    t_true = np.array([-42.0, 117.0], np.float32)
    pb = pa + t_true
    out = rng.rand(k) < 0.4                # 40% gross outliers
    pb[out] += rng.randn(out.sum(), 2) * 90 + 15
    ok = rng.rand(k) < 0.85
    est = matching.estimate_translation(jnp.asarray(pa), jnp.asarray(pb),
                                        jnp.asarray(ok))
    np.testing.assert_allclose(np.asarray(est.t), t_true, atol=1e-3)
    assert int(est.n_inliers) > 100
    assert float(est.rms) < 0.1


def test_ransac_translation_no_valid_matches():
    pa = jnp.zeros((16, 2), jnp.float32)
    est = matching.estimate_translation(pa, pa + 3.0,
                                        jnp.zeros((16,), jnp.bool_))
    assert int(est.n_inliers) == 0


def test_ransac_similarity_recovers_scale_rotation():
    rng = np.random.RandomState(11)
    k = 400
    pa = rng.rand(k, 2).astype(np.float32) * 300
    z = 1.25 * np.exp(1j * 0.4)
    ca = pa[:, 1] + 1j * pa[:, 0]
    cb = z * ca + (30.0 - 14.0j)           # t = (ty, tx) = (-14, 30)
    pb = np.stack([cb.imag, cb.real], -1).astype(np.float32)
    out = rng.rand(k) < 0.3
    pb[out] += rng.randn(out.sum(), 2) * 60
    est = matching.estimate_similarity(jnp.asarray(pa), jnp.asarray(pb),
                                       jnp.asarray(~out))
    assert abs(float(est.scale) - 1.25) < 1e-3
    assert abs(float(est.theta) - 0.4) < 1e-3
    np.testing.assert_allclose(np.asarray(est.t), [-14.0, 30.0], atol=1e-2)


def test_register_pair_vmappable():
    """The batched registration used by MatchPhase: vmap over a pair axis."""
    rng = np.random.RandomState(0)
    p, k = 3, 64
    ys = jnp.asarray(rng.randint(0, 200, (p, k)).astype(np.float32))
    xs = jnp.asarray(rng.randint(0, 200, (p, k)).astype(np.float32))
    desc = jnp.asarray(rng.randint(0, 2 ** 32, size=(p, k, 8),
                                   dtype=np.uint64).astype(np.uint32))
    valid = jnp.ones((p, k), jnp.bool_)
    keys = jax.random.split(jax.random.PRNGKey(0), p)

    def one(ya, xa, da, va, key):
        m, est = matching.register_pair(ya, xa, da, va, ya + 5.0, xa - 9.0,
                                        da, va, key)
        return est.t, est.n_inliers

    t, n = jax.vmap(one)(ys, xs, desc, valid, keys)
    assert t.shape == (p, 2) and n.shape == (p,)
    np.testing.assert_allclose(np.asarray(t),
                               np.tile([[5.0, -9.0]], (p, 1)), atol=1e-4)
    assert (np.asarray(n) == k).all()


# ---------------------------------------------------------------------------
# streaming-DB paths: parity at sizes straddling the old VMEM gate
# ---------------------------------------------------------------------------

# L2 at d=128 stops fitting the 12 MiB resident-kernel budget near ~21k
# rows; these sizes straddle that boundary (and 24*2048+1 exceeds it with
# a 1-row non-multiple-of-chunk tail)
STRADDLE_NK = [16384, 20480, 24576, 24 * 2048 + 1]


def test_straddle_sizes_actually_straddle_the_gate():
    from repro.kernels.ops import matcher_fits_vmem
    fits = [matcher_fits_vmem(nk, 128, "l2") for nk in STRADDLE_NK]
    assert fits[0] and not fits[-1], fits     # both sides represented


@pytest.mark.parametrize("nk", STRADDLE_NK)
def test_l2_stream_paths_parity_across_vmem_gate(nk):
    """jnp_stream and the streaming Pallas kernel (interpret) agree with
    the oracle at DB sizes the resident kernel can and cannot hold —
    including a non-multiple-of-chunk tail — with db_valid masking."""
    nq = 37
    q, db, v = floats(nq, 0), floats(nk, 1), mask(nk, 2)
    ob, os_, oi = ref.match_best2(q, db, v, metric="l2")
    for path in ("jnp_stream", "pallas_stream"):
        b, s, i = ops.match_best2(q, db, v, metric="l2", path=path,
                                  interpret=True)
        np.testing.assert_allclose(np.asarray(b), np.asarray(ob),
                                   rtol=1e-5, atol=1e-4, err_msg=path)
        np.testing.assert_allclose(np.asarray(s), np.asarray(os_),
                                   rtol=1e-5, atol=1e-4, err_msg=path)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(oi),
                                      err_msg=path)


def test_hamming_stream_kernel_bit_identical_with_tail():
    """Streaming kernel with a non-multiple-of-kblock tail (pad rows are
    masked out inside ops): integer distances leave no tolerance."""
    nq, nk = 64, 3 * 512 + 129            # hamming kblock=512, ragged tail
    q, db, v = packed(nq, 0), packed(nk, 1), mask(nk, 2)
    o = ref.match_best2(q, db, v, metric="hamming")
    got = ops.match_best2(q, db, v, metric="hamming", path="pallas_stream",
                          interpret=True)
    for a, b in zip(got, o):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_use_pallas_true_has_no_silent_fallback():
    """use_pallas=True used to silently fall back to jnp beyond the VMEM
    gate; now it must resolve to the streaming kernel instead."""
    assert ops.match_path(64, 4096, 128, metric="l2",
                          use_pallas=True) == "pallas_resident"
    assert ops.match_path(64, STRADDLE_NK[-1], 128, metric="l2",
                          use_pallas=True) == "pallas_stream"
    # and a forced-kernel call above the gate still matches the oracle
    nq, nk = 16, 24576
    q, db, v = floats(nq, 0), floats(nk, 1), mask(nk, 2)
    ob, _, oi = ref.match_best2(q, db, v, metric="l2")
    b, _, i = ops.match_best2(q, db, v, metric="l2", use_pallas=True,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(ob),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(oi))


def test_blocked_oracle_equals_plain_oracle():
    q, db, v = packed(23, 0), packed(1000, 1), mask(1000, 2)
    plain = ref.match_best2(q, db, v, metric="hamming")
    blocked = ref.match_best2_blocked(q, db, v, metric="hamming", block=300)
    for a, b in zip(blocked, plain):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_match_best2_rejects_unknown_path_and_metric():
    q, db = floats(4, 0), floats(8, 1)
    with pytest.raises(ValueError, match="unknown path"):
        ops.match_best2(q, db, metric="l2", path="bogus")
    with pytest.raises(ValueError, match="unknown metric"):
        ops.match_best2(q, db, metric="cosine")
    with pytest.raises(TypeError, match="bit-packed"):
        ops.match_best2(q, db, metric="hamming")


# ---------------------------------------------------------------------------
# partition invariance of matching (extends core/bundle.py's interior-
# ownership guarantee to the new subsystem)
# ---------------------------------------------------------------------------
def _scene_features(scene, tile, alg):
    from repro.configs.difet_paper import DifetConfig
    from repro.core.bundle import tile_scene
    from repro.core.engine import extract_features
    cfg = DifetConfig(tile=tile, halo=24, max_keypoints_per_tile=512,
                      fast_threshold=0.08)
    b = tile_scene(scene, cfg)
    r = jax.jit(lambda t, h: extract_features(t, h, alg, cfg))(
        b.tiles, b.headers)
    return {k: np.asarray(v) for k, v in r.items()}


def _match_set(fa, fb):
    m = matching.match_pair(jnp.asarray(fa["top_desc"]),
                            jnp.asarray(fa["top_valid"]),
                            jnp.asarray(fb["top_desc"]),
                            jnp.asarray(fb["top_valid"]))
    ok = np.asarray(m.ok)
    idx = np.asarray(m.idx_b)
    quads = {(int(fa["top_ys"][i]), int(fa["top_xs"][i]),
              int(fb["top_ys"][idx[i]]), int(fb["top_xs"][idx[i]]))
             for i in np.nonzero(ok)[0]}
    return quads


def test_match_partition_invariance():
    """The same scene pair tiled differently must yield IDENTICAL match
    sets: responses/keypoints are interior-owned (halo 24 >= every stencil
    and descriptor-patch half-width), descriptors read identical pixels,
    and the matcher's tie-breaks depend on distances — not tile layout."""
    from repro.data.landsat import synthetic_scene
    base = synthetic_scene(220, 340, seed=9, density=4.0)
    scene_a = base[:, :240].copy()
    scene_b = base[:, 100:].copy()         # overlaps a by 140 columns
    sets = []
    for tile in (64, 100):
        fa = _scene_features(scene_a, tile, "brief")
        fb = _scene_features(scene_b, tile, "brief")
        sets.append(_match_set(fa, fb))
    assert sets[0], "no matches found — test scene too sparse"
    assert sets[0] == sets[1]
    # the dominant offset must be the known 100-column shift (a small
    # false-match tail from repetitive structure is expected — RANSAC's job)
    good = sum(1 for ya, xa, yb, xb in sets[0]
               if ya - yb == 0 and xa - xb == 100)
    assert good / len(sets[0]) > 0.7, sorted(sets[0])
