"""SSM blocks: chunked-parallel forms must equal step-by-step recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as S


@pytest.mark.parametrize("seq,chunk", [(16, 4), (12, 12), (24, 8)])
def test_mamba2_chunked_equals_recurrent(seq, chunk):
    cfg = get_config("zamba2-2.7b").reduced()
    cfg = cfg.replace(ssm=cfg.ssm.__class__(
        d_state=8, d_conv=4, expand=2, head_dim=8, chunk_size=chunk))
    p = S.mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, seq, cfg.d_model) * 0.3, jnp.float32)
    y_par = S.mamba2_apply(p, cfg, x)

    st = S.mamba2_init_state(cfg, 2)
    outs = []
    for i in range(seq):
        o, st = S.mamba2_decode(p, cfg, x[:, i:i + 1], st)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("seq", [8, 16])
def test_mlstm_chunked_equals_recurrent(seq):
    cfg = get_config("xlstm-350m").reduced()
    p = S.mlstm_init(jax.random.PRNGKey(1), cfg, jnp.float32)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(2, seq, cfg.d_model) * 0.3, jnp.float32)
    y_par = S.mlstm_apply(p, cfg, x)

    st = S.mlstm_init_state(cfg, 2)
    st = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32)
                                if a.dtype == jnp.bfloat16 else a, st)
    outs = []
    for i in range(seq):
        o, st = S.mlstm_decode(p, cfg, x[:, i:i + 1], st)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=5e-3, atol=5e-3)


def test_slstm_apply_equals_decode():
    cfg = get_config("xlstm-350m").reduced()
    p = S.slstm_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    rng = np.random.RandomState(2)
    seq = 10
    x = jnp.asarray(rng.randn(2, seq, cfg.d_model) * 0.3, jnp.float32)
    y_par = S.slstm_apply(p, cfg, x)
    st = S.slstm_init_state(cfg, 2)
    outs = []
    for i in range(seq):
        o, st = S.slstm_decode(p, cfg, x[:, i:i + 1], st)
        outs.append(o)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)


def test_mamba2_state_decay_bounds():
    """SSD decay must keep states bounded (stability invariant)."""
    cfg = get_config("zamba2-2.7b").reduced()
    p = S.mamba2_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    st = S.mamba2_init_state(cfg, 1)
    x = jnp.ones((1, 1, cfg.d_model), jnp.float32)
    for _ in range(50):
        _, st = S.mamba2_decode(p, cfg, x, st)
    assert bool(jnp.isfinite(st["ssm"]).all())
    assert float(jnp.abs(st["ssm"]).max()) < 1e4
