import os
import sys

# Tests must see the real single CPU device (the 512-device override is
# exclusively dryrun.py's).  Keep compile caches warm across tests.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


class FakeMesh:
    """Mesh stand-in exposing just what the sharding rules consume
    (axis_names / shape / size) without touching device state."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)
        self.size = int(np.prod(list(axes.values())))


@pytest.fixture
def mesh_16x16():
    return FakeMesh(data=16, model=16)


@pytest.fixture
def mesh_pod():
    return FakeMesh(pod=2, data=16, model=16)
