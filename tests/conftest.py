import hashlib
import os
import random
import sys

# Tests must see the real single CPU device (the 512-device override is
# exclusively dryrun.py's).  Keep compile caches warm across tests.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# Hypothesis determinism: explicit profiles with deadlines disabled (the
# chaos/fleet tests share CI machines with compile-heavy neighbours, so
# wall-clock deadlines flake) and derandomized example generation — the
# same examples on every run, every shard, every repeat of the 3x CI
# flake gate.  Select with HYPOTHESIS_PROFILE (default "dev"; CI uses
# "ci").  Optional dependency: absent hypothesis, the property tests
# skip themselves and there is nothing to configure.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("dev", deadline=None, derandomize=True)
    _hyp_settings.register_profile("ci", deadline=None, derandomize=True,
                                   max_examples=25, print_blob=True)
    _hyp_settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:
    pass


@pytest.fixture(autouse=True)
def _seed_stochastic_sources(request):
    """Determinism sweep: every test starts from a seed derived from its
    own nodeid, so any code reaching for the global ``random`` /
    ``np.random`` state is reproducible per-test and independent of
    execution order, sharding, or the CI repeat count."""
    digest = hashlib.sha256(request.node.nodeid.encode()).digest()
    seed = int.from_bytes(digest[:4], "big")
    random.seed(seed)
    np.random.seed(seed)


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


class FakeMesh:
    """Mesh stand-in exposing just what the sharding rules consume
    (axis_names / shape / size) without touching device state."""

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)
        self.size = int(np.prod(list(axes.values())))


@pytest.fixture
def mesh_16x16():
    return FakeMesh(data=16, model=16)


@pytest.fixture
def mesh_pod():
    return FakeMesh(pod=2, data=16, model=16)
