"""Roofline-analysis mode must be numerics-preserving: unrolled chunk scans
and unrolled layer stacks compute exactly what production computes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import attention as A
from repro.models import build_model
from repro.models.analysis_flags import single_chunk, single_chunk_active
from repro.distributed.sharding import largest_divisible_prefix


def test_single_chunk_flag_scoped():
    assert not single_chunk_active()
    with single_chunk():
        assert single_chunk_active()
    assert not single_chunk_active()


def test_unrolled_online_attention_matches_scanned():
    rng = np.random.RandomState(0)
    q = jnp.asarray(rng.randn(1, 16, 2, 8), jnp.float32)
    k = jnp.asarray(rng.randn(1, 16, 2, 8), jnp.float32)
    v = jnp.asarray(rng.randn(1, 16, 2, 8), jnp.float32)
    a = A.attention_online(q, k, v, causal=True, chunk=4, unroll=False)
    b = A.attention_online(q, k, v, causal=True, chunk=4, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_unrolled_stacks_match_scanned():
    cfg = get_config("internlm2-1.8b").reduced().replace(remat="nothing")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.RandomState(1).randint(
        0, cfg.vocab_size, (2, 16)), jnp.int32)
    logits_scan, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    model.cfg = cfg.replace(unroll_stacks=True)
    logits_unroll, _ = jax.jit(model.forward)(params, {"tokens": tokens})
    # bf16 params: scan vs unrolled loop reassociate accumulations —
    # differences are O(bf16 eps * depth), not algorithmic
    np.testing.assert_allclose(np.asarray(logits_scan),
                               np.asarray(logits_unroll),
                               rtol=0.05, atol=0.05)


class _M:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}
    size = 256


def test_largest_divisible_prefix():
    m = _M()
    assert largest_divisible_prefix(256, ("data", "model"), m) \
        == ("data", "model")
    assert largest_divisible_prefix(32, ("data", "model"), m) == "data"
    assert largest_divisible_prefix(7, ("data", "model"), m) is None
    assert largest_divisible_prefix(128, ("data", "model"), m) == "data"
