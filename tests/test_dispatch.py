"""Benchmark-gated matcher dispatch (`kernels/dispatch.py`): candidate
eligibility, shape bucketing, measure-once semantics, and disk-cache
persistence across processes (simulated by clearing the in-memory memo)."""
import json
import os

import numpy as np
import pytest

from repro.kernels import dispatch, ops


@pytest.fixture
def fresh_cache(tmp_path, monkeypatch):
    """Point the dispatch cache at an empty per-test file and drop the
    in-process memo, so tests never see (or pollute) the user's cache."""
    path = str(tmp_path / "dispatch.json")
    monkeypatch.setenv(dispatch.CACHE_ENV, path)
    dispatch.clear_memory_cache()
    yield path
    dispatch.clear_memory_cache()


def test_shape_bucket_rounds_up_pow2_keeps_d_exact():
    assert dispatch.shape_bucket(100, 3000, 128) == (128, 4096, 128)
    assert dispatch.shape_bucket(128, 4096, 8) == (128, 4096, 8)
    assert dispatch.shape_bucket(1, 1, 64) == (1, 1, 64)
    # same bucket -> same key; different d -> different key
    k1 = dispatch.bucket_key("l2", "cpu", 100, 3000, 128)
    assert k1 == dispatch.bucket_key("l2", "cpu", 128, 4096, 128)
    assert k1 != dispatch.bucket_key("l2", "cpu", 128, 4096, 64)


def test_candidate_paths_eligibility():
    # CPU: never a pallas candidate (interpret mode is not a perf path)
    assert dispatch.candidate_paths("l2", "cpu", 4096, 128) == \
        dispatch.JNP_PATHS
    # big DB drops the materializing candidates everywhere
    big = dispatch.FULL_MAX_ROWS + 1
    assert dispatch.candidate_paths("l2", "cpu", big, 128) == ("jnp_stream",)
    assert dispatch.candidate_paths("hamming", "tpu", big, 8) == \
        ("jnp_stream", "pallas_stream")
    # TPU small DB: all four compete
    assert dispatch.candidate_paths("hamming", "tpu", 4096, 8) == \
        dispatch.MATCH_PATHS
    # use_pallas restricts the pool
    assert dispatch.candidate_paths("l2", "cpu", 4096, 128,
                                    use_pallas=False) == dispatch.JNP_PATHS
    assert dispatch.candidate_paths("hamming", "tpu", 4096, 8,
                                    use_pallas=True) == dispatch.PALLAS_PATHS


def test_choose_path_measures_once_then_memoizes(fresh_cache):
    before = dispatch.measure_count
    p1 = dispatch.choose_path("l2", 64, 2048, 64)
    measured = dispatch.measure_count - before
    assert measured == len(dispatch.candidate_paths(
        "l2", "cpu", 2048, 64))              # one probe per candidate
    assert p1 in dispatch.JNP_PATHS
    # same bucket again (even a different shape inside it): no re-measure
    p2 = dispatch.choose_path("l2", 33, 1100, 64)
    assert p2 == p1
    assert dispatch.measure_count == before + measured


def test_choose_path_single_candidate_skips_measurement(fresh_cache):
    before = dispatch.measure_count
    p = dispatch.choose_path("l2", 64, dispatch.FULL_MAX_ROWS + 1, 64)
    assert p == "jnp_stream"
    assert dispatch.measure_count == before   # nothing to race: no probe


def test_disk_cache_survives_memory_clear(fresh_cache):
    before = dispatch.measure_count
    p1 = dispatch.choose_path("hamming", 64, 1024, 8)
    measured = dispatch.measure_count - before
    assert measured > 0
    assert os.path.exists(fresh_cache)
    entry = json.load(open(fresh_cache))
    [(key, val)] = entry.items()
    assert val["path"] == p1 and "us" in val
    # a "new process": empty memo, same disk file -> disk hit, no probe
    dispatch.clear_memory_cache()
    p2 = dispatch.choose_path("hamming", 64, 1024, 8)
    assert p2 == p1
    assert dispatch.measure_count == before + measured


def test_corrupt_disk_cache_remeasures(fresh_cache):
    with open(fresh_cache, "w") as f:
        f.write("{not json")
    before = dispatch.measure_count
    p = dispatch.choose_path("l2", 32, 512, 32)
    assert p in dispatch.JNP_PATHS
    assert dispatch.measure_count > before    # fell through to measurement


def test_match_best2_uses_dispatch_and_probe_caps(fresh_cache):
    """End to end: a default (use_pallas=None) call triggers exactly one
    measurement round; probes never materialize beyond the caps."""
    rng = np.random.RandomState(0)
    q = rng.randn(40, 32).astype(np.float32)
    db = rng.randn(900, 32).astype(np.float32)
    before = dispatch.measure_count
    out1 = ops.match_best2(q, db, metric="l2")
    assert dispatch.measure_count > before
    after = dispatch.measure_count
    out2 = ops.match_best2(q, db, metric="l2")
    assert dispatch.measure_count == after
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # cap check is pure arithmetic on the probe shape helper
    us = dispatch.measure_path("jnp_stream", "l2",
                               dispatch.PROBE_NQ_CAP * 4,
                               dispatch.PROBE_NK_CAP * 4, 16)
    assert us > 0.0
