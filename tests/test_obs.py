"""Observability layer (`repro/obs/`): bounded-memory histograms and
the registry, span tracing + flight recorder, exporters/validator, the
kernel profiler, dispatch-cache provenance, and the end-to-end gates —
trace-id continuity across a chaos kill, and traced-run bit-parity."""
import json
import os
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import FlightRecorder, NoopRecorder, Span


@pytest.fixture
def flight(tmp_path):
    """Install a FlightRecorder (tracing ON) for the test, restore the
    process default (noop) afterwards.  ``DIFET_CHAOS_DUMP_DIR``
    redirects crash-dump artifacts to a stable path — CI sets it so a
    failing chaos test leaves its Chrome trace behind for upload."""
    dump_dir = os.environ.get("DIFET_CHAOS_DUMP_DIR", str(tmp_path))
    Path(dump_dir).mkdir(parents=True, exist_ok=True)
    rec = FlightRecorder(capacity=4096, dump_dir=dump_dir)
    prev = obs_trace.set_recorder(rec)
    yield rec
    obs_trace.set_recorder(prev)


@pytest.fixture
def fresh_registry():
    """Swap in an empty registry so counter assertions see only this
    test's traffic; restore the process default afterwards."""
    reg = MetricsRegistry()
    prev = obs_metrics.set_registry(reg)
    yield reg
    obs_metrics.set_registry(prev)


# ---- metrics primitives ----------------------------------------------------

def test_histogram_bounded_memory_under_load():
    """The regression that retires the unbounded latency lists: 100k
    observations grow the histogram by ZERO bytes of per-observation
    state — bucket count and attribute set stay constant."""
    h = Histogram("t.load")
    n_buckets = len(h._counts)
    rng = np.random.RandomState(0)
    h.observe_many(rng.lognormal(-6, 2, size=100_000).tolist())
    assert len(h._counts) == n_buckets        # no per-observation growth
    assert h.count == 100_000
    assert sum(h._counts) == 100_000
    assert set(vars(h)) == set(vars(Histogram("t.fresh")))  # no new attrs


def test_histogram_quantiles_interpolated_accuracy():
    """Interpolated quantiles land within one bucket width (factor 1.25
    edges => <=25% relative error) of numpy's exact percentiles."""
    rng = np.random.RandomState(7)
    vals = rng.lognormal(mean=-5.0, sigma=1.0, size=20_000)
    h = Histogram("t.acc")
    h.observe_many(vals.tolist())
    for q in (0.50, 0.95, 0.99):
        exact = float(np.percentile(vals, q * 100))
        est = h.quantile(q)
        assert exact / 1.3 <= est <= exact * 1.3, (q, est, exact)
    assert h.quantile(0.0) >= 0.0
    assert h.quantile(1.0) <= h.max * (1 + 1e-9)
    # monotone in q — the scheduler stats() p99 >= p50 contract
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 0.95, 0.99)]
    assert all(b >= a for a, b in zip(qs, qs[1:]))
    assert h.mean == pytest.approx(vals.mean(), rel=1e-6)


def test_histogram_edge_cases():
    h = Histogram("t.edge")
    assert h.quantile(0.5) == 0.0             # empty
    snap = h.snapshot()
    assert snap["count"] == 0 and snap["p99"] == 0.0
    h.observe(0.001)                          # single observation
    assert h.quantile(0.5) == pytest.approx(0.001, rel=0.3)
    h.observe(1e9)                            # overflow bucket
    assert h.count == 2 and h.max == 1e9
    with pytest.raises(ValueError):
        h.quantile(1.5)
    with pytest.raises(ValueError):
        Histogram("t.bad", bounds=(2.0, 1.0))


def test_registry_create_on_first_use_and_type_guard():
    reg = MetricsRegistry()
    c = reg.counter("difet.test.n")
    assert reg.counter("difet.test.n") is c   # shared instance
    c.inc()
    c.inc(2.5)
    reg.gauge("difet.test.depth").set(7)
    reg.histogram("difet.test.lat_s").observe(0.25)
    with pytest.raises(TypeError):
        reg.histogram("difet.test.n")         # name is a Counter
    snap = reg.snapshot()
    assert snap["difet.test.n"] == 3.5
    assert snap["difet.test.depth"] == 7.0
    assert snap["difet.test.lat_s"]["count"] == 1
    assert reg.names() == sorted(snap)
    reg.reset()
    assert reg.names() == []


def test_counter_gauge_thread_safety():
    c, g = Counter("c"), Gauge("g")

    def work():
        for _ in range(1000):
            c.inc()
            g.set(1.0)

    ts = [threading.Thread(target=work) for _ in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert c.value == 8000.0
    assert g.value == 1.0


# ---- tracing ---------------------------------------------------------------

def test_noop_default_records_nothing():
    prev = obs_trace.set_recorder(NoopRecorder())
    try:
        assert not obs_trace.enabled()
        assert obs_trace.emit_span("x", "router", 0.0, 1.0) is None
        with obs_trace.span("y", "cache"):
            pass
        assert obs_trace.get_recorder().spans() == []
    finally:
        obs_trace.set_recorder(prev)


def test_flight_recorder_ring_bound_and_dump_dedupe(tmp_path):
    rec = FlightRecorder(capacity=10, dump_dir=str(tmp_path))
    prev = obs_trace.set_recorder(rec)
    try:
        for i in range(25):
            obs_trace.emit_span(f"s{i}", "router", float(i), float(i) + 0.5)
        spans = rec.spans()
        assert len(spans) == 10               # ring bound holds
        assert spans[0].name == "s15"         # oldest fell off the back
        assert rec.emitted == 25
        p1 = rec.dump_on("crash")
        p2 = rec.dump_on("crash")             # deduped: one artifact
        assert p1 is not None and p2 is None
        doc = json.load(open(p1))
        assert doc["metadata"]["dump_reason"] == "crash"
        assert len(doc["traceEvents"]) == 10
        assert rec.dump_on("shed-other") is not None    # new reason dumps
        assert set(rec.dumps) == {"crash", "shed-other"}
    finally:
        obs_trace.set_recorder(prev)


def test_span_ids_ambient_trace_and_attrs(flight):
    tid = obs_trace.new_trace_id()
    assert obs_trace.current_trace_id() == ""
    with obs_trace.use_trace(tid):
        assert obs_trace.current_trace_id() == tid
        with obs_trace.span("disk_get", "cache", bytes=128):
            pass
    assert obs_trace.current_trace_id() == ""       # restored
    [s] = flight.spans()
    assert s.trace_id == tid                        # ambient id captured
    assert s.layer == "cache" and dict(s.attrs)["bytes"] == 128
    assert s.t1 >= s.t0 and s.duration_s >= 0.0
    sid = obs_trace.emit_span("child", "cache", 0.0, 1.0,
                              trace_id=tid, parent_id=s.span_id)
    child = flight.spans()[-1]
    assert child.parent_id == s.span_id and child.span_id == sid


# ---- exporters + validator -------------------------------------------------

def _mk_span(name, layer, t0, t1, tid="t1"):
    return Span(name=name, layer=layer, trace_id=tid, span_id="s1",
                parent_id="", t0=t0, t1=t1, thread="main")


def test_chrome_export_schema_and_validator():
    spans = [_mk_span("queue_wait", "scheduler", 2.0, 3.0),
             _mk_span("admit", "router", 1.0, 1.5),
             _mk_span("device_step", "kernel", 3.0, 3.2)]
    doc = obs_export.spans_to_chrome(spans, metadata={"run": "test"})
    evs = doc["traceEvents"]
    assert [e["name"] for e in evs] == ["admit", "queue_wait", "device_step"]
    assert evs[0]["ts"] == 0.0                      # rebased to trace start
    assert evs[0]["dur"] == pytest.approx(0.5e6)    # microseconds
    assert evs[1]["cat"] == "scheduler"
    assert obs_export.validate_chrome_trace(
        doc, required_layers=("router", "scheduler", "kernel")) == []
    # validator catches: missing layer, open span, wrong phase, bad order
    assert obs_export.validate_chrome_trace(doc, required_layers=("cache",))
    bad = {"traceEvents": [dict(evs[0], dur=-1.0)]}
    assert any("unclosed" in p
               for p in obs_export.validate_chrome_trace(bad))
    bad = {"traceEvents": [dict(evs[0], ph="B")]}
    assert any("ph" in p for p in obs_export.validate_chrome_trace(bad))
    bad = {"traceEvents": [dict(evs[1], ts=5.0), dict(evs[0], ts=1.0)]}
    assert any("monotonic" in p
               for p in obs_export.validate_chrome_trace(bad))
    assert obs_export.validate_chrome_trace({}) == \
        ["traceEvents missing or empty"]


def test_latency_breakdown_and_report(fresh_registry):
    reg = fresh_registry
    reg.histogram("difet.scheduler.queue_s").observe_many([0.001, 0.002])
    reg.histogram("difet.kernel.step_s").observe(0.005)
    reg.counter("difet.router.admitted").inc(3)
    payload = obs_export.metrics_payload(reg)
    rows = obs_export.latency_breakdown(payload["metrics"])
    assert [r["stage"] for r in rows] == ["queue", "kernel"]
    assert rows[0]["count"] == 2
    report = obs_export.render_report(payload)
    assert "queue" in report and "difet.router.admitted" in report


# ---- kernel profiler -------------------------------------------------------

def test_profiler_disabled_by_default_and_rows_when_on():
    assert not obs_profile.profiler().enabled
    obs_profile.record_call("match:l2:jnp_full:q64k1024d32", 1.0)
    assert obs_profile.profiler().snapshot() == {}       # noop discarded
    prev = obs_profile.set_profiler(obs_profile.KernelProfiler())
    try:
        with obs_profile.profile_call("k1"):
            pass
        obs_profile.record_call("k1", 0.5)
        obs_profile.record_compile("k1", 2.0)
        rows = obs_profile.profiler().snapshot()
        assert rows["k1"]["calls"] == 2
        assert rows["k1"]["wall_s"] >= 0.5
        assert rows["k1"]["compiles"] == 1
        assert rows["k1"]["compile_s"] == 2.0
    finally:
        obs_profile.set_profiler(prev)
    with obs_profile.capture(None) as on:
        assert on is False                               # gated, optional


def test_match_best2_profiles_by_dispatch_bucket(tmp_path, monkeypatch):
    from repro.kernels import dispatch, ops
    monkeypatch.setenv(dispatch.CACHE_ENV, str(tmp_path / "d.json"))
    dispatch.clear_memory_cache()
    rng = np.random.RandomState(0)
    q = rng.randn(16, 32).astype(np.float32)
    db = rng.randn(200, 32).astype(np.float32)
    base = [np.asarray(x) for x in ops.match_best2(q, db, metric="l2")]
    prev = obs_profile.set_profiler(obs_profile.KernelProfiler())
    try:
        out = ops.match_best2(q, db, metric="l2")
        rows = obs_profile.profiler().snapshot()
        match_rows = [k for k in rows if k.startswith("match:l2:")]
        assert match_rows, rows
        assert "q16k256d32" in match_rows[0]     # pow2 dispatch bucket key
    finally:
        obs_profile.set_profiler(prev)
        dispatch.clear_memory_cache()
    for a, b in zip(base, out):                  # profiling never forks bits
        np.testing.assert_array_equal(a, np.asarray(b))


def test_dispatch_cache_provenance_and_explain(tmp_path, monkeypatch):
    """Satellite: every measured verdict persists WHY it won — candidate
    set, per-candidate timings, probe shape — and explain() decodes it."""
    from repro.kernels import dispatch
    path = str(tmp_path / "dispatch.json")
    monkeypatch.setenv(dispatch.CACHE_ENV, path)
    dispatch.clear_memory_cache()
    try:
        p = dispatch.choose_path("l2", 32, 512, 16)
        entry = json.load(open(path))
        [(key, val)] = entry.items()
        assert val["path"] == p
        assert val["metric"] == "l2" and val["backend"] == "cpu"
        assert val["bucket"] == [32, 512, 16]
        assert sorted(val["candidates"]) == sorted(val["us"])
        assert all(us > 0 for us in val["us"].values())
        rows = dispatch.explain()
        assert rows[key]["path"] == p
        assert rows[key]["margin"] >= 1.0        # winner beat the runner-up
    finally:
        dispatch.clear_memory_cache()


# ---- serving integration ---------------------------------------------------

def _serve_cfg():
    from repro.configs.difet_paper import DifetConfig
    from repro.serve import ServeConfig
    return ServeConfig(base=DifetConfig(tile=32, halo=8,
                                        max_keypoints_per_tile=16),
                       buckets=(32,), max_batch=4)


def test_scheduler_quantiles_bounded_not_listy():
    """Satellite (a): scheduler stats() quantiles come from the bounded
    histogram — no per-request list anywhere on the instance — and the
    p99 >= p50 >= 0 contract holds under traffic."""
    from repro.data.landsat import synthetic_scene
    from repro.serve import FeatureService
    svc = FeatureService(_serve_cfg())
    try:
        svc.warmup([("harris",)])
        n_buckets = len(svc.scheduler.queue_hist._counts)
        for i in range(32):
            svc.extract(synthetic_scene(32, 32, i), ("harris",), timeout=60)
        s = svc.scheduler.stats()
        assert s["items"] == 32
        assert s["p99_queue_ms"] >= s["p50_queue_ms"] >= 0.0
        assert len(svc.scheduler.queue_hist._counts) == n_buckets
        assert svc.scheduler.queue_hist.count == 32
        # nothing on the scheduler accumulates per-request entries
        for v in vars(svc.scheduler).values():
            if isinstance(v, (list, tuple)) and len(v) > 20:
                pytest.fail(f"unbounded per-request container: {v[:3]}...")
    finally:
        svc.close()


def test_untraced_service_emits_no_spans():
    from repro.data.landsat import synthetic_scene
    from repro.serve import FeatureService
    assert not obs_trace.enabled()               # process default is noop
    svc = FeatureService(_serve_cfg())
    try:
        svc.warmup([("harris",)])
        svc.extract(synthetic_scene(32, 32, 1), ("harris",), timeout=60)
        assert obs_trace.get_recorder().spans() == []
    finally:
        svc.close()


def test_traced_run_bit_identical_to_untraced(flight):
    """Instrumentation only observes: the traced service returns the
    exact bits of an untraced one on the same tile."""
    from repro.data.landsat import synthetic_scene
    from repro.serve import FeatureService
    from test_fleet import assert_results_equal

    tile = synthetic_scene(32, 32, 42)

    def run():
        svc = FeatureService(_serve_cfg())
        try:
            svc.warmup([("harris",)])
            return {a: {k: np.asarray(v) for k, v in r.items()}
                    for a, r in svc.extract(tile, ("harris",),
                                            timeout=60).results.items()}
        finally:
            svc.close()

    traced = run()
    obs_trace.set_recorder(NoopRecorder())
    untraced = run()
    assert_results_equal(traced, untraced)
    assert len(flight.spans()) > 0               # the traced run DID record


def test_traced_request_spans_every_layer(flight, tmp_path):
    """One routed request produces spans from router + scheduler + batch
    + kernel, all sharing the trace id minted at admission; a disk-tier
    service adds cache spans under the same id."""
    from repro.data.landsat import synthetic_scene
    from repro.serve import Router, RouterConfig, FeatureService
    from repro.serve.api import ServeConfig
    import dataclasses as dc

    cfg = dc.replace(_serve_cfg(), cache_dir=str(tmp_path / "tier"))
    svc = FeatureService(cfg, name="rep-1")
    router = Router(RouterConfig())
    try:
        svc.warmup([("harris",)])
        router.add_replica("rep-1", svc)
        h = router.submit(synthetic_scene(32, 32, 9), ("harris",))
        h.result(60)
        spans = flight.spans()
        admits = [s for s in spans if s.name == "admit"]
        assert len(admits) == 1
        tid = admits[0].trace_id
        assert tid                                # minted at admission
        layers_for_tid = {s.layer for s in spans if s.trace_id == tid}
        assert {"router", "scheduler", "batch",
                "cache"} <= layers_for_tid, layers_for_tid
        assert any(s.layer == "kernel" for s in spans)  # batch-scoped
        wait = [s for s in spans
                if s.name == "queue_wait" and s.trace_id == tid]
        assert wait and dict(wait[0].attrs)["replica"] == "rep-1"
    finally:
        router.close()
        svc.close()


def test_trace_id_survives_chaos_readmit(flight):
    """Satellite (c): kill a replica holding queued + in-flight work; the
    re-admitted request's spans on the survivor carry the ORIGINAL trace
    id, linked by a router `readmit` span naming old and new replica."""
    from repro.data.landsat import synthetic_scene
    from repro.serve import Fleet
    from test_fleet import assert_results_equal, direct, fleet_cfg

    step_lock = threading.Lock()
    fleet = Fleet(fleet_cfg(2, max_batch=4), step_lock=step_lock)
    try:
        tiles = [synthetic_scene(32, 32, 900 + i) for i in range(8)]
        with step_lock:                    # hold every batch in flight
            handles = [fleet.submit(t, ("harris",), scene_key=f"sc-{i}")
                       for i, t in enumerate(tiles)]
            victim = max(fleet.ready_replicas(),
                         key=lambda n: fleet.router._slots[n]
                         .service.scheduler.queue_depth)
            fleet.kill_replica(victim)     # re-admission happens in here
        results = [h.result(60) for h in handles]
        for t, r in zip(tiles, results):
            assert_results_equal(r.results, direct(t))

        spans = flight.spans()
        admit_tids = {s.trace_id for s in spans if s.name == "admit"}
        readmits = [s for s in spans if s.name == "readmit"]
        assert readmits                    # the kill produced re-admissions
        for s in readmits:
            attrs = dict(s.attrs)
            assert s.trace_id in admit_tids          # SAME trace id
            assert attrs["old_replica"] == victim
            assert attrs["new_replica"] != victim
        # the recompute on the survivor is tagged with the original id:
        # a queue_wait span with a readmitted trace id, recorded AFTER
        # the kill, living on the surviving replica
        readmit_tids = {s.trace_id for s in readmits}
        t_kill = min(s.t0 for s in readmits)
        recompute = [s for s in spans
                     if s.name == "queue_wait" and s.t1 >= t_kill
                     and s.trace_id in readmit_tids
                     and dict(s.attrs).get("replica") != victim]
        assert recompute, "no recompute spans carry the original trace id"
        # the dead replica's orphaned work was marked
        assert any(s.name == "killed" and s.layer == "scheduler"
                   for s in spans)
        # flight recorder dumped the replica_died artifact exactly once
        assert "replica_died" in flight.dumps
    finally:
        fleet.close()


def test_shed_counters_in_registry(fresh_registry):
    from repro.serve import Router, RouterConfig, Shed
    router = Router(RouterConfig())
    with pytest.raises(Shed):
        router.submit(np.zeros((32, 32), np.float32), ("harris",))
    snap = fresh_registry.snapshot()
    assert snap.get("difet.router.shed.no_ready_replica") == 1.0


def test_trace_id_survives_process_kill_readmit(flight, tmp_path):
    """The process-fleet variant of trace-id continuity: a replica
    *process* is SIGKILLed holding outstanding work, the death is
    discovered via the stale lease, and the router's `readmit` spans
    carry the ORIGINAL admission-minted trace id — the request's
    identity survives a real cross-process crash."""
    from chaos import ChaosPlan, clear_plan, wait_until, write_plan
    from repro.data.landsat import synthetic_scene
    from repro.serve import Fleet
    from repro.serve.fleet import DEAD
    from test_proc_fleet import proc_fleet_cfg

    fleet = Fleet(proc_fleet_cfg(tmp_path, 2))
    try:
        for name in fleet.ready_replicas():   # keep work outstanding
            write_plan(fleet.transport_dir / name,
                       ChaosPlan(hold_responses_s=30.0))
        tiles = [synthetic_scene(32, 32, 950 + i) for i in range(6)]
        handles = [fleet.submit(t, ("harris",), scene_key=f"pk-{i}")
                   for i, t in enumerate(tiles)]
        victim = next(iter(fleet.router._outstanding.values())).replica
        fleet.sigkill_replica(victim)
        for name in fleet.ready_replicas():
            clear_plan(fleet.transport_dir / name)

        def detected():
            fleet.maintenance_tick()
            return fleet.replicas[victim].state == DEAD
        wait_until(detected, 20, desc="stale-lease detection")
        for h in handles:                     # all accepted work completes
            h.result(90)

        spans = flight.spans()
        admit_tids = {s.trace_id for s in spans if s.name == "admit"}
        readmits = [s for s in spans if s.name == "readmit"]
        assert readmits                       # the SIGKILL forced re-admission
        for s in readmits:
            attrs = dict(s.attrs)
            assert s.trace_id in admit_tids   # original admission-minted id
            assert attrs["old_replica"] == victim
            assert attrs["new_replica"] != victim
    finally:
        fleet.close()
