"""Config registry + reduced-config invariants."""
import pytest

from repro.configs import (
    get_config, all_arch_ids, applicable_shapes, SHAPES)

EXPECTED_ARCHS = {
    "internlm2-1.8b", "qwen1.5-110b", "glm4-9b", "smollm-135m",
    "whisper-large-v3", "deepseek-v3-671b", "dbrx-132b", "internvl2-2b",
    "xlstm-350m", "zamba2-2.7b",
}


def test_all_assigned_archs_registered():
    assert EXPECTED_ARCHS == set(all_arch_ids())


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["decode_32k"].is_decode
    assert SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_applicable_shapes(arch):
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    assert "train_4k" in shapes
    # long_500k only for sub-quadratic archs (DESIGN.md §4)
    assert ("long_500k" in shapes) == (cfg.family in ("ssm", "hybrid"))


@pytest.mark.parametrize("arch", sorted(EXPECTED_ARCHS))
def test_reduced_is_small_and_same_family(arch):
    cfg = get_config(arch)
    r = cfg.reduced()
    assert r.family == cfg.family
    assert r.d_model <= 128 and r.n_layers <= 4 and r.vocab_size <= 512
    assert (r.moe is None) == (cfg.moe is None)
    assert (r.mla is None) == (cfg.mla is None)
    assert (r.ssm is None) == (cfg.ssm is None)


def test_exact_assigned_dims():
    q = get_config("qwen1.5-110b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab_size) == (80, 8192, 64, 8, 49152, 152064)
    assert q.qkv_bias
    d = get_config("deepseek-v3-671b")
    assert d.moe.n_experts == 256 and d.moe.n_experts_per_tok == 8
    assert d.mla.kv_lora_rank == 512
    z = get_config("zamba2-2.7b")
    assert z.ssm.d_state == 64 and z.n_layers == 54
