"""Training step builder: value_and_grad + AdamW, with optional microbatch
gradient accumulation (compute/comm overlap falls out of the scan + XLA
latency hiding) and optional gradient compression with error feedback.

State layout (a plain pytree so checkpointing/sharding stay trivial):
    {"params": ..., "opt": {"m","v","count"}, "step": int32[,"err": ...]}
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamW
from repro.optim.compression import compress_decompress


@dataclass(frozen=True)
class TrainStepConfig:
    learning_rate: float = 3e-4
    microbatches: int = 1            # grad accumulation steps
    grad_compression: bool = False   # int8 + error feedback


def make_init_fn(model, optimizer: AdamW, step_cfg: TrainStepConfig):
    def init_fn(key):
        params = model.init(key)
        state = {"params": params, "opt": optimizer.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if step_cfg.grad_compression:
            state["err"] = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return state
    return init_fn


def _split_microbatches(batch, n):
    return jax.tree_util.tree_map(
        lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(model, optimizer: AdamW, step_cfg: TrainStepConfig,
                    lr_fn: Optional[Callable] = None):
    lr_fn = lr_fn or (lambda step: step_cfg.learning_rate)

    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def train_step(state, batch):
        params = state["params"]
        if step_cfg.microbatches > 1:
            mb = _split_microbatches(batch, step_cfg.microbatches)

            def acc_body(carry, microbatch):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, microbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.float32(0.0)), mb)
            loss = loss_sum / step_cfg.microbatches
            grads = jax.tree_util.tree_map(
                lambda g: g / step_cfg.microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        new_state = {}
        if step_cfg.grad_compression:
            grads, new_err = compress_decompress(grads, state["err"])
            new_state["err"] = new_err

        lr = lr_fn(state["step"])
        new_params, new_opt, gnorm = optimizer.update(
            grads, state["opt"], params, lr)
        new_state.update({"params": new_params, "opt": new_opt,
                          "step": state["step"] + 1})
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       lr=jnp.float32(lr))
        return new_state, metrics

    return train_step
