from repro.train.step import (  # noqa: F401
    make_train_step, make_init_fn, TrainStepConfig,
)
