"""Synthetic LandSat-8-like scenes (the paper's inputs are ~7000x7000 RGBA
LandSat-8 tiles; we synthesize structured scenes with the same statistics:
smooth terrain + field/urban edges + speckle noise — enough corner/blob
structure for every detector to fire)."""
from __future__ import annotations

import numpy as np


def synthetic_scene(h: int, w: int, seed: int = 0,
                    density: float = 1.0) -> np.ndarray:
    """Grayscale float32 [h, w] in [0, 1].  ``density`` scales the count of
    fields/blobs (1.0 = the historical default; stitching workloads use
    denser scenes so pairwise registration has enough corners to verify)."""
    rng = np.random.RandomState(seed)
    # smooth low-frequency terrain
    coarse = rng.rand(max(h // 64, 2), max(w // 64, 2)).astype(np.float32)
    reps = (h // coarse.shape[0] + 1, w // coarse.shape[1] + 1)
    terrain = np.kron(coarse, np.ones(reps, np.float32))[:h, :w]
    for _ in range(2):   # cheap smoothing passes
        terrain = 0.25 * (np.roll(terrain, 1, 0) + np.roll(terrain, -1, 0)
                          + np.roll(terrain, 1, 1) + np.roll(terrain, -1, 1))
    img = 0.5 * terrain
    # rectangular "fields" with crisp edges/corners
    n_fields = max(4, int(density * (h * w) / 20000))
    for _ in range(n_fields):
        y0 = rng.randint(0, max(h - 8, 1))
        x0 = rng.randint(0, max(w - 8, 1))
        fh = rng.randint(6, max(h // 8, 7))
        fw = rng.randint(6, max(w // 8, 7))
        img[y0:y0 + fh, x0:x0 + fw] += rng.uniform(-0.35, 0.35)
    # bright point targets (blobs)
    for _ in range(max(2, n_fields // 4)):
        y = rng.randint(2, max(h - 3, 3))
        x = rng.randint(2, max(w - 3, 3))
        img[y - 1:y + 2, x - 1:x + 2] += 0.5
    img += 0.01 * rng.randn(h, w).astype(np.float32)   # sensor noise
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def synthetic_scene_rgba(h: int, w: int, seed: int = 0) -> np.ndarray:
    """RGBA uint8 [h, w, 4] — the paper's input format (32-bit pixels)."""
    g = synthetic_scene(h, w, seed)
    rgba = np.stack([g, g * 0.9, g * 0.8, np.ones_like(g)], axis=-1)
    return (rgba * 255).astype(np.uint8)
