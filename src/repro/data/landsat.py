"""Synthetic LandSat-8-like scenes and band-striped scene readers.

The paper's inputs are ~7000x7000 RGBA LandSat-8 tiles; we synthesize
structured scenes with the same statistics — smooth terrain + field/urban
edges + speckle noise, enough corner/blob structure for every detector to
fire.  LandSat-8 itself is distributed as one GeoTIFF *per band*; the
streaming ingest mirrors that: a scene on disk is a directory of per-band
``.npy`` stripes (`write_scene_bands`) that `BandSceneReader` memory-maps
and reads row-stripe by row-stripe, composing grayscale with exactly the
same arithmetic as `core/bundle.py::rgba_to_gray` — so the streamed pixels
are bit-identical to the eager path (docs/ingest.md).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Tuple

import numpy as np

# grayscale composition weights per band name — the same Rec.601 weights as
# rgba_to_gray, keyed by the LandSat-8 visible band ids (B4=red, B3=green,
# B2=blue).  "gray" means the scene is already single-band.
GRAY_WEIGHTS = {"B4": 0.299, "B3": 0.587, "B2": 0.114}


def synthetic_scene(h: int, w: int, seed: int = 0,
                    density: float = 1.0) -> np.ndarray:
    """Grayscale float32 [h, w] in [0, 1].  ``density`` scales the count of
    fields/blobs (1.0 = the historical default; stitching workloads use
    denser scenes so pairwise registration has enough corners to verify)."""
    rng = np.random.RandomState(seed)
    # smooth low-frequency terrain
    coarse = rng.rand(max(h // 64, 2), max(w // 64, 2)).astype(np.float32)
    reps = (h // coarse.shape[0] + 1, w // coarse.shape[1] + 1)
    terrain = np.kron(coarse, np.ones(reps, np.float32))[:h, :w]
    for _ in range(2):   # cheap smoothing passes
        terrain = 0.25 * (np.roll(terrain, 1, 0) + np.roll(terrain, -1, 0)
                          + np.roll(terrain, 1, 1) + np.roll(terrain, -1, 1))
    img = 0.5 * terrain
    # rectangular "fields" with crisp edges/corners
    n_fields = max(4, int(density * (h * w) / 20000))
    for _ in range(n_fields):
        y0 = rng.randint(0, max(h - 8, 1))
        x0 = rng.randint(0, max(w - 8, 1))
        fh = rng.randint(6, max(h // 8, 7))
        fw = rng.randint(6, max(w // 8, 7))
        img[y0:y0 + fh, x0:x0 + fw] += rng.uniform(-0.35, 0.35)
    # bright point targets (blobs)
    for _ in range(max(2, n_fields // 4)):
        y = rng.randint(2, max(h - 3, 3))
        x = rng.randint(2, max(w - 3, 3))
        img[y - 1:y + 2, x - 1:x + 2] += 0.5
    img += 0.01 * rng.randn(h, w).astype(np.float32)   # sensor noise
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def synthetic_scene_rgba(h: int, w: int, seed: int = 0) -> np.ndarray:
    """RGBA uint8 [h, w, 4] — the paper's input format (32-bit pixels)."""
    g = synthetic_scene(h, w, seed)
    rgba = np.stack([g, g * 0.9, g * 0.8, np.ones_like(g)], axis=-1)
    return (rgba * 255).astype(np.uint8)


# ---------------------------------------------------------------------------
# band-striped scene storage + streaming readers
# ---------------------------------------------------------------------------

class SceneReader:
    """Row-stripe access to one grayscale scene.

    The streaming ingest contract (`data/pipeline.py`): a reader exposes
    ``shape`` up front and serves ``read_rows(y0, y1)`` — a float32
    grayscale stripe ``[y1 - y0, w]`` in ``[0, 1]`` — without ever
    materializing the full scene.  Implementations must produce pixels
    bit-identical to the eager path (`core/bundle.py::rgba_to_gray` over
    the whole image), so pipelined extraction is bit-exact.
    """

    name: str
    shape: Tuple[int, int]

    def read_rows(self, y0: int, y1: int) -> np.ndarray:
        """Return grayscale rows ``[y0, y1)`` as float32 ``[y1-y0, w]``."""
        raise NotImplementedError

    def stripes(self, stripe_rows: int):
        """Yield ``read_rows`` stripes of ``stripe_rows`` rows (last one
        ragged).  ``stripe_rows`` must be positive."""
        if stripe_rows <= 0:
            raise ValueError(f"stripe_rows must be positive, "
                             f"got {stripe_rows}")
        h = self.shape[0]
        for y0 in range(0, h, stripe_rows):
            yield self.read_rows(y0, min(y0 + stripe_rows, h))


class ArraySceneReader(SceneReader):
    """In-memory reader over a grayscale / RGBA array (tests, smoke runs).

    Accepts float32 grayscale ``[H, W]``, uint8 grayscale, or RGBA uint8
    ``[H, W, 4]``; conversion happens per stripe with the same expression
    as the eager path, so streamed pixels match it bit-for-bit.
    """

    def __init__(self, image: np.ndarray, name: str = "scene"):
        self._img = np.asarray(image)
        if self._img.ndim not in (2, 3):
            raise ValueError(f"scene must be [H,W] or [H,W,C], "
                             f"got shape {self._img.shape}")
        self.name = name
        self.shape = tuple(self._img.shape[:2])

    def read_rows(self, y0: int, y1: int) -> np.ndarray:
        """Grayscale rows ``[y0, y1)`` as float32 ``[y1-y0, w]`` — the
        eager converter applied to just this slice."""
        from repro.core.bundle import rgba_to_gray
        return rgba_to_gray(self._img[y0:y1])


class BandSceneReader(SceneReader):
    """Memory-mapped reader over a band-striped on-disk scene.

    A scene directory (written by `write_scene_bands`) holds one ``.npy``
    per band plus a ``scene.json`` manifest; LandSat-8 distributes scenes
    the same way (one GeoTIFF per band).  ``read_rows`` touches only the
    requested row slab of each band memmap, composing grayscale with the
    Rec.601 weights (`GRAY_WEIGHTS`) in the exact `rgba_to_gray` order —
    one stripe of host memory per call, never the whole ~230 MB scene.

    Raises ``IOError`` for truncated/corrupt band files and ``ValueError``
    when the manifest's bands are missing, extra, or shape-mismatched.
    """

    def __init__(self, root):
        self.root = Path(root)
        meta_path = self.root / "scene.json"
        if not meta_path.exists():
            raise FileNotFoundError(f"no scene.json under {self.root}")
        meta = json.loads(meta_path.read_text())
        self.name = meta["name"]
        self.shape = (int(meta["h"]), int(meta["w"]))
        bands = tuple(meta["bands"])
        if bands != ("gray",) and set(bands) != set(GRAY_WEIGHTS):
            raise ValueError(
                f"scene {self.name!r}: band set {bands} is neither "
                f"('gray',) nor {tuple(sorted(GRAY_WEIGHTS))}")
        self._bands: Dict[str, np.ndarray] = {}
        for b in bands:
            path = self.root / f"{b}.npy"
            try:
                arr = np.load(path, mmap_mode="r", allow_pickle=False)
            except Exception as e:  # noqa: BLE001 — truncation surfaces here
                raise IOError(
                    f"scene {self.name!r}: band file {path} unreadable "
                    f"(truncated or corrupt): {e}") from e
            if arr.shape != self.shape:
                raise ValueError(
                    f"scene {self.name!r}: band {b!r} shape {arr.shape} "
                    f"!= manifest shape {self.shape}")
            self._bands[b] = arr

    def read_rows(self, y0: int, y1: int) -> np.ndarray:
        """Grayscale rows ``[y0, y1)`` as float32 ``[y1-y0, w]``, reading
        only that row slab from each band's memmap."""
        if "gray" in self._bands:
            g = self._bands["gray"][y0:y1]
            if g.dtype == np.uint8:
                return np.asarray(g, np.float32) / 255.0
            return np.asarray(g, np.float32)
        # same weights table and expression ORDER as rgba_to_gray:
        # bitwise-identical floats
        r = np.asarray(self._bands["B4"][y0:y1], np.float32) / 255.0
        g = np.asarray(self._bands["B3"][y0:y1], np.float32) / 255.0
        b = np.asarray(self._bands["B2"][y0:y1], np.float32) / 255.0
        return (GRAY_WEIGHTS["B4"] * r + GRAY_WEIGHTS["B3"] * g
                + GRAY_WEIGHTS["B2"] * b)


def write_scene_bands(root, name: str, image: np.ndarray) -> Path:
    """Store a scene band-striped: one ``.npy`` per band + ``scene.json``.

    RGBA uint8 input splits into B4/B3/B2 visible-band files (alpha is
    constant in the paper's inputs and grayscale never reads it);
    grayscale input is stored as a single ``gray`` band.  Returns the
    scene directory, readable by `BandSceneReader`.
    """
    image = np.asarray(image)
    d = Path(root) / name
    d.mkdir(parents=True, exist_ok=True)
    if image.ndim == 3:
        bands = {"B4": image[..., 0], "B3": image[..., 1],
                 "B2": image[..., 2]}
    elif image.ndim == 2:
        bands = {"gray": image}
    else:
        raise ValueError(f"scene must be [H,W] or [H,W,4], "
                         f"got shape {image.shape}")
    for b, arr in bands.items():
        np.save(d / f"{b}.npy", np.ascontiguousarray(arr),
                allow_pickle=False)
    (d / "scene.json").write_text(json.dumps(
        {"name": name, "h": int(image.shape[0]), "w": int(image.shape[1]),
         "bands": sorted(bands)}, indent=1))
    return d


def write_synthetic_scene_set(root, n_scenes: int, h: int, w: int,
                              seed0: int = 0) -> list:
    """Materialize the paper's fixed scene set (N synthetic RGBA scenes)
    band-striped under ``root``; returns the scene directories in
    deterministic name order — the manifest order every worker count must
    agree on."""
    return [write_scene_bands(root, f"scene_{seed0 + i:04d}",
                              synthetic_scene_rgba(h, w, seed=seed0 + i))
            for i in range(n_scenes)]
