"""Token data pipeline for the LM substrate: a deterministic synthetic
stream (Zipf-ish unigram + local repetition structure so models can learn)
with shift-by-one label alignment and sharded host loading."""
from __future__ import annotations

import numpy as np


def token_stream(vocab_size: int, seed: int = 0):
    rng = np.random.RandomState(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        yield int(rng.choice(vocab_size, p=probs))


def synthetic_lm_batch(batch: int, seq: int, vocab_size: int, seed: int = 0,
                       repeat_period: int = 16):
    """tokens/labels int32 [batch, seq+? -> seq]; labels are tokens shifted
    left by one (next-token).  A periodic copy pattern gives the model
    learnable structure (loss visibly decreases in the examples)."""
    rng = np.random.RandomState(seed)
    base = rng.zipf(1.5, size=(batch, seq + 1)).astype(np.int64)
    toks = (base % (vocab_size - 2)) + 1
    # inject copy structure: token at t == token at t - repeat_period
    for t in range(repeat_period, seq + 1, repeat_period):
        toks[:, t] = toks[:, t - repeat_period]
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}
