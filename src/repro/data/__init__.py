from repro.data.landsat import synthetic_scene, synthetic_scene_rgba  # noqa: F401
from repro.data.tokens import synthetic_lm_batch, token_stream  # noqa: F401
