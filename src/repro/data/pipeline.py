"""Streaming tile pipeline: scene stripes → halo tiles → staged batches.

The eager path (`core/bundle.py::tile_scene`) pads a whole scene in host
memory and cuts every tile at once — fine for test scenes, impossible for
the paper's ~230 MB LandSat-8 inputs times N. This module is the streaming
replacement, and the ingest layer of the horizontal-scalability subsystem
(docs/ingest.md):

    SceneReader.stripes()  →  StreamTiler  →  batch packer  →  Prefetcher
    (row stripes, mmap)       (halo tiles,     (fixed-shape     (host thread,
                               row window)      TileBundles)     double buffer)

* `StreamTiler` keeps only the row window a tile row needs (reflect
  padding included), so resident host memory is O(tile + 2·halo) rows per
  scene regardless of scene height.  Its tiles are **bit-identical** to
  `tile_scene` output in the same order (`tests/test_pipeline.py`).
* `iter_tile_batches` packs tiles from a scene sequence into fixed-shape
  `TileBundle` batches (the last batch pad-flagged to shape), so every
  batch hits one compiled program — and a batch is the unit the manifest
  orders and workers lease (`core/job.py`).
* `Prefetcher` runs the iterator on a host thread with a bounded queue
  (depth 2 = double buffering) and optionally stages arrays onto devices
  with `jax.device_put`, so host tiling/IO overlaps device compute.
  Errors propagate to the consumer; `close()` always reclaims the thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core.bundle import TileBundle
from repro.data.landsat import SceneReader

__all__ = ["StreamTiler", "iter_scene_tiles", "iter_tile_batches",
           "Prefetcher", "reflect_indices"]


def reflect_indices(n: int, pad_before: int, pad_after: int) -> np.ndarray:
    """Source indices for ``np.pad(mode="reflect")`` along one axis.

    Returns int64 ``[pad_before + n + pad_after]`` mapping each padded
    position to its source index in ``[0, n)`` — the index form of numpy's
    even-reflect (no edge repeat), including multi-bounce for pads wider
    than the axis.  Lets the tiler compute any padded row from raw scene
    rows without materializing a padded scene.
    """
    if n == 1:
        return np.zeros(pad_before + 1 + pad_after, np.int64)
    j = np.arange(-pad_before, n + pad_after)
    period = 2 * (n - 1)
    j = np.abs(j) % period
    return np.where(j >= n, period - j, j)


class StreamTiler:
    """Incremental `tile_scene`: feed row stripes, collect finished tiles.

    Tiles come out in the same row-major ``(ty, tx)`` order, with the same
    float32 values and int32 headers, as ``tile_scene(gray, cfg,
    scene_id)`` on the fully materialized scene — the parity is bitwise
    and tested.  Internally each arriving row is reflect-padded
    horizontally once; a tile row is emitted as soon as the last raw row
    it references (bottom reflection included) has arrived, and raw rows
    no longer referenced by any future tile row are dropped.

    Args:
        h, w:      scene extent in pixels (known up front from the reader).
        cfg:       tiling geometry (``cfg.tile`` interior, ``cfg.halo``
                   overlap ring).
        scene_id:  stamped into every emitted header.

    Use ``feed(stripe)`` per stripe and ``finish()`` once after the last
    stripe; both return ``(tiles, headers)`` lists for the tile rows that
    completed.
    """

    def __init__(self, h: int, w: int, cfg: DifetConfig, scene_id: int = 0):
        if h <= 0 or w <= 0:
            raise ValueError(f"empty scene: {h}x{w}")
        t, halo = cfg.tile, cfg.halo
        self.cfg = cfg
        self.scene_id = scene_id
        self.h, self.w = h, w
        self.ny = (h + t - 1) // t
        self.nx = (w + t - 1) // t
        # row index maps of the padded scene (height ny*t + 2*halo):
        # padded row -> source scene row, exactly np.pad(reflect) semantics
        self._row_src = reflect_indices(h, halo, halo + self.ny * t - h)
        self._col_pad = (halo, halo + self.nx * t - w)
        # per tile row: the last raw row it references decides readiness
        self._last_needed = [
            int(self._row_src[ty * t: ty * t + t + 2 * halo].max())
            for ty in range(self.ny)]
        # raw row -> number of tile rows still referencing it (for eviction)
        self._refcount = np.zeros(h, np.int64)
        for ty in range(self.ny):
            for r in np.unique(self._row_src[ty * t:
                                             ty * t + t + 2 * halo]):
                self._refcount[r] += 1
        self._rows = {}          # raw row index -> horizontally padded row
        self._next_row = 0       # next raw row index expected from feed()
        self._next_ty = 0        # next tile row to emit

    def feed(self, stripe: np.ndarray) -> Tuple[List[np.ndarray],
                                                List[Tuple]]:
        """Consume one ``[rows, w]`` stripe; return tiles that completed.

        Stripes must arrive in order and cover the scene exactly; a stripe
        wider/narrower than ``w`` raises (the truncated-scene guard).
        """
        stripe = np.asarray(stripe, np.float32)
        if stripe.ndim != 2 or stripe.shape[1] != self.w:
            raise ValueError(f"stripe shape {stripe.shape} does not match "
                             f"scene width {self.w}")
        if self._next_row + stripe.shape[0] > self.h:
            raise ValueError(
                f"stripe overruns scene: rows "
                f"[{self._next_row}, {self._next_row + stripe.shape[0]}) "
                f"beyond h={self.h}")
        for i in range(stripe.shape[0]):
            r = self._next_row + i
            if self._refcount[r]:
                self._rows[r] = np.pad(stripe[i], self._col_pad,
                                       mode="reflect")
        self._next_row += stripe.shape[0]
        return self._drain()

    def finish(self) -> Tuple[List[np.ndarray], List[Tuple]]:
        """Assert full coverage and return any remaining tile rows."""
        if self._next_row != self.h:
            raise ValueError(f"scene truncated: got {self._next_row} of "
                             f"{self.h} rows")
        tiles, headers = self._drain()
        if self._next_ty != self.ny:
            raise AssertionError("tiler finished with pending tile rows")
        return tiles, headers

    def _drain(self):
        t, halo = self.cfg.tile, self.cfg.halo
        tiles, headers = [], []
        while (self._next_ty < self.ny
               and self._last_needed[self._next_ty] < self._next_row):
            ty = self._next_ty
            src = self._row_src[ty * t: ty * t + t + 2 * halo]
            slab = np.stack([self._rows[int(r)] for r in src])
            for tx in range(self.nx):
                x0 = tx * t
                tiles.append(slab[:, x0:x0 + t + 2 * halo])
                headers.append((self.scene_id, ty, tx,
                                min(t, self.h - ty * t),
                                min(t, self.w - tx * t), 0))
            for r in np.unique(src):
                self._refcount[r] -= 1
                if self._refcount[r] == 0:
                    del self._rows[int(r)]
            self._next_ty += 1
        return tiles, headers


def iter_scene_tiles(reader: SceneReader, cfg: DifetConfig,
                     scene_id: int = 0,
                     stripe_rows: Optional[int] = None):
    """Stream one scene's halo tiles: yields ``(tile, header)`` pairs in
    `tile_scene` order without materializing the scene.  ``stripe_rows``
    defaults to one tile row's worth of raw rows."""
    h, w = reader.shape
    stripe_rows = stripe_rows or (cfg.tile + 2 * cfg.halo)
    tiler = StreamTiler(h, w, cfg, scene_id)
    for stripe in reader.stripes(stripe_rows):
        for pair in zip(*tiler.feed(stripe)):
            yield pair
    for pair in zip(*tiler.finish()):
        yield pair


def scene_tile_count(shape: Tuple[int, int], cfg: DifetConfig) -> int:
    """Tiles `tile_scene` cuts from a scene of this shape (header math
    only — no pixels read)."""
    h, w = shape
    return (((h + cfg.tile - 1) // cfg.tile)
            * ((w + cfg.tile - 1) // cfg.tile))


def count_batches(shapes: Sequence[Tuple[int, int]], cfg: DifetConfig,
                  batch_tiles: int) -> int:
    """Batches `iter_tile_batches` will yield for scenes of these shapes —
    lets a manifest be written before any pixel is read."""
    total = sum(scene_tile_count(s, cfg) for s in shapes)
    return (total + batch_tiles - 1) // batch_tiles


def iter_tile_batches(readers: Sequence[SceneReader], cfg: DifetConfig,
                      batch_tiles: int,
                      stripe_rows: Optional[int] = None,
                      start: int = 0, stop: Optional[int] = None
                      ) -> Iterator[Tuple[int, TileBundle]]:
    """Pack a scene sequence into fixed-shape `TileBundle` batches.

    Tiles stream scene by scene (scene_id = position in ``readers``) in
    `bundle_scenes` order; batch *i* holds flat tiles
    ``[i·batch_tiles, (i+1)·batch_tiles)`` of that order, the final
    partial batch padded to shape with pad-flagged empty tiles
    (`TileBundle.pad_to`), which the engine masks out.  Fixed shapes mean
    one compiled program serves every batch, and the batch index is the
    manifest work item a worker leases (`core/job.py`).

    ``start``/``stop`` select the contiguous batch slice ``[start, stop)``
    — a worker's share of the manifest.  Scenes contributing no tile to
    the slice are skipped without reading a pixel (their tile counts come
    from header math), so N workers re-read only boundary scenes, not the
    whole set.  Yields ``(batch_index, bundle)`` pairs.
    """
    if batch_tiles <= 0:
        raise ValueError(f"batch_tiles must be positive, got {batch_tiles}")
    n_batches = count_batches([r.shape for r in readers], cfg, batch_tiles)
    stop = n_batches if stop is None else min(stop, n_batches)
    if start < 0 or start > stop:
        raise ValueError(f"bad batch slice [{start}, {stop})")
    tiles: List[np.ndarray] = []
    headers: List[Tuple] = []
    flat = 0                       # global flat tile index
    for sid, reader in enumerate(readers):
        n_s = scene_tile_count(reader.shape, cfg)
        first_b = flat // batch_tiles
        last_b = (flat + n_s - 1) // batch_tiles
        if last_b < start or first_b >= stop:
            flat += n_s            # scene wholly outside the slice: no IO
            continue
        for tile, header in iter_scene_tiles(reader, cfg, sid, stripe_rows):
            if start <= flat // batch_tiles < stop:
                tiles.append(tile)
                headers.append(header)
                if len(tiles) == batch_tiles:
                    yield (flat // batch_tiles,
                           TileBundle(np.stack(tiles),
                                      np.asarray(headers, np.int32), cfg))
                    tiles, headers = [], []
            flat += 1
            if stop < n_batches and flat >= stop * batch_tiles:
                # slice exhausted mid-scene: every batch before `stop` is
                # full and already yielded — stop reading stripes now
                return
    if tiles:                      # the globally-last batch, pad-flagged
        yield (flat // batch_tiles,
               TileBundle(np.stack(tiles), np.asarray(headers, np.int32),
                          cfg).pad_to(batch_tiles))


def batch_slices(n_batches: int, n_workers: int) -> List[Tuple[int, int]]:
    """Contiguous near-even ``[lo, hi)`` batch slices, one per worker —
    the restart-deterministic work partition (same inputs → same slices,
    any worker count covers every batch exactly once)."""
    bounds = np.linspace(0, n_batches, n_workers + 1).astype(int)
    return [(int(bounds[i]), int(bounds[i + 1])) for i in range(n_workers)]


class Prefetcher:
    """Host-side prefetch queue with optional device staging.

    Wraps any iterator in a daemon thread + bounded queue.  With
    ``depth=2`` (the default) this is classic double buffering: while the
    consumer computes on batch *i*, the thread is already tiling/reading
    batch *i+1* — and, when ``device_put=True``, has issued its
    host→device transfer, so the copy overlaps compute too.

    Error contract: an exception in the producer (e.g. a truncated scene
    mid-stream) is captured, the thread exits, and the exception re-raises
    in the consumer at the point of the failed batch.  ``close()`` (or
    ``with``) shuts the thread down promptly even if the consumer abandons
    iteration early — the producer never blocks forever on a full queue.

    Staging: with ``device_put=True`` each yielded item is placed on
    device in the producer thread.  ``TileBundle``s (bare or inside a
    yielded tuple, as `iter_tile_batches` produces) stage tiles and
    headers separately — ``sharding`` may be a single device/sharding
    applied to both, or a ``(tiles_sharding, headers_sharding)`` pair
    (tiles are rank 3, headers rank 2, so NamedShardings need the pair
    form, e.g. ``batch_pspec(mesh, 3)`` / ``batch_pspec(mesh, 2)``).
    Plain arrays use the tiles sharding; non-array items (batch indices)
    pass through untouched.
    """

    _DONE = object()

    def __init__(self, it: Iterable, depth: int = 2,
                 device_put: bool = False, sharding=None):
        if depth <= 0:
            raise ValueError(f"depth must be positive, got {depth}")
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._device_put = device_put
        self._shardings = (tuple(sharding) if isinstance(sharding, tuple)
                           else (sharding, sharding))
        self._thread = threading.Thread(
            target=self._produce, args=(iter(it),), daemon=True,
            name="difet-prefetch")
        self._thread.start()

    def _stage_one(self, x):
        import jax
        tiles_sh, headers_sh = self._shardings
        if isinstance(x, TileBundle):
            return TileBundle(jax.device_put(x.tiles, tiles_sh),
                              jax.device_put(x.headers, headers_sh),
                              x.cfg)
        if isinstance(x, np.ndarray):
            return jax.device_put(x, tiles_sh)
        return x

    def _stage(self, item):
        if not self._device_put:
            return item
        if isinstance(item, tuple):
            return tuple(self._stage_one(x) for x in item)
        return self._stage_one(item)

    def _put(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._q.put(item, timeout=0.05)
                return True
            except queue.Full:
                continue
        return False

    def _produce(self, it):
        try:
            for item in it:
                if not self._put(self._stage(item)):
                    return                      # consumer closed early
        except BaseException as e:  # noqa: BLE001 — propagated to consumer
            self._error = e
        self._put(self._DONE)

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            try:
                item = self._q.get(timeout=0.05)
            except queue.Empty:
                if not self._thread.is_alive() and self._q.empty():
                    # producer died without a sentinel (shouldn't happen)
                    raise StopIteration
                continue
            if item is self._DONE:
                if self._error is not None:
                    err, self._error = self._error, None
                    raise err
                raise StopIteration
            return item

    def close(self):
        """Stop the producer thread and drop queued batches."""
        self._stop.set()
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
