"""Shape/sharding spec builders for the launchers and the dry-run.

Everything here works on ``jax.eval_shape`` abstractions — no device
allocation — so the full-size configs can be lowered with placeholder
meshes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    param_pspec_tree, dp_axes, batch_pspec, _dp_over_model_active,
)


def _data_axes(mesh):
    dp = dp_axes(mesh)
    if _dp_over_model_active() and "model" in mesh.axis_names:
        dp = dp + ("model",)
    return dp


def state_abstract(model, optimizer, step_cfg):
    """Abstract train state via eval_shape (no allocation)."""
    from repro.train.step import make_init_fn
    init_fn = make_init_fn(model, optimizer, step_cfg)
    return jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0)))


def params_abstract(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def state_pspecs(state_shapes, mesh):
    """Params + optimizer m/v share the param rules; counters replicated."""
    param_specs = param_pspec_tree(state_shapes["params"], mesh)
    out = {"params": param_specs,
           "opt": {"m": param_specs, "v": param_specs, "count": P()},
           "step": P()}
    if "err" in state_shapes:
        out["err"] = param_specs
    return out


# Per-device replicated-weight budget for serving params.  0 disables the
# feature (measured: decode collective is KV-gather-dominated, not param
# gathers, so replication bought nothing — §Perf second-round table).
SERVING_FSDP_BYTES_THRESHOLD = 0


def params_pspecs(params_shapes, mesh, serving: bool = False):
    """Parameter shardings.  For serving (no optimizer states), weights are
    replicated over the dp axes when they fit the per-device budget —
    FSDP-sharded weights would otherwise be all-gathered every decode step
    (the dominant decode collective, §Perf).  Large models keep FSDP."""
    specs = param_pspec_tree(params_shapes, mesh)
    if not serving:
        return specs
    model_sz = mesh.shape.get("model", 1)
    total_bytes = sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(params_shapes))
    if total_bytes / model_sz > SERVING_FSDP_BYTES_THRESHOLD:
        return specs                      # too big to replicate over dp
    dp = set(dp_axes(mesh))

    def drop_dp(spec):
        out = []
        for ax in tuple(spec):
            if ax is None:
                out.append(None)
            elif isinstance(ax, tuple):
                kept = tuple(a for a in ax if a not in dp)
                out.append(kept[0] if len(kept) == 1 else (kept or None))
            else:
                out.append(None if ax in dp else ax)
        return P(*out)

    return jax.tree_util.tree_map(
        drop_dp, specs, is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(batch_shapes, mesh):
    """Shard the leading (batch) dim of every batch leaf on the dp axes
    (largest divisible prefix, so dp_over_model degrades gracefully)."""
    from repro.distributed.sharding import largest_divisible_prefix
    dp = _data_axes(mesh)

    def f(leaf):
        if not leaf.shape:
            return P()
        ax = largest_divisible_prefix(leaf.shape[0], dp, mesh)
        return P(ax, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map(f, batch_shapes)


def cache_pspecs(cache_shapes, mesh, *, batch_size, max_seq, cfg):
    """Decode-cache sharding: batch dim on dp when divisible; otherwise the
    sequence dim (long-context B=1 → sequence-parallel KV).  KV-head dims
    shard on ``model`` when divisible."""
    from repro.distributed.sharding import largest_divisible_prefix
    dp = _data_axes(mesh)
    model_sz = mesh.shape.get("model", 1)

    def f(leaf):
        spec = [None] * len(leaf.shape)
        used_dp = False
        for i, d in enumerate(leaf.shape):
            if d == batch_size and not used_dp:
                ax = largest_divisible_prefix(d, dp, mesh)
                if ax is not None:
                    spec[i] = ax
                    used_dp = True
                break
        if not used_dp and max_seq:
            for i, d in enumerate(leaf.shape):
                if d == max_seq:
                    ax = largest_divisible_prefix(d, dp, mesh)
                    if ax is not None:
                        spec[i] = ax
                        used_dp = True
                    break
        # second axis: kv-head dim on model when divisible, else the
        # sequence dim (sequence-parallel KV — ragged head counts)
        def _has_model(s):
            return s == "model" or (isinstance(s, tuple) and "model" in s)
        placed_model = any(_has_model(s) for s in spec)
        for i, d in enumerate(leaf.shape):
            if spec[i] is None and d in (cfg.n_kv_heads, cfg.n_heads) \
                    and i >= 2 and d % model_sz == 0:
                spec[i] = "model"
                placed_model = True
                break
        # (head-dim sharding was tried here and REFUTED — §Perf: RoPE's
        # half-split and the flat qkv projections force reshards, 250x the
        # decode collective vs sequence-sharding.  Sequence it is.)
        if not placed_model and max_seq:
            for i, d in enumerate(leaf.shape):
                if spec[i] is None and d == max_seq and d % model_sz == 0:
                    spec[i] = "model"
                    break
        return P(*spec)

    return jax.tree_util.tree_map(f, cache_shapes)


def to_named(tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
