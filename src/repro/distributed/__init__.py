from repro.distributed.sharding import (  # noqa: F401
    use_mesh, current_mesh, shard_activation, param_pspec_tree,
    make_param_shardings, batch_pspec, dp_axes, data_mesh,
)
