"""Sharding rules: FSDP × TP × EP with divisibility fallback.

Logical axes
------------
* ``fsdp``   — parameter shards over the data-parallel axes (ZeRO-3 style):
               ``("pod", "data")`` on a multi-pod mesh, ``("data",)`` otherwise.
* ``tensor`` — tensor-parallel over ``model``.
* ``expert`` — expert-parallel over ``model`` (MoE expert dim).

Rules are name-based (matched against the param path suffix) and produce a
spec for the *unstacked* param; scan-stacked layer params get the spec
left-padded with ``None`` for the layer axis.  Any mesh axis that does not
divide the corresponding dim is dropped (MaxText-style fallback) so ragged
head counts (smollm 9H, whisper 20H, ...) and vocabs still shard wherever
divisibility allows.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
import numpy as np
from jax import tree_util as jtu
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Set the ambient mesh for sharding constraints (also enters `with mesh`)."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


def current_mesh() -> Optional[Mesh]:
    """The ambient mesh set by `use_mesh`, or None outside any context."""
    m = getattr(_state, "mesh", None)
    if m is not None:
        return m
    return None


@contextlib.contextmanager
def activation_dp_over_model(flag: bool):
    """When True, activation batch dims shard over (dp axes + model) —
    pure-DP activations for archs whose heads can't TP-shard."""
    prev = getattr(_state, "dp_over_model", False)
    _state.dp_over_model = flag
    try:
        yield
    finally:
        _state.dp_over_model = prev


def _dp_over_model_active() -> bool:
    return getattr(_state, "dp_over_model", False)


def dp_axes(mesh: Mesh):
    """Data-parallel mesh axes (pod-major on multi-pod meshes)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


# ---------------------------------------------------------------------------
# logical-axis resolution with divisibility fallback
# ---------------------------------------------------------------------------
def _resolve_axis(logical, mesh: Mesh):
    if logical is None:
        return None
    if logical == "fsdp":
        return dp_axes(mesh)
    if logical in ("tensor", "expert"):
        return ("model",) if "model" in mesh.axis_names else ()
    if logical == "dp":
        return dp_axes(mesh)
    raise ValueError(f"unknown logical axis {logical!r}")


def resolve_spec(logical_spec, shape, mesh: Mesh) -> P:
    """logical spec + concrete shape -> PartitionSpec with fallback."""
    # left-pad for stacked/extra leading dims
    pad = len(shape) - len(logical_spec)
    logical_spec = (None,) * pad + tuple(logical_spec)
    out = []
    for dim, logical in zip(shape, logical_spec):
        axes = _resolve_axis(logical, mesh)
        if not axes:
            out.append(None)
            continue
        kept = []
        prod = 1
        for a in axes:
            asz = mesh.shape[a]
            if dim % (prod * asz) == 0:
                kept.append(a)
                prod *= asz
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


# ---------------------------------------------------------------------------
# parameter rules (ordered; first match on path suffix wins)
# ---------------------------------------------------------------------------
PARAM_RULES = [
    # embeddings / lm head: [V, D]
    (r"(emb|head|patch_proj)/w$",        ("tensor", "fsdp")),
    (r"pos_emb$",                        (None, None)),
    # MoE experts: [E, d, ff] / [E, ff, d]
    (r"moe/w[iu]$",                      ("expert", "fsdp", None)),
    (r"moe/wo$",                         ("expert", None, "fsdp")),
    (r"moe/router$",                     ("fsdp", None)),
    # attention in-projections: [d, X]
    (r"(wq|wk|wv|wuq|wdq|wdkv|wkr)$",    ("fsdp", "tensor")),
    (r"(wuk|wuv)$",                      (None, "tensor")),   # [r, H*hd]
    # out-projections: [X, d]
    (r"wo$",                             ("tensor", "fsdp")),
    # MLP / xlstm / ssm in-projections: [d, F]
    (r"(wi|wu|in_proj|up_proj)$",        ("fsdp", "tensor")),
    (r"(out_proj|down_proj)$",           ("tensor", "fsdp")),
    # biases on tensor-sharded outputs
    (r"b[qkv]$",                         ("tensor",)),
    (r"bi$",                             ("tensor",)),
    (r"(bo|b)$",                         (None,)),
    # SSM per-channel params: [d_inner] or [H] — shard over tensor
    (r"(A_log|D|dt_bias)$",              ("tensor",)),
    (r"conv/w$",                         (None, "tensor")),
    (r"conv/b$",                         ("tensor",)),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jtu.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jtu.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def pspec_for(path_str: str, shape, mesh: Mesh) -> P:
    """PartitionSpec for one param: first `PARAM_RULES` suffix match wins,
    2D+ params fall back to (fsdp, tensor) on the trailing dims, scalars
    and norm scales replicate.  Sharded dims always divide the mesh axes
    (`resolve_spec` drops any axis that doesn't)."""
    for pat, logical in PARAM_RULES:
        if re.search(pat, path_str):
            return resolve_spec(logical, shape, mesh)
    if len(shape) >= 2:
        # generic 2D+ fallback: fsdp on -2, tensor on -1
        return resolve_spec(("fsdp", "tensor"), shape, mesh)
    return P()   # scalars / norm scales replicated


def param_pspec_tree(params_shapes, mesh: Mesh):
    """Map a pytree of ShapeDtypeStruct/arrays -> pytree of PartitionSpec."""
    def f(path, leaf):
        return pspec_for(_path_str(path), leaf.shape, mesh)
    return jtu.tree_map_with_path(f, params_shapes)


def make_param_shardings(params_shapes, mesh: Mesh):
    """`param_pspec_tree` with every spec wrapped in a NamedSharding —
    the form ``jax.jit(in_shardings=...)`` and device_put consume."""
    specs = param_pspec_tree(params_shapes, mesh)
    return jtu.tree_map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# activation sharding (used inside model code)
# ---------------------------------------------------------------------------
def _act_spec(kind: str, rank: int, mesh: Mesh) -> P:
    dp = dp_axes(mesh)
    if _dp_over_model_active() and "model" in mesh.axis_names:
        dp = dp + ("model",)
        if kind == "logits":   # vocab can't also use model — pure DP
            return P(dp, *([None] * (rank - 1)))
    dp = dp[0] if len(dp) == 1 else dp
    if kind == "hidden":      # [B, S, D]
        return P(dp, *([None] * (rank - 1)))
    if kind == "expert":      # [E, C, D] — EP on E only (C-dim sharding
        # REFUTED in §Perf iter 4: it forces cross-dp all-reduces on the
        # expert einsums, +40GiB all-reduce traffic)
        return P("model", *([None] * (rank - 1)))
    if kind == "logits":      # [B, S, V]
        return P(dp, None, "model")
    if kind == "batch":       # any batch-leading tensor
        return P(dp, *([None] * (rank - 1)))
    if kind == "kv_cache":    # [B, S, KVH, hd] — batch-sharded
        return P(dp, *([None] * (rank - 1)))
    raise ValueError(kind)


def largest_divisible_prefix(dim: int, axes, mesh: Mesh):
    """Longest prefix of ``axes`` whose size product divides ``dim``."""
    kept = []
    prod = 1
    for a in axes:
        if dim % (prod * mesh.shape[a]) != 0:
            break
        kept.append(a)
        prod *= mesh.shape[a]
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else tuple(kept)


def shard_activation(x, kind: str):
    """with_sharding_constraint if a mesh is ambient, identity otherwise."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = _act_spec(kind, x.ndim, mesh)
    # divisibility fallback: keep the largest prefix of grouped axes that
    # divides (so dp_over_model degrades to plain dp, not to replicated)
    concrete = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            concrete.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        concrete.append(largest_divisible_prefix(dim, axes, mesh))
    return jax.lax.with_sharding_constraint(x, P(*concrete))


def batch_pspec(mesh: Mesh, rank: int = 2) -> P:
    """PartitionSpec sharding only the leading (batch) dim over the data
    axes: ``P(data, None, ...)`` padded to ``rank``.  This is the one spec
    the DIFET tile path needs — tiles ``[N, H, W]`` and headers ``[N, 6]``
    both split over ``N``, everything per-tile stays local (the paper's
    "good locality": the map needs no cross-tile communication)."""
    dp = dp_axes(mesh)
    dp = dp[0] if len(dp) == 1 else dp
    return P(dp, *([None] * (rank - 1)))


def data_mesh(n_devices: Optional[int] = None) -> Mesh:
    """A 1-D ``("data",)`` mesh over the first ``n_devices`` local devices
    (all of them by default) — the mesh shape of the DIFET extraction
    workload, where the only parallel axis is the tile batch.  On a
    single-device host this degrades to a size-1 mesh, under which every
    sharding constraint is a no-op but the same code paths compile."""
    devs = jax.devices()
    n = len(devs) if n_devices is None else n_devices
    if not 1 <= n <= len(devs):
        raise ValueError(f"n_devices={n} outside [1, {len(devs)}]")
    return Mesh(np.asarray(devs[:n]), ("data",))
