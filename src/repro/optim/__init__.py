from repro.optim.adamw import AdamW, clip_by_global_norm  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
from repro.optim.compression import compress_decompress  # noqa: F401
