"""AdamW with fp32 state, decoupled weight decay, global-norm clipping.

ZeRO-style sharding falls out of the sharding rules: optimizer state trees
mirror the parameter tree, so each m/v leaf inherits its parameter's
FSDP × TP sharding — states are never replicated across data-parallel ranks.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params, lr):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        count = state["count"] + 1
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * gf
            v = self.b2 * v + (1 - self.b2) * gf * gf
            mhat = m / b1c
            vhat = v / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            if p.ndim >= 2:
                step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            return new_p, m, v

        out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                     params)
        new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "count": count}, gnorm
