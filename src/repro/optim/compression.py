"""Gradient compression with error feedback (optional, off by default).

On a real multi-pod deployment the cross-pod gradient reduction is the
slowest collective (DCN, not ICI).  int8 quantization with per-tensor scale
cuts that payload 4x (bf16) at the cost of quantization noise, which error
feedback re-injects on the next step (1-bit-Adam-style).  Under pjit the
reduction itself is implicit in the sharded backward pass, so this module
implements the *numerics* (quantize → dequantize + error buffer); the
payload saving is accounted analytically in the roofline (§Perf), and the
comm-path integration point is the grads pytree inside train_step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _q(g, err):
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), gf - deq


def compress_decompress(grads, error_state):
    """Returns (dequantized grads, new error feedback state)."""
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)
    out = jax.tree_util.tree_map(_q, grads, error_state)
    deq = jax.tree_util.tree_map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
    return deq, err
