"""Structured span tracing: request-scoped timelines across every layer
of the serving stack, recorded into a bounded flight recorder.

A *span* is one timed operation (``queue_wait``, ``device_step``,
``disk_get``, ``readmit``, …) tagged with the **trace id** minted when
its request passed router admission — so one request's whole journey
(admission → replica queue → batch execution → cache tiers → possibly a
``readmit`` hop after ``ReplicaDied``) shares one id and renders as one
lane in ``chrome://tracing`` (`repro/obs/export.py`).

The recorder is process-global and defaults to :class:`NoopRecorder`:
every instrumentation site guards on ``enabled()`` before touching a
clock, so the disabled cost is one attribute read per site — measurably
free (the bench_serve/bench_fleet throughput gates run with the no-op
recorder and must stay green).  :class:`FlightRecorder` keeps the last N
finished spans in a ring buffer and can dump them as Chrome-trace JSON
on demand or on a crash/shed trigger (``dump_on``) — the "what was the
fleet doing right before it died" artifact.

Timestamps are ``time.monotonic()`` floats; cross-thread ordering within
a process is meaningful (Linux CLOCK_MONOTONIC), and the exporter
rebases to trace start.  Instrumentation only *observes* — it never
changes batch formation, routing, or numerics, so traced runs stay
bit-identical to untraced ones (tested).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import os
import threading
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = ["Span", "NoopRecorder", "FlightRecorder", "get_recorder",
           "set_recorder", "enabled", "new_trace_id", "new_span_id",
           "current_trace_id", "use_trace", "span", "emit_span"]


@dataclasses.dataclass(frozen=True)
class Span:
    """One finished operation on a request's timeline.

    ``layer`` is the span taxonomy's coarse category (``router`` /
    ``scheduler`` / ``batch`` / ``kernel`` / ``cache`` / ``compile`` /
    ``job`` — docs/observability.md), ``trace_id`` ties the span to the
    admission that minted it (empty for background work), ``t0``/``t1``
    are ``time.monotonic()`` seconds, ``attrs`` carries small JSON-able
    details (replica, bucket, shed reason, …), and ``pid`` is the
    recording process (0 = unattributed; the fleet-telemetry aggregator
    stamps worker pids so each worker renders as its own Chrome-trace
    process lane)."""
    name: str
    layer: str
    trace_id: str
    span_id: str
    parent_id: str
    t0: float
    t1: float
    thread: str
    attrs: Tuple[Tuple[str, object], ...] = ()
    pid: int = 0

    @property
    def duration_s(self) -> float:
        """Span length in seconds (always >= 0 for a closed span)."""
        return self.t1 - self.t0


class NoopRecorder:
    """The default recorder: tracing off.  ``enabled`` is False and every
    instrumentation site checks it before building a span, so the only
    per-request cost is that one check."""
    enabled = False

    def emit(self, span: Span) -> None:
        """Discard (never called on guarded sites; safe if it is)."""

    def spans(self) -> List[Span]:
        """Always empty."""
        return []


class FlightRecorder:
    """Bounded ring buffer of the most recent finished spans.

    ``capacity`` bounds memory (a deque of dataclasses — old spans fall
    off the back under sustained traffic, which is the point: the flight
    recorder answers "what just happened", not "what ever happened").
    ``dump_on(reason)`` writes the current ring as Chrome-trace JSON into
    ``dump_dir`` — wired to the crash/shed paths (`serve/scheduler.py::
    BatchScheduler.kill`, `serve/router.py::Router._shed`), deduped per
    reason so a shed storm produces one artifact, not thousands."""
    enabled = True

    def __init__(self, capacity: int = 4096,
                 dump_dir: Optional[str] = None):
        self.capacity = int(capacity)
        self.dump_dir = dump_dir
        self._ring: "deque[Span]" = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._dumped: Dict[str, str] = {}      # reason -> artifact path
        self.emitted = 0

    def emit(self, span: Span) -> None:
        """Append one finished span (oldest falls off past capacity)."""
        with self._lock:
            self._ring.append(span)
            self.emitted += 1

    def spans(self) -> List[Span]:
        """Snapshot of the ring, oldest first."""
        with self._lock:
            return list(self._ring)

    def take_since(self, cursor: int) -> Tuple[List[Span], int]:
        """Spans emitted since a previous cursor (``(spans, new_cursor)``
        — start from cursor 0).  The incremental read the telemetry
        shipper batches over: spans that fell off the ring between reads
        are lost (bounded shipping is the contract), but nothing is ever
        shipped twice."""
        with self._lock:
            new = max(0, self.emitted - int(cursor))
            if new == 0:
                return [], self.emitted
            tail = list(self._ring)[-min(new, len(self._ring)):]
            return tail, self.emitted

    def clear(self) -> None:
        """Empty the ring (per-phase isolation in drivers/tests)."""
        with self._lock:
            self._ring.clear()

    def dump_on(self, reason: str) -> Optional[str]:
        """Dump the ring to ``dump_dir/flightrec-<reason>.json`` (Chrome
        trace format) the *first* time each reason fires; returns the
        artifact path, or None when ``dump_dir`` is unset / already
        dumped for this reason."""
        if not self.dump_dir:
            return None
        with self._lock:
            if reason in self._dumped:
                return None
            safe = "".join(c if c.isalnum() or c in "-_" else "-"
                           for c in reason)
            path = os.path.join(self.dump_dir, f"flightrec-{safe}.json")
            self._dumped[reason] = path
        from repro.obs import export as _export     # local: avoid cycle
        _export.write_chrome_trace(path, self.spans(),
                                   metadata={"dump_reason": reason})
        return path

    @property
    def dumps(self) -> Dict[str, str]:
        """``{reason: artifact path}`` of every dump taken so far."""
        with self._lock:
            return dict(self._dumped)


_RECORDER: object = NoopRecorder()
_trace_ids = itertools.count(1)
_span_ids = itertools.count(1)
_current: "contextvars.ContextVar[str]" = contextvars.ContextVar(
    "difet_trace_id", default="")


def get_recorder():
    """The process-global recorder (:class:`NoopRecorder` by default)."""
    return _RECORDER


def set_recorder(rec) -> object:
    """Install a recorder (returns the previous one).  Pass a
    :class:`FlightRecorder` to turn tracing on, :class:`NoopRecorder`
    to turn it off."""
    global _RECORDER
    prev, _RECORDER = _RECORDER, rec
    return prev


def enabled() -> bool:
    """Is tracing on?  The guard every instrumentation site checks
    before touching a clock or building a span."""
    return _RECORDER.enabled


def new_trace_id() -> str:
    """Mint a request trace id (process-unique; minted at router
    admission and propagated through every layer the request crosses)."""
    return f"t{os.getpid():x}-{next(_trace_ids):08x}"


def new_span_id() -> str:
    """Mint a span id (for parent/child links, e.g. batch → per-item)."""
    return f"s{next(_span_ids):08x}"


def current_trace_id() -> str:
    """The ambient trace id for this thread/context ('' when none) —
    how layers without a threaded-through id (the cache tiers) tag their
    spans."""
    return _current.get()


@contextlib.contextmanager
def use_trace(trace_id: str) -> Iterator[None]:
    """Set the ambient trace id for the duration of the block (restored
    on exit; cheap contextvar set/reset)."""
    tok = _current.set(trace_id)
    try:
        yield
    finally:
        _current.reset(tok)


def emit_span(name: str, layer: str, t0: float, t1: float, *,
              trace_id: Optional[str] = None, parent_id: str = "",
              span_id: Optional[str] = None, **attrs) -> Optional[str]:
    """Record an already-timed span (the scheduler computes queue-wait
    from stamps it takes anyway; no nested timing needed).  Returns the
    span id, or None when tracing is off."""
    rec = _RECORDER
    if not rec.enabled:
        return None
    sid = span_id or new_span_id()
    rec.emit(Span(name=name, layer=layer,
                  trace_id=(current_trace_id() if trace_id is None
                            else trace_id),
                  span_id=sid, parent_id=parent_id, t0=t0, t1=t1,
                  thread=threading.current_thread().name,
                  attrs=tuple(sorted(attrs.items())),
                  pid=os.getpid()))
    return sid


@contextlib.contextmanager
def span(name: str, layer: str, *, trace_id: Optional[str] = None,
         parent_id: str = "", **attrs) -> Iterator[None]:
    """Time a block and record it as one span.  When tracing is off this
    is one boolean check and a bare yield — the zero-cost-when-disabled
    contract the serving hot paths rely on."""
    rec = _RECORDER
    if not rec.enabled:
        yield
        return
    t0 = time.monotonic()
    try:
        yield
    finally:
        emit_span(name, layer, t0, time.monotonic(), trace_id=trace_id,
                  parent_id=parent_id, **attrs)
