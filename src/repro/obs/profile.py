"""Kernel profiling hooks: per-call wall/compile stamps keyed by the
PR 5 dispatch bucket, plus optional ``jax.profiler`` trace capture.

The benchmark-gated dispatcher (`kernels/dispatch.py`) decides *which*
matcher implementation runs, but after the decision the kernels execute
invisibly inside jit.  :class:`KernelProfiler` makes the hot calls
attributable: when enabled, `kernels/ops.py::match_best2` blocks on its
result and stamps the wall time under ``(metric, path, shape-bucket)``
— the same bucket key the dispatcher caches verdicts under, so a
profile row lines up 1:1 with a dispatch-cache entry — and the serving
compile path (`serve/buckets.py::warmup` / ``CompileCache``) stamps
per-program compile seconds.  Disabled (the default), the only cost is
one boolean check per call site, and no call gains a synchronization
point — profiling must never change async dispatch behavior of an
unprofiled run.

For whole-program traces, :func:`capture` wraps a block in
``jax.profiler.trace`` (TensorBoard-loadable) when the installed jax
exposes it — gated, never required, because CI runs CPU-only jax where
capture may be unavailable.
"""
from __future__ import annotations

import contextlib
import threading
import time
from typing import Dict, Iterator, Optional, Tuple

__all__ = ["KernelProfiler", "profiler", "set_profiler", "profile_call",
           "record_call", "record_compile", "capture"]


class KernelProfiler:
    """Accumulates per-key call/compile stamps (bounded: one row per
    distinct key — keys are dispatch buckets / program ids, a small
    closed set).

    A row holds ``calls``, total/last wall seconds, and compile seconds
    when a compile was attributed to the key.  ``snapshot()`` renders
    rows JSON-able for the metrics exporter."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._rows: Dict[str, Dict[str, float]] = {}
        self._lock = threading.Lock()

    def _row(self, key: str) -> Dict[str, float]:
        r = self._rows.get(key)
        if r is None:
            r = self._rows[key] = {"calls": 0, "wall_s": 0.0,
                                   "last_wall_s": 0.0, "compile_s": 0.0,
                                   "compiles": 0}
        return r

    def record_call(self, key: str, wall_s: float) -> None:
        """Stamp one timed call under ``key``."""
        with self._lock:
            r = self._row(key)
            r["calls"] += 1
            r["wall_s"] += wall_s
            r["last_wall_s"] = wall_s

    def record_compile(self, key: str, compile_s: float) -> None:
        """Attribute one compile (trace + XLA) to ``key``."""
        with self._lock:
            r = self._row(key)
            r["compiles"] += 1
            r["compile_s"] += compile_s

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """``{key: row}`` copy of every profiled key."""
        with self._lock:
            return {k: dict(v) for k, v in sorted(self._rows.items())}

    def reset(self) -> None:
        """Drop every row (per-run isolation)."""
        with self._lock:
            self._rows.clear()


class _NoopProfiler(KernelProfiler):
    """Disabled profiler: instrumentation sites see ``enabled=False``
    and skip timing entirely."""

    def __init__(self):
        super().__init__(enabled=False)


_PROFILER: KernelProfiler = _NoopProfiler()


def profiler() -> KernelProfiler:
    """The process-global profiler (disabled by default)."""
    return _PROFILER


def set_profiler(p: KernelProfiler) -> KernelProfiler:
    """Install a profiler (returns the previous one); pass
    ``KernelProfiler()`` to enable, ``None``-like noop to disable."""
    global _PROFILER
    prev, _PROFILER = _PROFILER, p
    return prev


def record_call(key: str, wall_s: float) -> None:
    """Module-level convenience for :meth:`KernelProfiler.record_call`
    (no-op when profiling is disabled)."""
    p = _PROFILER
    if p.enabled:
        p.record_call(key, wall_s)


def record_compile(key: str, compile_s: float) -> None:
    """Module-level convenience for :meth:`KernelProfiler.record_compile`
    (no-op when profiling is disabled)."""
    p = _PROFILER
    if p.enabled:
        p.record_compile(key, compile_s)


@contextlib.contextmanager
def profile_call(key: str, *, block=None) -> Iterator[None]:
    """Time a block under ``key`` when profiling is enabled (one boolean
    check otherwise).  ``block`` (optional) is called with no args before
    the clock stops — pass a ``block_until_ready`` thunk so async work is
    actually on the clock."""
    p = _PROFILER
    if not p.enabled:
        yield
        return
    t0 = time.monotonic()
    try:
        yield
    finally:
        if block is not None:
            block()
        p.record_call(key, time.monotonic() - t0)


@contextlib.contextmanager
def capture(logdir: Optional[str]) -> Iterator[bool]:
    """Optional ``jax.profiler`` trace capture around a block: yields
    True when a capture is actually running (jax present, profiler
    available, ``logdir`` set), False otherwise — callers behave
    identically either way, the capture is pure side-band."""
    if not logdir:
        yield False
        return
    try:
        import jax
        ctx = jax.profiler.trace(logdir)
    except Exception:  # noqa: BLE001 — capture is best-effort by contract
        yield False
        return
    with ctx:
        yield True
