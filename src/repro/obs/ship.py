"""Worker-side telemetry shipping: periodic *delta* snapshots of the
process-local metrics registry plus bounded span batches, spooled onto
the ``telemetry/`` channel of the worker's mailbox
(`serve/transport.py::WorkerMailbox`).

A process replica's `MetricsRegistry` histograms and `FlightRecorder`
spans die with the process — the parent only ever saw the flat
``stats()`` dict.  The shipper closes that gap with the repo's one
trusted cross-process primitive, tmp→atomic-rename files: every
``interval_s`` it publishes one sequenced message containing

* per-**counter** value deltas and per-**gauge** current values,
* per-**histogram** bucket-count deltas (against the previous
  `Histogram.counts()` baseline) with the matching count/sum deltas and
  lifetime min/max — deltas, so the parent-side merge
  (`repro/obs/agg.py`) is idempotent-by-sequence and *exact* under the
  shared fixed log-spaced bucket edges,
* the spans emitted since the previous shipment (bounded batch via
  `FlightRecorder.take_since`), serialized with the worker's pid and a
  wall/monotonic clock anchor so the aggregator can rebase them onto
  the parent's monotonic timeline,
* the worker's flight-recorder dump ledger (reason → artifact path),
  which the parent correlates with its own death/shed events.

One flush is forced at drain/retire (``ship(final=True)``) so a cleanly
retiring worker loses no tail telemetry; a SIGKILL'd worker loses at
most one interval's worth — the same bounded-loss contract any push
telemetry pipeline accepts.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs.trace import FlightRecorder, Span

__all__ = ["span_to_wire", "span_from_wire", "TelemetryShipper"]


def span_to_wire(s: Span) -> Dict[str, object]:
    """`Span` → JSON-able dict for a telemetry shipment (attrs become a
    list of ``[key, value]`` pairs; non-JSON attr values are
    stringified)."""
    attrs = []
    for k, v in s.attrs:
        if not isinstance(v, (bool, int, float, str)) and v is not None:
            v = str(v)
        attrs.append([k, v])
    return {"name": s.name, "layer": s.layer, "trace_id": s.trace_id,
            "span_id": s.span_id, "parent_id": s.parent_id,
            "t0": s.t0, "t1": s.t1, "thread": s.thread,
            "pid": s.pid, "attrs": attrs}


def span_from_wire(d: Dict[str, object], *,
                   dt: float = 0.0, pid: Optional[int] = None) -> Span:
    """Inverse of `span_to_wire`.  ``dt`` shifts both timestamps (the
    aggregator's clock rebase onto the parent's monotonic timeline) and
    ``pid`` overrides the recorded process id when set."""
    return Span(name=str(d["name"]), layer=str(d["layer"]),
                trace_id=str(d["trace_id"]), span_id=str(d["span_id"]),
                parent_id=str(d.get("parent_id", "")),
                t0=float(d["t0"]) + dt, t1=float(d["t1"]) + dt,
                thread=str(d.get("thread", "")),
                attrs=tuple((str(k), v) for k, v in d.get("attrs", [])),
                pid=int(pid if pid is not None else d.get("pid", 0)))


class TelemetryShipper:
    """Periodic delta shipper for one worker process (module docstring).

    Construct once after the worker's service is built; baselines start
    at zero so the first shipment carries everything observed since
    process start (warm-up compiles included).  Call :meth:`maybe_ship`
    from the worker's poll loop and :meth:`ship` with ``final=True`` on
    drain."""

    def __init__(self, mailbox, worker: str, *,
                 registry: Optional[obs_metrics.MetricsRegistry] = None,
                 recorder: Optional[FlightRecorder] = None,
                 interval_s: float = 0.25, max_spans: int = 1024):
        self.mailbox = mailbox
        self.worker = worker
        self.registry = registry or obs_metrics.registry()
        self.recorder = recorder
        self.interval_s = float(interval_s)
        self.max_spans = int(max_spans)
        self.seq = 0
        self._last_ship = time.monotonic()
        self._counter_base: Dict[str, float] = {}
        self._hist_base: Dict[str, Tuple[int, ...]] = {}
        self._hist_agg_base: Dict[str, Tuple[int, float]] = {}
        self._span_cursor = 0

    # -- delta assembly -------------------------------------------------------
    def _metric_deltas(self) -> Tuple[Dict[str, float], Dict[str, float],
                                      Dict[str, Dict[str, object]]]:
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, object]] = {}
        for name, m in self.registry.metrics().items():
            if isinstance(m, obs_metrics.Histogram):
                cur = m.counts()
                base = self._hist_base.get(name, (0,) * len(cur))
                n0, s0 = self._hist_agg_base.get(name, (0, 0.0))
                delta = [c - b for c, b in zip(cur, base)]
                n1, s1 = m.count, m.sum
                if any(delta):
                    hists[name] = {
                        "bounds": list(m.bounds), "delta": delta,
                        "count": n1 - n0, "sum": s1 - s0,
                        "min": m.min, "max": m.max}
                self._hist_base[name] = cur
                self._hist_agg_base[name] = (n1, s1)
            elif isinstance(m, obs_metrics.Gauge):
                gauges[name] = m.value
            else:
                v = m.value
                d = v - self._counter_base.get(name, 0.0)
                if d:
                    counters[name] = d
                self._counter_base[name] = v
        return counters, gauges, hists

    def _span_batch(self) -> List[Dict[str, object]]:
        if self.recorder is None:
            return []
        spans, self._span_cursor = self.recorder.take_since(self._span_cursor)
        return [span_to_wire(s) for s in spans[-self.max_spans:]]

    # -- publication ----------------------------------------------------------
    def ship(self, final: bool = False) -> Optional[int]:
        """Publish one delta shipment now; returns its sequence number,
        or None when there was nothing new to ship (a ``final`` flush
        always publishes, so the parent observes the retire marker)."""
        counters, gauges, hists = self._metric_deltas()
        spans = self._span_batch()
        dumps = dict(self.recorder.dumps) if self.recorder else {}
        if not (final or counters or hists or spans):
            self._last_ship = time.monotonic()
            return None
        self.seq += 1
        meta = {"worker": self.worker, "pid": os.getpid(), "seq": self.seq,
                "final": bool(final),
                "wall_minus_mono": time.time() - time.monotonic(),
                "counters": counters, "gauges": gauges, "hists": hists,
                "spans": spans, "dumps": dumps}
        self.mailbox.publish_telemetry(self.worker, self.seq, meta)
        self._last_ship = time.monotonic()
        return self.seq

    def maybe_ship(self, now: Optional[float] = None) -> Optional[int]:
        """Ship iff ``interval_s`` has elapsed since the last attempt;
        the worker loop calls this every iteration."""
        now = time.monotonic() if now is None else now
        if now - self._last_ship < self.interval_s:
            return None
        return self.ship()
