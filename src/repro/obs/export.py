"""Exporters: spans → Chrome-trace JSON, registry → flat metrics JSON,
plus the schema validator and the per-run latency-breakdown report.

Two machine-readable artifacts per observed run:

* **Chrome trace** (``chrome://tracing`` / Perfetto ``traceEvents``
  format): every finished span becomes one complete ``"ph": "X"`` event
  — ``cat`` is the span's layer, ``ts``/``dur`` are microseconds rebased
  to trace start, ``args`` carries trace/span ids and attrs.  The
  ``tid`` is the recording thread, so replica runner threads render as
  separate rows.
* **Metrics JSON**: the flat :class:`repro/obs/metrics.py::MetricsRegistry`
  snapshot + the kernel profiler rows — the artifact
  ``benchmarks/run.py`` folds into ``BENCH_<rev>.json`` so a benchmark
  row carries the provenance (dispatch decisions, cache hit mix, layer
  latency quantiles) of the run that produced it.

:func:`validate_chrome_trace` is the CI smoke gate's schema check:
events well-formed, all spans closed (``dur >= 0``), timestamps
monotonic in file order, and at least one span per required layer.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from repro.obs import metrics as _metrics
from repro.obs import profile as _profile
from repro.obs.trace import Span

__all__ = ["spans_to_chrome", "write_chrome_trace", "metrics_payload",
           "write_metrics_json", "validate_chrome_trace",
           "latency_breakdown", "render_report", "render_prometheus"]

# the per-layer latency histograms the breakdown table reports, in
# request-path order (docs/observability.md metric table)
BREAKDOWN_METRICS = (
    ("queue", "difet.scheduler.queue_s"),
    ("compile", "difet.compile.program_s"),
    ("kernel", "difet.kernel.step_s"),
    ("disk_read", "difet.cache.disk_read_s"),
    ("disk_write", "difet.cache.disk_write_s"),
)


def spans_to_chrome(spans: Sequence[Span],
                    metadata: Optional[dict] = None) -> dict:
    """Render finished spans as a Chrome-trace document (events sorted
    by start time, timestamps rebased to the earliest span)."""
    ordered = sorted(spans, key=lambda s: (s.t0, s.t1))
    t_base = ordered[0].t0 if ordered else 0.0
    events = []
    for s in ordered:
        args = {"trace_id": s.trace_id, "span_id": s.span_id}
        if s.parent_id:
            args["parent_id"] = s.parent_id
        args.update(dict(s.attrs))
        events.append({"name": s.name, "cat": s.layer, "ph": "X",
                       "ts": (s.t0 - t_base) * 1e6,
                       "dur": max(0.0, s.t1 - s.t0) * 1e6,
                       "pid": s.pid, "tid": s.thread, "args": args})
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "metadata": {"span_count": len(events), **(metadata or {})}}
    return doc


def write_chrome_trace(path: str, spans: Sequence[Span],
                       metadata: Optional[dict] = None) -> str:
    """Write :func:`spans_to_chrome` output to ``path``; returns it."""
    with open(path, "w") as f:
        json.dump(spans_to_chrome(spans, metadata), f, indent=1)
    return path


def metrics_payload(registry: Optional[_metrics.MetricsRegistry] = None,
                    extra: Optional[dict] = None) -> dict:
    """The metrics-JSON document: flat registry snapshot + kernel
    profiler rows (+ caller ``extra`` sections, e.g. fleet ``stats()``)."""
    reg = registry or _metrics.registry()
    doc = {"metrics": reg.snapshot(),
           "kernel_profile": _profile.profiler().snapshot()}
    if extra:
        doc.update(extra)
    return doc


def write_metrics_json(path: str,
                       registry: Optional[_metrics.MetricsRegistry] = None,
                       extra: Optional[dict] = None) -> str:
    """Write :func:`metrics_payload` to ``path``; returns it."""
    with open(path, "w") as f:
        json.dump(metrics_payload(registry, extra), f, indent=1,
                  sort_keys=True, default=str)
    return path


def validate_chrome_trace(doc: dict,
                          required_layers: Sequence[str] = ()) -> List[str]:
    """Minimal schema check for an exported trace; returns problem
    strings (empty = valid).  Checks: ``traceEvents`` present and
    non-empty, every event carries name/cat/ph/ts/dur, every span is
    closed (``dur >= 0``) and complete (``ph == "X"``), ``ts`` is
    monotonic non-decreasing in file order, and every layer in
    ``required_layers`` contributed at least one span."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    last_ts = -1.0
    seen_layers = set()
    for i, ev in enumerate(events):
        for field in ("name", "cat", "ph", "ts", "dur"):
            if field not in ev:
                problems.append(f"event {i} missing {field!r}")
        ph, ts, dur = ev.get("ph"), ev.get("ts", -1.0), ev.get("dur", -1.0)
        if ph != "X":
            problems.append(f"event {i} ({ev.get('name')}): ph={ph!r}, "
                            f"expected complete span 'X'")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
        elif ts < last_ts:
            problems.append(f"event {i}: ts {ts} < previous {last_ts} "
                            f"(not monotonic)")
        else:
            last_ts = ts
        if not isinstance(dur, (int, float)) or dur < 0:
            problems.append(f"event {i} ({ev.get('name')}): unclosed span "
                            f"(dur={dur!r})")
        seen_layers.add(ev.get("cat"))
    for layer in required_layers:
        if layer not in seen_layers:
            problems.append(f"no spans from required layer {layer!r} "
                            f"(saw {sorted(l for l in seen_layers if l)})")
    return problems


def _prom_name(name: str) -> str:
    """Metric name → Prometheus identifier (dots and every other
    non-``[a-zA-Z0-9_]`` character become underscores)."""
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_num(v: float) -> str:
    """Render a sample value the way Prometheus text format expects
    (integers without a trailing ``.0``, floats in short form)."""
    f = float(v)
    return str(int(f)) if f == int(f) else format(f, ".10g")


def render_prometheus(
        registry: Optional[_metrics.MetricsRegistry] = None) -> str:
    """Render the registry in the Prometheus text exposition format.

    Counters and gauges become single samples; histograms expose their
    raw buckets as *cumulative* ``<name>_bucket{le="<edge>"}`` series
    (Prometheus semantics: each bucket counts every observation at or
    below its upper edge, closing with ``le="+Inf"``) plus ``_sum`` and
    ``_count``.  Bucket edges print via ``%.6g`` so the output is
    byte-stable — the golden test in ``tests/test_telemetry.py`` pins
    it.  Scrape-side, ``histogram_quantile()`` over these buckets agrees
    with `Histogram.quantile` to within one bucket width."""
    reg = registry or _metrics.registry()
    lines: List[str] = []
    for name, m in sorted(reg.metrics().items()):
        pname = _prom_name(name)
        if isinstance(m, _metrics.Histogram):
            lines.append(f"# TYPE {pname} histogram")
            cum = 0
            for edge, c in zip(m.bounds, m.counts()):
                cum += c
                lines.append(f'{pname}_bucket{{le="{edge:.6g}"}} {cum}')
            lines.append(f'{pname}_bucket{{le="+Inf"}} {m.count}')
            lines.append(f"{pname}_sum {_prom_num(m.sum)}")
            lines.append(f"{pname}_count {m.count}")
        elif isinstance(m, _metrics.Gauge):
            lines.append(f"# TYPE {pname} gauge")
            lines.append(f"{pname} {_prom_num(m.value)}")
        else:
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {_prom_num(m.value)}")
    return "\n".join(lines) + ("\n" if lines else "")


def latency_breakdown(metrics: Dict[str, object]) -> List[dict]:
    """Rows for the per-run latency-breakdown table from a flat metrics
    snapshot: one row per instrumented layer stage (queue / compile /
    kernel / disk tier) with count, mean and p50/p95/p99 milliseconds."""
    rows = []
    for stage, name in BREAKDOWN_METRICS:
        h = metrics.get(name)
        if not isinstance(h, dict) or not h.get("count"):
            continue
        rows.append({"stage": stage, "metric": name,
                     "count": int(h["count"]),
                     "mean_ms": h["mean"] * 1e3,
                     "p50_ms": h["p50"] * 1e3,
                     "p95_ms": h["p95"] * 1e3,
                     "p99_ms": h["p99"] * 1e3,
                     "total_s": h["sum"]})
    return rows


def render_report(payload: dict) -> str:
    """Human-readable per-run report: the latency-breakdown table plus
    headline counters, from a :func:`metrics_payload`-shaped document."""
    metrics = payload.get("metrics", {})
    lines = ["per-layer latency breakdown:"]
    rows = latency_breakdown(metrics)
    if rows:
        head = (f"  {'stage':<12}{'count':>8}{'mean ms':>10}"
                f"{'p50 ms':>10}{'p95 ms':>10}{'p99 ms':>10}{'total s':>10}")
        lines.append(head)
        lines.append("  " + "-" * (len(head) - 2))
        for r in rows:
            lines.append(f"  {r['stage']:<12}{r['count']:>8}"
                         f"{r['mean_ms']:>10.3f}{r['p50_ms']:>10.3f}"
                         f"{r['p95_ms']:>10.3f}{r['p99_ms']:>10.3f}"
                         f"{r['total_s']:>10.3f}")
    else:
        lines.append("  (no layer histograms recorded)")
    counters = {k: v for k, v in metrics.items()
                if isinstance(v, (int, float))}
    if counters:
        lines.append("counters:")
        for k in sorted(counters):
            lines.append(f"  {k} = {counters[k]:g}")
    prof = payload.get("kernel_profile") or {}
    if prof:
        lines.append("kernel profile (per dispatch bucket):")
        for key, row in prof.items():
            lines.append(f"  {key}: calls={int(row['calls'])} "
                         f"wall={row['wall_s'] * 1e3:.2f}ms "
                         f"last={row['last_wall_s'] * 1e3:.3f}ms "
                         f"compiles={int(row['compiles'])} "
                         f"compile={row['compile_s'] * 1e3:.1f}ms")
    return "\n".join(lines)
