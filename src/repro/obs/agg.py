"""Parent-side telemetry aggregation: merge worker shipments into
fleet-level metrics, stitch cross-process spans into one Chrome trace,
and correlate worker flight-recorder dumps with parent events.

The counterpart of `repro/obs/ship.py`.  `TelemetryAggregator.ingest`
consumes the payload dicts the workers spooled onto their mailboxes'
``telemetry/`` channels and folds them into the parent registry:

* **histograms** merge bucket-wise into ``difet.fleet.*`` names
  (``difet.scheduler.queue_s`` → ``difet.fleet.scheduler.queue_s``).
  Because every histogram in the stack shares the fixed log-spaced
  edges of `repro/obs/metrics.py::default_bounds`, the merge is *exact*:
  the fleet histogram is indistinguishable from one that observed the
  union of all workers' streams, and its total count equals the sum of
  the per-worker observation counts (``worker_counts`` keeps that
  ledger; the ``--fleet --smoke`` CI gate asserts the equality).
* **counters** add their shipped deltas; **gauges** keep a per-worker
  last value and expose the fleet sum.
* **spans** are rebased from the worker's monotonic clock onto the
  parent's (via the shipped wall/monotonic anchor) and stamped with the
  worker's pid, so `spans_to_chrome` renders one process lane per
  worker and the admission-minted trace ids join ``admit → mailbox →
  worker exec → response`` across the process boundary.
* **dump ledgers** (worker flight-recorder artifacts) are correlated
  with the parent-side death/shed events recorded via `record_event` —
  "which worker dumped, why, and what the fleet was doing around it".
"""
from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.obs import metrics as obs_metrics
from repro.obs.ship import span_from_wire
from repro.obs.trace import Span

__all__ = ["fleet_metric_name", "TelemetryAggregator"]

FLEET_PREFIX = "difet.fleet."


def fleet_metric_name(name: str) -> str:
    """Worker metric name → its fleet-level aggregate name:
    ``difet.<layer>.<x>`` becomes ``difet.fleet.<layer>.<x>`` (names
    already under ``difet.fleet.`` or outside the ``difet.`` namespace
    are prefixed verbatim, so worker and parent metrics never collide in
    the parent registry)."""
    if name.startswith("difet.") and not name.startswith(FLEET_PREFIX):
        return FLEET_PREFIX + name[len("difet."):]
    return FLEET_PREFIX + name


class TelemetryAggregator:
    """Fleet-level merge of worker telemetry shipments (module
    docstring).  One instance per fleet, fed by
    `serve/fleet.py::Fleet.poll_telemetry`."""

    MAX_SPANS = 32768
    MAX_EVENTS = 512

    def __init__(self,
                 registry: Optional[obs_metrics.MetricsRegistry] = None):
        self.registry = registry or obs_metrics.registry()
        self.spans: "deque[Span]" = deque(maxlen=self.MAX_SPANS)
        self.worker_counts: Dict[str, Dict[str, int]] = {}
        self.worker_pids: Dict[str, int] = {}
        self.worker_seq: Dict[str, int] = {}
        self.worker_final: Dict[str, bool] = {}
        self.worker_dumps: Dict[str, Dict[str, str]] = {}
        self.events: List[Dict[str, object]] = []
        self._gauge_last: Dict[str, Dict[str, float]] = {}
        self.ingested = 0
        self.dropped = 0

    # -- ingestion ------------------------------------------------------------
    def _merge_hist(self, worker: str, name: str, h: Dict[str, object]) -> None:
        fname = fleet_metric_name(name)
        bounds = tuple(h.get("bounds", ()))
        fleet = self.registry.histogram(fname, bounds or None)
        if fleet.bounds != bounds:
            self.dropped += 1       # mismatched edges: merge would lie
            return
        fleet.merge_counts(h["delta"], count=int(h.get("count", 0)),
                           sum=float(h.get("sum", 0.0)),
                           min=float(h.get("min", float("inf"))),
                           max=float(h.get("max", float("-inf"))))
        ledger = self.worker_counts.setdefault(worker, {})
        ledger[name] = ledger.get(name, 0) + int(h.get("count", 0))

    def ingest(self, payloads: Sequence[Dict[str, object]]) -> int:
        """Fold a batch of shipped telemetry payloads (as collected by
        ``WorkerMailbox.collect_telemetry``) into the fleet registry and
        span store; returns how many were applied.  Payloads replaying
        an already-seen sequence number are dropped — collection
        consumes files, but a crash between read and unlink must not
        double-count deltas."""
        applied = 0
        parent_anchor = time.time() - time.monotonic()
        for p in payloads:
            worker = str(p.get("worker", "?"))
            seq = int(p.get("seq", 0))
            if seq <= self.worker_seq.get(worker, 0):
                self.dropped += 1
                continue
            self.worker_seq[worker] = seq
            pid = int(p.get("pid", 0))
            self.worker_pids[worker] = pid
            if p.get("final"):
                self.worker_final[worker] = True
            for name, d in (p.get("counters") or {}).items():
                self.registry.counter(fleet_metric_name(name)).inc(float(d))
            for name, v in (p.get("gauges") or {}).items():
                per = self._gauge_last.setdefault(name, {})
                per[worker] = float(v)
                self.registry.gauge(fleet_metric_name(name)).set(
                    sum(per.values()))
            for name, h in (p.get("hists") or {}).items():
                self._merge_hist(worker, name, h)
            # clock rebase: worker monotonic → parent monotonic via the
            # shipped wall-clock anchor (both sides' wall clocks agree;
            # their monotonic epochs don't)
            dt = float(p.get("wall_minus_mono", parent_anchor)) \
                - parent_anchor
            for w in (p.get("spans") or ()):
                self.spans.append(span_from_wire(w, dt=dt, pid=pid))
            dumps = p.get("dumps") or {}
            if dumps:
                self.worker_dumps.setdefault(worker, {}).update(
                    {str(k): str(v) for k, v in dumps.items()})
            applied += 1
            self.ingested += 1
        return applied

    # -- correlation ----------------------------------------------------------
    def record_event(self, kind: str, **attrs) -> None:
        """Note a parent-side event worth correlating against worker
        dumps (replica death, shed storm, SLO alert).  Bounded log."""
        self.events.append({"kind": kind, "t": time.monotonic(), **attrs})
        del self.events[:-self.MAX_EVENTS]

    def correlate_dumps(self, window_s: float = 10.0) -> List[Dict[str, object]]:
        """Join each worker flight-recorder dump with the parent events
        recorded within ``window_s`` of its ingestion — the "this worker
        dumped `shed-…` right as the parent declared replica-3 dead"
        digest the chaos summary prints."""
        now = time.monotonic()
        out = []
        for worker, dumps in sorted(self.worker_dumps.items()):
            near = [e for e in self.events if now - e["t"] <= window_s]
            for reason, path in sorted(dumps.items()):
                out.append({"worker": worker, "reason": reason,
                            "path": path, "parent_events": list(near)})
        return out

    # -- stitched views -------------------------------------------------------
    def stitched_spans(self, parent_spans: Sequence[Span] = ()) -> List[Span]:
        """Parent + every worker's spans on one rebased timeline, sorted
        by start — feed to `spans_to_chrome` for the single fleet-wide
        Chrome trace with per-worker pid/tid lanes."""
        merged = list(parent_spans) + list(self.spans)
        return sorted(merged, key=lambda s: (s.t0, s.t1))

    def fleet_counts(self) -> Dict[str, int]:
        """Per-metric total observation count summed over workers — the
        ground truth the merged ``difet.fleet.*`` histogram counts must
        equal (asserted by ``launch/obs.py --fleet --smoke``)."""
        totals: Dict[str, int] = {}
        for ledger in self.worker_counts.values():
            for name, n in ledger.items():
                totals[name] = totals.get(name, 0) + n
        return totals
