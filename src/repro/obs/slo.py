"""SLO burn-rate monitoring over the fleet-aggregated telemetry:
multi-window error-budget evaluation driving autoscaling signals and
flight-recorder dumps.

The PR 8 autoscaler compared a single windowed p99 against a threshold
— fine for scaling, but as an *alert* it is both twitchy (one slow
batch pages) and blind (a slow constant burn never crosses it).  This
module implements the standard multi-window **burn rate** scheme
instead: the SLO is "fraction ``objective`` of requests complete within
``latency_slo_s`` and are not shed"; the remaining fraction is the
error budget; the burn rate over a window is the budget consumed per
unit budget allowed.  An alert requires the burn to exceed its
threshold over **both** a fast window (catches cliffs, seconds) and a
slow window (confirms it isn't a blip) — the fast window gives the
latency, the slow window the precision.

Inputs are the *fleet-aggregated* artifacts of `repro/obs/agg.py`: the
admission→completion histogram (``difet.fleet.request_latency_s``,
fed by every worker's responses) and the typed shed counters — so an
N-process fleet is judged as one system.  On alert the monitor takes
exactly one deduped flight-recorder dump (``slo-burn-rate``), and its
windowed p99 is what `serve/fleet.py::Fleet.autoscale_tick` consumes
in telemetry mode — fleet-wide, not parent-only.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, Optional, Sequence, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

__all__ = ["SloPolicy", "BurnRateMonitor"]


@dataclasses.dataclass(frozen=True)
class SloPolicy:
    """Burn-rate alerting policy.

    ``latency_slo_s``/``objective``: the SLO — a request is *good* when
    it completes within ``latency_slo_s`` and was not shed; fraction
    ``objective`` of requests must be good, the rest is error budget.
    ``fast_window_s``/``slow_window_s`` are the two evaluation windows;
    ``fast_burn``/``slow_burn`` their burn-rate thresholds (the classic
    page-severity pairing is 14.4x over 5m *and* 6x over 1h, scaled
    down here to serving-bench time constants)."""
    latency_slo_s: float = 0.5
    objective: float = 0.999
    fast_window_s: float = 5.0
    slow_window_s: float = 60.0
    fast_burn: float = 14.4
    slow_burn: float = 6.0


class BurnRateMonitor:
    """Multi-window burn-rate evaluator over one latency histogram plus
    shed counters (module docstring).

    ``tick()`` samples the inputs, evaluates both windows, and returns a
    report dict; when both windows breach, it requests one deduped
    flight-recorder dump (reason ``slo-burn-rate``) from the installed
    recorder.  Samples are kept just long enough to cover the slow
    window — bounded memory, like everything else in ``repro/obs``."""

    DUMP_REASON = "slo-burn-rate"

    def __init__(self, hist: obs_metrics.Histogram,
                 shed_counters: Sequence[obs_metrics.Counter] = (),
                 policy: Optional[SloPolicy] = None,
                 clock=time.monotonic):
        self.hist = hist
        # a sequence of Counters, or a zero-arg callable returning one
        # (the router creates its typed shed counters lazily)
        self.shed_counters = (shed_counters if callable(shed_counters)
                              else tuple(shed_counters))
        self.policy = policy or SloPolicy()
        self.clock = clock
        # (t, bucket counts, total count, shed total) samples
        self._samples: "deque[Tuple[float, Tuple[int, ...], int, float]]" \
            = deque()
        self.alerts = 0
        self.last_report: Dict[str, object] = {}
        self._sample()                      # t0 baseline

    # -- sampling -------------------------------------------------------------
    def _shed_total(self) -> float:
        counters = (self.shed_counters() if callable(self.shed_counters)
                    else self.shed_counters)
        return float(sum(c.value for c in counters))

    def _sample(self) -> Tuple[float, Tuple[int, ...], int, float]:
        s = (self.clock(), self.hist.counts(), self.hist.count,
             self._shed_total())
        self._samples.append(s)
        horizon = s[0] - self.policy.slow_window_s - 1.0
        while len(self._samples) > 2 and self._samples[1][0] < horizon:
            self._samples.popleft()
        return s

    def _window_base(self, now: float, window_s: float):
        """The newest sample at least ``window_s`` old (or the oldest
        retained one, while history is still shorter than the window)."""
        base = self._samples[0]
        for s in self._samples:
            if now - s[0] >= window_s:
                base = s
            else:
                break
        return base

    # -- evaluation -----------------------------------------------------------
    def _good_cut(self) -> int:
        """Number of leading buckets whose upper edge is within the SLO
        (an observation in them is definitely good)."""
        n = 0
        for edge in self.hist.bounds:
            if edge <= self.policy.latency_slo_s:
                n += 1
            else:
                break
        return n

    def _window_burn(self, cur, base) -> Dict[str, object]:
        _, c0, n0, shed0 = base
        _, c1, n1, shed1 = cur
        delta = [a - b for a, b in zip(c1, c0)]
        total = max(0, n1 - n0)
        sheds = max(0.0, shed1 - shed0)
        cut = self._good_cut()
        good = sum(delta[:cut])
        bad = max(0, total - good) + sheds
        events = total + sheds
        budget = max(1e-9, 1.0 - self.policy.objective)
        burn = (bad / events) / budget if events else 0.0
        p99 = None
        if total:
            p99 = self.hist.quantile_since(c0, 0.99)
        return {"events": events, "bad": bad, "burn": burn, "p99": p99}

    def tick(self, now: Optional[float] = None) -> Dict[str, object]:
        """Sample + evaluate both windows.  Returns
        ``{"burn_fast", "burn_slow", "p99_fast", "alerting", "dump"}``
        (``dump`` is the artifact path the first time the alert fires,
        None otherwise — `FlightRecorder.dump_on` dedupes the reason)."""
        now = self.clock() if now is None else now
        cur = self._sample()
        fast = self._window_burn(cur, self._window_base(
            now, self.policy.fast_window_s))
        slow = self._window_burn(cur, self._window_base(
            now, self.policy.slow_window_s))
        alerting = (fast["burn"] >= self.policy.fast_burn
                    and slow["burn"] >= self.policy.slow_burn)
        dump = None
        if alerting:
            self.alerts += 1
            rec = obs_trace.get_recorder()
            if rec.enabled:
                dump = getattr(rec, "dump_on",
                               lambda _r: None)(self.DUMP_REASON)
        self.last_report = {
            "burn_fast": fast["burn"], "burn_slow": slow["burn"],
            "p99_fast": fast["p99"], "events_fast": fast["events"],
            "alerting": alerting, "dump": dump, "t": now}
        return self.last_report
