"""DIFET observability subsystem (docs/observability.md).

One package, four concerns, threaded through every serving layer:

* ``metrics.py`` — lock-cheap counters/gauges + fixed-bucket histograms
  with bounded-memory p50/p95/p99 (retires the unbounded per-request
  latency lists behind the old ``stats()`` quantiles);
* ``trace.py`` — structured span tracing: a trace id minted at router
  admission follows the request through queueing, batch execution, the
  cache tiers and crash re-admission; recorded into a bounded flight
  recorder, no-op (and measurably free) by default;
* ``profile.py`` — kernel profiling hooks keyed by the PR 5 dispatch
  bucket, plus optional ``jax.profiler`` capture;
* ``export.py`` — Chrome-trace JSON + flat metrics JSON exporters, the
  Prometheus text renderer, the schema validator CI gates on, and the
  latency-breakdown report.

The PR 9 fleet telemetry plane extends all of it across process
boundaries:

* ``ship.py`` — worker-side periodic *delta* shipping (metric bucket
  deltas + span batches) over the mailbox ``telemetry/`` channel;
* ``agg.py`` — parent-side aggregation: exact bucket-wise histogram
  merges into ``difet.fleet.*``, cross-process span stitching onto one
  rebased timeline, worker-dump correlation;
* ``slo.py`` — multi-window SLO burn-rate monitoring over the
  aggregated fleet metrics, feeding the autoscaler and the flight
  recorder.

Drivers: ``python -m repro.launch.obs`` (traced fleet run → artifacts →
report; ``--explain-dispatch`` decodes the dispatch cache;
``--fleet --smoke`` gates the cross-process telemetry plane).
"""
from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, registry, set_registry)
from repro.obs.trace import (FlightRecorder, NoopRecorder, Span,  # noqa: F401
                             get_recorder, set_recorder, enabled,
                             new_trace_id, current_trace_id, use_trace,
                             span, emit_span)
from repro.obs.profile import (KernelProfiler, profiler,  # noqa: F401
                               set_profiler, profile_call, capture)
from repro.obs.export import (spans_to_chrome, write_chrome_trace,  # noqa: F401
                              metrics_payload, write_metrics_json,
                              validate_chrome_trace, latency_breakdown,
                              render_report, render_prometheus)
from repro.obs.ship import (TelemetryShipper, span_to_wire,  # noqa: F401
                            span_from_wire)
from repro.obs.agg import (TelemetryAggregator,  # noqa: F401
                           fleet_metric_name)
from repro.obs.slo import BurnRateMonitor, SloPolicy  # noqa: F401
