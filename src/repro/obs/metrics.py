"""Lock-cheap metrics primitives: counters, gauges, and fixed-bucket
histograms with bounded-memory quantile estimation.

The PR 6 ``stats()`` counters answered "how many", but every latency
quantile in the stack was computed by appending one float per request to
a list and calling ``np.percentile`` on it — per-request memory growth
for the life of the process and an O(n log n) sort per stats poll.  The
:class:`Histogram` here replaces that: observations land in a *fixed*
set of log-spaced buckets (one integer increment per observe, a few
hundred bytes total regardless of traffic), and ``quantile`` answers
p50/p95/p99 by cumulative-count walk + linear interpolation inside the
crossing bucket.  The price is bounded quantile error (one bucket width,
~12% with the default edges), which is exactly the precision an SLO
gate needs and all a production registry can afford.

:class:`MetricsRegistry` is the process-wide namespace: metrics are
created on first use under the ``difet.<layer>.<name>`` convention
(docs/observability.md) and snapshot into one flat JSON-able dict that
`repro/obs/export.py` writes next to the Chrome trace.  Everything is
thread-safe; the hot paths take one short lock per observation.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "default_bounds", "registry", "set_registry"]


def default_bounds(lo: float = 1e-5, hi: float = 60.0,
                   factor: float = 1.25) -> Tuple[float, ...]:
    """Log-spaced histogram edges from ``lo`` to past ``hi`` (geometric
    ``factor`` steps) — the default covers 10 us .. 60 s, the span from a
    cache hit to a pathological queue stall, in ~70 buckets."""
    edges = [lo]
    while edges[-1] < hi:
        edges.append(edges[-1] * factor)
    return tuple(edges)


_DEFAULT_BOUNDS = default_bounds()


class Counter:
    """Monotonic counter.  ``inc`` is one lock + one add — cheap enough
    for admission paths; ``value`` reads the current total."""

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        """Add ``n`` (default 1) to the counter."""
        with self._lock:
            self._v += n

    @property
    def value(self) -> float:
        """Current total."""
        with self._lock:
            return self._v


class Gauge:
    """Last-write-wins instantaneous value (queue depth, replica count)."""

    def __init__(self, name: str):
        self.name = name
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        """Record the current level."""
        with self._lock:
            self._v = float(v)

    @property
    def value(self) -> float:
        """Most recently set level."""
        with self._lock:
            return self._v


class Histogram:
    """Fixed-bucket histogram with bounded memory and interpolated
    quantiles.

    ``bounds`` are the (sorted, positive) bucket upper edges; an
    observation lands in the first bucket whose edge is >= the value
    (one binary search + one integer increment), values beyond the last
    edge land in a single overflow bucket.  Memory is
    ``len(bounds) + 1`` integers *forever* — the regression test in
    ``tests/test_obs.py`` holds this against 100k observations, which is
    what retires the unbounded per-request latency lists behind the old
    scheduler/router ``stats()``.

    ``quantile(q)`` walks the cumulative counts to the crossing bucket
    and linearly interpolates inside it (clamped by the tracked
    min/max), so the error is at most one bucket width."""

    def __init__(self, name: str, bounds: Optional[Sequence[float]] = None):
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(bounds) if bounds \
            else _DEFAULT_BOUNDS
        if list(self.bounds) != sorted(self.bounds) or self.bounds[0] <= 0:
            raise ValueError("histogram bounds must be sorted and positive")
        self._counts = [0] * (len(self.bounds) + 1)   # +1 overflow bucket
        self._lock = threading.Lock()
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _bucket_index(self, v: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:                                 # first edge >= v
            mid = (lo + hi) // 2
            if self.bounds[mid] < v:
                lo = mid + 1
            else:
                hi = mid
        return lo

    def observe(self, v: float) -> None:
        """Record one observation (seconds, bytes, whatever the metric's
        unit is) — O(log buckets), constant memory."""
        v = float(v)
        i = self._bucket_index(v)
        with self._lock:
            self._counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    def observe_many(self, vs: Sequence[float]) -> None:
        """Bulk ``observe`` (one lock round-trip per value is fine; this
        exists for test/backfill ergonomics)."""
        for v in vs:
            self.observe(v)

    def _interpolate(self, counts: Sequence[int], total: int, q: float,
                     vmin: float, vmax: float) -> float:
        """Cumulative-count walk + linear interpolation over an arbitrary
        per-bucket count vector (the lifetime counts for `quantile`, a
        count *delta* for `quantile_since`)."""
        rank = q * total
        cum = 0.0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else 0.0
                hi = self.bounds[i] if i < len(self.bounds) else vmax
                lo, hi = max(lo, vmin if hi >= vmin else lo), min(hi, vmax)
                if hi <= lo:
                    return lo
                frac = (rank - cum) / c
                return lo + frac * (hi - lo)
            cum += c
        return vmax

    def quantile(self, q: float) -> float:
        """Estimate the ``q``-quantile (0..1) by linear interpolation in
        the crossing bucket; 0.0 on an empty histogram."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            total = self.count
            if total == 0:
                return 0.0
            counts = list(self._counts)
            vmin, vmax = self.min, self.max
        return self._interpolate(counts, total, q, vmin, vmax)

    def counts(self) -> Tuple[int, ...]:
        """Immutable per-bucket count snapshot (overflow bucket last) —
        the *baseline* for :meth:`quantile_since` windowed reads."""
        with self._lock:
            return tuple(self._counts)

    def quantile_since(self, baseline: Sequence[int],
                       q: float) -> Optional[float]:
        """Windowed quantile: the ``q``-quantile of only the observations
        recorded *since* ``baseline`` (a prior :meth:`counts` snapshot).
        Returns None when the window is empty — the SLO autoscaler's
        "no recent traffic" signal.  Interpolation is clamped by the
        lifetime min/max (the windowed extrema aren't tracked), so the
        error stays within one bucket width."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            cur = list(self._counts)
            vmin, vmax = self.min, self.max
        if len(baseline) != len(cur):
            raise ValueError("baseline shape mismatch (different bounds?)")
        window = [max(0, c - b) for c, b in zip(cur, baseline)]
        total = sum(window)
        if total == 0:
            return None
        return self._interpolate(window, total, q, vmin, vmax)

    def merge_counts(self, counts: Sequence[int], *, count: Optional[int] = None,
                     sum: float = 0.0, min: float = math.inf,
                     max: float = -math.inf) -> None:
        """Fold another histogram's per-bucket counts (or a counts
        *delta* between two snapshots) into this one, bucket-wise.  Both
        histograms must share the same ``bounds`` — under that invariant
        the merge is *exact*: the merged histogram is indistinguishable
        from one that observed the union stream (the fleet-telemetry
        mergeability property test in ``tests/test_telemetry.py``).
        ``count``/``sum`` are the observation count and value sum covered
        by ``counts`` (``count`` defaults to ``sum(counts)``);
        ``min``/``max`` widen the tracked extrema."""
        counts = [int(c) for c in counts]
        if len(counts) != len(self._counts):
            raise ValueError(
                f"merge shape mismatch: {len(counts)} buckets vs "
                f"{len(self._counts)} (different bounds?)")
        n = int(count) if count is not None else 0
        if count is None:
            for c in counts:
                n += c
        with self._lock:
            for i, c in enumerate(counts):
                self._counts[i] += int(c)
            self.count += n
            self.sum += float(sum)
            if min < self.min:
                self.min = float(min)
            if max > self.max:
                self.max = float(max)

    @property
    def mean(self) -> float:
        """Arithmetic mean of every observation (exact, not bucketed)."""
        with self._lock:
            return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> Dict[str, float]:
        """Flat JSON-able summary: count/sum/min/max/mean + p50/p95/p99."""
        with self._lock:
            n, s = self.count, self.sum
            vmin = self.min if n else 0.0
            vmax = self.max if n else 0.0
        return {"count": n, "sum": s, "min": vmin, "max": vmax,
                "mean": (s / n if n else 0.0),
                "p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}


class MetricsRegistry:
    """Process-wide named-metric namespace (``difet.<layer>.<name>``).

    ``counter``/``gauge``/``histogram`` create on first use and return
    the shared instance afterwards (one lock around the name map; the
    returned metric carries its own lock, so hot paths hold the registry
    lock only at creation).  ``snapshot()`` renders every metric into one
    flat dict for the metrics-JSON exporter."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            return m

    def counter(self, name: str) -> Counter:
        """The :class:`Counter` registered under ``name`` (created on
        first use; type mismatch with an existing name raises)."""
        m = self._get(name, lambda: Counter(name))
        if not isinstance(m, Counter):
            raise TypeError(f"{name} is a {type(m).__name__}, not Counter")
        return m

    def gauge(self, name: str) -> Gauge:
        """The :class:`Gauge` registered under ``name``."""
        m = self._get(name, lambda: Gauge(name))
        if not isinstance(m, Gauge):
            raise TypeError(f"{name} is a {type(m).__name__}, not Gauge")
        return m

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        """The :class:`Histogram` registered under ``name`` (``bounds``
        only applies at creation)."""
        m = self._get(name, lambda: Histogram(name, bounds))
        if not isinstance(m, Histogram):
            raise TypeError(f"{name} is a {type(m).__name__}, not Histogram")
        return m

    def names(self) -> List[str]:
        """Sorted registered metric names."""
        with self._lock:
            return sorted(self._metrics)

    def metrics(self) -> Dict[str, object]:
        """``{name: metric object}`` snapshot of the namespace (the
        metric objects themselves, not copies — the Prometheus exporter
        and the telemetry shipper walk this to read raw bucket counts)."""
        with self._lock:
            return dict(self._metrics)

    def snapshot(self) -> Dict[str, object]:
        """Flat ``{name: value-or-histogram-summary}`` dict of every
        registered metric — the metrics-JSON payload."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, object] = {}
        for name, m in sorted(items):
            out[name] = m.snapshot() if isinstance(m, Histogram) else m.value
        return out

    def reset(self) -> None:
        """Drop every metric (tests + per-run isolation in drivers)."""
        with self._lock:
            self._metrics.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-default registry every layer instruments into."""
    return _REGISTRY


def set_registry(reg: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-default registry (returns the previous one) —
    drivers use a fresh registry per run for clean per-run artifacts."""
    global _REGISTRY
    prev, _REGISTRY = _REGISTRY, reg
    return prev
