"""SmolLM-135M — llama-architecture small dense model. [hf:HuggingFaceTB/SmolLM-135M]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    head_dim=64,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    dp_over_model=True,   # 9 heads can't TP-shard over model=16
    rope_theta=1e4,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
))
