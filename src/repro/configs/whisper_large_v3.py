"""Whisper-large-v3 backbone — encoder-decoder transformer. [arXiv:2212.04356]

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, encoder_seq, d_model) and the
encoder consumes them directly.  MHA (n_kv_heads == n_heads), learned
positional embeddings (no RoPE) as in the original.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,                 # decoder layers
    n_encoder_layers=32,
    encoder_seq_len=1500,        # whisper 30 s of audio -> 1500 frames
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    rope_theta=0.0,              # 0 -> learned/sinusoidal positions, no RoPE
    dp_over_model=True,          # 20 heads can't TP-shard over model=16
    source="arXiv:2212.04356; unverified",
))
