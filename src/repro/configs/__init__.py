"""Architecture configs.  Importing this package registers every assigned
architecture (plus the paper's own DIFET pipeline config) in the registry."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, XLSTMConfig,
    ShapeConfig, SHAPES, applicable_shapes, get_config, all_arch_ids,
)
# architecture modules register themselves on import
from repro.configs import (  # noqa: F401
    internlm2_1_8b,
    qwen1_5_110b,
    glm4_9b,
    smollm_135m,
    whisper_large_v3,
    deepseek_v3_671b,
    dbrx_132b,
    internvl2_2b,
    xlstm_350m,
    zamba2_2_7b,
    difet_paper,
)

ARCH_IDS = all_arch_ids()
