"""DBRX-132B — fine-grained MoE, 16 experts top-4, GQA kv=8. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,                  # (unused: every layer is MoE; kept for report)
    vocab_size=100352,
    rope_theta=5e5,
    moe=MoEConfig(
        n_experts=16,
        n_experts_per_tok=4,
        d_ff_expert=10752,
        n_shared_experts=0,
        n_dense_layers=0,
        capacity_factor=1.25,
    ),
    remat="full",
    prefill_chunks=8,
    source="hf:databricks/dbrx-base; unverified",
))
