"""The paper's own workload config: DIFET feature extraction over LandSat-8.

Paper setup (Section 4): LandSat-8 scenes ~7000x7000 RGBA (~230 MB), N in
{3, 20} images, clusters of {1, 2, 4} nodes.  Our TPU-native analogue tiles
each scene into fixed tiles with halo overlap (DESIGN.md §2) and shards the
tile bundle across the ``data`` mesh axis.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class DifetConfig:
    # scene/tiling geometry
    scene_hw: Tuple[int, int] = (7681, 7831)   # the paper's example scene
    tile: int = 512                             # interior tile size (pixels)
    halo: int = 24                              # overlap; >= max detector window
    # detector parameters (OpenCV-compatible defaults, as the paper uses)
    harris_k: float = 0.04
    harris_threshold: float = 0.01              # relative to max response
    shi_tomasi_threshold: float = 0.01
    fast_threshold: float = 0.15                # intensity delta (0..1 scale)
    fast_arc: int = 9                           # FAST-9
    surf_hessian_threshold: float = 400.0       # paper: "Set surf hessian threshold to 400"
    # scale space (SIFT)
    n_octaves: int = 4
    scales_per_octave: int = 3
    sift_contrast_threshold: float = 0.04
    sift_edge_threshold: float = 10.0
    # descriptor parameters
    brief_n_bits: int = 256
    brief_patch: int = 31
    orb_n_features: int = 500
    # capacity: max keypoints kept per tile (static shapes on TPU)
    max_keypoints_per_tile: int = 512
    # numerics
    dtype: str = "float32"


PAPER_CONFIG = DifetConfig()

# Algorithms evaluated in the paper's Tables 1 & 2, in paper order.
PAPER_ALGORITHMS = (
    "harris", "shi_tomasi", "sift", "surf", "fast", "brief", "orb",
)
