"""Config system: architecture + shape + run configs.

Every assigned architecture is described by one :class:`ModelConfig`.  The
same dataclass covers dense / MoE / enc-dec / VLM / SSM / hybrid families so
that the model builder (``repro.models.model``) can be driven purely by
config — no per-arch model code outside the block library.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    n_experts_per_tok: int = 0      # top-k
    d_ff_expert: int = 0            # per-expert hidden
    n_shared_experts: int = 0       # DeepSeek-style always-on experts
    n_dense_layers: int = 0         # leading layers that stay dense
    capacity_factor: float = 1.25   # dispatch capacity multiplier
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dimensions."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block dimensions."""
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 6            # every k-th block is sLSTM, rest mLSTM
    proj_factor: float = 2.0        # mLSTM up-projection factor
    conv_kernel: int = 4


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0        # frames fed to the encoder (stub frontend)
    # vlm
    n_image_patches: int = 0        # patch embeddings prepended (stub frontend)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid (zamba2): a shared attention+MLP block applied every k ssm layers
    shared_attn_every: int = 0
    # numerics / runtime
    dtype: str = "bfloat16"
    remat: str = "dots"             # nothing | dots | full
    # analysis mode: python-loop the layer stacks instead of lax.scan so
    # cost_analysis sees every layer (roofline correction pass only)
    unroll_stacks: bool = False
    # prefill processes the request batch in this many sequential chunks
    # (lax.map) — bounds prefill activation peak for MoE archs at 32k
    prefill_chunks: int = 1
    # activations shard batch over (dp axes + model): for archs whose head
    # counts don't divide the model axis (smollm 9H, whisper 20H, xlstm 4H)
    # TP replicates activation compute 16x — pure-DP activations instead
    # (§Perf iteration: weights stay rule-sharded; XLA gathers them per
    # layer, which is cheap for <=1.5B-param models)
    dp_over_model: bool = False
    # source provenance, for documentation only
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
        )
        if self.n_encoder_layers:
            kw["n_encoder_layers"] = 2
            kw["encoder_seq_len"] = 16
        if self.n_image_patches:
            kw["n_image_patches"] = 8
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, n_experts_per_tok=2, d_ff_expert=64,
                n_dense_layers=min(self.moe.n_dense_layers, 1))
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk_size=32)
        if self.xlstm is not None:
            kw["xlstm"] = dataclasses.replace(self.xlstm, slstm_every=2)
        if self.shared_attn_every:
            kw["shared_attn_every"] = 2
        return self.replace(**kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> Tuple[str, ...]:
    """The assigned shape cells for this arch (skips per DESIGN.md §4)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        names.append("long_500k")
    return tuple(names)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    # import the arch modules lazily so `configs.base` has no import cycle
    from repro import configs as _c  # noqa: F401  (triggers registration)
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_arch_ids():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
