"""InternVL2-2B — InternViT frontend (STUB) + InternLM2 backbone. [arXiv:2404.16821]

The ViT frontend is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, n_image_patches, d_model) which are
prepended to the token embeddings.  Backbone matches internlm2 at 2B scale.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    n_image_patches=256,         # one 448x448 tile -> 256 visual tokens
    rope_theta=1e6,
    source="arXiv:2404.16821; hf",
))
