"""xLSTM-350M — sLSTM + mLSTM blocks, attention-free. [arXiv:2405.04517]

d_ff=0 per the assignment: xLSTM blocks carry their own up/down projections
(proj_factor), there is no separate transformer FFN.
"""
from repro.configs.base import ModelConfig, XLSTMConfig, register

CONFIG = register(ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=6, proj_factor=2.0, conv_kernel=4),
    dp_over_model=True,   # 4 heads can't TP-shard over model=16
    source="arXiv:2405.04517; unverified",
))
