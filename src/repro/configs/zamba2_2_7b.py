"""Zamba2-2.7B — Mamba2 backbone + shared attention block. [arXiv:2411.15242]

54 Mamba2 (SSD) layers; a single *shared* attention+MLP block is applied
every ``shared_attn_every`` layers (weight-tied across applications), as in
the Zamba2 design.  ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,                  # shared block MLP width
    vocab_size=32000,
    shared_attn_every=6,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    source="arXiv:2411.15242; hf",
))
