"""DeepSeek-V3 671B — MLA + fine-grained MoE (1 shared + 256 routed, top-8).
[arXiv:2412.19437; hf]

First 3 layers are dense (d_ff=18432); remaining 58 are MoE with per-expert
hidden 2048.  MLA dims per the tech report.  MTP head omitted from the
compute graph (training objective substrate implements next-token CE; MTP is
an auxiliary head, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig, register

CONFIG = register(ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,              # MLA: latent KV, head count == n_heads
    head_dim=128,                # nope head dim; rope part in MLAConfig
    d_ff=18432,                  # dense layers' FFN width
    vocab_size=129280,
    rope_theta=1e4,
    moe=MoEConfig(
        n_experts=256,
        n_experts_per_tok=8,
        d_ff_expert=2048,
        n_shared_experts=1,
        n_dense_layers=3,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    remat="full",
    prefill_chunks=8,
    source="arXiv:2412.19437; hf",
))
