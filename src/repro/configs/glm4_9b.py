"""GLM4-9B — dense transformer, aggressive GQA (kv=2), RoPE. [hf:THUDM/glm-4-9b]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    rope_theta=1e6,
    source="hf:THUDM/glm-4-9b; hf",
))
