"""TileBundle — the HIB (HipiImageBundle) analogue for SPMD execution.

The paper packs variable-size scenes into an HDFS bundle so each mapper gets
one image.  On a TPU pod the analogue is a fixed-shape tile tensor that
``jax.sharding`` can split over the ``data`` axis: scenes are cut into
``tile × tile`` interior tiles with a ``halo`` overlap so that stencil
detectors see enough context at tile borders; each tile's header records its
scene id, grid position, and valid interior extent (for edge tiles that
needed padding).  Feature ownership is *interior-only*: a corner found in a
halo belongs to the neighbouring tile, so global results are exactly
partition-invariant (tested).
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.configs.difet_paper import DifetConfig


@dataclasses.dataclass
class TileBundle:
    """A batch of tiles + header table (host-side metadata).

    tiles:   float32 [n_tiles, tile+2*halo, tile+2*halo]  (grayscale, 0..1)
    headers: int32   [n_tiles, 6] — (scene_id, ty, tx, valid_h, valid_w, pad)
    """
    tiles: np.ndarray
    headers: np.ndarray
    cfg: DifetConfig

    def __len__(self):
        return self.tiles.shape[0]

    @property
    def tile_hw(self) -> int:
        return self.cfg.tile + 2 * self.cfg.halo

    def pad_to(self, n: int) -> "TileBundle":
        """Pad with empty tiles (header pad flag = 1) to a multiple for SPMD."""
        cur = len(self)
        if cur >= n:
            return self
        extra = n - cur
        t = np.zeros((extra, self.tile_hw, self.tile_hw), np.float32)
        h = np.zeros((extra, 6), np.int32)
        h[:, 5] = 1
        return TileBundle(np.concatenate([self.tiles, t]),
                          np.concatenate([self.headers, h]), self.cfg)


def rgba_to_gray(img: np.ndarray) -> np.ndarray:
    """RGBA uint8 [H,W,4] -> grayscale float32 [H,W] in [0,1] (paper step 2)."""
    if img.ndim == 2:
        return img.astype(np.float32) / (255.0 if img.dtype == np.uint8 else 1.0)
    rgb = img[..., :3].astype(np.float32) / 255.0
    return 0.299 * rgb[..., 0] + 0.587 * rgb[..., 1] + 0.114 * rgb[..., 2]


def tile_scene(gray: np.ndarray, cfg: DifetConfig,
               scene_id: int = 0) -> TileBundle:
    """Cut one grayscale scene into halo-padded fixed tiles."""
    t, halo = cfg.tile, cfg.halo
    h, w = gray.shape
    ny = (h + t - 1) // t
    nx = (w + t - 1) // t
    padded = np.pad(gray, ((halo, halo + ny * t - h), (halo, halo + nx * t - w)),
                    mode="reflect")
    tiles, headers = [], []
    for ty in range(ny):
        for tx in range(nx):
            y0, x0 = ty * t, tx * t
            tiles.append(padded[y0:y0 + t + 2 * halo, x0:x0 + t + 2 * halo])
            valid_h = min(t, h - y0)
            valid_w = min(t, w - x0)
            headers.append((scene_id, ty, tx, valid_h, valid_w, 0))
    return TileBundle(np.stack(tiles).astype(np.float32),
                      np.asarray(headers, np.int32), cfg)


def bundle_scenes(scenes: Sequence[np.ndarray], cfg: DifetConfig) -> TileBundle:
    bundles = [tile_scene(rgba_to_gray(s) if s.ndim == 3 else s, cfg, i)
               for i, s in enumerate(scenes)]
    return TileBundle(
        np.concatenate([b.tiles for b in bundles]),
        np.concatenate([b.headers for b in bundles]),
        cfg)


def _atomic_savez(path: Path, **arrays) -> None:
    """Crash-safe npz write: savez into a sibling ``<name>.tmp``, then
    atomically ``Path.replace`` it over the target (the same protocol as
    ``DifetJob._commit``).  A writer dying mid-write leaves only an
    invisible ``*.npz.tmp`` — never a truncated ``.npz`` that would poison
    every subsequent restart of a checkpointed job."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        np.savez_compressed(f, **arrays)
    tmp.replace(path)


class BundleStore:
    """Pluggable bundle storage (the HDFS stand-in): local npz files + a
    JSON index.  Used by DifetJob for checkpointed, restartable jobs.
    All writes are atomic (tmp + rename); ``list()``/``has_result`` only
    ever see fully-committed files (``*.npz.tmp`` leftovers are invisible
    and get overwritten by the retry)."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def put(self, name: str, bundle: TileBundle) -> None:
        _atomic_savez(self.root / f"{name}.npz",
                      tiles=bundle.tiles, headers=bundle.headers,
                      cfg=json.dumps(dataclasses.asdict(bundle.cfg)))

    def get(self, name: str) -> TileBundle:
        z = np.load(self.root / f"{name}.npz", allow_pickle=False)
        cfg = DifetConfig(**{k: tuple(v) if isinstance(v, list) else v
                             for k, v in json.loads(str(z["cfg"])).items()})
        return TileBundle(z["tiles"], z["headers"], cfg)

    def list(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.npz")
                      if not p.name.endswith(".result.npz"))

    def put_result(self, name: str, result: Dict[str, np.ndarray]) -> None:
        _atomic_savez(self.root / f"{name}.result.npz", **result)

    def has_result(self, name: str) -> bool:
        return (self.root / f"{name}.result.npz").exists()

    def get_result(self, name: str) -> Dict[str, np.ndarray]:
        z = np.load(self.root / f"{name}.result.npz")
        return {k: z[k] for k in z.files}
