"""DIFET execution engine: the paper's map/shuffle/reduce on a TPU mesh.

Paper (Hadoop)                      Here (SPMD)
--------------                      -----------------------------------------
HIB bundle in HDFS                  TileBundle sharded over the `data` axis
mapper per image                    vmapped per-tile extractor, jit-compiled
  (decode→gray→detect→describe)       (detect → NMS → top-K → describe)
shuffle                             implicit resharding of per-tile results
reduce (collect outputs)            psum of counts + global top-K merge

The per-tile map needs no cross-tile communication (the paper's "good
locality" of LIFs); the only collectives are the final count all-reduce and
the top-K gather — which is why the workload scales out near-linearly
(Table 1) and why we reproduce that with a collective-light schedule.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core import detectors as D
from repro.core import descriptors as DS
from repro.core import nms
from repro.distributed.sharding import shard_activation


class AlgorithmSpec(NamedTuple):
    """One detector/descriptor algorithm as the engine consumes it.

    Fields:
        response:  ``(img [H,W], cfg, use_pallas) -> [H,W]`` dense
            per-pixel response map (algorithms sharing a response
            function share its computation, see `extract_tile_multi`).
        describe:  ``(img [H,W], ys [K], xs [K]) -> [K, D]`` descriptor
            extractor, or ``None`` for detector-only algorithms.
        threshold: ``cfg -> float`` absolute response threshold applied
            to the dense map before counting/top-K selection.
    """
    response: Callable
    describe: Optional[Callable]
    threshold: Callable


def _harris_resp(img, cfg, use_pallas):
    return D.harris_response(img, k=cfg.harris_k, use_pallas=use_pallas)


def _shi_resp(img, cfg, use_pallas):
    return D.shi_tomasi_response(img, use_pallas=use_pallas)


def _fast_resp(img, cfg, use_pallas):
    return D.fast_score(img, threshold=cfg.fast_threshold, arc=cfg.fast_arc,
                        use_pallas=use_pallas)


def _sift_resp(img, cfg, use_pallas):
    # octave-0 (full-res) extrema map drives keypoints.  OpenCV divides the
    # nominal contrast threshold by scales_per_octave — mirror that.
    # Routed through the fused scale-space path: one fused octave
    # computation (a single Pallas DMA on TPU) instead of a per-level
    # pyramid.
    return D.sift_dog_response(
        img, cfg.n_octaves, cfg.scales_per_octave,
        cfg.sift_contrast_threshold / cfg.scales_per_octave,
        use_pallas=use_pallas)[0]


def _surf_resp(img, cfg, use_pallas):
    return D.surf_hessian_response(img, use_pallas=use_pallas)


# paper thresholds are on 8-bit images; ours are [0,1] — rescale where the
# response is quadratic in intensity (hessian/structure-tensor) vs linear.
ALGORITHMS: Dict[str, AlgorithmSpec] = {
    "harris": AlgorithmSpec(_harris_resp, None,
                            lambda c: c.harris_threshold * 1e-4),
    "shi_tomasi": AlgorithmSpec(_shi_resp, None,
                                lambda c: c.shi_tomasi_threshold * 1e-2),
    "sift": AlgorithmSpec(_sift_resp, DS.sift_descriptors,
                          lambda c: c.sift_contrast_threshold
                          / c.scales_per_octave),
    "surf": AlgorithmSpec(_surf_resp, DS.surf_descriptors,
                          lambda c: c.surf_hessian_threshold / 255.0 ** 2),
    "fast": AlgorithmSpec(_fast_resp, None, lambda c: 0.0),
    "brief": AlgorithmSpec(_fast_resp, DS.brief_descriptors,
                           lambda c: 0.0),
    "orb": AlgorithmSpec(_fast_resp, DS.orb_descriptors, lambda c: 0.0),
}


def normalize_algorithms(spec) -> tuple:
    """Canonicalize an algorithm selection: accepts a comma-separated string
    or a sequence of names, strips whitespace, drops duplicates (first
    occurrence wins), and rejects unknown names with the valid choices
    spelled out.  Shared by the CLI drivers and the serving API so both
    fail the same way."""
    names = spec.split(",") if isinstance(spec, str) else list(spec)
    valid = ", ".join(sorted(ALGORITHMS))
    out = []
    for raw in names:
        name = raw.strip()
        if not name:
            continue
        if name not in ALGORITHMS:
            raise ValueError(
                f"unknown algorithm {name!r}; valid choices: {valid}")
        if name not in out:
            out.append(name)
    if not out:
        raise ValueError(f"no algorithms selected; valid choices: {valid}")
    return tuple(out)


def _select_and_describe(spec: AlgorithmSpec, cfg: DifetConfig, tile, header,
                         resp):
    """NMS → capacity-K selection → describe, given a precomputed response
    map.  Factored out of ``extract_tile`` so algorithms sharing a response
    (fast/brief/orb all use the FAST score) compute it once."""
    thr = spec.threshold(cfg)
    valid_h, valid_w = header[3], header[4]
    not_pad = header[5] == 0
    mask = nms.interior_mask(resp.shape, cfg.halo, valid_h, valid_w) & not_pad
    count = nms.count_above(resp, thr, mask)
    resp_nms = nms.nms3x3(resp)
    k = cfg.max_keypoints_per_tile
    ys, xs, scores, valid = nms.topk_keypoints(resp_nms, k, thr, mask)
    out = {"count": count, "scores": scores, "valid": valid}
    # global scene coordinates (interior-relative)
    out["ys"] = header[1] * cfg.tile + (ys - cfg.halo)
    out["xs"] = header[2] * cfg.tile + (xs - cfg.halo)
    if spec.describe is not None:
        desc = spec.describe(tile, ys, xs)
        out["desc"] = jnp.where(valid[:, None], desc,
                                jnp.zeros_like(desc))
    return out


def extract_tile(algorithm: str, cfg: DifetConfig, tile, header,
                 use_pallas: bool = False):
    """The DIFET 'map function' for one tile (cf. the paper's pseudo-code:
    convert → grayscale → detect → describe → emit).  Returns a dict of
    fixed-shape features."""
    spec = ALGORITHMS[algorithm]
    resp = spec.response(tile, cfg, use_pallas)
    return _select_and_describe(spec, cfg, tile, header, resp)


def extract_tile_multi(algorithms, cfg: DifetConfig, tile, header,
                       use_pallas: bool = False):
    """Per-tile map for several algorithms at once, computing each distinct
    response function ONCE: ``fast``/``brief``/``orb`` share the FAST score
    map instead of recomputing it thrice.  Returns {algorithm: features}."""
    resp_cache = {}
    out = {}
    for alg in algorithms:
        spec = ALGORITHMS[alg]
        if spec.response not in resp_cache:
            resp_cache[spec.response] = spec.response(tile, cfg, use_pallas)
        out[alg] = _select_and_describe(spec, cfg, tile, header,
                                        resp_cache[spec.response])
    return out


def _reduce_features(per_tile):
    """The reduce: total count all-reduce + global top-K merge."""
    total = per_tile["count"].sum()
    t, k = per_tile["scores"].shape
    flat_scores = per_tile["scores"].reshape(t * k)
    flat_valid = per_tile["valid"].reshape(t * k)
    masked = jnp.where(flat_valid, flat_scores, -jnp.inf)
    top_scores, idx = jax.lax.top_k(masked, min(k * 4, t * k))
    gather = lambda a: jnp.take(a.reshape(t * k, *a.shape[2:]), idx, axis=0)
    result = {
        "total_count": total,
        "per_tile_count": per_tile["count"],
        "top_scores": jnp.where(jnp.isfinite(top_scores), top_scores, 0.0),
        "top_ys": gather(per_tile["ys"]),
        "top_xs": gather(per_tile["xs"]),
        "top_valid": gather(per_tile["valid"]) & jnp.isfinite(top_scores),
        "keypoint_count": per_tile["valid"].sum(),
    }
    if "desc" in per_tile:
        result["top_desc"] = gather(per_tile["desc"])
    return result


def extract_features(bundle_tiles, bundle_headers, algorithm: str,
                     cfg: DifetConfig, use_pallas: bool = False):
    """vmapped map over tiles + the reduce: total count and global top-K."""
    per_tile = jax.vmap(
        functools.partial(extract_tile, algorithm, cfg,
                          use_pallas=use_pallas))(
        bundle_tiles, bundle_headers)
    return _reduce_features(per_tile)


def extract_features_multi(bundle_tiles, bundle_headers, algorithms,
                           cfg: DifetConfig, use_pallas: bool = False):
    """Multi-algorithm extraction with shared response maps: one vmapped map
    computes every requested algorithm per tile (fast/brief/orb reuse a
    single FAST score), then each algorithm gets its own reduce.  Returns
    {algorithm: result} with per-algorithm results identical to
    ``extract_features`` (same ops on the same inputs)."""
    algorithms = tuple(algorithms)
    per_tile = jax.vmap(
        functools.partial(extract_tile_multi, algorithms, cfg,
                          use_pallas=use_pallas))(
        bundle_tiles, bundle_headers)
    return {alg: _reduce_features(per_tile[alg]) for alg in algorithms}


def extract_request_features(bundle_tiles, bundle_headers, algorithms,
                             cfg: DifetConfig, use_pallas: bool = False):
    """Serving-path extraction: per-REQUEST results at batch shape.

    ``extract_features_multi`` reduces across the whole batch (one job, many
    tiles); here every batch row is an independent service request, so the
    reduce runs per tile over its own [1, K] candidate set.  Per-tile values
    are batch-invariant — each row runs the same elementwise program
    regardless of its neighbours or position — so a request's result is
    bit-identical to a direct single-tile ``extract_features_multi`` call no
    matter which batch the scheduler rode it in (asserted by the
    ``benchmarks/bench_serve.py`` parity gate and
    ``tests/test_serve.py::test_served_parity``)."""
    algorithms = tuple(algorithms)
    per_tile = jax.vmap(
        functools.partial(extract_tile_multi, algorithms, cfg,
                          use_pallas=use_pallas))(
        bundle_tiles, bundle_headers)

    def _single(tree):
        return _reduce_features(
            jax.tree_util.tree_map(lambda a: a[None], tree))

    return {alg: jax.vmap(_single)(per_tile[alg]) for alg in algorithms}


def make_serve_step(algorithms, cfg: DifetConfig, use_pallas: bool = False):
    """jit-compiled serving step for one (shape bucket, algorithm set) pair.
    The scheduler always pads batches to a fixed size, so each pair
    compiles exactly once (`serve/buckets.py::CompileCache`)."""
    return jax.jit(functools.partial(
        extract_request_features, algorithms=tuple(algorithms), cfg=cfg,
        use_pallas=use_pallas))


def make_distributed_extractor(algorithm: str, cfg: DifetConfig, mesh,
                               use_pallas: bool = False):
    """jit-compiled distributed extractor: tiles sharded over the data axis;
    the reduce lowers to one all-reduce (counts) + one gather (top-K)."""
    from repro.distributed.sharding import use_mesh, batch_pspec
    from jax.sharding import NamedSharding

    tile_sh = NamedSharding(mesh, batch_pspec(mesh, 3))
    hdr_sh = NamedSharding(mesh, batch_pspec(mesh, 2))

    fn = functools.partial(extract_features, algorithm=algorithm, cfg=cfg,
                           use_pallas=use_pallas)

    @functools.partial(jax.jit, in_shardings=(tile_sh, hdr_sh))
    def run(tiles, headers):
        return fn(tiles, headers)

    return run
