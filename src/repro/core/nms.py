"""Non-max suppression + capacity-K keypoint selection (static shapes).

MapReduce emits variable-length keypoint lists; SPMD needs fixed shapes.
A detector's dense response map goes through 3x3 NMS, halo/interior
ownership masking, then top-K selection per tile.  Counts are computed on
the *dense* thresholded map (before truncation) so Table-2 numbers are
exact regardless of capacity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def nms3x3(resp):
    """Keep values that are the strict max of their 3x3 neighbourhood.

    Response plateaus are tie-broken deterministically: among window pixels
    equal to the window max, only the one with the smallest row-major flat
    index survives, so a plateau emits at most one keypoint per 3x3 window
    (the seed's ``resp >= mx`` emitted one at EVERY plateau pixel).
    Regression: ``tests/test_nms_property.py::test_nms_plateau_tiebreak``.
    """
    win = (1,) * (resp.ndim - 2) + (3, 3)
    strides = (1,) * resp.ndim
    mx = lax.reduce_window(resp, -jnp.inf, lax.max, win, strides, "SAME")
    h, w = resp.shape[-2:]
    idx = (jnp.arange(h)[:, None] * w + jnp.arange(w)[None, :]).astype(
        jnp.int32)
    idx = jnp.broadcast_to(idx, resp.shape)
    sentinel = jnp.iinfo(jnp.int32).max
    # candidate = own index where the pixel attains its window max; the
    # window-min over candidates is the canonical (smallest-index) claimant
    cand = jnp.where(resp >= mx, idx, sentinel)
    min_idx = lax.reduce_window(cand, sentinel, lax.min, win, strides, "SAME")
    return jnp.where((resp >= mx) & (idx == min_idx), resp, 0.0)


def interior_mask(shape_hw, halo: int, valid_h, valid_w):
    """Ownership mask: only interior (non-halo) pixels within the valid
    extent of the tile (edge tiles are padded) emit features."""
    h, w = shape_hw
    ys = jnp.arange(h)
    xs = jnp.arange(w)
    my = (ys >= halo) & (ys < halo + valid_h)
    mx = (xs >= halo) & (xs < halo + valid_w)
    return my[:, None] & mx[None, :]


def count_above(resp, threshold, mask):
    """Exact feature count on the dense map (paper Table 2 analogue)."""
    return jnp.sum(((resp > threshold) & mask).astype(jnp.int32))


def topk_keypoints(resp, k: int, threshold, mask):
    """Select up to K strongest responses.

    Returns (ys [K], xs [K], scores [K], valid [K]) — fixed shapes; invalid
    slots have score 0 and valid=False.  Ties broken by flat index so the
    selection is deterministic and partition-invariant.
    """
    h, w = resp.shape[-2:]
    flat = jnp.where(mask & (resp > threshold), resp, -jnp.inf).reshape(
        *resp.shape[:-2], h * w)
    scores, idx = lax.top_k(flat, k)
    valid = jnp.isfinite(scores)
    scores = jnp.where(valid, scores, 0.0)
    ys = (idx // w).astype(jnp.int32)
    xs = (idx % w).astype(jnp.int32)
    return ys, xs, scores, valid


def merge_topk(scores_a, payload_a, scores_b, payload_b, k: int):
    """Merge two top-K sets (the 'shuffle' step of global reduction)."""
    scores = jnp.concatenate([scores_a, scores_b], axis=-1)
    top, idx = lax.top_k(scores, k)
    payload = jax.tree_util.tree_map(
        lambda a, b: jnp.take_along_axis(
            jnp.concatenate([a, b], axis=-1), idx, axis=-1),
        payload_a, payload_b)
    return top, payload
