"""Descriptor matching + robust registration (fully jit/vmap-able).

The layer between extraction and applications: DIFET computes per-scene
top-K descriptor sets (fixed shapes + validity masks, `core/engine.py`);
this module pairs them.  The same group's companion work stitches LandSat
scenes by pairwise feature matching (arXiv:1808.08522) — `launch/stitch.py`
drives that pipeline on top of these primitives.

* ``match_pair`` — mutual-nearest-neighbour + Lowe ratio filtering over
  fixed-shape (K, D) sets.  Distances come from the tiled matcher kernel /
  its jnp twin (`kernels/ops.match_best2`); metric inferred from dtype
  (packed uint32 -> Hamming, float -> squared L2).
* ``estimate_translation`` / ``estimate_similarity`` — fixed-iteration
  RANSAC with static shapes: hypothesis sampling, scoring and refinement
  are all dense [iters, K] ops, so a whole batch of scene pairs vmaps into
  one dispatch (`core/mosaic.py` shards that batch over the mesh).

Convention: a model maps scene-a coordinates to scene-b, ``pb ≈ T(pa)``.
For pure translation ``T(p) = p + t`` with ``t = (dy, dx)``; if scene
origins are ``O_a``/``O_b`` in a common frame then ``t = O_a - O_b``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import index as kindex
from repro.kernels import ops as kops

# any real distance is far below this; masked/overflow slots are far above
# (Hamming BIG = 2^30, empty-db L2 = +inf)
_MATCHED_CUT = 1e6


class PairMatches(NamedTuple):
    idx_b: jnp.ndarray    # [Ka] int32 — best database index per query
    ok: jnp.ndarray       # [Ka] bool — valid & mutual & ratio-accepted
    dist: jnp.ndarray     # [Ka] best distance (int Hamming / squared L2)


class TranslationEstimate(NamedTuple):
    t: jnp.ndarray          # [2] (dy, dx): pb ≈ pa + t
    inliers: jnp.ndarray    # [K] bool
    n_inliers: jnp.ndarray  # int32
    rms: jnp.ndarray        # f32 — rms inlier residual (px)


class SimilarityEstimate(NamedTuple):
    scale: jnp.ndarray      # f32
    theta: jnp.ndarray      # f32 radians (x-y plane, counter-clockwise)
    t: jnp.ndarray          # [2] (ty, tx)
    inliers: jnp.ndarray    # [K] bool
    n_inliers: jnp.ndarray  # int32
    rms: jnp.ndarray        # f32


def infer_metric(desc) -> str:
    return "hamming" if desc.dtype == jnp.uint32 else "l2"


def _filter_matches(valid_a, best, second, idx, ridx, ratio, metric
                    ) -> PairMatches:
    """Mutual + ratio acceptance shared by the exact and approx modes.

    The ratio test compares squared L2 distances, so the threshold is
    squared for float descriptors; Hamming distances are linear.  A query
    whose best and second-best distances tie is rejected by the strict
    ratio inequality — that (plus smallest-index argmin tie-breaks in the
    matcher) makes the surviving match set independent of database order,
    hence partition-invariant (tests/test_matcher.py).
    """
    r = ratio * ratio if metric == "l2" else ratio
    ka = idx.shape[0]
    mutual = jnp.take(ridx, idx) == jnp.arange(ka, dtype=jnp.int32)
    bf = best.astype(jnp.float32)
    sf = second.astype(jnp.float32)
    matched = bf < _MATCHED_CUT           # kills all-masked / empty databases
    ok = (valid_a != 0) & mutual & matched & (bf < r * sf)
    return PairMatches(idx, ok, best)


@functools.partial(jax.jit, static_argnames=("metric", "use_pallas"))
def _match_pair_exact(desc_a, valid_a, desc_b, valid_b, ratio, *,
                      metric: str, use_pallas: Optional[bool]) -> PairMatches:
    best, second, idx = kops.match_best2(desc_a, desc_b, valid_b,
                                         metric=metric, use_pallas=use_pallas)
    _, _, ridx = kops.match_best2(desc_b, desc_a, valid_a,
                                  metric=metric, use_pallas=use_pallas)
    return _filter_matches(valid_a, best, second, idx, ridx, ratio, metric)


def match_pair(desc_a, valid_a, desc_b, valid_b, ratio: float = 0.8, *,
               metric: Optional[str] = None, use_pallas: Optional[bool] = None,
               mode: str = "exact", probes: Optional[int] = None,
               index_a=None, index_b=None) -> PairMatches:
    """Mutual-NN + Lowe ratio matches from set a into set b.

    ``mode="exact"`` (default) scores every database row through the
    benchmark-gated `kernels/ops.match_best2` dispatcher (``use_pallas``
    forwards to it: None = measured auto-dispatch, True = force the
    kernels, False = force jnp) — fully jit-compatible.

    ``mode="approx"`` routes both directions through the pre-filter
    indexes in `kernels/index.py` (multi-probe LSH for packed Hamming
    bits, k-means inverted lists for L2) with an exact re-rank of the
    candidate sets, so accepted matches carry true distances and the only
    approximation is recall.  ``probes`` is the recall knob (more probed
    buckets -> higher recall, more candidates scored); ``index_a`` /
    ``index_b`` accept prebuilt `kernels.index.build_index` objects so a
    database matched against many query sets is indexed once.  Index
    construction is host-side, so approx mode is eager — call it outside
    jit.
    """
    metric = metric or infer_metric(desc_a)
    if mode == "exact":
        return _match_pair_exact(desc_a, valid_a, desc_b, valid_b, ratio,
                                 metric=metric, use_pallas=use_pallas)
    if mode != "approx":
        raise ValueError(f"unknown mode {mode!r}")
    if index_b is None:
        index_b = kindex.build_index(desc_b, valid_b, metric=metric)
    if index_a is None:
        index_a = kindex.build_index(desc_a, valid_a, metric=metric)
    best, second, idx = index_b.search(desc_a, probes)
    _, _, ridx = index_a.search(desc_b, probes)
    return _filter_matches(valid_a, best, second, idx, ridx, ratio, metric)


def _sample_valid(key, ok, shape):
    """Uniform indices into the True entries of ``ok`` (jit-able inverse-CDF
    draw via searchsorted on the running count).  Arbitrary if none are
    True — callers get 0 inliers in that case, never an exception."""
    cum = jnp.cumsum(ok.astype(jnp.int32))
    n_ok = cum[-1]
    u = jax.random.uniform(key, shape)
    target = jnp.floor(u * n_ok.astype(jnp.float32)).astype(jnp.int32)
    idx = jnp.searchsorted(cum, target, side="right")
    return jnp.clip(idx, 0, ok.shape[0] - 1).astype(jnp.int32)


def _finish(resid, okb, tol):
    inl = okb & (resid < tol)
    n = inl.sum().astype(jnp.int32)
    rms = jnp.sqrt(jnp.where(inl, resid * resid, 0.0).sum()
                   / jnp.maximum(n, 1).astype(jnp.float32))
    return inl, n, rms


@functools.partial(jax.jit, static_argnames=("iters",))
def estimate_translation(pa, pb, ok, key=None, tol: float = 2.0, *,
                         iters: int = 128) -> TranslationEstimate:
    """RANSAC translation: pa, pb [K, 2] (y, x); ok [K] bool.

    Fixed ``iters`` one-point hypotheses scored densely ([iters, K]
    residual matrix — no data-dependent shapes), then a least-squares
    refinement (inlier-mean offset) of the best hypothesis.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    okb = ok != 0
    pa = pa.astype(jnp.float32)
    pb = pb.astype(jnp.float32)
    s = _sample_valid(key, okb, (iters,))
    t = pb[s] - pa[s]                                        # [T, 2]
    resid = jnp.linalg.norm(pa[None] + t[:, None] - pb[None], axis=-1)
    inl = okb[None, :] & (resid < tol)
    hyp = jnp.argmax(inl.sum(axis=1))
    w = inl[hyp].astype(jnp.float32)
    t_ref = ((pb - pa) * w[:, None]).sum(axis=0) / jnp.maximum(w.sum(), 1.0)
    inl2, n2, rms = _finish(jnp.linalg.norm(pa + t_ref - pb, axis=-1),
                            okb, tol)
    return TranslationEstimate(t_ref, inl2, n2, rms)


@functools.partial(jax.jit, static_argnames=("iters",))
def estimate_similarity(pa, pb, ok, key=None, tol: float = 2.0, *,
                        iters: int = 256) -> SimilarityEstimate:
    """RANSAC similarity (scale + rotation + translation) via complex
    arithmetic: points are ``c = x + iy``, the model is ``c_b = z c_a + t``
    with ``z = scale · e^{iθ}``.  Two-point hypotheses; weighted complex
    least squares refines the winner."""
    if key is None:
        key = jax.random.PRNGKey(0)
    okb = ok != 0
    a = (pa[:, 1] + 1j * pa[:, 0]).astype(jnp.complex64)
    b = (pb[:, 1] + 1j * pb[:, 0]).astype(jnp.complex64)
    s = _sample_valid(key, okb, (iters, 2))
    a1, a2 = a[s[:, 0]], a[s[:, 1]]
    b1, b2 = b[s[:, 0]], b[s[:, 1]]
    den = a2 - a1
    good = jnp.abs(den) > 1e-6
    z = (b2 - b1) / jnp.where(good, den, 1.0)
    t = b1 - z * a1
    resid = jnp.abs(z[:, None] * a[None, :] + t[:, None] - b[None, :])
    inl = okb[None, :] & (resid < tol) & good[:, None]
    hyp = jnp.argmax(inl.sum(axis=1))
    w = inl[hyp].astype(jnp.float32)
    sw = jnp.maximum(w.sum(), 1e-6)
    am = (w * a).sum() / sw
    bm = (w * b).sum() / sw
    z2 = ((w * jnp.conj(a - am) * (b - bm)).sum()
          / jnp.maximum((w * jnp.abs(a - am) ** 2).sum(), 1e-9))
    t2 = bm - z2 * am
    inl2, n2, rms = _finish(jnp.abs(z2 * a + t2 - b), okb, tol)
    return SimilarityEstimate(jnp.abs(z2), jnp.angle(z2),
                              jnp.stack([jnp.imag(t2), jnp.real(t2)]),
                              inl2, n2, rms)


@functools.partial(jax.jit, static_argnames=("metric", "model", "iters",
                                             "use_pallas"))
def register_pair(ya, xa, desc_a, valid_a, yb, xb, desc_b, valid_b,
                  key=None, ratio: float = 0.8, tol: float = 2.0, *,
                  metric: Optional[str] = None, model: str = "translation",
                  iters: int = 128, use_pallas: Optional[bool] = None):
    """Match two scenes' feature sets and estimate the transform between
    them: the one-call registration primitive (vmapped over a pair batch by
    `core/mosaic.py`).  Returns (PairMatches, estimate)."""
    m = match_pair(desc_a, valid_a, desc_b, valid_b, ratio,
                   metric=metric, use_pallas=use_pallas)
    pa = jnp.stack([ya, xa], axis=-1).astype(jnp.float32)
    pb = jnp.stack([jnp.take(yb, m.idx_b), jnp.take(xb, m.idx_b)],
                   axis=-1).astype(jnp.float32)
    if model == "translation":
        est = estimate_translation(pa, pb, m.ok, key, tol, iters=iters)
    elif model == "similarity":
        est = estimate_similarity(pa, pb, m.ok, key, tol, iters=iters)
    else:
        raise ValueError(f"unknown model {model!r}")
    return m, est
