"""Feature descriptors: SIFT (128-d), SURF (64-d), BRIEF (256-bit),
ORB (steered BRIEF, 256-bit).

Descriptors are computed at capacity-K keypoints per tile with static
shapes: patch extraction is a vmapped ``dynamic_slice`` (clipped at tile
borders), histogramming is dense one-hot einsums (MXU-friendly — see
DESIGN.md §5 for why these are not Pallas kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pyramid import blur_separable, sobel_gradients


def extract_patches(img, ys, xs, size: int):
    """img [H,W]; ys,xs [K] (patch centers) -> patches [K, size, size].
    Start indices clip so patches near borders stay in-bounds.

    One batched gather with precomputed flat indices instead of K vmapped
    ``dynamic_slice`` calls: the K sequential slices become a single
    ``jnp.take``, shared by the SIFT/SURF/BRIEF/ORB descriptor stages
    (DESIGN.md §5).  Start-index clipping matches the dynamic_slice clamp,
    so values are identical.
    """
    h, w = img.shape
    half = size // 2
    y0 = jnp.clip(ys - half, 0, h - size)                   # [K]
    x0 = jnp.clip(xs - half, 0, w - size)
    d = jnp.arange(size)
    rows = y0[:, None] + d[None, :]                         # [K, size]
    cols = x0[:, None] + d[None, :]
    flat = rows[:, :, None] * w + cols[:, None, :]          # [K, size, size]
    return jnp.take(img.reshape(-1), flat, axis=0)


# ---------------------------------------------------------------------------
# SIFT descriptor
# ---------------------------------------------------------------------------
def _gaussian_window(size, sigma):
    c = (size - 1) / 2.0
    y = np.arange(size) - c
    g = np.exp(-0.5 * (y / sigma) ** 2)
    return jnp.asarray(np.outer(g, g).astype(np.float32))


def sift_descriptors(img, ys, xs, n_bins=8, n_cells=4, patch=16):
    """128-d SIFT descriptors at keypoints.  [K] -> [K, 128] (L2-normalized,
    0.2-clipped).  Orientation from a 36-bin gradient histogram; spatial
    binning is hard-assignment (trilinear interpolation omitted — counts and
    invariances preserved; noted in DESIGN.md)."""
    g = patch + 2
    patches = extract_patches(img, ys, xs, g)               # [K,g,g]
    gx, gy = sobel_gradients(patches)
    gx = gx[:, 1:-1, 1:-1]
    gy = gy[:, 1:-1, 1:-1]                                  # [K,p,p]
    mag = jnp.sqrt(gx * gx + gy * gy + 1e-12)
    ang = jnp.arctan2(gy, gx)                               # [-pi, pi]

    # --- dominant orientation: 36-bin weighted histogram -------------------
    w36 = _gaussian_window(patch, patch / 3.0)
    bins36 = jnp.floor((ang + np.pi) / (2 * np.pi) * 36).astype(jnp.int32) % 36
    hist36 = jax.vmap(
        lambda b, m: jnp.zeros((36,)).at[b.reshape(-1)].add(
            (m * w36).reshape(-1)))(bins36, mag)
    theta = (jnp.argmax(hist36, axis=-1).astype(jnp.float32) + 0.5) \
        / 36.0 * 2 * np.pi - np.pi                          # [K]

    # --- rotate gradient field by -theta, bin into 4x4x8 -------------------
    rel_ang = (ang - theta[:, None, None] + 3 * np.pi) % (2 * np.pi)
    obins = jnp.floor(rel_ang / (2 * np.pi) * n_bins).astype(jnp.int32) % n_bins
    cell = patch // n_cells
    yy = jnp.arange(patch) // cell
    cell_idx = (yy[:, None] * n_cells + yy[None, :]).astype(jnp.int32)
    flat_bin = cell_idx[None] * n_bins + obins               # [K,p,p]
    wgt = mag * _gaussian_window(patch, patch / 2.0)
    desc = jax.vmap(
        lambda b, m: jnp.zeros((n_cells * n_cells * n_bins,))
        .at[b.reshape(-1)].add(m.reshape(-1)))(flat_bin, wgt)
    desc = desc / jnp.maximum(
        jnp.linalg.norm(desc, axis=-1, keepdims=True), 1e-6)
    desc = jnp.minimum(desc, 0.2)
    desc = desc / jnp.maximum(
        jnp.linalg.norm(desc, axis=-1, keepdims=True), 1e-6)
    return desc


# ---------------------------------------------------------------------------
# SURF descriptor
# ---------------------------------------------------------------------------
def surf_descriptors(img, ys, xs, patch=20):
    """64-d SURF: 4x4 subregions × (Σdx, Σ|dx|, Σdy, Σ|dy|) of Haar responses."""
    g = patch + 2
    patches = extract_patches(img, ys, xs, g)
    # Haar responses ~ central differences on the smoothed patch
    sm = blur_separable(patches, 1.0)
    dx = sm[:, 1:-1, 2:] - sm[:, 1:-1, :-2]
    dy = sm[:, 2:, 1:-1] - sm[:, :-2, 1:-1]                 # [K,p,p]
    w = _gaussian_window(patch, 3.3)
    dx, dy = dx * w, dy * w
    sub = patch // 4
    dxs = dx.reshape(-1, 4, sub, 4, sub)
    dys = dy.reshape(-1, 4, sub, 4, sub)
    feats = jnp.stack([
        dxs.sum(axis=(2, 4)), jnp.abs(dxs).sum(axis=(2, 4)),
        dys.sum(axis=(2, 4)), jnp.abs(dys).sum(axis=(2, 4)),
    ], axis=-1)                                             # [K,4,4,4]
    desc = feats.reshape(-1, 64)
    return desc / jnp.maximum(
        jnp.linalg.norm(desc, axis=-1, keepdims=True), 1e-6)


# ---------------------------------------------------------------------------
# BRIEF / ORB descriptors (binary)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=4)
def brief_pairs(n_bits: int = 256, patch: int = 31, seed: int = 7):
    """The fixed BRIEF sampling pattern: isotropic Gaussian, sigma=patch/5
    (Calonder et al. 2010, G I).  Returns int32 [n_bits, 4] = (y1,x1,y2,x2)."""
    rng = np.random.RandomState(seed)
    sigma = patch / 5.0
    pts = np.clip(rng.randn(n_bits, 4) * sigma, -(patch // 2), patch // 2)
    return np.round(pts).astype(np.int32)


def _sample_pairs(patches, pairs, patch):
    """patches [K,p,p]; pairs [n,4] (offsets from center) -> bits [K,n]."""
    half = patch // 2
    y1 = pairs[:, 0] + half
    x1 = pairs[:, 1] + half
    y2 = pairs[:, 2] + half
    x2 = pairs[:, 3] + half
    flat = patches.reshape(patches.shape[0], -1)
    i1 = y1 * patch + x1
    i2 = y2 * patch + x2
    v1 = jnp.take(flat, i1, axis=1)
    v2 = jnp.take(flat, i2, axis=1)
    return (v1 < v2)


def pack_bits(bits):
    """bool [K, n] -> uint32 [K, n//32]."""
    k, n = bits.shape
    b = bits.reshape(k, n // 32, 32).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return (b << shifts).sum(axis=-1, dtype=jnp.uint32)


def brief_descriptors(img, ys, xs, n_bits=256, patch=31):
    """BRIEF: smoothed-intensity pair tests -> packed uint32 [K, n_bits/32]."""
    sm = blur_separable(img, 2.0)
    patches = extract_patches(sm, ys, xs, patch)
    pairs = jnp.asarray(brief_pairs(n_bits, patch))
    return pack_bits(_sample_pairs(patches, pairs, patch))


def orb_orientation(patches):
    """Intensity-centroid orientation (Rublee et al. 2011): theta [K]."""
    p = patches.shape[-1]
    c = (p - 1) / 2.0
    ys = jnp.arange(p) - c
    m10 = (patches * ys[None, None, :]).sum(axis=(-2, -1))   # x moment
    m01 = (patches * ys[None, :, None]).sum(axis=(-2, -1))   # y moment
    return jnp.arctan2(m01, m10)


def orb_descriptors(img, ys, xs, n_bits=256, patch=31):
    """ORB = oriented FAST + rotated BRIEF: the pair pattern is rotated by
    the patch orientation (discretized to 2π/30 as in the paper)."""
    sm = blur_separable(img, 2.0)
    big = patch + 14                                        # rotation margin
    patches = extract_patches(sm, ys, xs, big)
    theta = orb_orientation(
        patches[:, 7:7 + patch, 7:7 + patch])               # [K]
    step = 2 * np.pi / 30.0
    theta_q = jnp.round(theta / step) * step
    cos, sin = jnp.cos(theta_q), jnp.sin(theta_q)           # [K]
    pairs = jnp.asarray(brief_pairs(n_bits, patch)).astype(jnp.float32)
    # rotate both endpoints: (y,x) -> (x sin + y cos, x cos - y sin)
    def rot(y, x):
        ry = jnp.round(x[None, :] * sin[:, None] + y[None, :] * cos[:, None])
        rx = jnp.round(x[None, :] * cos[:, None] - y[None, :] * sin[:, None])
        return ry.astype(jnp.int32), rx.astype(jnp.int32)
    ry1, rx1 = rot(pairs[:, 0], pairs[:, 1])
    ry2, rx2 = rot(pairs[:, 2], pairs[:, 3])
    half = big // 2
    flat = patches.reshape(patches.shape[0], -1)
    i1 = (ry1 + half) * big + (rx1 + half)
    i2 = (ry2 + half) * big + (rx2 + half)
    v1 = jnp.take_along_axis(flat, i1, axis=1)
    v2 = jnp.take_along_axis(flat, i2, axis=1)
    return pack_bits(v1 < v2)
