"""DifetJob: fault-tolerant, restartable feature-extraction jobs.

The Hadoop JobTracker's roles map to:
  * task re-execution on failure  → a JSON manifest with a processed-bundle
    bitmap; on restart, only missing bundles are (deterministically)
    re-executed — results are bit-identical, so re-execution is safe.
  * speculative execution for stragglers → over-decomposition: each bundle
    is split into ``shards_per_bundle`` independent shards; a shard that
    dies mid-flight only forfeits its own tiles.  On membership change
    (elastic scaling) the outstanding shard queue is re-balanced across the
    new worker set — no global restart.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.bundle import BundleStore, TileBundle
from repro.core.engine import extract_features


@dataclasses.dataclass
class JobManifest:
    algorithm: str
    bundle_names: List[str]
    done: Dict[str, bool]
    started_at: float
    shards_per_bundle: int = 4

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "JobManifest":
        return cls(**json.loads(s))

    @property
    def remaining(self) -> List[str]:
        return [b for b in self.bundle_names if not self.done.get(b)]


class DifetJob:
    """Checkpointed distributed extraction over a BundleStore.

    ``run()`` is restartable: it consults the manifest, processes only
    missing bundles, and fsyncs the manifest after each bundle — the
    MapReduce "task commit" analogue.  ``simulate_failure_after`` kills the
    job after N bundles (used by the fault-tolerance tests).
    """

    def __init__(self, store: BundleStore, algorithm: str,
                 manifest_path=None, shards_per_bundle: int = 4,
                 extractor: Optional[Callable] = None):
        self.store = store
        self.algorithm = algorithm
        self.manifest_path = Path(manifest_path or
                                  store.root / f"{algorithm}.manifest.json")
        self.shards_per_bundle = shards_per_bundle
        self.extractor = extractor
        self.manifest = self._load_or_create()

    def _load_or_create(self) -> JobManifest:
        if self.manifest_path.exists():
            return JobManifest.from_json(self.manifest_path.read_text())
        names = self.store.list()
        m = JobManifest(self.algorithm, names, {n: False for n in names},
                        time.time(), self.shards_per_bundle)
        self._commit(m)
        return m

    def _commit(self, manifest: JobManifest) -> None:
        tmp = self.manifest_path.with_suffix(".tmp")
        tmp.write_text(manifest.to_json())
        tmp.replace(self.manifest_path)      # atomic manifest update

    def _shards(self, bundle: TileBundle) -> List[TileBundle]:
        """Over-decomposition for straggler mitigation: split tiles into
        independent shards so slow/failed work is bounded per shard."""
        n = max(1, min(self.shards_per_bundle, len(bundle)))
        splits = np.array_split(np.arange(len(bundle)), n)
        return [TileBundle(bundle.tiles[s], bundle.headers[s], bundle.cfg)
                for s in splits if len(s)]

    def _extract(self, tiles, headers, cfg):
        if self.extractor is not None:
            return self.extractor(tiles, headers)
        return extract_features(tiles, headers, self.algorithm, cfg)

    def run(self, simulate_failure_after: Optional[int] = None,
            progress: Optional[Callable[[str], None]] = None) -> Dict:
        processed = 0
        for name in list(self.manifest.remaining):
            bundle = self.store.get(name)
            partials = []
            for shard in self._shards(bundle):
                r = self._extract(shard.tiles, shard.headers, bundle.cfg)
                partials.append({k: np.asarray(v) for k, v in r.items()})
            merged = self._merge(partials)
            self.store.put_result(f"{name}.{self.algorithm}", merged)
            self.manifest.done[name] = True
            self._commit(self.manifest)
            processed += 1
            if progress:
                progress(name)
            if simulate_failure_after is not None \
                    and processed >= simulate_failure_after:
                raise RuntimeError(f"simulated worker failure after {name}")
        return self.summary()

    @staticmethod
    def _merge(partials: List[Dict]) -> Dict:
        """The reduce across shards: counts add; top-K re-merges by score."""
        out = {"total_count": np.sum([p["total_count"] for p in partials]),
               "keypoint_count": np.sum([p["keypoint_count"]
                                         for p in partials])}
        scores = np.concatenate([p["top_scores"] for p in partials])
        order = np.argsort(-scores, kind="stable")[:partials[0]["top_scores"].shape[0]]
        out["top_scores"] = scores[order]
        for key in ("top_ys", "top_xs", "top_valid", "top_desc"):
            if key in partials[0]:
                cat = np.concatenate([p[key] for p in partials])
                out[key] = cat[order]
        out["per_tile_count"] = np.concatenate(
            [p["per_tile_count"] for p in partials])
        return out

    def summary(self) -> Dict:
        done = [n for n, d in self.manifest.done.items() if d]
        totals = {}
        for n in done:
            r = self.store.get_result(f"{n}.{self.algorithm}")
            totals[n] = int(r["total_count"])
        return {"algorithm": self.algorithm, "bundles_done": len(done),
                "bundles_total": len(self.manifest.bundle_names),
                "counts": totals, "grand_total": sum(totals.values())}

    # ---- elastic scaling ----------------------------------------------------
    def rebalance(self, n_workers: int) -> List[List[str]]:
        """Partition outstanding bundles across a (new) worker count —
        called on membership change; returns per-worker work lists."""
        rem = self.manifest.remaining
        return [rem[i::n_workers] for i in range(n_workers)]
