"""Checkpointed, restartable jobs: the Hadoop JobTracker's roles map to:
  * task re-execution on failure  → a JSON manifest with a processed-item
    bitmap; on restart, only missing items are (deterministically)
    re-executed — results are bit-identical, so re-execution is safe.
  * speculative execution for stragglers → over-decomposition: each bundle
    is split into ``shards_per_bundle`` independent shards; a shard that
    dies mid-flight only forfeits its own tiles.  On membership change
    (elastic scaling) the outstanding work queue is re-balanced across the
    new worker set — no global restart.

``ManifestJob`` is the generic machinery (manifest + atomic commit + resume
loop); ``DifetJob`` is the extraction phase over bundles, and the stitching
workload's pairwise-registration phase (`core/mosaic.py::MatchPhase`)
reuses the same machinery for its match manifest.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.bundle import BundleStore, TileBundle
from repro.core.engine import extract_features, extract_features_multi


@dataclasses.dataclass
class JobManifest:
    algorithm: str                  # job name (extraction: algorithm string)
    bundle_names: List[str]         # work-item names, in execution order
    done: Dict[str, bool]
    started_at: float
    shards_per_bundle: int = 4

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "JobManifest":
        return cls(**json.loads(s))

    @property
    def remaining(self) -> List[str]:
        return [b for b in self.bundle_names if not self.done.get(b)]


class ManifestJob:
    """Checkpointed work queue over named items.

    ``run()`` is restartable: it consults the manifest, processes only
    missing items via ``process(name)`` (subclass hook), and commits the
    manifest write-tmp-then-rename after each item — the MapReduce "task
    commit" analogue.  ``simulate_failure_after`` kills the job after N
    items (used by the fault-tolerance tests).
    """

    def __init__(self, store: BundleStore, job_name: str,
                 items: Optional[Sequence[str]] = None, manifest_path=None,
                 shards_per_bundle: int = 4):
        self.store = store
        self.job_name = job_name
        self.manifest_path = Path(manifest_path or
                                  store.root / f"{job_name}.manifest.json")
        self.shards_per_bundle = shards_per_bundle
        self._items = items
        self.manifest = self._load_or_create()

    def _load_or_create(self) -> JobManifest:
        if self.manifest_path.exists():
            return JobManifest.from_json(self.manifest_path.read_text())
        names = (list(self._items) if self._items is not None
                 else self.store.list())
        m = JobManifest(self.job_name, names, {n: False for n in names},
                        time.time(), self.shards_per_bundle)
        self._commit(m)
        return m

    def _commit(self, manifest: JobManifest) -> None:
        tmp = self.manifest_path.with_suffix(".tmp")
        tmp.write_text(manifest.to_json())
        tmp.replace(self.manifest_path)      # atomic manifest update

    def process(self, name: str) -> None:
        raise NotImplementedError

    def run(self, simulate_failure_after: Optional[int] = None,
            progress: Optional[Callable[[str], None]] = None) -> Dict:
        processed = 0
        for name in list(self.manifest.remaining):
            self.process(name)
            self.manifest.done[name] = True
            self._commit(self.manifest)
            processed += 1
            if progress:
                progress(name)
            if simulate_failure_after is not None \
                    and processed >= simulate_failure_after:
                raise RuntimeError(f"simulated worker failure after {name}")
        return self.summary()

    def summary(self) -> Dict:
        done = [n for n, d in self.manifest.done.items() if d]
        return {"job": self.job_name, "bundles_done": len(done),
                "bundles_total": len(self.manifest.bundle_names)}

    # ---- elastic scaling ----------------------------------------------------
    def rebalance(self, n_workers: int) -> List[List[str]]:
        """Partition outstanding items across a (new) worker count —
        called on membership change; returns per-worker work lists."""
        rem = self.manifest.remaining
        return [rem[i::n_workers] for i in range(n_workers)]


class DifetJob(ManifestJob):
    """Checkpointed distributed extraction over a BundleStore.

    ``algorithm`` may be a single name or a comma-separated list
    (``"fast,brief,orb"``): multi-algorithm extraction routes through
    ``extract_features_multi`` so algorithms sharing a response function
    compute it once per tile; results are stored per algorithm
    (``<bundle>.<alg>``), identical to single-algorithm runs.
    """

    def __init__(self, store: BundleStore, algorithm: str,
                 manifest_path=None, shards_per_bundle: int = 4,
                 extractor: Optional[Callable] = None):
        # a custom extractor's output is opaque — store it under the full
        # job name rather than splitting into per-algorithm results
        if extractor is not None:
            self.algorithms = (algorithm,)
        else:
            self.algorithms = tuple(a.strip() for a in algorithm.split(",")
                                    if a.strip())
            algorithm = ",".join(self.algorithms)   # normalized whitespace
        self.algorithm = algorithm
        self.extractor = extractor
        super().__init__(store, algorithm, manifest_path=manifest_path,
                         shards_per_bundle=shards_per_bundle)

    def _shards(self, bundle: TileBundle) -> List[TileBundle]:
        """Over-decomposition for straggler mitigation: split tiles into
        independent shards so slow/failed work is bounded per shard."""
        n = max(1, min(self.shards_per_bundle, len(bundle)))
        splits = np.array_split(np.arange(len(bundle)), n)
        return [TileBundle(bundle.tiles[s], bundle.headers[s], bundle.cfg)
                for s in splits if len(s)]

    def _extract(self, tiles, headers, cfg) -> Dict[str, Dict]:
        if self.extractor is not None:
            return {self.algorithm: self.extractor(tiles, headers)}
        if len(self.algorithms) > 1:
            return extract_features_multi(tiles, headers, self.algorithms,
                                          cfg)
        return {self.algorithm:
                extract_features(tiles, headers, self.algorithm, cfg)}

    def process(self, name: str) -> None:
        bundle = self.store.get(name)
        partials: Dict[str, List[Dict]] = {}
        for shard in self._shards(bundle):
            r = self._extract(shard.tiles, shard.headers, bundle.cfg)
            for alg, res in r.items():
                partials.setdefault(alg, []).append(
                    {k: np.asarray(v) for k, v in res.items()})
        for alg, parts in partials.items():
            self.store.put_result(f"{name}.{alg}", self._merge(parts))

    @staticmethod
    def _merge(partials: List[Dict]) -> Dict:
        """The reduce across shards: counts add; top-K re-merges by score."""
        out = {"total_count": np.sum([p["total_count"] for p in partials]),
               "keypoint_count": np.sum([p["keypoint_count"]
                                         for p in partials])}
        scores = np.concatenate([p["top_scores"] for p in partials])
        order = np.argsort(-scores, kind="stable")[:partials[0]["top_scores"].shape[0]]
        out["top_scores"] = scores[order]
        for key in ("top_ys", "top_xs", "top_valid", "top_desc"):
            if key in partials[0]:
                cat = np.concatenate([p[key] for p in partials])
                out[key] = cat[order]
        out["per_tile_count"] = np.concatenate(
            [p["per_tile_count"] for p in partials])
        return out

    def _alg_counts(self, done: List[str], alg: str) -> Dict[str, int]:
        return {n: int(self.store.get_result(f"{n}.{alg}")["total_count"])
                for n in done}

    def summary(self) -> Dict:
        done = [n for n, d in self.manifest.done.items() if d]
        base = {"algorithm": self.algorithm, "bundles_done": len(done),
                "bundles_total": len(self.manifest.bundle_names)}
        if len(self.algorithms) == 1:
            counts = self._alg_counts(done, self.algorithm)
            return {**base, "counts": counts,
                    "grand_total": sum(counts.values())}
        per_alg = {}
        for alg in self.algorithms:
            counts = self._alg_counts(done, alg)
            per_alg[alg] = {"counts": counts,
                            "grand_total": sum(counts.values())}
        return {**base, "per_algorithm": per_alg,
                "grand_total": sum(p["grand_total"]
                                   for p in per_alg.values())}
