"""Checkpointed, restartable jobs: the Hadoop JobTracker's roles map to:
  * task re-execution on failure  → a JSON manifest with a processed-item
    bitmap; on restart, only missing items are (deterministically)
    re-executed — results are bit-identical, so re-execution is safe.
  * speculative execution for stragglers → over-decomposition: each bundle
    is split into ``shards_per_bundle`` independent shards; a shard that
    dies mid-flight only forfeits its own tiles.  On membership change
    (elastic scaling) the outstanding work queue is re-balanced across the
    new worker set — no global restart.

``ManifestJob`` is the generic machinery (manifest + atomic commit + resume
loop + per-worker leases); ``DifetJob`` is the extraction phase over
bundles, and the stitching workload's pairwise-registration phase
(`core/mosaic.py::MatchPhase`) reuses the same machinery for its match
manifest.

Multi-worker protocol (docs/scaling.md): the manifest's item order is
fixed at creation and never rewritten — restart-determinism means any
worker count walks the *same* ordered list.  Workers coordinate through
``LeaseBoard``: an item is claimed by atomically creating a sidecar lease
file; a crashed worker's lease expires after ``ttl_s`` and any live
worker re-claims the item.  Because processing is deterministic and the
result commit is atomic, a lease race at worst duplicates work — it never
corrupts a result.  That is what makes the worker count *elastic*: kill
workers, restart with more or fewer, and the job resumes cleanly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bundle import BundleStore, TileBundle
from repro.core.engine import extract_features, extract_features_multi
from repro.obs import metrics as obs_metrics


@dataclasses.dataclass
class JobManifest:
    """The on-disk job state: ordered work items + their done bitmap.

    ``bundle_names`` is fixed at creation and NEVER rewritten — the
    restart-determinism contract: every restart, and every worker of an
    elastic pool, walks the same ordered list (leases partition it).

    Fields:
        algorithm:         job name (extraction jobs: the algorithm string).
        bundle_names:      work-item names in execution order.
        done:              item name -> committed flag.
        started_at:        epoch seconds at manifest creation.
        shards_per_bundle: over-decomposition factor (straggler bound).
    """
    algorithm: str
    bundle_names: List[str]
    done: Dict[str, bool]
    started_at: float
    shards_per_bundle: int = 4

    def to_json(self) -> str:
        """Serialize for the atomic manifest commit."""
        return json.dumps(dataclasses.asdict(self), indent=1)

    @classmethod
    def from_json(cls, s: str) -> "JobManifest":
        """Parse a manifest previously written by `to_json`."""
        return cls(**json.loads(s))

    @property
    def remaining(self) -> List[str]:
        """Unprocessed item names, in manifest (execution) order."""
        return [b for b in self.bundle_names if not self.done.get(b)]


class LeaseBoard:
    """Per-item worker leases: filesystem claims for elastic worker pools.

    ``acquire(item, worker)`` claims an item by creating
    ``<item>.lease`` with ``O_CREAT | O_EXCL`` — the same cross-process
    atomicity the manifest commit relies on.  A lease older than
    ``ttl_s`` is considered orphaned (its worker died) and is stolen with
    an atomic replace.  Re-acquiring one's own lease refreshes it.

    The board is an *optimization*, not a correctness boundary: item
    processing is deterministic and result commits are atomic, so the
    worst outcome of a steal race is two workers redundantly computing
    the same bit-identical result (MapReduce speculative execution).
    """

    def __init__(self, root, ttl_s: float = 600.0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.ttl_s = ttl_s

    def _path(self, item: str) -> Path:
        return self.root / f"{item}.lease"

    def _write(self, path: Path, worker: str) -> None:
        # unique tmp per writer (two stealers racing must not consume each
        # other's tmp file; the losing replace just overwrites benignly)
        tmp = path.with_suffix(
            f".tmp.{os.getpid()}.{threading.get_ident()}")
        tmp.write_text(json.dumps({"worker": worker, "t": time.time()}))
        tmp.replace(path)

    def acquire(self, item: str, worker: str) -> bool:
        """Try to claim ``item`` for ``worker``; True on success (including
        refreshing a lease this worker already holds or stealing a stale
        one)."""
        path = self._path(item)
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            try:
                lease = json.loads(path.read_text())
            except (OSError, ValueError):
                lease = None                    # mid-write/corrupt: steal
            if lease is not None:
                if lease.get("worker") == worker:
                    self._write(path, worker)   # refresh our own lease
                    return True
                if time.time() - lease.get("t", 0.0) < self.ttl_s:
                    return False                # live lease held elsewhere
            self._write(path, worker)           # stale/orphaned: steal
            obs_metrics.registry().counter("difet.job.lease_steals").inc()
            return True
        with os.fdopen(fd, "w") as f:
            json.dump({"worker": worker, "t": time.time()}, f)
        obs_metrics.registry().counter("difet.job.lease_acquires").inc()
        return True

    def release(self, item: str, worker: str) -> None:
        """Drop ``worker``'s lease on ``item`` (no-op if not held)."""
        path = self._path(item)
        try:
            if json.loads(path.read_text()).get("worker") == worker:
                path.unlink()
        except (OSError, ValueError):
            pass

    def holder(self, item: str) -> Optional[Tuple[str, float]]:
        """``(worker, age_s)`` of the current lease on ``item``, or None
        if unleased (or the lease file is torn mid-write)."""
        try:
            lease = json.loads(self._path(item).read_text())
            return (lease["worker"], time.time() - lease.get("t", 0.0))
        except (OSError, ValueError, KeyError):
            return None

    def fresh(self, item: str) -> bool:
        """Is ``item`` held by a lease younger than ``ttl_s``?  The
        liveness predicate fleets use: a worker that stops heartbeating
        (re-acquiring its own lease) goes stale after one TTL."""
        h = self.holder(item)
        return h is not None and h[1] < self.ttl_s


class ManifestJob:
    """Checkpointed work queue over named items.

    ``run()`` is restartable: it consults the manifest, processes only
    missing items via ``process(name)`` (subclass hook), and commits the
    manifest write-tmp-then-rename after each item — the MapReduce "task
    commit" analogue.  ``simulate_failure_after`` kills the job after N
    items (used by the fault-tolerance tests).

    ``run(worker_id=...)`` joins an elastic worker pool: items are walked
    in manifest order but claimed through the job's `LeaseBoard`, so any
    number of concurrent workers (or restarts with a *different* worker
    count) partition the remaining work without a coordinator.
    """

    def __init__(self, store: BundleStore, job_name: str,
                 items: Optional[Sequence[str]] = None, manifest_path=None,
                 shards_per_bundle: int = 4, lease_ttl_s: float = 600.0):
        self.store = store
        self.job_name = job_name
        self.manifest_path = Path(manifest_path or
                                  store.root / f"{job_name}.manifest.json")
        self.shards_per_bundle = shards_per_bundle
        self.lease_ttl_s = lease_ttl_s
        self._items = items
        self.manifest = self._load_or_create()

    def _load_or_create(self) -> JobManifest:
        if self.manifest_path.exists():
            return JobManifest.from_json(self.manifest_path.read_text())
        names = (list(self._items) if self._items is not None
                 else self.store.list())
        m = JobManifest(self.job_name, names, {n: False for n in names},
                        time.time(), self.shards_per_bundle)
        self._commit(m)
        return m

    def _commit(self, manifest: JobManifest) -> None:
        # tmp name is unique per writer: concurrent workers committing the
        # same manifest must not consume each other's tmp file mid-replace
        tmp = self.manifest_path.with_suffix(
            f".tmp.{os.getpid()}.{threading.get_ident()}")
        tmp.write_text(manifest.to_json())
        tmp.replace(self.manifest_path)      # atomic manifest update
        obs_metrics.registry().counter("difet.job.manifest_commits").inc()

    def _merge_done_from_disk(self) -> None:
        """OR the on-disk manifest's done map into memory (tolerates a
        concurrent writer; a failed read just keeps the local view)."""
        try:
            disk = JobManifest.from_json(self.manifest_path.read_text())
            for n, d in disk.done.items():
                if d:
                    self.manifest.done[n] = True
        except (OSError, ValueError, TypeError):
            pass

    def _commit_merged(self) -> None:
        """Multi-worker commit: re-read the on-disk manifest and OR the
        done maps before the atomic replace, so concurrent workers don't
        erase each other's marks.  The residual read-replace race only
        drops a *mark*, never a result (results live in the store and are
        re-checked), so a re-run self-heals."""
        self._merge_done_from_disk()
        self._commit(self.manifest)

    @property
    def leases(self) -> LeaseBoard:
        """The job's lease board (sidecar dir next to the manifest)."""
        if not hasattr(self, "_leases"):
            self._leases = LeaseBoard(
                self.manifest_path.with_suffix(".leases"),
                ttl_s=self.lease_ttl_s)
        return self._leases

    def process(self, name: str) -> None:
        """Produce + commit the result for one item (subclass hook)."""
        raise NotImplementedError

    def run(self, simulate_failure_after: Optional[int] = None,
            progress: Optional[Callable[[str], None]] = None,
            worker_id: Optional[str] = None) -> Dict:
        """Process remaining items in manifest order; returns `summary()`.

        Args:
            simulate_failure_after: raise after N items (fault-tolerance
                tests — the restart path is the recovery protocol).
            progress: optional per-item callback with the item name.
            worker_id: join the elastic worker pool under this identity —
                items are claimed via the lease board, skipped when
                another live worker holds them, and released on commit.
                ``None`` (single-worker mode) bypasses leasing entirely.
        """
        processed = 0
        for name in list(self.manifest.remaining):
            if worker_id is not None:
                if self.manifest.done.get(name):
                    continue
                # a peer may have finished this item after our snapshot:
                # one cheap manifest re-read avoids re-extracting a whole
                # bundle (work, not correctness — results are idempotent)
                self._merge_done_from_disk()
                if self.manifest.done.get(name):
                    continue
                if not self.leases.acquire(name, worker_id):
                    continue                    # leased by a live worker
            self.process(name)
            self.manifest.done[name] = True
            if worker_id is not None:
                self._commit_merged()
                self.leases.release(name, worker_id)
            else:
                self._commit(self.manifest)
            processed += 1
            if progress:
                progress(name)
            if simulate_failure_after is not None \
                    and processed >= simulate_failure_after:
                raise RuntimeError(f"simulated worker failure after {name}")
        return self.summary()

    def summary(self) -> Dict:
        """Progress report: ``{job, bundles_done, bundles_total}``."""
        done = [n for n, d in self.manifest.done.items() if d]
        return {"job": self.job_name, "bundles_done": len(done),
                "bundles_total": len(self.manifest.bundle_names)}

    # ---- elastic scaling ----------------------------------------------------
    def rebalance(self, n_workers: int) -> List[List[str]]:
        """Partition outstanding items across a (new) worker count —
        called on membership change; returns per-worker work lists."""
        rem = self.manifest.remaining
        return [rem[i::n_workers] for i in range(n_workers)]


class DifetJob(ManifestJob):
    """Checkpointed distributed extraction over a BundleStore.

    ``algorithm`` may be a single name or a comma-separated list
    (``"fast,brief,orb"``): multi-algorithm extraction routes through
    ``extract_features_multi`` so algorithms sharing a response function
    compute it once per tile; results are stored per algorithm
    (``<bundle>.<alg>``), identical to single-algorithm runs.

    With ``mesh`` set, every shard's tile batch is device-sharded over the
    mesh's data axes (`sharding.batch_pspec`): the batch is pad-flagged up
    to a device-count multiple, extracted under a jit with explicit input
    shardings (one compiled program per batch shape), and the result is
    sliced back — bit-identical to the same jitted program without input
    shardings, since pad tiles are masked before the reduce and
    `lax.top_k` tie-breaks by index (sharding is a layout change, never a
    numerics change; the eager no-mesh path may differ in float ulps from
    any jitted path because XLA fuses differently).
    """

    def __init__(self, store: BundleStore, algorithm: str,
                 manifest_path=None, shards_per_bundle: int = 4,
                 extractor: Optional[Callable] = None, mesh=None,
                 use_pallas: bool = False, lease_ttl_s: float = 600.0):
        # a custom extractor's output is opaque — store it under the full
        # job name rather than splitting into per-algorithm results
        if extractor is not None:
            self.algorithms = (algorithm,)
        else:
            self.algorithms = tuple(a.strip() for a in algorithm.split(",")
                                    if a.strip())
            algorithm = ",".join(self.algorithms)   # normalized whitespace
        self.algorithm = algorithm
        self.extractor = extractor
        self.mesh = mesh
        self.use_pallas = use_pallas
        self._sharded_fns: Dict[tuple, Callable] = {}
        super().__init__(store, algorithm, manifest_path=manifest_path,
                         shards_per_bundle=shards_per_bundle,
                         lease_ttl_s=lease_ttl_s)

    def _shards(self, bundle: TileBundle) -> List[TileBundle]:
        """Over-decomposition for straggler mitigation: split tiles into
        independent shards so slow/failed work is bounded per shard."""
        n = max(1, min(self.shards_per_bundle, len(bundle)))
        splits = np.array_split(np.arange(len(bundle)), n)
        return [TileBundle(bundle.tiles[s], bundle.headers[s], bundle.cfg)
                for s in splits if len(s)]

    # ---- mesh-sharded extraction -------------------------------------------
    def _data_size(self) -> int:
        from repro.distributed.sharding import dp_axes
        return int(np.prod([self.mesh.shape[a]
                            for a in dp_axes(self.mesh)] or [1]))

    def _sharded_fn(self, tiles_shape, cfg) -> Callable:
        """One jitted, input-sharded program per (algorithms, batch shape,
        config); cached so a streaming pipeline's fixed-shape batches
        compile exactly once."""
        import functools
        import jax
        from jax.sharding import NamedSharding
        from repro.distributed.sharding import batch_pspec
        key = (self.algorithms, tuple(tiles_shape), cfg)
        if key not in self._sharded_fns:
            shardings = (NamedSharding(self.mesh, batch_pspec(self.mesh, 3)),
                         NamedSharding(self.mesh, batch_pspec(self.mesh, 2)))
            self._sharded_fns[key] = jax.jit(
                functools.partial(extract_features_multi,
                                  algorithms=self.algorithms, cfg=cfg,
                                  use_pallas=self.use_pallas),
                in_shardings=shardings)
        return self._sharded_fns[key]

    @staticmethod
    def _slice_result(res: Dict, n: int, k: int) -> Dict:
        """Undo device-count padding: drop pad rows from per-tile arrays
        and re-truncate the top-K merge to the unpadded candidate count.
        Pad tiles are all-invalid (-inf before top_k, which tie-breaks by
        index), so the kept prefix is bit-identical to the unpadded run."""
        out = dict(res)
        out["per_tile_count"] = res["per_tile_count"][:n]
        kk = min(k * 4, n * k)
        for key in ("top_scores", "top_ys", "top_xs", "top_valid",
                    "top_desc"):
            if key in res:
                out[key] = res[key][:kk]
        return out

    def _extract(self, tiles, headers, cfg) -> Dict[str, Dict]:
        if self.extractor is not None:
            return {self.algorithm: self.extractor(tiles, headers)}
        if self.mesh is not None:
            import jax
            n = tiles.shape[0]
            pad = (-n) % self._data_size()
            b = TileBundle(np.asarray(tiles), np.asarray(headers),
                           cfg).pad_to(n + pad)
            out = self._sharded_fn(b.tiles.shape, cfg)(b.tiles, b.headers)
            out = jax.device_get(out)
            return {alg: self._slice_result(r, n,
                                            cfg.max_keypoints_per_tile)
                    for alg, r in out.items()}
        if len(self.algorithms) > 1:
            return extract_features_multi(tiles, headers, self.algorithms,
                                          cfg, use_pallas=self.use_pallas)
        return {self.algorithm:
                extract_features(tiles, headers, self.algorithm, cfg,
                                 use_pallas=self.use_pallas)}

    def process(self, name: str) -> None:
        """Extract one bundle: split into shards, extract each (device-
        sharded when a mesh is set), merge shard partials, and commit one
        ``<name>.<algorithm>`` result per algorithm to the store."""
        bundle = self.store.get(name)
        partials: Dict[str, List[Dict]] = {}
        for shard in self._shards(bundle):
            r = self._extract(shard.tiles, shard.headers, bundle.cfg)
            for alg, res in r.items():
                partials.setdefault(alg, []).append(
                    {k: np.asarray(v) for k, v in res.items()})
        for alg, parts in partials.items():
            self.store.put_result(f"{name}.{alg}", self._merge(parts))

    @staticmethod
    def _merge(partials: List[Dict]) -> Dict:
        """The reduce across shards: counts add; top-K re-merges by score."""
        out = {"total_count": np.sum([p["total_count"] for p in partials]),
               "keypoint_count": np.sum([p["keypoint_count"]
                                         for p in partials])}
        scores = np.concatenate([p["top_scores"] for p in partials])
        order = np.argsort(-scores, kind="stable")[:partials[0]["top_scores"].shape[0]]
        out["top_scores"] = scores[order]
        for key in ("top_ys", "top_xs", "top_valid", "top_desc"):
            if key in partials[0]:
                cat = np.concatenate([p[key] for p in partials])
                out[key] = cat[order]
        out["per_tile_count"] = np.concatenate(
            [p["per_tile_count"] for p in partials])
        return out

    def _alg_counts(self, done: List[str], alg: str) -> Dict[str, int]:
        return {n: int(self.store.get_result(f"{n}.{alg}")["total_count"])
                for n in done}

    def summary(self) -> Dict:
        """Progress + feature counts: per-bundle ``counts`` and the
        ``grand_total`` for single-algorithm jobs; the same nested under
        ``per_algorithm`` for multi-algorithm jobs."""
        done = [n for n, d in self.manifest.done.items() if d]
        base = {"algorithm": self.algorithm, "bundles_done": len(done),
                "bundles_total": len(self.manifest.bundle_names)}
        if len(self.algorithms) == 1:
            counts = self._alg_counts(done, self.algorithm)
            return {**base, "counts": counts,
                    "grand_total": sum(counts.values())}
        per_alg = {}
        for alg in self.algorithms:
            counts = self._alg_counts(done, alg)
            per_alg[alg] = {"counts": counts,
                            "grand_total": sum(counts.values())}
        return {**base, "per_algorithm": per_alg,
                "grand_total": sum(p["grand_total"]
                                   for p in per_alg.values())}
