"""DIFET core: the paper's contribution — distributed local-feature
extraction over tile bundles (map/shuffle/reduce on a TPU mesh)."""
from repro.core.bundle import TileBundle, BundleStore, tile_scene, bundle_scenes  # noqa: F401
from repro.core.engine import (  # noqa: F401
    extract_features, extract_features_multi, make_distributed_extractor,
    ALGORITHMS,
)
from repro.core.job import DifetJob, JobManifest, ManifestJob  # noqa: F401
from repro.core.matching import (  # noqa: F401
    match_pair, register_pair, estimate_translation, estimate_similarity,
)
from repro.core.mosaic import MatchPhase, solve_layout  # noqa: F401
