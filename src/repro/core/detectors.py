"""Corner / interest-point detectors: Harris, Shi-Tomasi, FAST, plus the
SIFT DoG-extrema and SURF fast-Hessian detection maps.

Each detector returns a dense per-pixel *response map*; NMS + capacity-K
selection (``repro.core.nms``) turns maps into keypoints.  Dense maps are
what make the TPU adaptation work: counts (paper Table 2) are exact even
when the keypoint list is capacity-truncated.

Harris / Shi-Tomasi / FAST response hot-loops have Pallas TPU kernels in
``repro.kernels`` (``use_pallas=True``); the jnp implementations here are
the oracles they are tested against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pyramid import (
    blur_separable, blur_separable_seed, sobel_gradients, gaussian_pyramid,
    dog_pyramid, downsample2, fused_octave_response, integral_image, box_sum,
)


# ---------------------------------------------------------------------------
# structure tensor: Harris & Shi-Tomasi
# ---------------------------------------------------------------------------
def structure_tensor(img, sigma: float = 1.0):
    gx, gy = sobel_gradients(img)
    ixx = blur_separable(gx * gx, sigma)
    iyy = blur_separable(gy * gy, sigma)
    ixy = blur_separable(gx * gy, sigma)
    return ixx, iyy, ixy


def harris_response(img, k: float = 0.04, sigma: float = 1.0,
                    use_pallas: bool = False):
    """R = det(M) - k * trace(M)^2  (paper's Harris mapper, steps 2-3)."""
    if use_pallas:
        from repro.kernels.ops import harris as _pallas
        return _pallas(img, k=k, sigma=sigma, shi_tomasi=False)
    ixx, iyy, ixy = structure_tensor(img, sigma)
    det = ixx * iyy - ixy * ixy
    tr = ixx + iyy
    return det - k * tr * tr


def shi_tomasi_response(img, sigma: float = 1.0, use_pallas: bool = False):
    """min-eigenvalue response: lambda_min of the structure tensor."""
    if use_pallas:
        from repro.kernels.ops import harris as _pallas
        return _pallas(img, k=0.0, sigma=sigma, shi_tomasi=True)
    ixx, iyy, ixy = structure_tensor(img, sigma)
    half_tr = 0.5 * (ixx + iyy)
    rad = jnp.sqrt(jnp.maximum(
        0.25 * (ixx - iyy) ** 2 + ixy * ixy, 0.0))
    return half_tr - rad


# ---------------------------------------------------------------------------
# FAST segment test
# ---------------------------------------------------------------------------
# Bresenham circle of radius 3: 16 offsets in order.
FAST_OFFSETS = np.array([
    (-3, 0), (-3, 1), (-2, 2), (-1, 3), (0, 3), (1, 3), (2, 2), (3, 1),
    (3, 0), (3, -1), (2, -2), (1, -3), (0, -3), (-1, -3), (-2, -2), (-3, -1),
], np.int32)   # (dy, dx)


def _circle_values(img):
    """Stack the 16 circle-neighbour images: [..., 16, H, W]."""
    h, w = img.shape[-2], img.shape[-1]
    p = jnp.pad(img, [(0, 0)] * (img.ndim - 2) + [(3, 3), (3, 3)],
                mode="reflect")
    vals = [p[..., 3 + dy:3 + dy + h, 3 + dx:3 + dx + w]
            for dy, dx in FAST_OFFSETS]
    return jnp.stack(vals, axis=-3)


def _arc_max_run(flags):
    """flags [..., 16, H, W] bool -> max circular run length [..., H, W].

    Branch-free: duplicate the ring, then a length-``n`` window is all-true
    iff the windowed sum equals n; take the max window size via cumsum.
    """
    f = jnp.concatenate([flags, flags], axis=-3).astype(jnp.int32)
    c = jnp.cumsum(f, axis=-3)                              # [..., 32, H, W]
    c = jnp.concatenate([jnp.zeros_like(c[..., :1, :, :]), c], axis=-3)
    best = jnp.zeros(flags.shape[:-3] + flags.shape[-2:], jnp.int32)
    for n in range(1, 17):
        run = (c[..., n:, :, :] - c[..., :-n, :, :]) == n   # any n-window
        best = jnp.maximum(best, n * run.any(axis=-3).astype(jnp.int32))
    return best


def fast_score(img, threshold: float = 0.15, arc: int = 9,
               use_pallas: bool = False):
    """FAST-N score map: 0 where not a corner, else sum |I_p - I_center| - t
    over the contiguous arc pixels (OpenCV-style score)."""
    if use_pallas:
        from repro.kernels.ops import fast_score as _pallas
        return _pallas(img, threshold=threshold, arc=arc)
    circ = _circle_values(img)                              # [..., 16, H, W]
    center = img[..., None, :, :]
    brighter = circ > center + threshold
    darker = circ < center - threshold
    run_b = _arc_max_run(brighter)
    run_d = _arc_max_run(darker)
    is_corner = (run_b >= arc) | (run_d >= arc)
    diff = jnp.abs(circ - center) - threshold
    score_b = jnp.where(brighter, diff, 0.0).sum(axis=-3)
    score_d = jnp.where(darker, diff, 0.0).sum(axis=-3)
    return jnp.where(is_corner, jnp.maximum(score_b, score_d), 0.0)


# ---------------------------------------------------------------------------
# SIFT detection: DoG scale-space extrema
# ---------------------------------------------------------------------------
def sift_dog_response(img, n_octaves=4, scales_per_octave=3,
                      contrast_threshold=0.04, use_pallas: bool = False):
    """Returns the octave-0 extrema response map [..., H, W] (full-res) plus
    per-octave responses; response = |DoG| where the pixel is a 3x3x3
    scale-space extremum above the contrast threshold, else 0.

    Consumes the fused extrema map from ``fused_octave_response`` directly:
    per octave, one fused computation (a single Pallas DMA on TPU) yields
    the response and the next octave's seed level — no Gaussian/DoG pyramid
    is materialized.  Matches the level-by-level path
    (``sift_dog_response_levelwise``, kept for benchmarks) to ~2 ulp with
    identical thresholded detection masks (Table-2 counts unchanged).
    """
    base = blur_separable(img, 1.6, use_pallas)
    responses = []
    for o in range(n_octaves):
        resp, seed = fused_octave_response(
            base, scales_per_octave, contrast_threshold,
            use_pallas=use_pallas)
        responses.append(resp)
        base = downsample2(seed)
    return responses


def sift_dog_response_levelwise(img, n_octaves=4, scales_per_octave=3,
                                contrast_threshold=0.04,
                                use_pallas: bool = False):
    """The seed's level-by-level SIFT path (gaussian_pyramid -> dog_pyramid
    -> 26-neighbour stack).  Kept as the reference baseline that benchmarks
    (`benchmarks/run.py::bench_scalespace`) and equivalence tests compare the
    fused path against; not used by the engine.  Uses the seed blur
    formulation so the timing baseline is the seed's, not just its math."""
    octs = gaussian_pyramid(img, n_octaves, scales_per_octave,
                            use_pallas=use_pallas,
                            blur_fn=blur_separable_seed)
    dogs = dog_pyramid(octs)
    responses = []
    for d in dogs:                                          # [..., S, H, W]
        s = d.shape[-3]
        mid = d[..., 1:s - 1, :, :]
        p = jnp.pad(d, [(0, 0)] * (d.ndim - 3) + [(0, 0), (1, 1), (1, 1)],
                    mode="reflect")
        neigh = []
        for ds in (-1, 0, 1):
            for dy in (-1, 0, 1):
                for dx in (-1, 0, 1):
                    if ds == 0 and dy == 0 and dx == 0:
                        continue
                    neigh.append(p[..., 1 + ds:1 + ds + s - 2,
                                   1 + dy:1 + dy + mid.shape[-2],
                                   1 + dx:1 + dx + mid.shape[-1]])
        neigh = jnp.stack(neigh, axis=0)
        is_max = (mid > neigh.max(axis=0))
        is_min = (mid < neigh.min(axis=0))
        resp = jnp.where((is_max | is_min)
                         & (jnp.abs(mid) > contrast_threshold),
                         jnp.abs(mid), 0.0)
        responses.append(resp.max(axis=-3))                 # over scales
    return responses


# ---------------------------------------------------------------------------
# SURF detection: fast-Hessian (box-filter approximation, 9x9 lobe)
# ---------------------------------------------------------------------------
def surf_hessian_response(img, use_pallas: bool = False):
    """det(H_approx) with 9x9 box filters (SURF's first scale), normalized.

    Dxx: lobes 5(h) x 3(w); weights (1, -2, 1); Dyy transposed; Dxy four
    3x3 corner boxes with weights (+1, -1, -1, +1).

    ``use_pallas`` is accepted for a uniform detector signature but the
    integral-image path is *pallas-exempt* (DESIGN.md §6): the summed-area
    table is two cumsums + 8 gathers — already a single memory-bound sweep
    with no per-level rebuild to fuse, and ``jnp.cumsum`` lowers to an
    efficient scan that a hand-written kernel would not beat.
    """
    del use_pallas  # integral-image path is pallas-exempt (see docstring)
    ii = integral_image(img)
    # Dxx: three vertical-stacked boxes of 5x3 centered
    dxx = (box_sum(ii, -2, -4, 5, 3) - 2 * box_sum(ii, -2, -1, 5, 3)
           + box_sum(ii, -2, 2, 5, 3))
    dyy = (box_sum(ii, -4, -2, 3, 5) - 2 * box_sum(ii, -1, -2, 3, 5)
           + box_sum(ii, 2, -2, 3, 5))
    dxy = (box_sum(ii, -4, 1, 3, 3) + box_sum(ii, 1, -4, 3, 3)
           - box_sum(ii, -4, -4, 3, 3) - box_sum(ii, 1, 1, 3, 3))
    norm = 1.0 / 81.0
    dxx, dyy, dxy = dxx * norm, dyy * norm, dxy * norm
    return dxx * dyy - (0.9 * dxy) ** 2
