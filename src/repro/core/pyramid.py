"""Gaussian scale space and DoG pyramid (SIFT/SURF substrate).

Blur is separable; the hot loop optionally dispatches to the Pallas kernel
(`repro.kernels.blur`) on TPU, with the pure-jnp path as reference and CPU
fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=64)
def gaussian_kernel_1d(sigma: float, radius: int = 0) -> np.ndarray:
    if radius == 0:
        radius = max(1, int(np.ceil(3.0 * sigma)))
    x = np.arange(-radius, radius + 1, dtype=np.float32)
    k = np.exp(-0.5 * (x / sigma) ** 2)
    return (k / k.sum()).astype(np.float32)


def blur_separable(img, sigma: float, use_pallas: bool = False):
    """img [..., H, W] -> gaussian blurred (reflect padding)."""
    if use_pallas:
        from repro.kernels.ops import gaussian_blur as _pallas_blur
        return _pallas_blur(img, sigma)
    k = jnp.asarray(gaussian_kernel_1d(float(sigma)))
    r = (k.shape[0] - 1) // 2

    def conv_last(x):
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(r, r)], mode="reflect")
        windows = [xp[..., i:i + x.shape[-1]] for i in range(2 * r + 1)]
        return sum(w * k[i] for i, w in enumerate(windows))

    out = conv_last(img)                     # along W
    out = jnp.swapaxes(conv_last(jnp.swapaxes(out, -1, -2)), -1, -2)  # along H
    return out


def downsample2(img):
    return img[..., ::2, ::2]


def gaussian_pyramid(img, n_octaves: int, scales_per_octave: int,
                     sigma0: float = 1.6, use_pallas: bool = False):
    """Returns list of octaves; octave = [n_scales+3, ..., H_o, W_o]."""
    n_scales = scales_per_octave + 3
    k = 2.0 ** (1.0 / scales_per_octave)
    octaves = []
    base = blur_separable(img, sigma0, use_pallas)
    for o in range(n_octaves):
        levels = [base]
        sigma_prev = sigma0
        for s in range(1, n_scales):
            sigma_total = sigma0 * (k ** s)
            sigma_inc = float(np.sqrt(max(sigma_total ** 2 - sigma_prev ** 2,
                                          1e-6)))
            levels.append(blur_separable(levels[-1], sigma_inc, use_pallas))
            sigma_prev = sigma_total
        octave = jnp.stack(levels, axis=-3)     # [..., n_scales, H, W]
        octaves.append(octave)
        # next octave seeds from the level with sigma = 2*sigma0
        base = downsample2(levels[scales_per_octave])
    return octaves


def dog_pyramid(octaves):
    """Difference-of-Gaussians per octave: [..., n_scales-1, H, W]."""
    return [o[..., 1:, :, :] - o[..., :-1, :, :] for o in octaves]


def sobel_gradients(img):
    """img [..., H, W] -> (gx, gy), Sobel, reflect padding."""
    p = jnp.pad(img, [(0, 0)] * (img.ndim - 2) + [(1, 1), (1, 1)],
                mode="reflect")
    # p[..., y, x]; slices for the 3x3 neighbourhood
    def sl(dy, dx):
        h, w = img.shape[-2], img.shape[-1]
        return p[..., 1 + dy:1 + dy + h, 1 + dx:1 + dx + w]
    gx = (sl(-1, 1) + 2 * sl(0, 1) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(0, -1) - sl(1, -1)) / 8.0
    gy = (sl(1, -1) + 2 * sl(1, 0) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(-1, 0) - sl(-1, 1)) / 8.0
    return gx, gy


def integral_image(img):
    """Summed-area table with a leading zero row/col: [..., H+1, W+1]."""
    ii = jnp.cumsum(jnp.cumsum(img, axis=-2), axis=-1)
    return jnp.pad(ii, [(0, 0)] * (img.ndim - 2) + [(1, 0), (1, 0)])


def box_sum(ii, y0, x0, h, w):
    """Box sums from an integral image, static offsets (for SURF filters).

    ii: [..., H+1, W+1]; returns [..., H, W] where out[y,x] = sum of the
    (h, w) box whose top-left is at (y + y0, x + x0) — out-of-range reads
    clamp to the image border (same convention as OpenCV's filter margin).
    """
    H = ii.shape[-2] - 1
    W = ii.shape[-1] - 1

    def at(dy, dx):
        ys = jnp.clip(jnp.arange(H) + dy, 0, H)
        xs = jnp.clip(jnp.arange(W) + dx, 0, W)
        return ii[..., ys[:, None], xs[None, :]]

    return (at(y0 + h, x0 + w) - at(y0, x0 + w)
            - at(y0 + h, x0) + at(y0, x0))
