"""Gaussian scale space and DoG pyramid (SIFT/SURF substrate).

Blur is separable; the hot loop optionally dispatches to the Pallas kernel
(`repro.kernels.blur`) on TPU, with the pure-jnp path as reference and CPU
fallback.

The SIFT hot path no longer materializes the pyramid level-by-level:
``fused_octave_response`` produces a whole octave's extrema response (and
the next octave's seed level) in one fused computation — on TPU a single
``pallas_call`` (`repro.kernels.scalespace`), on CPU a streaming jnp path
that never builds the 26-neighbour stack.  ``gaussian_pyramid`` /
``dog_pyramid`` remain as the level-by-level reference substrate
(benchmarks time fused-vs-levelwise; see DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=64)
def gaussian_kernel_1d(sigma: float, radius: int = 0) -> np.ndarray:
    if radius == 0:
        radius = max(1, int(np.ceil(3.0 * sigma)))
    x = np.arange(-radius, radius + 1, dtype=np.float32)
    k = np.exp(-0.5 * (x / sigma) ** 2)
    return (k / k.sum()).astype(np.float32)


def blur_separable(img, sigma: float, use_pallas: bool = False):
    """img [..., H, W] -> gaussian blurred (reflect padding).

    One reflect pad + two valid passes (W then H) with no transposes —
    the same per-pixel add chain as the seed's pad-per-pass/transpose
    formulation (``blur_separable_seed``), ~10x faster on CPU XLA, which
    materialized every transpose and pad.  Values agree to <= ~2 ulp (XLA
    may contract mul+add to FMA differently across fusion boundaries);
    Table-2 detection masks are identical
    (``tests/test_kernels.py::test_fused_sift_response_matches_levelwise``).
    """
    if use_pallas:
        from repro.kernels.ops import gaussian_blur as _pallas_blur
        return _pallas_blur(img, sigma)
    taps = gaussian_kernel_1d(float(sigma))
    r = (len(taps) - 1) // 2
    h, w = img.shape[-2], img.shape[-1]
    xp = jnp.pad(img, [(0, 0)] * (img.ndim - 2) + [(r, r), (r, r)],
                 mode="reflect")
    tmp = sum(float(taps[j]) * xp[..., :, j:j + w] for j in range(2 * r + 1))
    return sum(float(taps[i]) * tmp[..., i:i + h, :] for i in range(2 * r + 1))


def blur_separable_seed(img, sigma: float, use_pallas: bool = False):
    """The seed's blur formulation: pad per pass, convolve along the last
    dim, transpose between passes.  Numerically identical to
    ``blur_separable``; kept as the level-by-level timing baseline
    (`benchmarks/run.py::bench_scalespace`) and as the equivalence oracle."""
    if use_pallas:
        from repro.kernels.ops import gaussian_blur as _pallas_blur
        return _pallas_blur(img, sigma)
    k = jnp.asarray(gaussian_kernel_1d(float(sigma)))
    r = (k.shape[0] - 1) // 2

    def conv_last(x):
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(r, r)], mode="reflect")
        windows = [xp[..., i:i + x.shape[-1]] for i in range(2 * r + 1)]
        return sum(w * k[i] for i, w in enumerate(windows))

    out = conv_last(img)                     # along W
    out = jnp.swapaxes(conv_last(jnp.swapaxes(out, -1, -2)), -1, -2)  # along H
    return out


def downsample2(img):
    return img[..., ::2, ::2]


@functools.lru_cache(maxsize=32)
def octave_increments(scales_per_octave: int, sigma0: float = 1.6):
    """Incremental blur sigmas for one octave's levels 1..n_scales-1.

    Level s has total sigma ``sigma0 * 2**(s/scales_per_octave)``; each level
    is produced from the previous by a blur of the returned increment (the
    Gaussian semigroup property), so taps can be compile-time constants.
    """
    n_scales = scales_per_octave + 3
    k = 2.0 ** (1.0 / scales_per_octave)
    incs = []
    sigma_prev = sigma0
    for s in range(1, n_scales):
        sigma_total = sigma0 * (k ** s)
        incs.append(float(np.sqrt(max(sigma_total ** 2 - sigma_prev ** 2,
                                      1e-6))))
        sigma_prev = sigma_total
    return tuple(incs)


def _ring8_and_full9(dog_level):
    """3x3 neighbourhood maxima of one DoG level [..., H, W].

    Returns (full9_max, full9_min, ring8_max, ring8_min): the max/min over
    the full 3x3 window and over the 8-neighbour ring (centre excluded),
    computed with separable shifted-max chains instead of a 26-image stack —
    exact (fp max is associative) but ~4x fewer buffers than stacking.
    """
    h, w = dog_level.shape[-2:]
    p = jnp.pad(dog_level, [(0, 0)] * (dog_level.ndim - 2) + [(1, 1), (1, 1)],
                mode="reflect")
    rows = lambda y: p[..., y:y + h + 2, :]                  # noqa: E731
    cols = lambda x, a: a[..., :, x:x + w]                   # noqa: E731
    # horizontal 3-max / left-right 2-max on the (h+2)-row band
    band = p
    h3mx = jnp.maximum(jnp.maximum(cols(0, band), cols(1, band)),
                       cols(2, band))                        # [..., h+2, w]
    h3mn = jnp.minimum(jnp.minimum(cols(0, band), cols(1, band)),
                       cols(2, band))
    lrmx = jnp.maximum(cols(0, band), cols(2, band))
    lrmn = jnp.minimum(cols(0, band), cols(2, band))
    row = lambda y, a: a[..., y:y + h, :]                    # noqa: E731
    full9_max = jnp.maximum(jnp.maximum(row(0, h3mx), row(1, h3mx)),
                            row(2, h3mx))
    full9_min = jnp.minimum(jnp.minimum(row(0, h3mn), row(1, h3mn)),
                            row(2, h3mn))
    ring8_max = jnp.maximum(jnp.maximum(row(0, h3mx), row(2, h3mx)),
                            row(1, lrmx))
    ring8_min = jnp.minimum(jnp.minimum(row(0, h3mn), row(2, h3mn)),
                            row(1, lrmn))
    return full9_max, full9_min, ring8_max, ring8_min


def fused_extrema_response(dogs, contrast_threshold):
    """Fused 3x3x3 DoG-extrema response: max over mid scales of |DoG| where
    the pixel is a strict scale-space extremum above the contrast threshold.

    ``dogs`` is a list of per-scale DoG images [..., H, W] (len >= 3).
    Bitwise-identical to the 26-neighbour-stack formulation (max/min
    decomposition is exact) but streams scale slabs instead of materializing
    a [26, S-2, H, W] volume.
    """
    stats = [_ring8_and_full9(d) for d in dogs]
    resp = None
    for s in range(1, len(dogs) - 1):
        below_mx, below_mn, _, _ = stats[s - 1]
        above_mx, above_mn, _, _ = stats[s + 1]
        _, _, ring_mx, ring_mn = stats[s]
        mid = dogs[s]
        neigh_max = jnp.maximum(jnp.maximum(below_mx, above_mx), ring_mx)
        neigh_min = jnp.minimum(jnp.minimum(below_mn, above_mn), ring_mn)
        is_ext = (mid > neigh_max) | (mid < neigh_min)
        r = jnp.where(is_ext & (jnp.abs(mid) > contrast_threshold),
                      jnp.abs(mid), 0.0)
        resp = r if resp is None else jnp.maximum(resp, r)
    return resp


def fused_octave_response(base, scales_per_octave: int,
                          contrast_threshold: float, sigma0: float = 1.6,
                          use_pallas: bool = False):
    """One octave of the SIFT detector, fused: (response, next-octave seed).

    ``base`` [..., H, W] is the octave's level 0 (already blurred to
    ``sigma0``).  Returns ``resp`` [..., H, W] — the 3x3x3 DoG-extrema
    response maxed over the octave's mid scales — and ``seed`` [..., H, W],
    the level with total sigma ``2*sigma0`` (downsample it to start the next
    octave).  No per-level pyramid list is materialized by the caller.

    Dispatch: ``use_pallas=True`` routes to the one-DMA Pallas kernel
    (`repro.kernels.scalespace`) when the octave's VMEM working set fits the
    budget (DESIGN.md §6); otherwise this streaming jnp path runs (it is
    also the CPU reference).
    """
    if use_pallas:
        from repro.kernels import ops as _ops
        h, w = base.shape[-2], base.shape[-1]
        if _ops.scalespace_fits_vmem(h, w, scales_per_octave, sigma0):
            return _ops.scalespace_octave(
                base, scales_per_octave=scales_per_octave,
                contrast_threshold=float(contrast_threshold), sigma0=sigma0)
    incs = octave_increments(scales_per_octave, sigma0)
    prev = base
    seed = None
    dogs = []
    for s, sigma_inc in enumerate(incs, start=1):
        cur = blur_separable(prev, sigma_inc)
        dogs.append(cur - prev)
        if s == scales_per_octave:
            seed = cur
        prev = cur
    resp = fused_extrema_response(dogs, contrast_threshold)
    return resp, seed


def gaussian_pyramid(img, n_octaves: int, scales_per_octave: int,
                     sigma0: float = 1.6, use_pallas: bool = False,
                     blur_fn=None):
    """Returns list of octaves; octave = [n_scales+3, ..., H_o, W_o].

    Level-by-level reference path: every level round-trips through HBM.
    The SIFT hot path uses ``fused_octave_response`` instead.  ``blur_fn``
    lets benchmarks pin the seed blur formulation
    (``blur_separable_seed``); default is ``blur_separable``.
    """
    blur_fn = blur_separable if blur_fn is None else blur_fn
    octaves = []
    base = blur_fn(img, sigma0, use_pallas)
    for o in range(n_octaves):
        levels = [base]
        for sigma_inc in octave_increments(scales_per_octave, sigma0):
            levels.append(blur_fn(levels[-1], sigma_inc, use_pallas))
        octave = jnp.stack(levels, axis=-3)     # [..., n_scales, H, W]
        octaves.append(octave)
        # next octave seeds from the level with sigma = 2*sigma0
        base = downsample2(levels[scales_per_octave])
    return octaves


def dog_pyramid(octaves):
    """Difference-of-Gaussians per octave: [..., n_scales-1, H, W]."""
    return [o[..., 1:, :, :] - o[..., :-1, :, :] for o in octaves]


def sobel_gradients(img):
    """img [..., H, W] -> (gx, gy), Sobel, reflect padding."""
    p = jnp.pad(img, [(0, 0)] * (img.ndim - 2) + [(1, 1), (1, 1)],
                mode="reflect")
    # p[..., y, x]; slices for the 3x3 neighbourhood
    def sl(dy, dx):
        h, w = img.shape[-2], img.shape[-1]
        return p[..., 1 + dy:1 + dy + h, 1 + dx:1 + dx + w]
    gx = (sl(-1, 1) + 2 * sl(0, 1) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(0, -1) - sl(1, -1)) / 8.0
    gy = (sl(1, -1) + 2 * sl(1, 0) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(-1, 0) - sl(-1, 1)) / 8.0
    return gx, gy


def integral_image(img):
    """Summed-area table with a leading zero row/col: [..., H+1, W+1]."""
    ii = jnp.cumsum(jnp.cumsum(img, axis=-2), axis=-1)
    return jnp.pad(ii, [(0, 0)] * (img.ndim - 2) + [(1, 0), (1, 0)])


def box_sum(ii, y0, x0, h, w):
    """Box sums from an integral image, static offsets (for SURF filters).

    ii: [..., H+1, W+1]; returns [..., H, W] where out[y,x] = sum of the
    (h, w) box whose top-left is at (y + y0, x + x0) — out-of-range reads
    clamp to the image border (same convention as OpenCV's filter margin).
    """
    H = ii.shape[-2] - 1
    W = ii.shape[-1] - 1

    def at(dy, dx):
        ys = jnp.clip(jnp.arange(H) + dy, 0, H)
        xs = jnp.clip(jnp.arange(W) + dx, 0, W)
        return ii[..., ys[:, None], xs[None, :]]

    return (at(y0 + h, x0 + w) - at(y0, x0 + w)
            - at(y0 + h, x0) + at(y0, x0))
