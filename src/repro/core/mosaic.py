"""Scene-pair registration + mosaic layout — the stitching companion
workload (arXiv:1808.08522/.08528) built on DIFET extraction results.

Pipeline (driven end-to-end by `launch/stitch.py`):

  1. per-scene extraction results (top-K keypoints + descriptors with
     validity masks) are loaded from the ``BundleStore``;
  2. the pair list is chunked and registered by ``MatchPhase`` — a
     checkpointed ``ManifestJob`` (same manifest/commit machinery as the
     extraction phase), each chunk one batched ``vmap`` of
     ``matching.register_pair`` whose leading pair axis is sharded over
     the mesh ``data`` axis (`distributed/sharding.py::batch_pspec`);
  3. ``solve_layout`` anchors the first scene and walks the
     inlier-verified pair graph (BFS spanning tree) to absolute scene
     positions; ``mosaic_summary`` reports the layout.

Pair results are stored per pair under a job-qualified name
(``<a>__<b>.match_<alg>_<digest>``), so a killed match phase resumes
exactly where it died and different configs sharing a store never alias —
the registration itself is deterministic (fixed RANSAC keys derived from
the pair index).
"""
from __future__ import annotations

import functools
import hashlib
import json
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bundle import BundleStore
from repro.core.job import ManifestJob
from repro.core import matching


def pair_name(a: str, b: str) -> str:
    return f"{a}__{b}"


def load_scene_features(store: BundleStore, scene: str,
                        algorithm: str) -> Dict[str, np.ndarray]:
    """Top-K features of one scene from its extraction result (global
    scene coordinates + descriptors + validity)."""
    r = store.get_result(f"{scene}.{algorithm}")
    if "top_desc" not in r:
        raise ValueError(
            f"algorithm {algorithm!r} stores no descriptors — the match "
            "phase needs one of sift/surf/brief/orb")
    return {"ys": r["top_ys"], "xs": r["top_xs"],
            "desc": r["top_desc"], "valid": r["top_valid"]}


def make_pair_solver(metric: Optional[str], ratio: float, tol: float,
                     iters: int, use_pallas: Optional[bool] = None):
    """jit'd batched registration: every array gains a leading pair axis P;
    one dispatch registers the whole chunk (matcher + RANSAC vmapped)."""

    def one(ya, xa, da, va, yb, xb, db, vb, key):
        m, est = matching.register_pair(
            ya, xa, da, va, yb, xb, db, vb, key, ratio, tol,
            metric=metric, model="translation", iters=iters,
            use_pallas=use_pallas)
        return {"t": est.t, "n_inliers": est.n_inliers,
                "n_matches": m.ok.sum().astype(jnp.int32), "rms": est.rms}

    return jax.jit(jax.vmap(one))


def _shard_batch(arrays: List, mesh) -> Tuple[List, int]:
    """Shard the leading pair axis over the mesh ``data`` axis (padding P
    to a multiple of the data-parallel extent; padded rows are cropped by
    the caller).  Identity on a single-device host."""
    p = arrays[0].shape[0]
    if mesh is None or mesh.size == 1:
        return arrays, p
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import batch_pspec, dp_axes
    dp = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    pad = (-p) % dp
    out = []
    for a in arrays:
        if pad:
            a = np.concatenate([a, np.repeat(a[-1:], pad, axis=0)], axis=0)
        out.append(jax.device_put(
            a, NamedSharding(mesh, batch_pspec(mesh, a.ndim))))
    return out, p


class MatchPhase(ManifestJob):
    """Checkpointed pairwise-registration phase over extraction results.

    Work items are fixed chunks of the pair list; each chunk is one
    batched, mesh-sharded solver dispatch, and each pair commits an
    individual ``<a>__<b>.match`` result.  Restart-deterministic: RANSAC
    keys are folded from the global pair index, not from wall clock.
    """

    def __init__(self, store: BundleStore, pairs: Sequence[Tuple[str, str]],
                 algorithm: str, *, metric: Optional[str] = None,
                 ratio: float = 0.8, tol: float = 2.0, iters: int = 128,
                 pairs_per_step: int = 8, mesh=None,
                 use_pallas: Optional[bool] = None, manifest_path=None,
                 seed: int = 0):
        self.pairs = [tuple(p) for p in pairs]
        self._pair_index = {p: i for i, p in enumerate(self.pairs)}
        self.algorithm = algorithm
        self.mesh = mesh
        self.seed = seed
        self._params = (metric, float(ratio), float(tol), int(iters),
                        use_pallas)
        self._chunks = {
            f"pairs_{i:05d}": self.pairs[i * pairs_per_step:
                                         (i + 1) * pairs_per_step]
            for i in range((len(self.pairs) + pairs_per_step - 1)
                           // pairs_per_step)}
        self._feats: Dict[str, Dict[str, np.ndarray]] = {}
        # the manifest records chunk names only, so a stale manifest from a
        # different pair list / chunking / RANSAC config would silently
        # skip work on resume — fingerprint the job config into the name
        # so changed configs get a fresh manifest (per-pair results are
        # deterministic, so re-registering an already-stored pair is safe)
        digest = hashlib.sha1(json.dumps(
            [self.pairs, pairs_per_step, self._params, seed],
            default=str).encode()).hexdigest()[:8]
        super().__init__(store, f"match_{algorithm}_{digest}",
                         items=sorted(self._chunks),
                         manifest_path=manifest_path)

    def _features(self, scene: str) -> Dict[str, np.ndarray]:
        if scene not in self._feats:
            self._feats[scene] = load_scene_features(self.store, scene,
                                                     self.algorithm)
        return self._feats[scene]

    @functools.cached_property
    def _solver(self):
        return make_pair_solver(*self._params)

    def process(self, name: str) -> None:
        chunk = self._chunks[name]
        fa = [self._features(a) for a, _ in chunk]
        fb = [self._features(b) for _, b in chunk]
        keys = np.stack([
            np.asarray(jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                          self._pair_index[p]))
            for p in chunk])
        batch = [np.stack([f["ys"] for f in fa]),
                 np.stack([f["xs"] for f in fa]),
                 np.stack([f["desc"] for f in fa]),
                 np.stack([f["valid"] for f in fa]),
                 np.stack([f["ys"] for f in fb]),
                 np.stack([f["xs"] for f in fb]),
                 np.stack([f["desc"] for f in fb]),
                 np.stack([f["valid"] for f in fb]),
                 keys]
        batch, p = _shard_batch(batch, self.mesh)
        out = jax.tree_util.tree_map(np.asarray, self._solver(*batch))
        for i, (a, b) in enumerate(chunk):
            self.store.put_result(self._result_name(a, b), {
                "t": out["t"][i], "n_inliers": out["n_inliers"][i],
                "n_matches": out["n_matches"][i], "rms": out["rms"][i]})

    def _result_name(self, a: str, b: str) -> str:
        # job-qualified (algorithm + config digest): two configs sharing a
        # store must never alias each other's pair registrations
        return f"{pair_name(a, b)}.{self.job_name}"

    def results(self) -> Dict[Tuple[str, str], Dict[str, np.ndarray]]:
        return {(a, b): self.store.get_result(self._result_name(a, b))
                for a, b in self.pairs
                if self.store.has_result(self._result_name(a, b))}


def solve_layout(scene_names: Sequence[str],
                 pair_results: Dict[Tuple[str, str], Dict],
                 min_inliers: int = 8):
    """Absolute scene positions from verified pairwise offsets.

    Registration gives ``t = O_a - O_b`` per pair (`core/matching.py`
    convention), so a BFS spanning tree from the anchor (first scene)
    propagates ``O_b = O_a - t``.  Pairs under ``min_inliers`` are dropped
    as unverified; scenes the surviving graph cannot reach are omitted
    from the returned positions (the caller reports them).

    Returns (positions {scene: [y, x] float64}, dropped_pairs).
    """
    adj: Dict[str, List[Tuple[str, np.ndarray]]] = {n: [] for n in scene_names}
    dropped = []
    for (a, b), r in pair_results.items():
        if int(r["n_inliers"]) < min_inliers:
            dropped.append((a, b))
            continue
        t = np.asarray(r["t"], np.float64)
        adj[a].append((b, -t))       # O_b = O_a - t
        adj[b].append((a, t))        # O_a = O_b + t
    anchor = scene_names[0]
    positions = {anchor: np.zeros(2)}
    queue = deque([anchor])
    while queue:
        cur = queue.popleft()
        for nxt, delta in adj[cur]:
            if nxt not in positions:
                positions[nxt] = positions[cur] + delta
                queue.append(nxt)
    return positions, dropped


def mosaic_summary(positions: Dict[str, np.ndarray],
                   scene_hw: Tuple[int, int]) -> Dict:
    """Mosaic layout: normalized per-scene offsets + overall canvas size."""
    if not positions:
        return {"n_scenes": 0, "mosaic_hw": (0, 0), "offsets": {}}
    pos = np.stack(list(positions.values()))
    origin = pos.min(axis=0)
    extent = pos.max(axis=0) - origin + np.asarray(scene_hw, np.float64)
    return {
        "n_scenes": len(positions),
        "mosaic_hw": (int(np.ceil(extent[0])), int(np.ceil(extent[1]))),
        "offsets": {k: (float(v[0] - origin[0]), float(v[1] - origin[1]))
                    for k, v in positions.items()},
    }
