"""Tiled brute-force descriptor matcher kernel (popcount-Hamming + L2).

The matching stage pairs every query descriptor against a scene's database
and keeps the best and second-best distances (the Lowe ratio test needs
both).  A naive lowering materializes the full [Q, K] distance matrix in
HBM — for binary descriptors it is even worse, because the obvious jnp
formulation unpacks 256-bit descriptors into 256 bools (32x the traffic).

This kernel keeps the whole database VMEM-resident: each program owns one
``QBLOCK``-query block, streams the database in ``KCHUNK`` chunks that never
leave VMEM, and maintains running (best, second-best, argbest) registers —
only three [Q]-vectors are written back to HBM.

* **Hamming (BRIEF/ORB)**: descriptors stay bit-packed as uint32 lanes
  (256 bits = 8 words); per-word XOR + SWAR popcount (the shift-mask-add
  reduction — 5 integer VPU ops per word) summed over words.  Distances
  are exact int32, so kernel/oracle/fallback agree *bit-identically*.
* **L2 (SIFT/SURF)**: the ``|q|^2 + |k|^2 - 2 q.k`` expansion; the q.k
  block is one MXU ``dot_general`` per chunk.

``best2_scan`` below is the exact per-block formulation the kernel runs,
written on jnp values — it doubles as the CPU/fallback path (dispatched by
``ops.match_best2`` when the database exceeds the VMEM budget or the host
has no TPU), so fallback and kernel results are the same computation.

Invalid database slots (validity masks come from capacity-K extraction)
are forced to a BIG distance before the running update; ties are broken
toward the smallest database index (``argmin`` first-occurrence + a
strictly-less merge), so matches are deterministic and partition-invariant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 128          # queries per program (VPU sublane-friendly)
BIG_HAMMING = 1 << 30     # > any packed-bit distance; < int32 max


def kchunk_for(metric: str) -> int:
    """Database rows per VMEM-resident chunk.  Hamming holds a [Q, C, W]
    XOR/popcount intermediate (W words per descriptor), so it chunks 4x
    finer than L2, whose per-chunk state is just the [Q, C] distance
    block coming off the MXU."""
    return 256 if metric == "hamming" else 1024


def popcount32(x):
    """Per-word population count of a uint32 array (SWAR bit-slicing)."""
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return (x * 0x01010101) >> 24          # byte-sum via overflowing multiply


def _chunk_best2(d, start, big):
    """Best/second/argbest of one [Q, C] distance chunk; indices global."""
    arg = jnp.argmin(d, axis=1).astype(jnp.int32)   # first occurrence = smallest idx
    best = jnp.min(d, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    second = jnp.min(jnp.where(cols == arg[:, None], big, d), axis=1)
    return best, second, arg + jnp.int32(start)


def best2_scan(q, db, db_valid, *, metric: str, kchunk: int = None):
    """Running best/second-best over database chunks.

    q [Q, D], db [K, D], db_valid [K] (bool or int) -> (best [Q],
    second [Q], idx [Q] int32).  Runs on VMEM values inside the kernel and
    on plain arrays as the jnp fallback — identical formulation either way.
    """
    nq, nk = q.shape[0], db.shape[0]
    kchunk = kchunk_for(metric) if kchunk is None else kchunk
    if metric == "hamming":
        big = jnp.int32(BIG_HAMMING)
    elif metric == "l2":
        big = jnp.float32(jnp.inf)
        qn = jnp.sum(q * q, axis=-1)
        dn = jnp.sum(db * db, axis=-1)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    best = jnp.full((nq,), big)
    second = jnp.full((nq,), big)
    bidx = jnp.zeros((nq,), jnp.int32)
    for start in range(0, nk, kchunk):
        c = db[start:start + kchunk]
        m = db_valid[start:start + kchunk]
        if metric == "hamming":
            x = q[:, None, :] ^ c[None, :, :]               # [Q, C, W]
            d = popcount32(x).astype(jnp.int32).sum(axis=-1)
        else:
            dot = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                      preferred_element_type=jnp.float32)
            d = qn[:, None] + dn[start:start + kchunk][None, :] - 2.0 * dot
        d = jnp.where(m[None, :] != 0, d, big)
        cb, cs, ci = _chunk_best2(d, start, big)
        take = cb < best                  # ties keep the earlier (smaller) idx
        second = jnp.where(take, jnp.minimum(best, cs), jnp.minimum(second, cb))
        bidx = jnp.where(take, ci, bidx)
        best = jnp.where(take, cb, best)
    return best, second, bidx


def match_kernel(q_ref, db_ref, mask_ref, best_ref, sec_ref, idx_ref, *,
                 metric: str, kchunk: int):
    """q_ref [QBLOCK, D]; db_ref [K, D] (whole DB, VMEM-resident across the
    query grid); mask_ref [1, K] int32; outputs [1, QBLOCK] each."""
    b, s, i = best2_scan(q_ref[...], db_ref[...], mask_ref[0],
                         metric=metric, kchunk=kchunk)
    best_ref[0] = b
    sec_ref[0] = s
    idx_ref[0] = i


def match_pallas(q, db, db_mask, *, metric: str, interpret: bool,
                 kchunk: int = None):
    """q [NQ, D] (NQ a QBLOCK multiple), db [NK, D], db_mask [1, NK] int32
    -> (best [NQ], second [NQ], idx [NQ])."""
    nq, d = q.shape
    nk = db.shape[0]
    kchunk = kchunk_for(metric) if kchunk is None else kchunk
    dist_dt = jnp.int32 if metric == "hamming" else jnp.float32
    grid = (nq // QBLOCK,)
    kern = functools.partial(match_kernel, metric=metric, kchunk=kchunk)
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((QBLOCK, d), lambda i: (i, 0)),
                  pl.BlockSpec((nk, d), lambda i: (0, 0)),
                  pl.BlockSpec((1, nk), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, QBLOCK), lambda i: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((grid[0], QBLOCK), dist_dt),
                   jax.ShapeDtypeStruct((grid[0], QBLOCK), dist_dt),
                   jax.ShapeDtypeStruct((grid[0], QBLOCK), jnp.int32)],
        interpret=interpret,
    )(q, db, db_mask)
    return tuple(o.reshape(-1) for o in outs)
