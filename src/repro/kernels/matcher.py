"""Tiled brute-force descriptor matcher kernels (popcount-Hamming + L2).

The matching stage pairs every query descriptor against a scene's database
and keeps the best and second-best distances (the Lowe ratio test needs
both).  A naive lowering materializes the full [Q, K] distance matrix in
HBM — for binary descriptors it is even worse, because the obvious jnp
formulation unpacks 256-bit descriptors into 256 bools (32x the traffic).

Two Pallas kernels cover the database-size spectrum:

* **Resident** (`match_pallas`): the whole database stays VMEM-resident
  across the query grid; each program owns one ``QBLOCK``-query block and
  scans the database in chunks that never leave VMEM.  Cheapest when the
  database fits the VMEM budget (``ops.matcher_fits_vmem``).
* **Streaming** (`match_pallas_stream`): a second *database* grid
  dimension tiles the database into ``KBLOCK``-row chunks that Pallas
  pipelines HBM→VMEM with double-buffered DMA; the (best, second-best,
  argbest) registers live in the revisited output block, carried in VMEM
  across the whole database sweep.  One query batch scans millions of
  descriptors without ever holding more than two chunks on-chip.

Distance formulations (identical across kernels and jnp paths):

* **Hamming (BRIEF/ORB)**: descriptors stay bit-packed as uint32 lanes
  (256 bits = 8 words); per-word XOR + SWAR popcount (the shift-mask-add
  reduction — 5 integer VPU ops per word) summed over words.  Distances
  are exact int32, so kernel/oracle/fallback agree *bit-identically*.
* **L2 (SIFT/SURF)**: the ``|q|^2 + |k|^2 - 2 q.k`` expansion; the q.k
  block is one MXU ``dot_general`` per chunk, fp32-accumulated.  The
  ``|q|^2`` term is constant per query row, so the scan ranks on the
  partial ``|k|^2 - 2 q.k`` and adds ``|q|^2`` once at the end — no
  per-chunk re-broadcast of the query norms over the [Q, C] block.

The jnp twins — `best2_full` (one [Q, K] block) and `best2_stream`
(``lax.scan`` over database chunks, the same carried-register merge the
streaming kernel runs) — are real production paths, not just fallbacks:
`kernels/dispatch.py` microbenchmarks them against the kernels per
(metric, backend, shape-bucket) and `ops.match_best2` routes each call
site to whichever wins on the current host.

Invalid database slots (validity masks come from capacity-K extraction)
are forced to a BIG distance before the running update; ties are broken
toward the smallest database index (``argmin`` first-occurrence + a
strictly-less merge), so matches are deterministic and partition-invariant
— in every path, streaming included (chunks merge in database order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 128          # queries per program (VPU sublane-friendly)
BIG_HAMMING = 1 << 30     # > any packed-bit distance; < int32 max


def kchunk_for(metric: str) -> int:
    """Database rows per VMEM-resident chunk.  Hamming holds a [Q, C, W]
    XOR/popcount intermediate (W words per descriptor), so it chunks 4x
    finer than L2, whose per-chunk state is just the [Q, C] distance
    block coming off the MXU."""
    return 256 if metric == "hamming" else 1024


def kblock_for(metric: str) -> int:
    """Database rows per streamed chunk (the streaming kernel's DB grid
    tile and `best2_stream`'s scan step).  Wider than `kchunk_for` — a
    streamed chunk is also the DMA transfer unit, so it must amortize
    the HBM round-trip, not just bound the VMEM temporary."""
    return 512 if metric == "hamming" else 2048


def big_for(metric: str):
    """The masked/initial distance: larger than any real distance, exact
    in the metric's dtype (int32 Hamming / fp32 inf for L2)."""
    return jnp.int32(BIG_HAMMING) if metric == "hamming" \
        else jnp.float32(jnp.inf)


def popcount32(x):
    """Per-word population count of a uint32 array (SWAR bit-slicing)."""
    x = x - ((x >> 1) & 0x55555555)
    x = (x & 0x33333333) + ((x >> 2) & 0x33333333)
    x = (x + (x >> 4)) & 0x0F0F0F0F
    return (x * 0x01010101) >> 24          # byte-sum via overflowing multiply


def _chunk_dist(q, c, m, metric, big, dn=None):
    """Distances of one DB chunk: [Q, C], invalid slots forced to big.
    L2 omits the |q|^2 term (constant per row — callers add it once at
    the end of the scan); ``dn`` lets callers pass a precomputed |k|^2."""
    if metric == "hamming":
        x = q[:, None, :] ^ c[None, :, :]               # [Q, C, W]
        d = popcount32(x).astype(jnp.int32).sum(axis=-1)
    else:
        dot = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        dn = jnp.sum(c * c, axis=-1) if dn is None else dn
        d = dn[None, :] - 2.0 * dot
    return jnp.where(m[None, :] != 0, d, big)


def _chunk_best2(d, start, big):
    """Best/second/argbest of one [Q, C] distance chunk; indices global."""
    arg = jnp.argmin(d, axis=1).astype(jnp.int32)   # first occurrence = smallest idx
    best = jnp.min(d, axis=1)
    cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    second = jnp.min(jnp.where(cols == arg[:, None], big, d), axis=1)
    return best, second, arg + jnp.int32(start)


def _merge_best2(carry, chunk):
    """Merge a chunk's (best, second, idx) into the carried registers.
    Strictly-less ``take`` keeps the earlier (smaller-index) winner on
    ties, so the merge order — database order — fixes the tie-break."""
    best, second, bidx = carry
    cb, cs, ci = chunk
    take = cb < best
    second = jnp.where(take, jnp.minimum(best, cs), jnp.minimum(second, cb))
    bidx = jnp.where(take, ci, bidx)
    best = jnp.where(take, cb, best)
    return best, second, bidx


def _l2_qnorm(q, best, second):
    """Fold the per-query |q|^2 back into the scanned partial distances
    (masked slots are +inf, which absorbs the add)."""
    qn = jnp.sum(q * q, axis=-1)
    return best + qn, second + qn


def best2_scan(q, db, db_valid, *, metric: str, kchunk: int = None):
    """Running best/second-best over database chunks (unrolled loop).

    q [Q, D], db [K, D], db_valid [K] (bool or int) -> (best [Q],
    second [Q], idx [Q] int32).  This is the exact per-block formulation
    the resident kernel runs on VMEM values; on plain arrays it doubles
    as a small-database jnp path.  The python loop unrolls into the
    trace, so it is only for databases a few chunks long — `best2_stream`
    is the rolled (lax.scan) twin for large databases.
    """
    nq, nk = q.shape[0], db.shape[0]
    kchunk = kchunk_for(metric) if kchunk is None else kchunk
    big = big_for(metric)
    if metric == "l2":
        dn = jnp.sum(db * db, axis=-1)
    elif metric != "hamming":
        raise ValueError(f"unknown metric {metric!r}")
    best = jnp.full((nq,), big)
    second = jnp.full((nq,), big)
    bidx = jnp.zeros((nq,), jnp.int32)
    for start in range(0, nk, kchunk):
        c = db[start:start + kchunk]
        m = db_valid[start:start + kchunk]
        d = _chunk_dist(q, c, m, metric, big,
                        dn=None if metric == "hamming"
                        else dn[start:start + kchunk])
        best, second, bidx = _merge_best2(
            (best, second, bidx), _chunk_best2(d, start, big))
    if metric == "l2":
        best, second = _l2_qnorm(q, best, second)
    return best, second, bidx


def best2_full(q, db, db_valid, *, metric: str):
    """One-block best/second-best: the whole [Q, K] distance matrix in a
    single chunk.  On hosts where materializing the matrix is cheap (CPU
    XLA; small K) this is the fastest formulation — the dispatcher picks
    it per backend (`kernels/dispatch.py`)."""
    big = big_for(metric)
    d = _chunk_dist(q, db, db_valid, metric, big)
    best, second, bidx = _chunk_best2(d, 0, big)
    if metric == "l2":
        best, second = _l2_qnorm(q, best, second)
    return best, second, bidx


def best2_stream(q, db, db_valid, *, metric: str, kchunk: int = None):
    """Rolled streaming scan: ``lax.scan`` over [K/C, C]-chunked database
    slabs with carried (best, second, argbest) registers — the jnp twin
    of the streaming Pallas kernel, and the path that lets one query
    batch scan millions of descriptors on any backend (constant working
    set, no [Q, K] materialization, trace size independent of K).

    The database is zero-padded to a chunk multiple (padding rows are
    masked invalid), so tail chunks need no special casing.
    """
    nq, nk = q.shape[0], db.shape[0]
    kchunk = kblock_for(metric) if kchunk is None else kchunk
    big = big_for(metric)
    if metric not in ("hamming", "l2"):
        raise ValueError(f"unknown metric {metric!r}")
    pad = (-nk) % kchunk
    if pad:
        db = jnp.pad(db, ((0, pad), (0, 0)))
        db_valid = jnp.pad(db_valid.astype(jnp.int32), (0, pad))
    n_chunks = (nk + pad) // kchunk
    dbc = db.reshape(n_chunks, kchunk, db.shape[1])
    mc = db_valid.reshape(n_chunks, kchunk)

    def step(carry, xs):
        c, m, start = xs
        d = _chunk_dist(q, c, m, metric, big)
        return _merge_best2(carry, _chunk_best2(d, start, big)), None

    init = (jnp.full((nq,), big), jnp.full((nq,), big),
            jnp.zeros((nq,), jnp.int32))
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * kchunk
    (best, second, bidx), _ = jax.lax.scan(step, init, (dbc, mc, starts))
    if metric == "l2":
        best, second = _l2_qnorm(q, best, second)
    return best, second, bidx


# ---- resident kernel (whole DB in VMEM across the query grid) --------------

def match_kernel(q_ref, db_ref, mask_ref, best_ref, sec_ref, idx_ref, *,
                 metric: str, kchunk: int):
    """q_ref [QBLOCK, D]; db_ref [K, D] (whole DB, VMEM-resident across the
    query grid); mask_ref [1, K] int32; outputs [1, QBLOCK] each."""
    b, s, i = best2_scan(q_ref[...], db_ref[...], mask_ref[0],
                         metric=metric, kchunk=kchunk)
    best_ref[0] = b
    sec_ref[0] = s
    idx_ref[0] = i


def match_pallas(q, db, db_mask, *, metric: str, interpret: bool,
                 kchunk: int = None):
    """q [NQ, D] (NQ a QBLOCK multiple), db [NK, D], db_mask [1, NK] int32
    -> (best [NQ], second [NQ], idx [NQ])."""
    nq, d = q.shape
    nk = db.shape[0]
    kchunk = kchunk_for(metric) if kchunk is None else kchunk
    dist_dt = jnp.int32 if metric == "hamming" else jnp.float32
    grid = (nq // QBLOCK,)
    kern = functools.partial(match_kernel, metric=metric, kchunk=kchunk)
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((QBLOCK, d), lambda i: (i, 0)),
                  pl.BlockSpec((nk, d), lambda i: (0, 0)),
                  pl.BlockSpec((1, nk), lambda i: (0, 0))],
        out_specs=[pl.BlockSpec((1, QBLOCK), lambda i: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((grid[0], QBLOCK), dist_dt),
                   jax.ShapeDtypeStruct((grid[0], QBLOCK), dist_dt),
                   jax.ShapeDtypeStruct((grid[0], QBLOCK), jnp.int32)],
        interpret=interpret,
    )(q, db, db_mask)
    return tuple(o.reshape(-1) for o in outs)


# ---- streaming kernel (tiled DB grid, carried registers) -------------------

def stream_kernel(q_ref, db_ref, mask_ref, best_ref, sec_ref, idx_ref, *,
                  metric: str, kblock: int, n_kblocks: int):
    """One (query-block, DB-chunk) grid step of the streaming matcher.

    The DB axis is the *minor* grid dimension, so for a fixed query block
    the output refs map to the same [1, QBLOCK] block across every DB
    step — Pallas keeps them VMEM-resident between revisits, making them
    the carried (best, second, argbest) registers; they are initialized
    at the first chunk and written back to HBM only after the last.
    Meanwhile ``db_ref``/``mask_ref`` advance along the DB grid, which
    Pallas pipelines as double-buffered HBM→VMEM DMA (chunk k+1 streams
    in while chunk k is scored).  L2 scans the qn-free partial distance
    and folds |q|^2 in at the final chunk (see module docstring)."""
    ki = pl.program_id(1)
    big = big_for(metric)
    dt = best_ref.dtype

    @pl.when(ki == 0)
    def _init():
        best_ref[...] = jnp.full(best_ref.shape, big, dt)
        sec_ref[...] = jnp.full(sec_ref.shape, big, dt)
        idx_ref[...] = jnp.zeros(idx_ref.shape, jnp.int32)

    q = q_ref[...]
    d = _chunk_dist(q, db_ref[...], mask_ref[0], metric, big)
    chunk = _chunk_best2(d, 0, big)
    chunk = (chunk[0], chunk[1], chunk[2] + ki * kblock)  # global indices
    best, second, bidx = _merge_best2(
        (best_ref[0], sec_ref[0], idx_ref[0]), chunk)
    idx_ref[0] = bidx
    if metric == "l2":
        last = ki == n_kblocks - 1
        qn = jnp.sum(q * q, axis=-1)
        best_ref[0] = jnp.where(last, best + qn, best)
        sec_ref[0] = jnp.where(last, second + qn, second)
    else:
        best_ref[0] = best
        sec_ref[0] = second


def match_pallas_stream(q, db, db_mask, *, metric: str, interpret: bool,
                        kblock: int = None):
    """Streaming/tiled-database matcher: q [NQ, D] (NQ a QBLOCK multiple),
    db [NK, D] (NK a KBLOCK multiple — pad rows masked invalid),
    db_mask [1, NK] int32 -> (best [NQ], second [NQ], idx [NQ]).

    VMEM working set is ~2 DB chunks + 1 query block + the chunk
    temporaries, independent of NK — the database streams from HBM, so
    NK is bounded by HBM, not by the 12 MiB VMEM budget that gates the
    resident kernel."""
    nq, d = q.shape
    nk = db.shape[0]
    kblock = kblock_for(metric) if kblock is None else kblock
    dist_dt = jnp.int32 if metric == "hamming" else jnp.float32
    grid = (nq // QBLOCK, nk // kblock)
    kern = functools.partial(stream_kernel, metric=metric, kblock=kblock,
                             n_kblocks=grid[1])
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((QBLOCK, d), lambda i, k: (i, 0)),
                  pl.BlockSpec((kblock, d), lambda i, k: (k, 0)),
                  pl.BlockSpec((1, kblock), lambda i, k: (0, k))],
        out_specs=[pl.BlockSpec((1, QBLOCK), lambda i, k: (i, 0))] * 3,
        out_shape=[jax.ShapeDtypeStruct((grid[0], QBLOCK), dist_dt),
                   jax.ShapeDtypeStruct((grid[0], QBLOCK), dist_dt),
                   jax.ShapeDtypeStruct((grid[0], QBLOCK), jnp.int32)],
        interpret=interpret,
    )(q, db, db_mask)
    return tuple(o.reshape(-1) for o in outs)
