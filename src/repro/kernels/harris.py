"""Fused structure-tensor corner kernel (Harris / Shi-Tomasi).

The jnp reference makes 7 HBM round-trips per tile (2 sobel maps, 3 product
maps, 3 blurred maps, response); this kernel does ONE: the padded tile is
DMA'd to VMEM, and gradients → products → separable Gaussian window →
response are all computed on VMEM values.

Grid: one program per tile (tiles are the DIFET work unit, 560² fp32 ≈
1.25 MiB — the full working set of ~8 live buffers ≈ 10 MiB fits v5e VMEM).
The lane dim (W) is padded to a 128 multiple by the caller (ops.py) so the
VPU sees aligned vectors.

Gaussian taps are compile-time constants (sigma is static per pallas_call),
so the separable window unrolls into 2·(2r+1) fused multiply-adds.
"""
from __future__ import annotations

import functools

import numpy as np
from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.core.pyramid import gaussian_kernel_1d


def _sobel_vmem(x, h, w):
    """Sobel gradients of the (h+2, w+2)-padded VMEM value x -> (h, w)."""
    sl = lambda dy, dx: x[1 + dy:1 + dy + h, 1 + dx:1 + dx + w]
    gx = (sl(-1, 1) + 2 * sl(0, 1) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(0, -1) - sl(1, -1)) / 8.0
    gy = (sl(1, -1) + 2 * sl(1, 0) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(-1, 0) - sl(-1, 1)) / 8.0
    return gx, gy


def _blur_vmem(x, taps, h, w):
    """Separable blur of the (h+2r, w+2r)-padded VMEM value -> (h, w)."""
    r = (len(taps) - 1) // 2
    tmp = sum(float(taps[j]) * x[:, j:j + w] for j in range(2 * r + 1))
    return sum(float(taps[i]) * tmp[i:i + h, :] for i in range(2 * r + 1))


def harris_kernel(x_ref, o_ref, *, k: float, taps, shi_tomasi: bool,
                  h: int, w: int):
    """x_ref: [1, h + 2*(r+1), w + 2*(r+1)]; o_ref: [1, h, w]."""
    r = (len(taps) - 1) // 2
    x = x_ref[0]
    # gradients on the blur-padded extent (valid for blurring afterwards)
    gx, gy = _sobel_vmem(x, h + 2 * r, w + 2 * r)
    ixx = _blur_vmem(gx * gx, taps, h, w)
    iyy = _blur_vmem(gy * gy, taps, h, w)
    ixy = _blur_vmem(gx * gy, taps, h, w)
    if shi_tomasi:
        half_tr = 0.5 * (ixx + iyy)
        rad = jnp.sqrt(jnp.maximum(0.25 * (ixx - iyy) ** 2 + ixy * ixy, 0.0))
        resp = half_tr - rad
    else:
        det = ixx * iyy - ixy * ixy
        tr = ixx + iyy
        resp = det - k * tr * tr
    o_ref[0] = resp


def harris_pallas(x_padded, *, k: float, sigma: float, shi_tomasi: bool,
                  h: int, w: int, interpret: bool):
    """x_padded: [n, h+2p, w+2p] with p = blur_radius + 1."""
    taps = tuple(gaussian_kernel_1d(float(sigma)).tolist())
    n, hp, wp = x_padded.shape
    kern = functools.partial(harris_kernel, k=k, taps=taps,
                             shi_tomasi=shi_tomasi, h=h, w=w)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, hp, wp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jnp.zeros((n, h, w), jnp.float32),
        interpret=interpret,
    )(x_padded)
