"""Pallas TPU kernels for DIFET's per-pixel and per-descriptor hot spots.

The stencil kernels (harris, fastscore, scalespace) fuse a multi-pass
pipeline into one VMEM-resident pass — one HBM read + one write per tile —
vs. XLA's one-materialization-per-stage lowering of the pure-jnp
reference.  The matcher kernel keeps a descriptor database VMEM-resident
and streams bit-packed/float distance chunks through running best-2
registers.  Kernels are validated in interpret mode against ``ref.py``
oracles over shape/dtype sweeps (tests/test_kernels.py,
tests/test_matcher.py).
"""
