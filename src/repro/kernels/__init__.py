"""Pallas TPU kernels for DIFET's stencil hot-spots.

Each kernel fuses a multi-pass stencil pipeline into one VMEM-resident pass
(one HBM read + one write per tile), vs. XLA's one-materialization-per-stage
lowering of the pure-jnp reference.  Kernels are validated in interpret mode
against ``ref.py`` oracles over shape/dtype sweeps (tests/test_kernels.py).
"""
