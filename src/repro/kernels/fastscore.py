"""FAST-N segment-test score Pallas kernel.

Hardware adaptation (DESIGN.md §5): the CPU/OpenCV FAST is branchy (early
exit on the 1-5-9-13 probe); on the TPU VPU we re-formulate branch-free —
all 16 circle neighbours are shifted VMEM slices, the "contiguous arc of
length >= N" test becomes an OR over 16 of an AND over N static shifted
boolean stacks, and the score is a masked reduction.  Everything stays in
VMEM; one HBM read per tile.
"""
from __future__ import annotations

import functools

from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.core.detectors import FAST_OFFSETS


def fast_kernel(x_ref, o_ref, *, threshold: float, arc: int, h: int, w: int):
    """x_ref: [1, h+6, w+6]; o_ref: [1, h, w]."""
    x = x_ref[0]
    center = x[3:3 + h, 3:3 + w]
    circ = [x[3 + dy:3 + dy + h, 3 + dx:3 + dx + w]
            for (dy, dx) in FAST_OFFSETS]
    brighter = [c > center + threshold for c in circ]
    darker = [c < center - threshold for c in circ]

    def has_arc(flags):
        hit = jnp.zeros((h, w), jnp.bool_)
        for start in range(16):
            run = flags[start % 16]
            for j in range(1, arc):
                run = run & flags[(start + j) % 16]
            hit = hit | run
        return hit

    is_corner = has_arc(brighter) | has_arc(darker)
    diff = [jnp.abs(c - center) - threshold for c in circ]
    score_b = sum(jnp.where(b, d, 0.0) for b, d in zip(brighter, diff))
    score_d = sum(jnp.where(dk, d, 0.0) for dk, d in zip(darker, diff))
    o_ref[0] = jnp.where(is_corner, jnp.maximum(score_b, score_d), 0.0)


def fast_pallas(x_padded, *, threshold: float, arc: int, h: int, w: int,
                interpret: bool):
    n, hp, wp = x_padded.shape
    kern = functools.partial(fast_kernel, threshold=threshold, arc=arc,
                             h=h, w=w)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, hp, wp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jnp.zeros((n, h, w), jnp.float32),
        interpret=interpret,
    )(x_padded)
