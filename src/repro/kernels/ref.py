"""Pure-jnp oracles for the Pallas kernels.

These mirror the kernels' exact padding convention (reflect-pad the *image*
once, then valid-slice) so kernel-vs-ref equality holds at every pixel.
The production jnp detectors (`repro.core.detectors`) pad per-stage instead;
the two conventions agree everywhere except a <= (blur_radius+1) border band
— and DIFET's interior-ownership rule (halo=24) makes that band irrelevant,
which tests/test_kernels.py asserts explicitly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.pyramid import gaussian_kernel_1d, octave_increments


def _pad(img, r):
    return jnp.pad(img, [(0, 0)] * (img.ndim - 2) + [(r, r), (r, r)],
                   mode="reflect")


def _sobel_valid(x, h, w):
    sl = lambda dy, dx: x[..., 1 + dy:1 + dy + h, 1 + dx:1 + dx + w]
    gx = (sl(-1, 1) + 2 * sl(0, 1) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(0, -1) - sl(1, -1)) / 8.0
    gy = (sl(1, -1) + 2 * sl(1, 0) + sl(1, 1)
          - sl(-1, -1) - 2 * sl(-1, 0) - sl(-1, 1)) / 8.0
    return gx, gy


def _blur_valid(x, taps, h, w):
    r = (len(taps) - 1) // 2
    tmp = sum(float(taps[j]) * x[..., :, j:j + w] for j in range(2 * r + 1))
    return sum(float(taps[i]) * tmp[..., i:i + h, :] for i in range(2 * r + 1))


def harris(img, *, k: float = 0.04, sigma: float = 1.0,
           shi_tomasi: bool = False):
    h, w = img.shape[-2:]
    taps = gaussian_kernel_1d(float(sigma))
    r = (len(taps) - 1) // 2
    x = _pad(img.astype(jnp.float32), r + 1)
    gx, gy = _sobel_valid(x, h + 2 * r, w + 2 * r)
    ixx = _blur_valid(gx * gx, taps, h, w)
    iyy = _blur_valid(gy * gy, taps, h, w)
    ixy = _blur_valid(gx * gy, taps, h, w)
    if shi_tomasi:
        half_tr = 0.5 * (ixx + iyy)
        rad = jnp.sqrt(jnp.maximum(0.25 * (ixx - iyy) ** 2 + ixy * ixy, 0.0))
        return half_tr - rad
    det = ixx * iyy - ixy * ixy
    tr = ixx + iyy
    return det - k * tr * tr


def gaussian_blur(img, sigma: float):
    h, w = img.shape[-2:]
    taps = gaussian_kernel_1d(float(sigma))
    r = (len(taps) - 1) // 2
    return _blur_valid(_pad(img.astype(jnp.float32), r), taps, h, w)


def scalespace_octave(base, *, scales_per_octave: int,
                      contrast_threshold: float, sigma0: float = 1.6):
    """Oracle for the fused scale-space kernel: same pad-once/valid-conv
    convention, but the extrema use the naive 26-neighbour stack — an
    independent formulation that cross-checks the kernel's decomposed
    shifted-max chains.  Returns (resp [...,H,W], seed [...,H,W])."""
    h, w = base.shape[-2:]
    incs = octave_increments(scales_per_octave, float(sigma0))
    taps_list = [gaussian_kernel_1d(s) for s in incs]
    margin = sum((len(t) - 1) // 2 for t in taps_list) + 1
    prev = _pad(base.astype(jnp.float32), margin)
    dogs, seed = [], None                        # dogs: (slab, margin)
    for s, taps in enumerate(taps_list, start=1):
        r = (len(taps) - 1) // 2
        m = margin - r
        cur = _blur_valid(prev, taps, h + 2 * m, w + 2 * m)
        dogs.append((cur - prev[..., r:r + h + 2 * m, r:r + w + 2 * m], m))
        if s == scales_per_octave:
            seed = cur[..., m:m + h, m:m + w]
        prev, margin = cur, m
    # align every DoG slab on the margin-1 extent, stack over scale
    d = jnp.stack([dg[..., m - 1:m - 1 + h + 2, m - 1:m - 1 + w + 2]
                   for dg, m in dogs], axis=-3)
    s_dim = d.shape[-3]
    mid = d[..., 1:s_dim - 1, 1:h + 1, 1:w + 1]
    neigh = []
    for ds in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if ds == 0 and dy == 0 and dx == 0:
                    continue
                neigh.append(d[..., 1 + ds:1 + ds + s_dim - 2,
                               1 + dy:1 + dy + h, 1 + dx:1 + dx + w])
    neigh = jnp.stack(neigh, axis=0)
    is_ext = (mid > neigh.max(axis=0)) | (mid < neigh.min(axis=0))
    resp = jnp.where(is_ext & (jnp.abs(mid) > contrast_threshold),
                     jnp.abs(mid), 0.0).max(axis=-3)
    return resp, seed


def _unpack_bits(x):
    """uint32 [N, W] -> bool [N, W*32] (little-endian within each word)."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (x[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(x.shape[0], -1).astype(jnp.bool_)


def match_best2(q, db, db_valid, *, metric: str):
    """Oracle for the matcher kernel: the full [Q, K] distance matrix with
    an *independent* formulation — Hamming by bit-unpacked XOR counting
    (vs the kernel's packed SWAR popcount), L2 by the same norm expansion
    but on the un-chunked matrix.  best/second by argmin + re-min; ties go
    to the smallest database index, matching the kernel's merge rule.
    Hamming distances are exact ints, so kernel equality is bitwise."""
    if metric == "hamming":
        d = jnp.sum(_unpack_bits(q)[:, None, :] != _unpack_bits(db)[None, :, :],
                    axis=-1, dtype=jnp.int32)
        big = jnp.int32(1 << 30)
    elif metric == "l2":
        q = q.astype(jnp.float32)
        db = db.astype(jnp.float32)
        qn = jnp.sum(q * q, axis=-1)
        dn = jnp.sum(db * db, axis=-1)
        d = qn[:, None] + dn[None, :] - 2.0 * (q @ db.T)
        big = jnp.float32(jnp.inf)
    else:
        raise ValueError(f"unknown metric {metric!r}")
    d = jnp.where(db_valid[None, :] != 0, d, big)
    arg = jnp.argmin(d, axis=1).astype(jnp.int32)
    best = jnp.min(d, axis=1)
    cols = jnp.arange(db.shape[0], dtype=jnp.int32)
    second = jnp.min(jnp.where(cols[None, :] == arg[:, None], big, d), axis=1)
    return best, second, arg


def match_best2_blocked(q, db, db_valid, *, metric: str,
                        block: int = 65536):
    """Big-database oracle: `match_best2` evaluated over database blocks
    with a running cross-block merge, so parity checks against streamed
    production paths scale to millions of rows without ever unpacking or
    materializing the whole [Q, K] matrix.  Same distances, same
    smallest-index tie-break (strictly-less merge in database order) —
    results equal `match_best2` exactly."""
    nq, nk = q.shape[0], db.shape[0]
    big = jnp.int32(1 << 30) if metric == "hamming" else jnp.float32(jnp.inf)
    best = np.full((nq,), np.asarray(big))
    second = np.full((nq,), np.asarray(big))
    bidx = np.zeros((nq,), np.int32)
    for start in range(0, nk, block):
        cb, cs, ci = (np.asarray(o) for o in match_best2(
            q, db[start:start + block], db_valid[start:start + block],
            metric=metric))
        ci = ci + np.int32(start)
        take = cb < best
        second = np.where(take, np.minimum(best, cs), np.minimum(second, cb))
        bidx = np.where(take, ci, bidx)
        best = np.where(take, cb, best)
    return jnp.asarray(best), jnp.asarray(second), jnp.asarray(bidx)


def fast_score(img, *, threshold: float = 0.15, arc: int = 9):
    from repro.core.detectors import FAST_OFFSETS
    h, w = img.shape[-2:]
    x = _pad(img.astype(jnp.float32), 3)
    center = x[..., 3:3 + h, 3:3 + w]
    circ = jnp.stack([x[..., 3 + dy:3 + dy + h, 3 + dx:3 + dx + w]
                      for dy, dx in FAST_OFFSETS], axis=-3)
    brighter = circ > center[..., None, :, :] + threshold
    darker = circ < center[..., None, :, :] - threshold

    def has_arc(flags):
        hit = jnp.zeros(flags.shape[:-3] + (h, w), jnp.bool_)
        for start in range(16):
            run = flags[..., start % 16, :, :]
            for j in range(1, arc):
                run = run & flags[..., (start + j) % 16, :, :]
            hit = hit | run
        return hit

    is_corner = has_arc(brighter) | has_arc(darker)
    diff = jnp.abs(circ - center[..., None, :, :]) - threshold
    score_b = jnp.where(brighter, diff, 0.0).sum(axis=-3)
    score_d = jnp.where(darker, diff, 0.0).sum(axis=-3)
    return jnp.where(is_corner, jnp.maximum(score_b, score_d), 0.0)
