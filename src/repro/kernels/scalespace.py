"""Fused SIFT scale-space octave kernel: one DMA per tile.

The level-by-level path DMAs every Gaussian level, every DoG difference and
the 26-neighbour extrema stack through HBM — (n_scales + n_scales-1 + 26)
round-trips per octave for the costliest algorithm in the paper's Table 1.
This kernel does ONE: the padded tile is DMA'd to VMEM and the whole
octave — incremental Gaussian stack, DoG differences, and the 3x3x3
DoG-extrema response — is computed on VMEM values inside a single
``pallas_call``.  Only two maps leave VMEM: the octave's extrema response
and the seed level (total sigma ``2*sigma0``) that the caller downsamples
to start the next octave.

Incremental-sigma taps are compile-time constants (the semigroup split of
the octave's sigmas is static), so every separable pass unrolls into
fused multiply-adds, mirroring ``harris_kernel``.

Convention: the caller reflect-pads the tile ONCE by the cumulative blur
radius (+1 for the extrema window); every level is then a valid
convolution with a shrinking margin.  ``kernels/ref.py::scalespace_octave``
is the oracle with the same convention; the production jnp path pads per
level instead, so the two agree only beyond the cumulative-radius band
(DESIGN.md §6).

Grid: one program per tile.  VMEM working set is ~(n_scales + 4) padded
slabs; the ops.py wrapper checks it against the ~16 MiB v5e budget and the
dispatcher falls back to the streaming jnp path for oversized tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _blur_valid(x, taps, out_h: int, out_w: int):
    """Separable valid blur of the VMEM value x -> (out_h, out_w)."""
    r = (len(taps) - 1) // 2
    tmp = sum(float(taps[j]) * x[:, j:j + out_w] for j in range(2 * r + 1))
    return sum(float(taps[i]) * tmp[i:i + out_h, :] for i in range(2 * r + 1))


def _win3x3(d, h: int, w: int):
    """d: margin-1 slab (h+2, w+2) -> (full9_max, full9_min, ring8_max,
    ring8_min), each (h, w), via separable shifted-max chains."""
    col = lambda x: (jnp.maximum(jnp.maximum(d[:, 0:w], d[:, 1:w + 1]),
                                 d[:, 2:w + 2]),
                     jnp.minimum(jnp.minimum(d[:, 0:w], d[:, 1:w + 1]),
                                 d[:, 2:w + 2]))
    h3mx, h3mn = col(d)
    lrmx = jnp.maximum(d[:, 0:w], d[:, 2:w + 2])
    lrmn = jnp.minimum(d[:, 0:w], d[:, 2:w + 2])
    full9_max = jnp.maximum(jnp.maximum(h3mx[0:h], h3mx[1:h + 1]),
                            h3mx[2:h + 2])
    full9_min = jnp.minimum(jnp.minimum(h3mn[0:h], h3mn[1:h + 1]),
                            h3mn[2:h + 2])
    ring8_max = jnp.maximum(jnp.maximum(h3mx[0:h], h3mx[2:h + 2]),
                            lrmx[1:h + 1])
    ring8_min = jnp.minimum(jnp.minimum(h3mn[0:h], h3mn[2:h + 2]),
                            lrmn[1:h + 1])
    return full9_max, full9_min, ring8_max, ring8_min


def scalespace_kernel(x_ref, resp_ref, seed_ref, *, taps_list, h: int,
                      w: int, seed_index: int, contrast_threshold: float):
    """x_ref: [1, h + 2P, w + 2P] with P = sum(blur radii) + 1 — the
    octave's level 0 (sigma0), pre-padded.  resp_ref/seed_ref: [1, h, w]."""
    margin = sum((len(t) - 1) // 2 for t in taps_list) + 1
    prev = x_ref[0]
    dogs = []                                    # (slab, margin) pairs
    for s, taps in enumerate(taps_list, start=1):
        r = (len(taps) - 1) // 2
        m = margin - r
        eh, ew = h + 2 * m, w + 2 * m
        cur = _blur_valid(prev, taps, eh, ew)
        dogs.append((cur - prev[r:r + eh, r:r + ew], m))
        if s == seed_index:
            seed_ref[0] = cur[m:m + h, m:m + w]
        prev, margin = cur, m
    # crop every DoG slab to margin 1 and take 3x3 window stats
    stats, mids = [], []
    for d, m in dogs:
        c = m - 1
        stats.append(_win3x3(d[c:c + h + 2, c:c + w + 2], h, w))
        mids.append(d[m:m + h, m:m + w])
    resp = jnp.zeros((h, w), jnp.float32)
    for s in range(1, len(dogs) - 1):
        below_mx, below_mn, _, _ = stats[s - 1]
        above_mx, above_mn, _, _ = stats[s + 1]
        _, _, ring_mx, ring_mn = stats[s]
        mid = mids[s]
        neigh_max = jnp.maximum(jnp.maximum(below_mx, above_mx), ring_mx)
        neigh_min = jnp.minimum(jnp.minimum(below_mn, above_mn), ring_mn)
        is_ext = (mid > neigh_max) | (mid < neigh_min)
        r_s = jnp.where(is_ext & (jnp.abs(mid) > contrast_threshold),
                        jnp.abs(mid), 0.0)
        resp = jnp.maximum(resp, r_s)
    resp_ref[0] = resp


def scalespace_pallas(x_padded, *, taps_list, h: int, w: int,
                      seed_index: int, contrast_threshold: float,
                      interpret: bool):
    """x_padded: [n, h+2P, w+2P] -> (resp [n,h,w], seed [n,h,w])."""
    n, hp, wp = x_padded.shape
    kern = functools.partial(
        scalespace_kernel, taps_list=taps_list, h=h, w=w,
        seed_index=seed_index, contrast_threshold=contrast_threshold)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, hp, wp), lambda i: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, h, w), lambda i: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, h, w), jnp.float32),
                   jax.ShapeDtypeStruct((n, h, w), jnp.float32)],
        interpret=interpret,
    )(x_padded)
