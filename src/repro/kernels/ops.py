"""jit'd public wrappers for the Pallas kernels.

Handle host-side reflect padding (so kernel slicing is 'valid'), lane-dim
alignment to 128 multiples, [H,W] vs [N,H,W] rank, and the interpret-mode
fallback on CPU (this container validates kernels in interpret mode; on a
real TPU set ``interpret=False``/default).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pyramid import gaussian_kernel_1d, octave_increments
from repro.kernels import dispatch as _dispatch
from repro.obs import profile as _obs_profile
from repro.kernels import harris as _harris
from repro.kernels import blur as _blur
from repro.kernels import fastscore as _fast
from repro.kernels import matcher as _matcher
from repro.kernels import scalespace as _scalespace

LANE = 128
# VMEM budget for the fused scale-space kernel: leave headroom below the
# ~16 MiB v5e per-core VMEM for double-buffered DMA + compiler spill
# (DESIGN.md §6).
VMEM_BUDGET_BYTES = 12 * 2 ** 20


def _interpret_default():
    return jax.default_backend() != "tpu"


def _prep(img, pad: int):
    """Reflect-pad by ``pad``; align padded W to a LANE multiple (extra
    right-pad is cropped from the output).  Returns (x [N,Hp,Wp], h, w,
    squeeze)."""
    squeeze = img.ndim == 2
    x = img[None] if squeeze else img
    n, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)), mode="reflect")
    extra = (-xp.shape[-1]) % LANE
    if extra:
        xp = jnp.pad(xp, ((0, 0), (0, 0), (0, extra)), mode="edge")
    return xp.astype(jnp.float32), h, w, squeeze


def _crop(out, h, w, squeeze):
    out = out[..., :h, :w]
    return out[0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("k", "sigma", "shi_tomasi",
                                             "interpret"))
def harris(img, *, k: float = 0.04, sigma: float = 1.0,
           shi_tomasi: bool = False, interpret: bool = None):
    """Fused Harris / Shi-Tomasi response.  img [H,W] or [N,H,W] -> same."""
    interpret = _interpret_default() if interpret is None else interpret
    r = max(1, int(np.ceil(3.0 * sigma)))
    xp, h, w, squeeze = _prep(img, r + 1)
    wk = xp.shape[-1] - 2 * (r + 1)       # lane-aligned interior width
    out = _harris.harris_pallas(xp, k=k, sigma=sigma, shi_tomasi=shi_tomasi,
                                h=h, w=wk, interpret=interpret)
    return _crop(out, h, w, squeeze)


@functools.partial(jax.jit, static_argnames=("sigma", "interpret"))
def gaussian_blur(img, sigma: float, interpret: bool = None):
    """Separable Gaussian blur.  img [..., H, W] (leading dims flattened)."""
    interpret = _interpret_default() if interpret is None else interpret
    lead = img.shape[:-2]
    x = img.reshape((-1,) + img.shape[-2:])
    r = max(1, int(np.ceil(3.0 * sigma)))
    xp, h, w, _ = _prep(x, r)
    wk = xp.shape[-1] - 2 * r
    out = _blur.blur_pallas(xp, sigma=sigma, h=h, w=wk, interpret=interpret)
    return out[..., :h, :w].reshape(lead + img.shape[-2:])


@functools.partial(jax.jit, static_argnames=("threshold", "arc", "interpret"))
def fast_score(img, *, threshold: float = 0.15, arc: int = 9,
               interpret: bool = None):
    """FAST-N corner score.  img [H,W] or [N,H,W] -> same."""
    interpret = _interpret_default() if interpret is None else interpret
    xp, h, w, squeeze = _prep(img, 3)
    wk = xp.shape[-1] - 6
    out = _fast.fast_pallas(xp, threshold=threshold, arc=arc, h=h, w=wk,
                            interpret=interpret)
    return _crop(out, h, w, squeeze)


def _scalespace_taps(scales_per_octave: int, sigma0: float):
    """Compile-time incremental taps for one octave's levels 1..n_scales-1."""
    return tuple(tuple(gaussian_kernel_1d(s).tolist())
                 for s in octave_increments(scales_per_octave, sigma0))


def scalespace_pad(scales_per_octave: int, sigma0: float = 1.6) -> int:
    """One-DMA padding: cumulative blur radius + 1 for the extrema window."""
    return sum((len(t) - 1) // 2
               for t in _scalespace_taps(scales_per_octave, sigma0)) + 1


def scalespace_vmem_bytes(h: int, w: int, scales_per_octave: int,
                          sigma0: float = 1.6) -> int:
    """Working-set estimate for the fused octave kernel: the padded input
    slab plus ~(n_levels + n_dogs + 4) live level/DoG/stat slabs (fp32),
    lane-aligned.  See DESIGN.md §6 for the budget table."""
    p = scalespace_pad(scales_per_octave, sigma0)
    wp = w + 2 * p
    wp += (-wp) % LANE
    slab = (h + 2 * p) * wp * 4
    n_levels = scales_per_octave + 3
    return (2 * n_levels + 2 + 4) * slab


def scalespace_fits_vmem(h: int, w: int, scales_per_octave: int,
                         sigma0: float = 1.6) -> bool:
    """True when a fused octave for an ``[h, w]`` tile fits the 12 MiB
    VMEM budget — the dispatcher's kernel/jnp-fallback gate."""
    return scalespace_vmem_bytes(h, w, scales_per_octave,
                                 sigma0) <= VMEM_BUDGET_BYTES


MATCH_QBLOCK = _matcher.QBLOCK


def matcher_vmem_bytes(nk: int, d: int, metric: str = "l2") -> int:
    """Working-set estimate for the matcher kernel: the VMEM-resident
    database slab + one query block + the per-chunk distance temporaries
    (Hamming also holds the [Q, C, W] XOR/popcount intermediate).  See
    DESIGN.md §7 for the budget table."""
    kc = min(_matcher.kchunk_for(metric), nk)
    db = nk * d * 4
    q = MATCH_QBLOCK * d * 4
    if metric == "hamming":
        tmp = MATCH_QBLOCK * kc * (2 * d + 2) * 4
    else:
        tmp = MATCH_QBLOCK * kc * 3 * 4 + 2 * nk * 4
    return db + q + tmp + 6 * MATCH_QBLOCK * 4


def matcher_fits_vmem(nk: int, d: int, metric: str = "l2") -> bool:
    """True when an ``[nk, d]`` descriptor database fits the matcher
    kernel's VMEM budget — the `match_best2` kernel/fallback gate."""
    return matcher_vmem_bytes(nk, d, metric) <= VMEM_BUDGET_BYTES


MATCH_PATHS = _dispatch.MATCH_PATHS


def match_path(nq: int, nk: int, d: int, *, metric: str = "l2",
               use_pallas: bool = None, backend: str = None) -> str:
    """Resolve which implementation a ``match_best2`` call of this shape
    will take — one of ``jnp_full | jnp_stream | pallas_resident |
    pallas_stream`` (`kernels/dispatch.py`).

    ``use_pallas=True`` forces a kernel: the VMEM-resident one when the
    database fits the budget, else the streaming tiled-DB kernel — there
    is no silent jnp fallback anymore.  ``use_pallas=False`` restricts to
    the jnp formulations; ``None`` (the default) lets the per-(metric,
    backend, shape-bucket) microbenchmark decide.  Benchmarks and tests
    call this to *assert* the dispatch decision (e.g. that a million-row
    database streams rather than falling back).
    """
    if use_pallas is True:
        if matcher_fits_vmem(nk, d, metric) and nk <= _dispatch.FULL_MAX_ROWS:
            return "pallas_resident"
        return "pallas_stream"
    return _dispatch.choose_path(metric, nq, nk, d, backend=backend,
                                 use_pallas=use_pallas)


@functools.partial(jax.jit, static_argnames=("metric", "path", "interpret"))
def _match_impl(queries, db, db_valid, *, metric: str, path: str,
                interpret: bool):
    """One matcher implementation, jit'd per (metric, path): padding and
    lane alignment happen inside the trace so callers stay shape-exact."""
    nq, nk = queries.shape[0], db.shape[0]
    if metric == "l2":
        queries = queries.astype(jnp.float32)
        db = db.astype(jnp.float32)
    if path == "jnp_full":
        return _matcher.best2_full(queries, db, db_valid, metric=metric)
    if path == "jnp_stream":
        return _matcher.best2_stream(queries, db, db_valid, metric=metric)
    if metric == "l2":
        extra = (-queries.shape[1]) % LANE     # zero-pad D to a lane multiple
        if extra:
            queries = jnp.pad(queries, ((0, 0), (0, extra)))
            db = jnp.pad(db, ((0, 0), (0, extra)))
    pad_q = (-nq) % MATCH_QBLOCK
    qp = jnp.pad(queries, ((0, pad_q), (0, 0))) if pad_q else queries
    mask = db_valid.astype(jnp.int32)
    if path == "pallas_resident":
        best, second, idx = _matcher.match_pallas(
            qp, db, mask[None, :], metric=metric, interpret=interpret)
    else:                                      # pallas_stream
        pad_k = (-nk) % _matcher.kblock_for(metric)
        if pad_k:                              # pad rows masked invalid
            db = jnp.pad(db, ((0, pad_k), (0, 0)))
            mask = jnp.pad(mask, (0, pad_k))
        best, second, idx = _matcher.match_pallas_stream(
            qp, db, mask[None, :], metric=metric, interpret=interpret)
    return best[:nq], second[:nq], idx[:nq]


def match_best2(queries, db, db_valid=None, *, metric: str = "l2",
                use_pallas: bool = None, interpret: bool = None,
                path: str = None):
    """Per-query (best, second-best, argbest) over a masked descriptor DB.

    queries [Q, D], db [K, D], db_valid [K] (None = all valid).  For
    ``metric="hamming"`` both must be bit-packed uint32 word lanes
    (``descriptors.pack_bits`` layout); distances are exact int32.  For
    ``metric="l2"`` inputs are cast to fp32 and distances are *squared* L2
    (monotonic for ranking; the ratio test squares its threshold).

    Dispatch is **benchmark-gated** (`kernels/dispatch.py`): by default
    (``use_pallas=None``) a one-shot microbenchmark per (metric, backend,
    shape-bucket) — cached on disk — picks the fastest of the jnp
    formulations and (on TPU) the Pallas kernels, so a backend where one
    path regresses silently gets the fast one.  ``use_pallas=True``
    forces a kernel (resident under the VMEM budget, streaming above it
    — a million-row database streams instead of falling back);
    ``use_pallas=False`` forces jnp; ``path`` pins an exact
    implementation (one of `MATCH_PATHS`, mainly for tests/benchmarks).
    Every path computes the same distances with the same masking and
    smallest-index tie-breaks, so the choice is performance, never
    numerics (Hamming results are bit-identical across all four).

    The decision needs only shapes, so calls from inside ``jit``/``vmap``
    traces resolve at trace time and bake the chosen path into the
    compiled program.
    """
    interpret = _interpret_default() if interpret is None else interpret
    nq, nk = queries.shape[0], db.shape[0]
    if db_valid is None:
        db_valid = jnp.ones((nk,), jnp.bool_)
    if metric == "hamming":
        if queries.dtype != jnp.uint32 or db.dtype != jnp.uint32:
            raise TypeError("hamming matching needs bit-packed uint32 "
                            "descriptors (descriptors.pack_bits)")
    elif metric != "l2":
        raise ValueError(f"unknown metric {metric!r}")
    if path is None:
        path = match_path(nq, nk, queries.shape[1], metric=metric,
                          use_pallas=use_pallas)
    elif path not in MATCH_PATHS:
        raise ValueError(f"unknown path {path!r} (want one of {MATCH_PATHS})")
    prof = _obs_profile.profiler()
    if not prof.enabled:
        # hot path: zero extra work, and critically NO synchronization —
        # profiling must never change the async dispatch behavior of an
        # unprofiled run
        return _match_impl(queries, db, db_valid, metric=metric, path=path,
                           interpret=interpret)
    qb, kb, db_w = _dispatch.shape_bucket(nq, nk, queries.shape[1])
    t0 = time.monotonic()
    out = _match_impl(queries, db, db_valid, metric=metric, path=path,
                      interpret=interpret)
    try:
        jax.block_until_ready(out)             # put async work on the clock
    except Exception:  # noqa: BLE001 — tracers inside someone else's jit
        pass
    prof.record_call(f"match:{metric}:{path}:q{qb}k{kb}d{db_w}",
                     time.monotonic() - t0)
    return out


@functools.partial(jax.jit, static_argnames=("scales_per_octave",
                                             "contrast_threshold", "sigma0",
                                             "interpret"))
def scalespace_octave(base, *, scales_per_octave: int,
                      contrast_threshold: float, sigma0: float = 1.6,
                      interpret: bool = None):
    """Fused SIFT octave: (extrema response, next-octave seed level).

    ``base`` [H,W] or [N,H,W], already blurred to ``sigma0`` (octave level
    0).  One pallas_call computes the whole octave's Gaussian stack, DoG
    differences and 3x3x3 extrema in VMEM; only the response and the seed
    level are written back.
    """
    interpret = _interpret_default() if interpret is None else interpret
    taps_list = _scalespace_taps(scales_per_octave, float(sigma0))
    p = sum((len(t) - 1) // 2 for t in taps_list) + 1
    xp, h, w, squeeze = _prep(base, p)
    wk = xp.shape[-1] - 2 * p
    resp, seed = _scalespace.scalespace_pallas(
        xp, taps_list=taps_list, h=h, w=wk,
        seed_index=scales_per_octave,
        contrast_threshold=float(contrast_threshold), interpret=interpret)
    return _crop(resp, h, w, squeeze), _crop(seed, h, w, squeeze)
