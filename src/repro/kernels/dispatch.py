"""Benchmark-gated matcher dispatch: measure once per shape-bucket, route
every later call to the winning implementation.

`BENCH_61e2246.json` caught the matcher's jnp "production" formulation at
a fraction of its oracle's speed on this host — the right formulation is
a *backend property* (packed chunked scans win on TPU where HBM traffic
dominates; one fused [Q, K] block wins on CPU XLA; interpret-mode Pallas
is never a perf path), so hardcoding any single choice loses somewhere.
Instead, `ops.match_best2` asks :func:`choose_path`, which runs a tiny
one-shot microbenchmark per ``(metric, backend, shape-bucket)`` the first
time a bucket is seen, persists the verdict to a small on-disk JSON
cache, and answers from memory afterwards — a call site on a backend
where one path regresses silently gets the fast one.

Buckets round (nq, nk) up to powers of two (descriptor width stays
exact), so the measurement cost is O(log^2) in shape space.  Probe
arrays are capped (`PROBE_NQ_CAP` / `PROBE_NK_CAP`): beyond the cap
every candidate is linear in the same streamed dimension, so the capped
contest ranks them correctly without materializing a million-row probe.

Candidate paths (see `kernels/matcher.py` for the implementations):

====================  =======================================================
``jnp_full``          one [Q, K] distance block (`best2_full`)
``jnp_stream``        lax.scan over DB chunks, carried registers
                      (`best2_stream`)
``pallas_resident``   whole-DB-in-VMEM kernel (`match_pallas`); TPU only,
                      and only when the DB fits the VMEM budget
``pallas_stream``     tiled-DB streaming kernel (`match_pallas_stream`);
                      TPU only
====================  =======================================================

Databases larger than `FULL_MAX_ROWS` drop the materializing candidates
(``jnp_full`` / ``pallas_resident``) outright — a million-row [Q, K]
block is a memory hazard regardless of speed — which is what lets one
query batch scan millions of descriptors through the streaming paths.

The cache file lives at ``$DIFET_DISPATCH_CACHE`` (default
``~/.cache/difet/matcher_dispatch.json``); delete it to re-measure, e.g.
after a driver or XLA upgrade.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import matcher as _matcher
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile

JNP_PATHS = ("jnp_full", "jnp_stream")
PALLAS_PATHS = ("pallas_resident", "pallas_stream")
MATCH_PATHS = JNP_PATHS + PALLAS_PATHS

# beyond this many DB rows the [Q, K] block / resident-DB candidates are
# excluded (memory hazard), leaving only the streaming paths
FULL_MAX_ROWS = 1 << 17
# probe-array caps: the microbenchmark never materializes more than this
PROBE_NQ_CAP = 512
PROBE_NK_CAP = 1 << 14
_PROBE_REPS = 3

CACHE_ENV = "DIFET_DISPATCH_CACHE"
_lock = threading.Lock()
_memory: Dict[str, str] = {}        # bucket key -> chosen path (per process)
# measurement counter, exposed for tests asserting cache hit/miss behavior
measure_count = 0


def cache_path() -> str:
    """Location of the on-disk dispatch cache (``$DIFET_DISPATCH_CACHE``
    or ``~/.cache/difet/matcher_dispatch.json``)."""
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "difet",
                        "matcher_dispatch.json")


def clear_memory_cache() -> None:
    """Drop the in-process bucket->path memo (the disk cache survives);
    mainly for tests that repoint ``$DIFET_DISPATCH_CACHE``."""
    with _lock:
        _memory.clear()


def _load_disk() -> Dict[str, dict]:
    try:
        with open(cache_path()) as f:
            d = json.load(f)
        return d if isinstance(d, dict) else {}
    except (OSError, ValueError):
        return {}


def _store_disk(key: str, entry: dict) -> None:
    path = cache_path()
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        d = _load_disk()
        d[key] = entry
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(d, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    except OSError:
        pass                    # read-only FS: in-memory memo still works


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def shape_bucket(nq: int, nk: int, d: int) -> Tuple[int, int, int]:
    """Round (nq, nk) up to powers of two; descriptor width stays exact.
    All shapes in a bucket share one measured verdict."""
    return _pow2(max(nq, 1)), _pow2(max(nk, 1)), int(d)


def bucket_key(metric: str, backend: str, nq: int, nk: int, d: int) -> str:
    qb, kb, db = shape_bucket(nq, nk, d)
    return f"{metric}|{backend}|q{qb}|k{kb}|d{db}"


def candidate_paths(metric: str, backend: str, nk: int, d: int,
                    use_pallas: Optional[bool] = None) -> Tuple[str, ...]:
    """Paths eligible for a (metric, backend, DB-size) combination.

    ``use_pallas=True`` restricts to the kernels, ``False`` to the jnp
    formulations, ``None`` lets the benchmark decide among all eligible.
    Pallas candidates require a TPU backend (interpret mode validates
    numerics, not speed); the materializing candidates drop out beyond
    `FULL_MAX_ROWS`.
    """
    big_db = nk > FULL_MAX_ROWS
    jnp_c = ("jnp_stream",) if big_db else JNP_PATHS
    if backend == "tpu":
        from repro.kernels import ops as _ops       # local: avoid cycle at import
        fits = _ops.matcher_fits_vmem(nk, d, metric)
        pallas_c = ("pallas_stream",) if (big_db or not fits) else PALLAS_PATHS
    else:
        pallas_c = ()
    if use_pallas is True:
        return pallas_c or (("pallas_stream",) if backend == "tpu"
                            else jnp_c)
    if use_pallas is False:
        return jnp_c
    return jnp_c + pallas_c


def _probe_arrays(metric: str, nq: int, nk: int, d: int):
    """Deterministic numpy probe inputs (numpy, not jnp: the caller may
    be inside someone else's trace — conversion happens in the probe
    thread, which has no ambient trace)."""
    rng = np.random.RandomState(0)
    if metric == "hamming":
        q = rng.randint(0, 2 ** 32, size=(nq, d),
                        dtype=np.uint64).astype(np.uint32)
        db = rng.randint(0, 2 ** 32, size=(nk, d),
                         dtype=np.uint64).astype(np.uint32)
    else:
        q = rng.randn(nq, d).astype(np.float32)
        db = rng.randn(nk, d).astype(np.float32)
    return q, db, np.ones((nk,), np.bool_)


def _time_call(fn, *args) -> float:
    """Median-of-reps wall time in us, with a *blocking* warm-up so the
    first rep never pays compile or the warm-up's async execution (the
    measurement bug behind the phantom 16x L2 'regression' in
    BENCH_61e2246)."""
    out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    samples = []
    for _ in range(_PROBE_REPS):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        samples.append(time.perf_counter() - t0)
    return float(np.median(samples) * 1e6)


def measure_path(path: str, metric: str, nq: int, nk: int, d: int) -> float:
    """One-shot microbenchmark of one candidate path at a (capped) bucket
    shape; returns us per call.

    The probe runs in a fresh thread: JAX trace state is thread-local, so
    a dispatch decision triggered *inside* someone else's jit trace (the
    usual case — `match_best2` called under a caller's jit) still
    executes its probe jits concretely instead of being inlined into the
    outer trace as tracers.  All probe inputs are built in the thread.
    """
    global measure_count
    measure_count += 1
    obs_metrics.registry().counter("difet.kernel.dispatch_measures").inc()
    nq = min(nq, PROBE_NQ_CAP)
    nk = min(nk, PROBE_NK_CAP)
    box: Dict[str, object] = {}

    def run():
        try:
            q, db, valid = _probe_arrays(metric, nq, nk, d)
            from repro.kernels import ops as _ops
            fn = jax.jit(functools.partial(_ops.match_best2, metric=metric,
                                           path=path))
            box["us"] = _time_call(fn, jnp.asarray(q), jnp.asarray(db),
                                   jnp.asarray(valid))
        except BaseException as e:             # surfaced by the caller
            box["err"] = e

    t = threading.Thread(target=run, name=f"difet-dispatch-probe-{path}")
    t.start()
    t.join()
    if "err" in box:
        raise box["err"]                       # type: ignore[misc]
    return float(box["us"])                    # type: ignore[arg-type]


def choose_path(metric: str, nq: int, nk: int, d: int, *,
                backend: Optional[str] = None,
                use_pallas: Optional[bool] = None) -> str:
    """The dispatch decision: fastest measured path for this bucket.

    First call per (metric, backend, bucket) runs the microbenchmark and
    persists the verdict; later calls answer from the in-process memo or
    the disk cache.  Single-candidate combinations skip measurement.
    """
    backend = backend or jax.default_backend()
    cands = candidate_paths(metric, backend, nk, d, use_pallas)
    if len(cands) == 1:
        return cands[0]
    abbrev = {"jnp_full": "jf", "jnp_stream": "js",
              "pallas_resident": "pr", "pallas_stream": "ps"}
    key = bucket_key(metric, backend, nq, nk, d) \
        + "|" + "".join(sorted(abbrev[c] for c in cands))
    with _lock:
        hit = _memory.get(key)
    if hit is not None:
        return hit
    disk = _load_disk().get(key)
    if isinstance(disk, dict) and disk.get("path") in cands:
        with _lock:
            _memory[key] = disk["path"]
        return disk["path"]
    qb, kb, db = shape_bucket(nq, nk, d)
    timings = {c: measure_path(c, metric, qb, kb, db) for c in cands}
    best = min(timings, key=timings.get)
    with _lock:
        _memory[key] = best
    for c, us in timings.items():              # probe wall → kernel profile
        obs_profile.record_call(f"dispatch:{metric}:{c}:q{qb}k{kb}d{db}",
                                us * 1e-6)
    # full provenance: enough to audit WHY this bucket routes where it
    # does without re-measuring (launch/obs.py --explain-dispatch)
    _store_disk(key, {"path": best, "us": timings,
                      "probe": [min(qb, PROBE_NQ_CAP),
                                min(kb, PROBE_NK_CAP), db],
                      "metric": metric, "backend": backend,
                      "bucket": [qb, kb, db],
                      "candidates": sorted(cands)})
    return best


def explain() -> Dict[str, dict]:
    """Decoded view of the on-disk dispatch cache: per bucket key, the
    winning path, its margin over the runner-up, and the full candidate
    timing table (``launch/obs.py --explain-dispatch`` renders this).
    Entries written before provenance fields existed decode with
    ``metric``/``backend`` parsed from the key."""
    out: Dict[str, dict] = {}
    for key, entry in sorted(_load_disk().items()):
        if not isinstance(entry, dict) or "path" not in entry:
            continue
        parts = key.split("|")
        row = {"path": entry["path"],
               "metric": entry.get("metric", parts[0]),
               "backend": entry.get("backend",
                                    parts[1] if len(parts) > 1 else "?"),
               "bucket": entry.get("bucket"),
               "probe": entry.get("probe"),
               "candidates": entry.get("candidates",
                                       sorted(entry.get("us", {}))),
               "us": dict(entry.get("us", {}))}
        us = row["us"]
        if len(us) >= 2:
            ranked = sorted(us.items(), key=lambda kv: kv[1])
            row["margin"] = (ranked[1][1] / ranked[0][1]
                             if ranked[0][1] > 0 else float("inf"))
        out[key] = row
    return out
