"""Separable Gaussian blur Pallas kernel — the SIFT scale-space hot loop.

SIFT rebuilds (n_scales+3) · n_octaves blurred images per tile (paper
Table 1: SIFT is 30-45x costlier than the other algorithms); fusing both
separable passes into one VMEM-resident kernel removes the intermediate
row-pass materialization that XLA writes back to HBM.
"""
from __future__ import annotations

import functools

from jax.experimental import pallas as pl
import jax.numpy as jnp

from repro.core.pyramid import gaussian_kernel_1d


def blur_kernel(x_ref, o_ref, *, taps, h: int, w: int):
    """x_ref: [1, h+2r, w+2r]; o_ref: [1, h, w]."""
    r = (len(taps) - 1) // 2
    x = x_ref[0]
    tmp = sum(float(taps[j]) * x[:, j:j + w] for j in range(2 * r + 1))
    o_ref[0] = sum(float(taps[i]) * tmp[i:i + h, :]
                   for i in range(2 * r + 1))


def blur_pallas(x_padded, *, sigma: float, h: int, w: int, interpret: bool):
    taps = tuple(gaussian_kernel_1d(float(sigma)).tolist())
    n, hp, wp = x_padded.shape
    kern = functools.partial(blur_kernel, taps=taps, h=h, w=w)
    return pl.pallas_call(
        kern,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, hp, wp), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda i: (i, 0, 0)),
        out_shape=jnp.zeros((n, h, w), jnp.float32),
        interpret=interpret,
    )(x_padded)
