"""Approximate pre-filter indexes for the matcher: multi-probe LSH over
packed Hamming bits (BRIEF/ORB) and a small k-means vocabulary with
inverted lists for L2 (SIFT/SURF).

Brute force scans every database row per query; at fleet scale (the
million-descriptor databases `ops.match_best2` streams) most of that work
scores rows that were never going to win.  These indexes cut the scored
set to a few hundred *candidates* per query, then re-rank the candidates
with the **exact** metric — so an approximate match is always a real
(best, second-best, argbest) over the candidate set, with the same
distances, masking, and smallest-index tie-breaks as the exact paths.
The only approximation is recall: a query whose true winner fell outside
the candidate set mismatches.  On matching workloads (near-duplicate
descriptors at small distance) recall at the default knobs is >0.95 of
the exact pipeline's accepted matches (`tests/test_index.py`,
`benchmarks/bench_matcher.py`); the ``probes`` knob trades recall back
against latency.

* :class:`LshIndex` — ``n_tables`` hash tables, each hashing ``n_bits``
  randomly-sampled bit positions of the packed descriptor into a bucket.
  Multi-probe: besides the query's own bucket, the ``probes-1``
  single-bit-flip neighbor buckets are scanned in each table, so a
  near-duplicate that disagrees on one sampled bit still collides —
  the standard trick to hold recall with far fewer tables.
* :class:`KMeansIndex` — a small Lloyd-iteration vocabulary; each valid
  database row lives in exactly one centroid's inverted list, queries
  scan the ``probes`` nearest centroids' lists.

Index *construction* is host-side numpy (it happens once per database);
the *query* path (`search`) is pure jnp on fixed-shape candidate arrays,
so it jits.  `core/matching.match_pair(mode="approx")` wires these under
the mutual-NN + ratio pipeline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import matcher as _matcher

_RERANK_CHUNK = 128     # candidate columns scored per slab in rerank


def default_bits(nk: int) -> int:
    """Hash width for an ``nk``-row database: ~log2(nk) keeps expected
    bucket occupancy O(1) without shredding recall (clamped to [6, 16])."""
    return int(np.clip(int(np.ceil(np.log2(max(nk, 2)))), 6, 16))


def rerank_exact(q, db, db_valid, cand, *, metric: str):
    """Exact best/second/argbest over per-query candidate sets.

    q [Q, D], db [K, D], db_valid [K], cand [Q, C] int32 global database
    indices (< 0 = empty slot) -> (best [Q], second [Q], idx [Q] int32).
    Candidates are sorted per row so duplicates (the same row surfaced by
    several tables/probes) can be masked — without dedup a duplicated
    best would masquerade as the second-best and wreck the ratio test —
    and so argmin's first-occurrence keeps the exact paths' smallest-
    index tie-break.  Distances are computed with the exact metric in
    `_RERANK_CHUNK`-column slabs (bounded temporaries at any C).
    """
    big = _matcher.big_for(metric)
    nq, nc = cand.shape
    cand = jnp.sort(cand, axis=1)                      # -1s first, dups adjacent
    dup = jnp.concatenate(
        [jnp.zeros((nq, 1), jnp.bool_), cand[:, 1:] == cand[:, :-1]], axis=1)
    ok = (cand >= 0) & ~dup & (db_valid[jnp.clip(cand, 0)] != 0)
    safe = jnp.clip(cand, 0)
    best = jnp.full((nq,), big)
    second = jnp.full((nq,), big)
    bidx = jnp.zeros((nq,), jnp.int32)
    for s in range(0, nc, _RERANK_CHUNK):
        csl = safe[:, s:s + _RERANK_CHUNK]
        rows = db[csl]                                 # [Q, c, D]
        if metric == "hamming":
            d = _matcher.popcount32(q[:, None, :] ^ rows) \
                .astype(jnp.int32).sum(axis=-1)
        else:
            diff = q[:, None, :].astype(jnp.float32) - rows.astype(jnp.float32)
            d = jnp.sum(diff * diff, axis=-1)
        d = jnp.where(ok[:, s:s + _RERANK_CHUNK], d, big)
        arg = jnp.argmin(d, axis=1).astype(jnp.int32)
        cb = jnp.min(d, axis=1)
        cols = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
        cs = jnp.min(jnp.where(cols == arg[:, None], big, d), axis=1)
        ci = jnp.take_along_axis(csl, arg[:, None], axis=1)[:, 0]
        best, second, bidx = _matcher._merge_best2(
            (best, second, bidx), (cb, cs, ci.astype(jnp.int32)))
    return best, second, bidx


class LshIndex:
    """Multi-probe LSH over bit-packed (uint32-word) binary descriptors.

    Build is numpy host-side; :meth:`search` is jnp/jit-able.  Inverted
    lists have fixed capacity ``bucket_cap``; overflowing rows are
    dropped from that table (but usually survive in another — the
    drop count is exposed as ``overflow``).
    """

    metric = "hamming"

    def __init__(self, db, db_valid=None, *, n_tables: int = 8,
                 n_bits: Optional[int] = None,
                 bucket_cap: Optional[int] = None,
                 probes: Optional[int] = None, seed: int = 0):
        db = np.asarray(db)
        if db.dtype != np.uint32:
            raise TypeError("LshIndex needs bit-packed uint32 descriptors "
                            "(descriptors.pack_bits layout)")
        nk, words = db.shape
        valid = (np.ones(nk, bool) if db_valid is None
                 else np.asarray(db_valid).astype(bool))
        self.n_tables = int(n_tables)
        self.n_bits = default_bits(nk) if n_bits is None else int(n_bits)
        # default probes: the query bucket + every single-bit flip
        self.probes = self.n_bits + 1 if probes is None else int(probes)
        if bucket_cap is None:
            # ~4x the expected uniform occupancy, floor 8: skewed buckets
            # keep their head entries, the tail is what overflow drops
            bucket_cap = max(8, int(4 * np.ceil(nk / 2 ** self.n_bits)))
        self.bucket_cap = int(bucket_cap)
        rng = np.random.RandomState(seed)
        # sampled bit positions: (table, bit) -> distinct bits per table
        pos = np.stack([rng.choice(words * 32, self.n_bits, replace=False)
                        for _ in range(self.n_tables)])
        self._word = (pos // 32).astype(np.int32)
        self._shift = (pos % 32).astype(np.uint32)
        codes = self._codes_np(db)                     # [T, K]
        lists = np.full((self.n_tables, 2 ** self.n_bits, self.bucket_cap),
                        -1, np.int32)
        self.overflow = 0
        rows = np.nonzero(valid)[0]
        for t in range(self.n_tables):
            # vectorized fill in db order (deterministic): stable-sort by
            # bucket, rank within bucket, keep ranks under capacity
            c = codes[t, rows]
            order = np.argsort(c, kind="stable")
            cs, rs = c[order], rows[order]
            first = np.concatenate([[True], cs[1:] != cs[:-1]])
            pos_in = np.arange(len(cs)) - \
                np.maximum.accumulate(np.where(first, np.arange(len(cs)), 0))
            keep = pos_in < self.bucket_cap
            self.overflow += int((~keep).sum())
            lists[t, cs[keep], pos_in[keep]] = rs[keep]
        self.n_rows = int(nk)
        self._db = jnp.asarray(db)
        self._valid = jnp.asarray(valid)
        self._lists = jnp.asarray(lists)
        self._wordj = jnp.asarray(self._word)
        self._shiftj = jnp.asarray(self._shift)

    def _codes_np(self, x: np.ndarray) -> np.ndarray:
        bits = (x[:, self._word] >> self._shift) & np.uint32(1)   # [N, T, B]
        weights = (np.uint32(1) << np.arange(self.n_bits, dtype=np.uint32))
        return bits.astype(np.uint32).dot(weights).T.astype(np.int32)

    def _codes(self, q) -> jnp.ndarray:
        bits = (q[:, self._wordj] >> self._shiftj) & jnp.uint32(1)
        weights = (jnp.uint32(1)
                   << jnp.arange(self.n_bits, dtype=jnp.uint32))
        return jnp.sum(bits * weights, axis=-1, dtype=jnp.uint32) \
            .astype(jnp.int32).T                       # [T, Q]

    def candidates(self, q, probes: Optional[int] = None) -> jnp.ndarray:
        """Candidate database indices per query: [Q, T*probes*cap] int32,
        -1 for empty slots; duplicates possible (rerank dedups)."""
        probes = self.probes if probes is None else int(probes)
        probes = min(probes, self.n_bits + 1)
        codes = self._codes(q)                          # [T, Q]
        flips = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             (1 << jnp.arange(probes - 1, dtype=jnp.int32))])
        probed = codes[:, :, None] ^ flips[None, None, :]   # [T, Q, P]
        tbl = jnp.arange(self.n_tables, dtype=jnp.int32)[:, None, None]
        cand = self._lists[tbl, probed]                 # [T, Q, P, cap]
        return jnp.moveaxis(cand, 0, 1).reshape(q.shape[0], -1)

    def search(self, q, probes: Optional[int] = None):
        """Approximate (best, second, idx) for q [Q, W] uint32: gather
        candidates from the probed buckets, exact-Hamming re-rank."""
        cand = self.candidates(q, probes)
        return rerank_exact(q, self._db, self._valid, cand,
                            metric=self.metric)


class KMeansIndex:
    """k-means vocabulary + inverted lists for float (L2) descriptors.

    A few Lloyd iterations over the valid rows build ``n_clusters``
    centroids; every row lives in exactly one centroid's fixed-capacity
    list (lists are disjoint, so no dedup pressure in rerank).  Queries
    scan the ``probes`` nearest centroids' lists.
    """

    metric = "l2"

    def __init__(self, db, db_valid=None, *, n_clusters: Optional[int] = None,
                 iters: int = 8, bucket_cap: Optional[int] = None,
                 probes: int = 8, seed: int = 0):
        db = np.asarray(db, np.float32)
        nk, d = db.shape
        valid = (np.ones(nk, bool) if db_valid is None
                 else np.asarray(db_valid).astype(bool))
        rows = np.nonzero(valid)[0]
        pts = db[rows] if len(rows) else db[:1]
        if n_clusters is None:
            n_clusters = int(np.clip(int(np.sqrt(max(len(pts), 1))), 4, 1024))
        self.n_clusters = min(int(n_clusters), max(len(pts), 1))
        rng = np.random.RandomState(seed)
        cent = pts[rng.choice(len(pts), self.n_clusters,
                              replace=len(pts) < self.n_clusters)].copy()
        for _ in range(int(iters)):
            d2 = (np.sum(pts * pts, 1)[:, None]
                  + np.sum(cent * cent, 1)[None, :] - 2.0 * pts @ cent.T)
            assign = np.argmin(d2, axis=1)
            for c in range(self.n_clusters):
                m = assign == c
                if m.any():
                    cent[c] = pts[m].mean(axis=0)
        d2 = (np.sum(pts * pts, 1)[:, None]
              + np.sum(cent * cent, 1)[None, :] - 2.0 * pts @ cent.T)
        assign = np.argmin(d2, axis=1)
        self.probes = min(int(probes), self.n_clusters)
        if bucket_cap is None:
            counts = np.bincount(assign, minlength=self.n_clusters)
            bucket_cap = max(8, int(counts.max())) if len(pts) else 8
        self.bucket_cap = int(bucket_cap)
        lists = np.full((self.n_clusters, self.bucket_cap), -1, np.int32)
        fill = np.zeros(self.n_clusters, np.int32)
        self.overflow = 0
        for i, c in zip(rows, assign):                 # db order: deterministic
            if fill[c] < self.bucket_cap:
                lists[c, fill[c]] = i
                fill[c] += 1
            else:
                self.overflow += 1
        self.n_rows = int(nk)
        self._db = jnp.asarray(db)
        self._valid = jnp.asarray(valid)
        self._cent = jnp.asarray(cent)
        self._lists = jnp.asarray(lists)

    def candidates(self, q, probes: Optional[int] = None) -> jnp.ndarray:
        """Candidate database indices per query: [Q, probes*cap] int32,
        -1 for empty slots (lists are disjoint — no duplicates)."""
        probes = self.probes if probes is None else \
            min(int(probes), self.n_clusters)
        q = q.astype(jnp.float32)
        d2 = (jnp.sum(q * q, 1)[:, None]
              + jnp.sum(self._cent * self._cent, 1)[None, :]
              - 2.0 * q @ self._cent.T)
        _, near = jax.lax.top_k(-d2, probes)            # [Q, probes]
        return self._lists[near].reshape(q.shape[0], -1)

    def search(self, q, probes: Optional[int] = None):
        """Approximate (best, second, idx) for q [Q, D] float: exact-L2
        re-rank over the ``probes`` nearest centroids' inverted lists."""
        cand = self.candidates(q, probes)
        return rerank_exact(q.astype(jnp.float32), self._db, self._valid,
                            cand, metric=self.metric)


def build_index(db, db_valid=None, *, metric: Optional[str] = None,
                **knobs):
    """Index factory: packed uint32 descriptors (or ``metric="hamming"``)
    get an :class:`LshIndex`, float descriptors a :class:`KMeansIndex`.
    ``knobs`` forward to the index constructor."""
    if metric is None:
        metric = "hamming" if np.asarray(db).dtype == np.uint32 else "l2"
    if metric == "hamming":
        return LshIndex(db, db_valid, **knobs)
    if metric == "l2":
        return KMeansIndex(db, db_valid, **knobs)
    raise ValueError(f"unknown metric {metric!r}")
