"""Model facades: one object per architecture family exposing

    init(key) -> params
    loss(params, batch) -> (scalar, metrics)        [train]
    prefill(params, batch) -> (logits, cache)       [inference prefill]
    decode_step(params, cache, tokens, pos) -> (logits, cache)
    init_cache(batch_size, max_seq) -> cache pytree
    input_specs(shape) -> dict of ShapeDtypeStruct  [dry-run stand-ins]

All functions are pure; ``build_model(cfg)`` selects the family.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention as A
from repro.models import blocks as B
from repro.models import layers as L
from repro.models import ssm as S
from repro.distributed.sharding import shard_activation

AUX_LOSS_WEIGHT = 0.01
Z_LOSS_WEIGHT = 1e-4


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def cross_entropy(logits, labels, ignore_index=-1):
    """logits [B,S,V] fp32; labels [B,S] int32.  Returns (loss, z_loss)."""
    mask = (labels != ignore_index)
    labels_safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    z = (lse ** 2 * mask).sum() / denom
    return nll.sum() / denom, z


def param_count(params) -> int:
    return sum(int(np.prod(p.shape)) for p in jax.tree_util.tree_leaves(params))


class BaseLM:
    """Dense / MoE / VLM decoder-only LM (GQA or MLA attention)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------- params ------------------------------------------------
    def init(self, key):
        cfg, dt = self.cfg, _dtype(self.cfg)
        ks = jax.random.split(key, 6)
        params = {"emb": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
                  "final_norm": L.rmsnorm_init(cfg.d_model)}
        if cfg.moe is not None and cfg.moe.n_dense_layers:
            nd = cfg.moe.n_dense_layers
            params["dense_stack"] = B.stack_init(
                lambda k: B.decoder_block_init(k, cfg, use_moe=False, dtype=dt),
                ks[1], nd)
            params["stack"] = B.stack_init(
                lambda k: B.decoder_block_init(k, cfg, use_moe=True, dtype=dt),
                ks[2], cfg.n_layers - nd)
        else:
            params["stack"] = B.stack_init(
                lambda k: B.decoder_block_init(
                    k, cfg, use_moe=cfg.moe is not None, dtype=dt),
                ks[2], cfg.n_layers)
        if not cfg.tie_embeddings:
            params["head"] = L.head_init(ks[3], cfg.d_model, cfg.vocab_size, dt)
        if cfg.n_image_patches:
            params["patch_proj"] = {"w": L.dense_init(ks[4], cfg.d_model,
                                                      cfg.d_model, dt)}
        return params

    # ---------------- embedding helpers ------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        h = L.embed(params["emb"], batch["tokens"])
        if cfg.n_image_patches:
            patches = L.matmul(batch["patches"].astype(h.dtype),
                               params["patch_proj"]["w"])
            h = jnp.concatenate([patches, h], axis=1)
        return shard_activation(h, "hidden")

    def _unembed(self, params, h):
        w = params["emb"] if self.cfg.tie_embeddings else params["head"]
        logits = L.unembed(w, h)
        return shard_activation(logits, "logits")

    def _positions(self, total_seq):
        return jnp.arange(total_seq)[None, :]

    # ---------------- forward / loss ----------------------------------------
    def forward(self, params, batch):
        cfg = self.cfg
        h = self._embed(params, batch)
        positions = self._positions(h.shape[1])
        aux = jnp.float32(0.0)
        if "dense_stack" in params:
            h, a = B.decoder_stack(params["dense_stack"], cfg, h, positions,
                                   remat=cfg.remat)
            aux += a
        h, a = B.decoder_stack(params["stack"], cfg, h, positions,
                               remat=cfg.remat)
        aux += a
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self._unembed(params, h), aux

    def loss(self, params, batch):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.n_image_patches:   # image positions carry no next-token loss
            logits = logits[:, cfg.n_image_patches:]
        ce, z = cross_entropy(logits, batch["labels"])
        total = ce + AUX_LOSS_WEIGHT * aux + Z_LOSS_WEIGHT * z
        return total, {"ce": ce, "aux": aux, "z": z}

    # ---------------- serving ----------------------------------------------
    def _prefill_once(self, params, batch):
        cfg = self.cfg
        h = self._embed(params, batch)
        positions = self._positions(h.shape[1])
        caches = []
        if "dense_stack" in params:
            h, kv = B.decoder_stack_prefill(params["dense_stack"], cfg, h,
                                            positions)
            caches.append(kv)
        h, kv = B.decoder_stack_prefill(params["stack"], cfg, h, positions)
        caches.append(kv)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        logits = self._unembed(params, h[:, -1:])
        cache = caches[0] if len(caches) == 1 else \
            {"dense": caches[0], "moe": caches[1]}
        return logits, cache

    def prefill(self, params, batch):
        """Prefill, optionally processing the request batch in sequential
        chunks (lax.map) — bounds activation/dispatch peak for MoE archs."""
        nc = self.cfg.prefill_chunks
        bsz = batch["tokens"].shape[0]
        if nc <= 1 or bsz % nc:
            return self._prefill_once(params, batch)
        chunked = jax.tree_util.tree_map(
            lambda x: x.reshape(nc, bsz // nc, *x.shape[1:]), batch)
        logits, cache = lax.map(
            lambda b: self._prefill_once(params, b), chunked)
        # outputs stack on axis 0: logits [nc, b', 1, V]; cache leaves
        # [nc, L, b', ...] — merge the chunk axis back into batch (dim 1)
        logits = logits.reshape(bsz, *logits.shape[2:])
        cache = jax.tree_util.tree_map(
            lambda a: jnp.moveaxis(a, 0, 1).reshape(
                a.shape[1], bsz, *a.shape[3:]), cache)
        return logits, cache

    def init_cache(self, batch_size, max_seq):
        cfg = self.cfg
        dt = _dtype(cfg)

        def stack_cache(n_layers):
            if cfg.mla is not None:
                m = cfg.mla
                return {
                    "ckv": jnp.zeros((n_layers, batch_size, max_seq,
                                      m.kv_lora_rank), dt),
                    "krope": jnp.zeros((n_layers, batch_size, max_seq,
                                        m.qk_rope_head_dim), dt),
                }
            hd = cfg.resolved_head_dim
            return {
                "k": jnp.zeros((n_layers, batch_size, max_seq,
                                cfg.n_kv_heads, hd), dt),
                "v": jnp.zeros((n_layers, batch_size, max_seq,
                                cfg.n_kv_heads, hd), dt),
            }

        if cfg.moe is not None and cfg.moe.n_dense_layers:
            return {"dense": stack_cache(cfg.moe.n_dense_layers),
                    "moe": stack_cache(cfg.n_layers - cfg.moe.n_dense_layers)}
        return stack_cache(cfg.n_layers)

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        h = L.embed(params["emb"], tokens)          # [B,1,D]
        if isinstance(cache, dict) and "dense" in cache:
            h, dcache = B.decoder_stack_decode(params["dense_stack"], cfg, h,
                                               cache["dense"], pos)
            h, mcache = B.decoder_stack_decode(params["stack"], cfg, h,
                                               cache["moe"], pos)
            new_cache = {"dense": dcache, "moe": mcache}
        else:
            h, new_cache = B.decoder_stack_decode(params["stack"], cfg, h,
                                                  cache, pos)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self._unembed(params, h), new_cache

    # ---------------- dry-run input stand-ins -------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct((b, s), jnp.int32)
        specs = {"tokens": tok}
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if cfg.n_image_patches:
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.n_image_patches, cfg.d_model), _dtype(cfg))
        if shape.is_decode:
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            specs.pop("patches", None)
        return specs


class WhisperModel(BaseLM):
    """Encoder-decoder (whisper backbone); conv/mel frontend is a stub —
    the batch provides precomputed frame embeddings [B, Se, D]."""

    MAX_DEC_POS = 32768

    def init(self, key):
        cfg, dt = self.cfg, _dtype(self.cfg)
        ks = jax.random.split(key, 6)
        return {
            "enc_stack": B.stack_init(
                lambda k: B.encoder_block_init(k, cfg, dt), ks[0],
                cfg.n_encoder_layers),
            "enc_norm": L.layernorm_init(cfg.d_model),
            "emb": L.embedding_init(ks[1], cfg.vocab_size, cfg.d_model, dt),
            "dec_pos": L.truncated_normal(ks[2],
                                          (self.MAX_DEC_POS, cfg.d_model),
                                          0.01, jnp.float32),
            "dec_stack": B.stack_init(
                lambda k: B.xdec_block_init(k, cfg, dt), ks[3], cfg.n_layers),
            "dec_norm": L.layernorm_init(cfg.d_model),
        }

    def encode(self, params, frames):
        cfg = self.cfg
        h = frames.astype(_dtype(cfg))
        h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model).astype(h.dtype)
        h = shard_activation(h, "hidden")
        h = B.encoder_stack(params["enc_stack"], cfg, h, None, remat=cfg.remat)
        return L.layernorm(params["enc_norm"], h, cfg.norm_eps)

    def forward(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tok = batch["tokens"]
        h = L.embed(params["emb"], tok)
        h = h + lax.dynamic_slice_in_dim(
            params["dec_pos"], 0, tok.shape[1], 0).astype(h.dtype)
        h = shard_activation(h, "hidden")
        h = B.xdec_stack(params["dec_stack"], cfg, h, enc, None,
                         remat=cfg.remat)
        h = L.layernorm(params["dec_norm"], h, cfg.norm_eps)
        return L.unembed(params["emb"], h), jnp.float32(0.0)

    def loss(self, params, batch):
        logits, _ = self.forward(params, batch)
        ce, z = cross_entropy(logits, batch["labels"])
        return ce + Z_LOSS_WEIGHT * z, {"ce": ce, "z": z}

    def init_cache(self, batch_size, max_seq):
        cfg, dt = self.cfg, _dtype(self.cfg)
        hd = cfg.resolved_head_dim
        se = cfg.encoder_seq_len
        ls = cfg.n_layers
        return {
            "k": jnp.zeros((ls, batch_size, max_seq, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((ls, batch_size, max_seq, cfg.n_kv_heads, hd), dt),
            "xk": jnp.zeros((ls, batch_size, se, cfg.n_heads, hd), dt),
            "xv": jnp.zeros((ls, batch_size, se, cfg.n_heads, hd), dt),
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        xk, xv = B.xdec_cross_kv(params["dec_stack"], cfg, enc)
        logits, _ = self.forward(params, batch)
        # self-attn KV rebuilt during decode; cross KV frozen
        return logits[:, -1:], {"xk": xk, "xv": xv}

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        h = L.embed(params["emb"], tokens)
        posemb = lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, 0)
        h = h + posemb.astype(h.dtype)
        h, new_cache = B.xdec_stack_decode(params["dec_stack"], cfg, h,
                                           cache, pos)
        h = L.layernorm(params["dec_norm"], h, cfg.norm_eps)
        return L.unembed(params["emb"], h), new_cache

    def input_specs(self, shape: ShapeConfig) -> Dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        dt = _dtype(cfg)
        specs = {
            "frames": jax.ShapeDtypeStruct((b, cfg.encoder_seq_len,
                                            cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        if shape.is_decode:
            specs["tokens"] = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            specs.pop("frames")
        return specs


class XLSTMModel(BaseLM):
    """xLSTM: scan over super-layers of (slstm_every-1) mLSTM + 1 sLSTM."""

    def _n_supers(self):
        return self.cfg.n_layers // self.cfg.xlstm.slstm_every

    def init(self, key):
        cfg, dt = self.cfg, _dtype(self.cfg)
        ks = jax.random.split(key, 3)
        return {
            "emb": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "stack": B.stack_init(
                lambda k: B.xlstm_super_init(k, cfg, dt), ks[1],
                self._n_supers()),
            "final_norm": L.rmsnorm_init(cfg.d_model),
            "head": L.head_init(ks[2], cfg.d_model, cfg.vocab_size, dt),
        }

    def forward(self, params, batch):
        cfg = self.cfg
        h = shard_activation(L.embed(params["emb"], batch["tokens"]), "hidden")
        h = B.xlstm_stack(params["stack"], cfg, h, remat=cfg.remat)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self._unembed(params, h), jnp.float32(0.0)

    def init_cache(self, batch_size, max_seq):
        cfg = self.cfg
        g = self._n_supers()
        n_m = max(cfg.xlstm.slstm_every - 1, 1)

        def rep(t, n):
            return jnp.broadcast_to(t[None], (n, *t.shape))

        m_state = jax.tree_util.tree_map(
            lambda t: rep(rep(t, n_m), g), S.mlstm_init_state(cfg, batch_size))
        s_state = jax.tree_util.tree_map(
            lambda t: rep(t, g), S.slstm_init_state(cfg, batch_size))
        return {"mlstm": m_state, "slstm": s_state}

    def prefill(self, params, batch):
        logits, _ = self.forward(params, batch)
        return logits[:, -1:], self.init_cache(batch["tokens"].shape[0], 0)

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        h = L.embed(params["emb"], tokens)
        h, new_cache = B.xlstm_stack_decode(params["stack"], cfg, h, cache)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self._unembed(params, h), new_cache


class ZambaModel(BaseLM):
    """Zamba2: Mamba2 backbone + weight-shared attention block."""

    def _n_supers(self):
        return self.cfg.n_layers // self.cfg.shared_attn_every

    def init(self, key):
        cfg, dt = self.cfg, _dtype(self.cfg)
        ks = jax.random.split(key, 4)
        return {
            "emb": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "stack": B.stack_init(
                lambda k: B.zamba_super_init(k, cfg, dt), ks[1],
                self._n_supers()),
            "shared": B.zamba_shared_init(ks[2], cfg, dt),
            "final_norm": L.rmsnorm_init(cfg.d_model),
            "head": L.head_init(ks[3], cfg.d_model, cfg.vocab_size, dt),
        }

    def forward(self, params, batch):
        cfg = self.cfg
        emb0 = shard_activation(L.embed(params["emb"], batch["tokens"]),
                                "hidden")
        positions = self._positions(emb0.shape[1])
        h = B.zamba_stack(params["stack"], cfg, emb0, params["shared"], emb0,
                          positions, remat=cfg.remat)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self._unembed(params, h), jnp.float32(0.0)

    def init_cache(self, batch_size, max_seq):
        cfg, dt = self.cfg, _dtype(self.cfg)
        g = self._n_supers()
        hd = cfg.resolved_head_dim

        def rep(t, n):
            return jnp.broadcast_to(t[None], (n, *t.shape))

        m_state = jax.tree_util.tree_map(
            lambda t: rep(rep(t, cfg.shared_attn_every), g),
            S.mamba2_init_state(cfg, batch_size))
        return {
            "mamba": m_state,
            "k": jnp.zeros((g, batch_size, max_seq, cfg.n_kv_heads, hd), dt),
            "v": jnp.zeros((g, batch_size, max_seq, cfg.n_kv_heads, hd), dt),
        }

    def prefill(self, params, batch):
        logits, _ = self.forward(params, batch)
        return logits[:, -1:], None

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        emb0 = L.embed(params["emb"], tokens)
        h, new_cache = B.zamba_stack_decode(params["stack"], cfg, emb0,
                                            params["shared"], emb0, cache, pos)
        h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
        return self._unembed(params, h), new_cache


def build_model(cfg: ModelConfig) -> BaseLM:
    if cfg.family == "audio":
        return WhisperModel(cfg)
    if cfg.family == "ssm" and cfg.xlstm is not None:
        return XLSTMModel(cfg)
    if cfg.family == "hybrid":
        return ZambaModel(cfg)
    return BaseLM(cfg)
