"""Per-family blocks and scan-over-layers stacks.

Homogeneous layer stacks are *stacked* along a leading axis and executed with
``lax.scan`` so HLO size is O(1) in depth — compile-time critical for the
80-layer and 61-layer assigned archs.  Heterogeneous patterns (xLSTM's
mLSTM/sLSTM interleave, Zamba2's shared-attention insertions, DeepSeek's
dense→MoE split) are expressed as scans over homogeneous *super-layers*.

Remat policy (config ``remat``): 'nothing' | 'dots' | 'full' wraps the scan
body in ``jax.checkpoint`` for training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.distributed.sharding import shard_activation


def _maybe_remat(fn, remat: str):
    if remat == "nothing":
        return fn
    if remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)          # 'full'


def stack_fold(body, carry, xs, unroll: bool):
    """lax.scan, or an unrolled python loop in analysis mode (so XLA's
    cost_analysis sees every layer — see launch/correction.py)."""
    if not unroll:
        return lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def stack_init(layer_init, key, n, *args):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: layer_init(k, *args))(keys)


# ===========================================================================
# decoder block: (GQA | MLA) attention + (SwiGLU | MoE) FFN, pre-RMSNorm
# ===========================================================================
def decoder_block_init(key, cfg, *, use_moe=False, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    attn = (A.mla_init(k1, cfg, dtype) if cfg.mla is not None
            else A.gqa_init(k1, cfg, dtype))
    p = {"ln1": L.rmsnorm_init(cfg.d_model), "attn": attn,
         "ln2": L.rmsnorm_init(cfg.d_model)}
    if use_moe:
        p["moe"] = M.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def decoder_block(p, cfg, h, positions, *, causal=True):
    """Returns (h, aux_loss)."""
    hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, _, _ = A.mla_attention(p["attn"], cfg, hn, positions, causal=causal)
    else:
        a = A.gqa_attention(p["attn"], cfg, hn, positions, causal=causal)
    h = h + a
    h = shard_activation(h, "hidden")
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        f, aux = M.moe_apply(p["moe"], cfg, hn)
    else:
        f, aux = L.swiglu(p["mlp"], hn), jnp.float32(0.0)
    h = h + f
    return shard_activation(h, "hidden"), aux


def decoder_block_decode(p, cfg, h, cache, pos):
    """Single-token decode.  cache: dict of per-layer cache tensors."""
    hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, ckv, kr = A.mla_decode_absorbed(p["attn"], cfg, hn,
                                           cache["ckv"], cache["krope"], pos)
        new_cache = {"ckv": ckv, "krope": kr}
    else:
        a, kc, vc = A.gqa_decode(p["attn"], cfg, hn,
                                 cache["k"], cache["v"], pos)
        new_cache = {"k": kc, "v": vc}
    h = h + a
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        f, _ = M.moe_apply(p["moe"], cfg, hn)
    else:
        f = L.swiglu(p["mlp"], hn)
    return h + f, new_cache


def decoder_block_prefill(p, cfg, h, positions):
    """Full-seq forward that also emits this layer's KV for cache population."""
    hn = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
    if cfg.mla is not None:
        a, ckv, krope = A.mla_attention(p["attn"], cfg, hn, positions,
                                        causal=True)
        kv = {"ckv": ckv, "krope": krope}
    else:
        a, k, v = A.gqa_prefill(p["attn"], cfg, hn, positions)
        kv = {"k": k, "v": v}
    h = h + a
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        f, _ = M.moe_apply(p["moe"], cfg, hn)
    else:
        f = L.swiglu(p["mlp"], hn)
    return h + f, kv


def decoder_stack(params, cfg, h, positions, *, causal=True, remat="dots"):
    """Scan a stacked decoder-block tree over h.  Returns (h, aux_sum)."""
    def body(carry, p_layer):
        h, aux = carry
        h2, a = decoder_block(p_layer, cfg, h, positions, causal=causal)
        return (h2, aux + a), None

    body = _maybe_remat(body, remat)
    (h, aux), _ = stack_fold(body, (h, jnp.float32(0.0)), params,
                             cfg.unroll_stacks)
    return h, aux


def decoder_stack_decode(params, cfg, h, caches, pos):
    def body(h, xs):
        p_layer, cache = xs
        h, new_cache = decoder_block_decode(p_layer, cfg, h, cache, pos)
        return h, new_cache

    h, new_caches = stack_fold(body, h, (params, caches),
                               cfg.unroll_stacks)
    return h, new_caches


def decoder_stack_prefill(params, cfg, h, positions):
    def body(h, p_layer):
        h, kv = decoder_block_prefill(p_layer, cfg, h, positions)
        return h, kv

    return stack_fold(body, h, params, cfg.unroll_stacks)


# ===========================================================================
# whisper encoder block (bidirectional, LayerNorm + GELU MLP)
# ===========================================================================
def encoder_block_init(key, cfg, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.layernorm_init(cfg.d_model),
        "attn": A.gqa_init(k1, cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model),
        "mlp": L.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def encoder_block(p, cfg, h, positions):
    hn = L.layernorm(p["ln1"], h, cfg.norm_eps)
    h = h + A.gqa_attention(p["attn"], cfg, hn, positions, causal=False)
    hn = L.layernorm(p["ln2"], h, cfg.norm_eps)
    h = h + L.gelu_mlp(p["mlp"], hn)
    return shard_activation(h, "hidden")


def encoder_stack(params, cfg, h, positions, remat="dots"):
    def body(h, p_layer):
        return encoder_block(p_layer, cfg, h, positions), None

    body = _maybe_remat(body, remat)
    h, _ = stack_fold(body, h, params, cfg.unroll_stacks)
    return h


# ===========================================================================
# whisper decoder block (causal self-attn + cross-attn + GELU MLP)
# ===========================================================================
def xdec_block_init(key, cfg, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": L.layernorm_init(cfg.d_model),
        "attn": A.gqa_init(k1, cfg, dtype),
        "ln_x": L.layernorm_init(cfg.d_model),
        "xattn": A.cross_attn_init(k2, cfg, dtype),
        "ln2": L.layernorm_init(cfg.d_model),
        "mlp": L.gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def xdec_block(p, cfg, h, enc_out, positions):
    hn = L.layernorm(p["ln1"], h, cfg.norm_eps)
    h = h + A.gqa_attention(p["attn"], cfg, hn, positions, causal=True)
    hn = L.layernorm(p["ln_x"], h, cfg.norm_eps)
    h = h + A.cross_attention(p["xattn"], cfg, hn, enc_out)
    hn = L.layernorm(p["ln2"], h, cfg.norm_eps)
    h = h + L.gelu_mlp(p["mlp"], hn)
    return shard_activation(h, "hidden")


def xdec_stack(params, cfg, h, enc_out, positions, remat="dots"):
    def body(h, p_layer):
        return xdec_block(p_layer, cfg, h, enc_out, positions), None

    body = _maybe_remat(body, remat)
    h, _ = stack_fold(body, h, params, cfg.unroll_stacks)
    return h


def xdec_block_decode(p, cfg, h, cache, pos):
    """cache: {'k','v' (self), 'xk','xv' (frozen cross)}."""
    b = h.shape[0]
    hn = L.layernorm(p["ln1"], h, cfg.norm_eps)
    a, kc, vc = A.gqa_decode(p["attn"], cfg, hn, cache["k"], cache["v"], pos)
    h = h + a
    hn = L.layernorm(p["ln_x"], h, cfg.norm_eps)
    h = h + A.cross_attention_cached(p["xattn"], cfg, hn,
                                     cache["xk"], cache["xv"])
    hn = L.layernorm(p["ln2"], h, cfg.norm_eps)
    h = h + L.gelu_mlp(p["mlp"], hn)
    return h, {"k": kc, "v": vc, "xk": cache["xk"], "xv": cache["xv"]}


def xdec_stack_decode(params, cfg, h, caches, pos):
    def body(h, xs):
        p_layer, cache = xs
        h, new_cache = xdec_block_decode(p_layer, cfg, h, cache, pos)
        return h, new_cache

    return stack_fold(body, h, (params, caches), cfg.unroll_stacks)


def xdec_cross_kv(params, cfg, enc_out):
    """Precompute frozen cross-attention K/V for every decoder layer."""
    b, se, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def one(p_layer):
        k = L.matmul(enc_out, p_layer["xattn"]["wk"]).reshape(
            b, se, cfg.n_heads, hd)
        v = L.matmul(enc_out, p_layer["xattn"]["wv"]).reshape(
            b, se, cfg.n_heads, hd)
        return k, v

    return jax.vmap(one)(params)      # ([L,B,Se,H,hd], [L,B,Se,H,hd])


# ===========================================================================
# xLSTM super-layer: (slstm_every - 1) mLSTM blocks + 1 sLSTM block
# ===========================================================================
def xlstm_super_init(key, cfg, dtype=jnp.bfloat16):
    n_m = cfg.xlstm.slstm_every - 1
    k1, k2 = jax.random.split(key)
    return {
        "mlstm": stack_init(lambda k: S.mlstm_init(k, cfg, dtype), k1,
                            max(n_m, 1)),
        "slstm": S.slstm_init(k2, cfg, dtype),
    }


def xlstm_super(p, cfg, h):
    def m_body(h, pm):
        hn = L.rmsnorm(pm["norm"], h, cfg.norm_eps)
        return h + S.mlstm_apply(pm, cfg, hn), None

    h, _ = stack_fold(m_body, h, p["mlstm"], cfg.unroll_stacks)
    hn = L.rmsnorm(p["slstm"]["norm"], h, cfg.norm_eps)
    h = h + S.slstm_apply(p["slstm"], cfg, hn)
    return shard_activation(h, "hidden")


def xlstm_stack(params, cfg, h, remat="dots"):
    def body(h, p_super):
        return xlstm_super(p_super, cfg, h), None

    body = _maybe_remat(body, remat)
    h, _ = stack_fold(body, h, params, cfg.unroll_stacks)
    return h


def xlstm_super_decode(p, cfg, h, state):
    def m_body(h, xs):
        pm, st = xs
        hn = L.rmsnorm(pm["norm"], h, cfg.norm_eps)
        d, st = S.mlstm_decode(pm, cfg, hn, st)
        return h + d, st

    h, m_states = stack_fold(m_body, h, (p["mlstm"], state["mlstm"]),
                             cfg.unroll_stacks)
    hn = L.rmsnorm(p["slstm"]["norm"], h, cfg.norm_eps)
    d, s_state = S.slstm_decode(p["slstm"], cfg, hn, state["slstm"])
    return h + d, {"mlstm": m_states, "slstm": s_state}


def xlstm_stack_decode(params, cfg, h, states):
    def body(h, xs):
        p_super, st = xs
        return xlstm_super_decode(p_super, cfg, h, st)

    return stack_fold(body, h, (params, states), cfg.unroll_stacks)


# ===========================================================================
# Zamba2 super-layer: k Mamba2 blocks + one *shared* attention block
# ===========================================================================
def zamba_shared_init(key, cfg, dtype=jnp.bfloat16):
    """Shared attention+MLP block over concat(h, h_emb0) (Zamba design)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    return {
        "ln": L.rmsnorm_init(2 * d),
        "wq": L.dense_init(ks[0], 2 * d, cfg.n_heads * hd, dtype),
        "wk": L.dense_init(ks[1], 2 * d, cfg.n_kv_heads * hd, dtype),
        "wv": L.dense_init(ks[2], 2 * d, cfg.n_kv_heads * hd, dtype),
        "wo": L.dense_init(ks[3], cfg.n_heads * hd, d, dtype),
        "ln2": L.rmsnorm_init(d),
        "mlp": L.swiglu_init(ks[4], d, cfg.d_ff, dtype),
    }


def _zamba_shared_qkv(p, cfg, hcat, positions):
    b, s, _ = hcat.shape
    hd = cfg.resolved_head_dim
    q = L.matmul(hcat, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L.matmul(hcat, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = L.matmul(hcat, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def zamba_shared_apply(p, cfg, h, emb0, positions):
    hcat = L.rmsnorm(p["ln"], jnp.concatenate([h, emb0], axis=-1),
                     cfg.norm_eps)
    q, k, v = _zamba_shared_qkv(p, cfg, hcat, positions)
    k = A._expand_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = A._expand_kv(v, cfg.n_heads // cfg.n_kv_heads)
    o = A.attention(q, k, v, causal=True)
    h = h + L.matmul(o.reshape(*h.shape[:2], -1), p["wo"])
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    return h + L.swiglu(p["mlp"], hn)


def zamba_shared_decode(p, cfg, h, emb0, k_cache, v_cache, pos):
    b = h.shape[0]
    hcat = L.rmsnorm(p["ln"], jnp.concatenate([h, emb0], axis=-1),
                     cfg.norm_eps)
    posv = jnp.full((b, 1), pos)
    q, k, v = _zamba_shared_qkv(p, cfg, hcat, posv)
    k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                       (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                       (0, pos, 0, 0))
    o = A.decode_attention(q, k_cache, v_cache, pos)
    h = h + L.matmul(o.reshape(b, 1, -1), p["wo"])
    hn = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    return h + L.swiglu(p["mlp"], hn), k_cache, v_cache


def zamba_super_init(key, cfg, dtype=jnp.bfloat16):
    return {
        "mamba": stack_init(
            lambda k: {"norm": L.rmsnorm_init(cfg.d_model),
                       **{"m": S.mamba2_init(k, cfg, dtype)}},
            key, cfg.shared_attn_every),
    }


def zamba_super(p, cfg, h, shared, emb0, positions):
    def m_body(h, pm):
        hn = L.rmsnorm(pm["norm"], h, cfg.norm_eps)
        return h + S.mamba2_apply(pm["m"], cfg, hn), None

    h, _ = stack_fold(m_body, h, p["mamba"], cfg.unroll_stacks)
    h = zamba_shared_apply(shared, cfg, h, emb0, positions)
    return shard_activation(h, "hidden")


def zamba_stack(params, cfg, h, shared, emb0, positions, remat="dots"):
    def body(h, p_super):
        return zamba_super(p_super, cfg, h, shared, emb0, positions), None

    body = _maybe_remat(body, remat)
    h, _ = stack_fold(body, h, params, cfg.unroll_stacks)
    return h


def zamba_super_decode(p, cfg, h, shared, emb0, state, pos):
    def m_body(h, xs):
        pm, st = xs
        hn = L.rmsnorm(pm["norm"], h, cfg.norm_eps)
        d, st = S.mamba2_decode(pm["m"], cfg, hn, st)
        return h + d, st

    h, m_states = stack_fold(m_body, h, (p["mamba"], state["mamba"]),
                             cfg.unroll_stacks)
    h, kc, vc = zamba_shared_decode(shared, cfg, h, emb0,
                                    state["k"], state["v"], pos)
    return h, {"mamba": m_states, "k": kc, "v": vc}


def zamba_stack_decode(params, cfg, h, shared, emb0, states, pos):
    def body(h, xs):
        p_super, st = xs
        return zamba_super_decode(p_super, cfg, h, shared, emb0, st, pos)

    return stack_fold(body, h, (params, states), cfg.unroll_stacks)
