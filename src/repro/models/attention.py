"""Attention variants: GQA (with optional QKV bias), MLA (DeepSeek-V3),
cross-attention, and KV-cache decode paths.

Two attention algorithms are provided:

* ``attention_einsum`` — materializes the [B,H,S,S] score matrix.  Fine for
  short sequences; memory term blows up past ~8k.
* ``attention_online`` — FlashAttention-style online-softmax over KV chunks
  via ``lax.scan``.  O(S · chunk) live memory instead of O(S²); this is the
  default for long sequences (a beyond-paper optimization recorded in
  EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L

NEG_INF = -1e30
# §Perf iter (smollm train_4k): einsum attention at S=4096 materialized
# [B,H,S,S] fp32 scores -> 9.6 GiB/dev; online-softmax from 4096 up removes
# them (memory term 12.8 s -> see EXPERIMENTS.md).  Below 4096 the score
# matrix is small enough that XLA's fusion wins.
ONLINE_ATTN_MIN_SEQ = 4096   # use online-softmax attention at/above this length


# ---------------------------------------------------------------------------
# core attention algorithms
# ---------------------------------------------------------------------------
def _expand_kv(k, n_rep):
    """[B,S,KVH,hd] -> [B,S,KVH*n_rep,hd] by head repetition (GQA)."""
    if n_rep == 1:
        return k
    b, s, kvh, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, n_rep, hd))
    return k.reshape(b, s, kvh * n_rep, hd)


def attention_einsum(q, k, v, *, causal, q_offset=0):
    """q: [B,Sq,H,hd]; k,v: [B,Sk,H,hd].  Returns [B,Sq,H,hd]."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(hd)
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        scores = jnp.where(qpos >= kpos, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def attention_online(q, k, v, *, causal, q_offset=0, chunk=1024,
                     unroll=False):
    """Online-softmax attention, scanning KV in chunks.

    Never materializes the full score matrix; peak live memory is
    O(B·H·Sq·hd) for the accumulator plus one [B,H,Sq,chunk] score block.
    ``unroll`` unrolls the chunk scan (analysis mode: cost_analysis counts
    while-loop bodies once — launch/correction.py).
    """
    b, sq, h, hd = q.shape
    hd_v = v.shape[-1]                 # may differ from q/k (MLA)
    sk = k.shape[1]
    if sk % chunk != 0:
        # fall back to a chunk that divides (power-of-two shapes in practice)
        chunk = int(np.gcd(sk, chunk)) or sk
    n_chunks = sk // chunk
    qf = q.astype(jnp.float32) / np.sqrt(hd)
    kc = k.reshape(b, n_chunks, chunk, h, hd)
    vc = v.reshape(b, n_chunks, chunk, h, hd_v)
    kc = jnp.moveaxis(kc, 1, 0)    # [n, B, chunk, H, hd]
    vc = jnp.moveaxis(vc, 1, 0)
    qpos = jnp.arange(sq)[:, None] + q_offset

    def body(carry, xs):
        acc, m, l, i = carry
        kb, vb = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(jnp.float32))
        if causal:
            kpos = i * chunk + jnp.arange(chunk)[None, :]
            s = jnp.where((qpos >= kpos)[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        acc_new = acc * scale[..., None] + pv
        return (acc_new, m_new, l_new, i + 1), None

    acc0 = jnp.zeros((b, h, sq, hd_v), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l, _), _ = lax.scan(body, (acc0, m0, l0, jnp.int32(0)),
                                 (kc, vc), unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)    # [B,Sq,H,hd]


def attention(q, k, v, *, causal, q_offset=0):
    from repro.models.analysis_flags import single_chunk_active
    if k.shape[1] >= ONLINE_ATTN_MIN_SEQ:
        # analysis mode unrolls the chunk scan so cost_analysis sees every
        # chunk, while keeping the SAME algorithm/memory pattern as prod
        return attention_online(q, k, v, causal=causal, q_offset=q_offset,
                                unroll=single_chunk_active())
    return attention_einsum(q, k, v, causal=causal, q_offset=q_offset)


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token decode: q [B,1,H,hd] vs cache [B,Smax,KVH,hd].

    Positions > ``pos`` are masked (cache may be partially filled).
    """
    b, smax, kvh, hd = k_cache.shape
    h = q.shape[2]
    k = _expand_kv(k_cache, h // kvh)
    v = _expand_kv(v_cache, h // kvh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) / np.sqrt(hd),
                   k.astype(jnp.float32))
    valid = (jnp.arange(smax) <= pos)[None, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA projection block
# ---------------------------------------------------------------------------
def gqa_init(key, cfg, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(kq, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": L.dense_init(kk, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": L.dense_init(kv, cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": L.dense_init(ko, cfg.n_heads * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    return p


def gqa_project_qkv(p, cfg, x, positions):
    """Returns q [B,S,H,hd], k/v [B,S,KVH,hd], with RoPE applied if enabled."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.matmul(x, p["wq"])
    k = L.matmul(x, p["wk"])
    v = L.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    if cfg.rope_theta:
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p, cfg, x, positions, *, causal=True):
    """Full-sequence GQA self-attention (train / prefill)."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    k = _expand_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _expand_kv(v, cfg.n_heads // cfg.n_kv_heads)
    o = attention(q, k, v, causal=causal)
    return L.matmul(o.reshape(*x.shape[:2], -1), p["wo"])


def gqa_prefill(p, cfg, x, positions):
    """Prefill: attention output plus the K/V tensors for cache population."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    ke = _expand_kv(k, cfg.n_heads // cfg.n_kv_heads)
    ve = _expand_kv(v, cfg.n_heads // cfg.n_kv_heads)
    o = attention(q, ke, ve, causal=True)
    return L.matmul(o.reshape(*x.shape[:2], -1), p["wo"]), k, v


def gqa_decode(p, cfg, x, k_cache, v_cache, pos):
    """x: [B,1,D]. Updates cache at ``pos``; returns (out, k_cache, v_cache)."""
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    q = L.matmul(x, p["wq"])
    k = L.matmul(x, p["wk"])
    v = L.matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, cfg.n_heads, hd)
    k = k.reshape(b, 1, cfg.n_kv_heads, hd)
    v = v.reshape(b, 1, cfg.n_kv_heads, hd)
    if cfg.rope_theta:
        posv = jnp.full((b, 1), pos)
        q = L.apply_rope(q, posv, cfg.rope_theta)
        k = L.apply_rope(k, posv, cfg.rope_theta)
    k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                       (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                       (0, pos, 0, 0))
    o = decode_attention(q, k_cache, v_cache, pos)
    out = L.matmul(o.reshape(b, 1, -1), p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------
def cross_attn_init(key, cfg, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": L.dense_init(kq, cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": L.dense_init(kk, cfg.d_model, cfg.n_heads * hd, dtype),
        "wv": L.dense_init(kv, cfg.d_model, cfg.n_heads * hd, dtype),
        "wo": L.dense_init(ko, cfg.n_heads * hd, cfg.d_model, dtype),
    }


def cross_attention(p, cfg, x, enc_out):
    b, s, _ = x.shape
    se = enc_out.shape[1]
    hd = cfg.resolved_head_dim
    q = L.matmul(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = L.matmul(enc_out, p["wk"]).reshape(b, se, cfg.n_heads, hd)
    v = L.matmul(enc_out, p["wv"]).reshape(b, se, cfg.n_heads, hd)
    o = attention(q, k, v, causal=False)
    return L.matmul(o.reshape(b, s, -1), p["wo"])


def cross_attention_cached(p, cfg, x, k, v):
    """Decode-time cross attention against a precomputed (frozen) K/V."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = L.matmul(x, p["wq"]).reshape(b, s, cfg.n_heads, hd)
    o = attention(q, k, v, causal=False)
    return L.matmul(o.reshape(b, s, -1), p["wo"])


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V3 Multi-head Latent Attention
# ---------------------------------------------------------------------------
def mla_init(key, cfg, dtype=jnp.bfloat16):
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": L.dense_init(ks[0], d, m.q_lora_rank, dtype),
        "q_norm": L.rmsnorm_init(m.q_lora_rank),
        "wuq": L.dense_init(ks[1], m.q_lora_rank, h * qk_head, dtype),
        "wdkv": L.dense_init(ks[2], d, m.kv_lora_rank, dtype),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank),
        "wuk": L.dense_init(ks[3], m.kv_lora_rank, h * m.qk_nope_head_dim, dtype),
        "wuv": L.dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wkr": L.dense_init(ks[5], d, m.qk_rope_head_dim, dtype),
        "wo": L.dense_init(ks[6], h * m.v_head_dim, d, dtype),
    }


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = L.rmsnorm(p["q_norm"], L.matmul(x, p["wdq"]), cfg.norm_eps)
    q = L.matmul(cq, p["wuq"]).reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = L.apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    """Compressed KV latent c_kv [B,S,r] and shared rope key [B,S,rope_d]."""
    m = cfg.mla
    c_kv = L.rmsnorm(p["kv_norm"], L.matmul(x, p["wdkv"]), cfg.norm_eps)
    k_rope = L.matmul(x, p["wkr"])[:, :, None, :]          # [B,S,1,rope_d]
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, cfg, x, positions, *, causal=True):
    """Naive (expanded) MLA for train/prefill: decompress K/V per position."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = L.matmul(c_kv, p["wuk"]).reshape(b, s, h, m.qk_nope_head_dim)
    v = L.matmul(c_kv, p["wuv"]).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], axis=-1)
    o = attention(q, k, v, causal=causal)
    return L.matmul(o.reshape(b, s, -1), p["wo"]), c_kv, k_rope


def mla_decode_absorbed(p, cfg, x, ckv_cache, krope_cache, pos):
    """Weight-absorbed MLA decode: attention runs in the latent space.

    ``wuk`` is absorbed into the query (q_nope @ wuk^T per head) and ``wuv``
    into the output projection, so the KV cache stays compressed at
    [B,S,kv_lora_rank] + [B,S,rope_d] — the whole point of MLA.
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    posv = jnp.full((b, 1), pos)
    q_nope, q_rope = _mla_q(p, cfg, x, posv)               # [B,1,H,*]
    c_kv, k_rope = _mla_latent(p, cfg, x, posv)            # [B,1,r], [B,1,rd]
    ckv_cache = lax.dynamic_update_slice(
        ckv_cache, c_kv.astype(ckv_cache.dtype), (0, pos, 0))
    krope_cache = lax.dynamic_update_slice(
        krope_cache, k_rope.astype(krope_cache.dtype), (0, pos, 0))
    # absorb W_uk: q_lat[b,1,h,r] = q_nope[b,1,h,n] @ W_uk[r, h, n]^T
    wuk = p["wuk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wuk,
                       preferred_element_type=jnp.float32)
    # scores in latent space + shared rope channel
    scale = 1.0 / np.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_lat = jnp.einsum("bqhr,bkr->bhqk", q_lat.astype(jnp.float32),
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    smax = ckv_cache.shape[1]
    valid = (jnp.arange(smax) <= pos)[None, None, None, :]
    probs = jax.nn.softmax(jnp.where(valid, s, NEG_INF), axis=-1)
    # attend in latent space, then decompress through absorbed W_uv
    o_lat = jnp.einsum("bhqk,bkr->bqhr", probs,
                       ckv_cache.astype(jnp.float32))      # [B,1,H,r]
    wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat, wuv,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return L.matmul(o.reshape(b, 1, -1), p["wo"]), ckv_cache, krope_cache
