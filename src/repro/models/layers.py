"""Primitive layers: norms, projections, RoPE, MLPs, embeddings.

Params are plain pytrees (nested dicts of jnp arrays).  Compute runs in the
config dtype (bf16 by default) with fp32 accumulation on every matmul via
``preferred_element_type``; norms and softmax run in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in, d_out, dtype=jnp.bfloat16):
    """Fan-in scaled init for a [d_in, d_out] projection."""
    return truncated_normal(key, (d_in, d_out), 1.0 / np.sqrt(d_in), dtype)


def matmul(x, w):
    """x @ w with fp32 accumulation, result in x.dtype."""
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


def layernorm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim, theta):
    exponent = np.arange(0, head_dim, 2, dtype=np.float32) / head_dim
    return 1.0 / (theta ** exponent)          # [head_dim//2]


def apply_rope(x, positions, theta):
    """x: [..., S, H, hd] (hd even); positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))              # [hd//2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd//2]
    angles = angles[..., None, :]                            # [..., S, 1, hd//2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len, d_model):
    """Whisper-style fixed sinusoidal embedding table [seq_len, d_model]."""
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    dim = np.arange(0, d_model, 2, dtype=np.float32)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / d_model)
    tab = np.zeros((seq_len, d_model), np.float32)
    tab[:, 0::2] = np.sin(pos * inv)
    tab[:, 1::2] = np.cos(pos * inv)
    return jnp.asarray(tab)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def swiglu_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype),   # gate
        "wu": dense_init(k2, d_model, d_ff, dtype),   # up
        "wo": dense_init(k3, d_ff, d_model, dtype),
    }


def swiglu(p, x):
    g = matmul(x, p["wi"])
    u = matmul(x, p["wu"])
    return matmul(jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u, p["wo"])


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype),
        "bi": jnp.zeros((d_ff,), dtype),
        "wo": dense_init(k2, d_ff, d_model, dtype),
        "bo": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(p, x):
    h = matmul(x, p["wi"]) + p["bi"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return matmul(h, p["wo"]) + p["bo"]


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------
def embedding_init(key, vocab, d_model, dtype=jnp.bfloat16):
    return {"w": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embed(p, tokens):
    return jnp.take(p["w"], tokens, axis=0)


def unembed(p, h):
    """h: [..., d] -> logits [..., vocab] in fp32."""
    return jnp.einsum("...d,vd->...v", h, p["w"],
                      preferred_element_type=jnp.float32)


def head_init(key, d_model, vocab, dtype=jnp.bfloat16):
    return {"w": truncated_normal(key, (vocab, d_model),
                                  1.0 / np.sqrt(d_model), dtype)}
