"""Mixture-of-Experts layer: sort-based capacity dispatch, EP-shardable.

Dispatch is the MegaBlocks/GShard "dropping" scheme re-expressed with static
shapes: tokens are routed top-k, (token, expert) pairs are sorted by expert,
truncated at per-expert capacity C, scattered into a dense [E, C, d] buffer,
pushed through batched expert FFNs (one einsum — MXU friendly, E shardable on
the ``model`` mesh axis), and combined back with gate weighting.  Under pjit
the [tokens]→[E,C,d] scatter/gather lowers to the EP all-to-all.

DeepSeek-V3-style options: sigmoid gating normalized over the selected
experts, plus always-on shared experts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.distributed.sharding import shard_activation


def moe_init(key, cfg, dtype=jnp.bfloat16):
    m = cfg.moe
    d, ff, e = cfg.d_model, m.d_ff_expert, m.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.truncated_normal(ks[0], (d, e), 1.0 / np.sqrt(d),
                                     jnp.float32),
        "wi": L.truncated_normal(ks[1], (e, d, ff), 1.0 / np.sqrt(d), dtype),
        "wu": L.truncated_normal(ks[2], (e, d, ff), 1.0 / np.sqrt(d), dtype),
        "wo": L.truncated_normal(ks[3], (e, ff, d), 1.0 / np.sqrt(ff), dtype),
    }
    if m.n_shared_experts:
        p["shared"] = L.swiglu_init(ks[4], d, ff * m.n_shared_experts, dtype)
    return p


def capacity(cfg, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * n_tokens * m.n_experts_per_tok / m.n_experts)
    return max(8, (c + 7) // 8 * 8)   # pad to multiple of 8 for tiling


def route(p, cfg, x):
    """Router: returns (gates [T,k], expert_ids [T,k], aux_loss scalar)."""
    m = cfg.moe
    t = x.shape[0]
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    scores = jax.nn.sigmoid(logits)                       # DeepSeek-V3 gating
    gates, idx = jax.lax.top_k(scores, m.n_experts_per_tok)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style, on softmax probabilities)
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)                                # [E]
    ce = jnp.zeros((m.n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    ce = ce / (t * m.n_experts_per_tok)
    aux = m.n_experts * jnp.sum(me * ce)
    return gates, idx, aux


def moe_apply(p, cfg, x):
    """x: [B,S,d] -> (y [B,S,d], aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    gates, idx, aux = route(p, cfg, xt)                    # [T,k]
    k = m.n_experts_per_tok
    c = capacity(cfg, t)

    # ---- dispatch: sort (token, expert) pairs by expert --------------------
    flat_expert = idx.reshape(-1)                          # [T*k]
    flat_token = jnp.repeat(jnp.arange(t), k)              # [T*k]
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_expert)
    e_sorted = flat_expert[order]
    t_sorted = flat_token[order]
    g_sorted = flat_gate[order]
    # position within expert segment
    counts = jnp.bincount(flat_expert, length=m.n_experts)           # [E]
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(t * k) - seg_start[e_sorted]
    keep = pos < c
    # scatter tokens into dense [E, C, d] (3-D scatter so the expert dim
    # stays shardable through the op — §Perf iteration 1: a flat [E*C, d]
    # scatter forced SPMD to replicate the dispatch buffer per device)
    dest_e = jnp.where(keep, e_sorted, m.n_experts)        # OOB row drops
    dest_c = jnp.where(keep, pos, c)
    x_gathered = jnp.take(xt, t_sorted, axis=0)            # [T*k, d]
    x_gathered = shard_activation(x_gathered, "batch")
    buf = jnp.zeros((m.n_experts, c, d), x.dtype)
    xe = buf.at[dest_e, dest_c].set(x_gathered, mode="drop")
    xe = shard_activation(xe, "expert")                    # [E,C,d] E->model

    # ---- expert FFN (batched swiglu over E) --------------------------------
    # accumulate in fp32 but keep the [E,C,ff] intermediates in bf16 — the
    # fp32 pair was 14 GiB/dev on dbrx train (§Perf); silu runs fp32 on the
    # fly inside the fused multiply
    g = jnp.einsum("ecd,edf->ecf", xe, p["wi"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = (jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    ye = shard_activation(ye, "expert")

    # ---- combine: gather back, gate-weight, scatter-add per token ----------
    y_pairs = ye[jnp.minimum(dest_e, m.n_experts - 1),
                 jnp.minimum(dest_c, c - 1)]               # [T*k, d]
    y_pairs = y_pairs * (g_sorted * keep)[:, None].astype(x.dtype)
    y_pairs = shard_activation(y_pairs, "batch")
    yt = jnp.zeros((t, d), x.dtype).at[t_sorted].add(y_pairs)
    yt = shard_activation(yt, "batch")
    y = yt.reshape(b, s, d)

    if m.n_shared_experts:
        y = y + L.swiglu(p["shared"], x)
    return y, aux
