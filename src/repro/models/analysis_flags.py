"""Analysis-mode flags.

``single_chunk()``: makes every *time-axis* chunked scan (online-softmax
attention, SSD chunks, mLSTM chunks) use one chunk spanning the whole
sequence, so XLA's counted-once while-loop body equals the true cost.  Used
only by the roofline correction pass (launch/correction.py) — never in a
production trace, where chunking is the memory-boundedness win.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def single_chunk_active() -> bool:
    return getattr(_state, "single_chunk", False)


@contextlib.contextmanager
def single_chunk():
    prev = getattr(_state, "single_chunk", False)
    _state.single_chunk = True
    try:
        yield
    finally:
        _state.single_chunk = prev
