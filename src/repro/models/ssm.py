"""State-space / recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM + sLSTM).

All blocks provide three entry points:
  * ``*_apply``  — full-sequence training/prefill form (chunked parallel scan)
  * ``*_decode`` — single-token recurrent step against a carried state
  * ``*_init_state`` — zero state for decode

The chunked SSD scan is the TPU-native adaptation of Mamba2: quadratic
attention-like compute *within* a chunk (MXU-friendly einsums) and a cheap
``lax.scan`` over chunk states *between* chunks — the same
halo/interior decomposition idea DIFET uses for image tiles (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers as L


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================
def mamba2_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def mamba2_init(key, cfg, dtype=jnp.bfloat16):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads = mamba2_dims(cfg)
    d_xbc = d_inner + 2 * s.d_state          # x stream + B + C (n_groups=1)
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [z | xBC | dt]
        "in_proj": L.dense_init(ks[0], d, d_inner + d_xbc + n_heads, dtype),
        "conv": {
            "w": L.truncated_normal(ks[1], (s.d_conv, d_xbc),
                                    1.0 / np.sqrt(s.d_conv), dtype),
            "b": jnp.zeros((d_xbc,), dtype),
        },
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, n_heads, dtype=jnp.float32))),
        "norm": L.rmsnorm_init(d_inner),
        "out_proj": L.dense_init(ks[2], d_inner, d, dtype),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, kernel K: xbc [B,S,C], w [K,C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(x, dt, A, B, C, chunk):
    """Chunked SSD scan, fully inside a ``lax.scan`` over chunks.

    Per-chunk work is quadratic-in-chunk MXU einsums; only the running state
    [B,H,P,N] is carried, so live memory is O(B·chunk²·H) for one chunk, not
    the whole sequence — this is what makes prefill_32k/long-context lowerable.

    x  [B,S,H,P];  dt [B,S,H] (positive);  A [H] (negative rates)
    B,C [B,S,N] (single group, broadcast over heads).  Returns y [B,S,H,P].
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    nc = s // chunk
    q = chunk
    # chunk-major layouts for scan: [nc, B, Q, ...]
    xc = jnp.moveaxis(x.reshape(b, nc, q, h, p), 1, 0).astype(jnp.float32)
    dtc = jnp.moveaxis(dt.reshape(b, nc, q, h), 1, 0)
    Bc = jnp.moveaxis(B.reshape(b, nc, q, n), 1, 0).astype(jnp.float32)
    Cc = jnp.moveaxis(C.reshape(b, nc, q, n), 1, 0).astype(jnp.float32)
    mask = jnp.tril(jnp.ones((q, q), bool))

    def body(state, inp):
        xb, dtb, Bb, Cb = inp                       # [B,Q,...]
        dA = dtb * A                                # [B,Q,H]
        cum = jnp.cumsum(dA, axis=1)
        xdt = xb * dtb[..., None]
        # intra-chunk: L[i,j] = exp(cum_i - cum_j), j <= i
        li = cum[:, :, None, :] - cum[:, None, :, :]        # [B,Q,Q,H]
        decay = jnp.where(mask[None, :, :, None], jnp.exp(li), 0.0)
        cb = jnp.einsum("bin,bjn->bij", Cb, Bb)
        y_intra = jnp.einsum("bij,bijh,bjhp->bihp", cb, decay, xdt)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bin,bih,bhpn->bihp", Cb, jnp.exp(cum), state)
        # state update
        seg = jnp.exp(cum[:, -1:, :] - cum)                 # [B,Q,H]
        upd = jnp.einsum("bjn,bjh,bjhp->bhpn", Bb, seg, xdt)
        state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + upd
        return state, y_intra + y_inter

    s0 = jnp.zeros((b, h, p, n), jnp.float32)
    from repro.models.analysis_flags import single_chunk_active
    _, ys = lax.scan(body, s0, (xc, dtc, Bc, Cc),
                     unroll=nc if single_chunk_active() else 1)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)      # [nc,B,Q,H,P]


def mamba2_apply(p, cfg, x):
    """x [B,S,d] -> [B,S,d]; full-sequence chunked SSD."""
    s_cfg = cfg.ssm
    b, s, d = x.shape
    d_inner, n_heads = mamba2_dims(cfg)
    d_xbc = d_inner + 2 * s_cfg.d_state
    zxbcdt = L.matmul(x, p["in_proj"])
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + d_xbc], axis=-1)
    xbc = _causal_conv(xbc, p["conv"]["w"], p["conv"]["b"])
    xs, B, C = jnp.split(xbc, [d_inner, d_inner + s_cfg.d_state], axis=-1)
    xs = xs.reshape(b, s, n_heads, s_cfg.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    chunk = min(s_cfg.chunk_size, s)
    if s % chunk:
        chunk = int(np.gcd(s, chunk)) or 1
    y = _ssd_chunked(xs, dt, A, B, C, chunk)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y, cfg.norm_eps)
    return L.matmul(y, p["out_proj"])


def mamba2_init_state(cfg, batch, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, n_heads = mamba2_dims(cfg)
    d_xbc = d_inner + 2 * s.d_state
    return {
        "ssm": jnp.zeros((batch, n_heads, s.head_dim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_xbc), dtype),
    }


def mamba2_decode(p, cfg, x, state):
    """x [B,1,d]; recurrent single-step update."""
    s_cfg = cfg.ssm
    b = x.shape[0]
    d_inner, n_heads = mamba2_dims(cfg)
    d_xbc = d_inner + 2 * s_cfg.d_state
    zxbcdt = L.matmul(x, p["in_proj"])[:, 0]              # [B, *]
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + d_xbc], axis=-1)
    # conv cache: window = [cache | new]
    win = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)
    conv_out = (win * p["conv"]["w"][None]).sum(axis=1) + p["conv"]["b"]
    xbc_c = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    new_conv = win[:, 1:, :]
    xs, B, C = jnp.split(xbc_c, [d_inner, d_inner + s_cfg.d_state], axis=-1)
    xs = xs.reshape(b, n_heads, s_cfg.head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                               # [B,H]
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    upd = jnp.einsum("bhp,bn,bh->bhpn", xs, Bf, dt)
    ssm = state["ssm"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cf) + xs * p["D"][None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    y = L.rmsnorm(p["norm"], y, cfg.norm_eps)
    return L.matmul(y, p["out_proj"]), {"ssm": ssm, "conv": new_conv}


# ===========================================================================
# mLSTM (xLSTM matrix-memory block)
# ===========================================================================
def mlstm_dims(cfg):
    d_up = int(cfg.xlstm.proj_factor * cfg.d_model)
    n_heads = cfg.n_heads
    head_dim = d_up // n_heads
    return d_up, n_heads, head_dim


def mlstm_init(key, cfg, dtype=jnp.bfloat16):
    x_cfg = cfg.xlstm
    d = cfg.d_model
    d_up, n_heads, head_dim = mlstm_dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "norm": L.rmsnorm_init(d),
        "up_proj": L.dense_init(ks[0], d, 2 * d_up, dtype),   # [u | z]
        "conv": {
            "w": L.truncated_normal(ks[1], (x_cfg.conv_kernel, d_up),
                                    1.0 / np.sqrt(x_cfg.conv_kernel), dtype),
            "b": jnp.zeros((d_up,), dtype),
        },
        "wq": L.dense_init(ks[2], d_up, d_up, dtype),
        "wk": L.dense_init(ks[3], d_up, d_up, dtype),
        "wv": L.dense_init(ks[4], d_up, d_up, dtype),
        "w_gates": L.truncated_normal(ks[5], (d_up, 2 * n_heads),
                                      1.0 / np.sqrt(d_up), jnp.float32),
        "b_gates": jnp.concatenate([
            jnp.linspace(3.0, 6.0, n_heads, dtype=jnp.float32),   # forget
            jnp.zeros((n_heads,), jnp.float32)]),                 # input
        "out_norm": L.rmsnorm_init(d_up),
        "down_proj": L.dense_init(ks[6], d_up, d, dtype),
    }


def _mlstm_chunked(q, k, v, log_f, log_i, chunk):
    """Stabilized chunkwise-parallel mLSTM (lax.scan over chunks).

    q,k,v: [B,S,H,P]; log_f/log_i: [B,S,H].  Returns h [B,S,H,P].

    Within a chunk: D[i,j] = exp(cumF_i - cumF_j + log_i_j - m_i) for j <= i.
    Across chunks the state (C, n) is carried with its own running stabilizer
    m_run; a query i sees the carried state scaled by
    exp(m_run + cumF_i - m_i).  Denominator: max(|Σ_j w_ij|, exp(-m_i)).
    """
    b, s, h, p = q.shape
    nc = s // chunk
    Q = chunk
    cm = lambda t: jnp.moveaxis(
        t.reshape(b, nc, Q, *t.shape[2:]), 1, 0).astype(jnp.float32)
    qc, kc, vc, lfc, lic = cm(q), cm(k), cm(v), cm(log_f), cm(log_i)
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    def body(carry, inp):
        C, nvec, m_run = carry                     # [B,H,P,P],[B,H,P],[B,H]
        qb, kb, vb, lf, li = inp                   # [B,Q,H,*]
        cumf = jnp.cumsum(lf, axis=1)              # [B,Q,H]
        logd = cumf[:, :, None, :] - cumf[:, None, :, :] + li[:, None, :, :]
        logd = jnp.where(mask[None, :, :, None], logd, -1e30)
        inter_log = m_run[:, None, :] + cumf       # [B,Q,H]
        m_i = jnp.maximum(jnp.max(logd, axis=2), inter_log)
        d = jnp.exp(logd - m_i[:, :, None, :])
        qk = jnp.einsum("bihp,bjhp->bijh", qb, kb) / np.sqrt(p)
        w = qk * d
        num = jnp.einsum("bijh,bjhp->bihp", w, vb)
        den = w.sum(axis=2)                        # [B,Q,H]
        # carried-state contribution
        scale = jnp.exp(inter_log - m_i)           # [B,Q,H]
        num = num + jnp.einsum("bihq,bhpq,bih->bihp", qb, C, scale)
        den = den + jnp.einsum("bihq,bhq,bih->bih", qb, nvec, scale)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update to end of chunk
        tot = cumf[:, -1, :]                       # [B,H]
        e_j = li + tot[:, None, :] - cumf          # [B,Q,H] decay j -> chunk end
        m_new = jnp.maximum(m_run + tot, jnp.max(e_j, axis=1))
        sj = jnp.exp(e_j - m_new[:, None, :])
        k_s = kb / np.sqrt(p)
        C = C * jnp.exp(m_run + tot - m_new)[:, :, None, None] + \
            jnp.einsum("bjh,bjhp,bjhq->bhpq", sj, vb, k_s)
        nvec = nvec * jnp.exp(m_run + tot - m_new)[:, :, None] + \
            jnp.einsum("bjh,bjhq->bhq", sj, k_s)
        return (C, nvec, m_new), y

    C0 = jnp.zeros((b, h, p, p), jnp.float32)
    n0 = jnp.zeros((b, h, p), jnp.float32)
    m0 = jnp.zeros((b, h), jnp.float32)
    from repro.models.analysis_flags import single_chunk_active
    _, ys = lax.scan(body, (C0, n0, m0), (qc, kc, vc, lfc, lic),
                     unroll=nc if single_chunk_active() else 1)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p)


def mlstm_apply(p, cfg, x):
    """Full-sequence mLSTM block (pre-norm residual handled by caller)."""
    b, s, d = x.shape
    d_up, n_heads, head_dim = mlstm_dims(cfg)
    uz = L.matmul(x, p["up_proj"])
    u, z = jnp.split(uz, 2, axis=-1)
    uc = _causal_conv(u, p["conv"]["w"], p["conv"]["b"])
    q = L.matmul(uc, p["wq"]).reshape(b, s, n_heads, head_dim)
    k = L.matmul(uc, p["wk"]).reshape(b, s, n_heads, head_dim)
    v = L.matmul(u, p["wv"]).reshape(b, s, n_heads, head_dim)
    gates = (uc.astype(jnp.float32) @ p["w_gates"]) + p["b_gates"]
    f_pre, i_pre = jnp.split(gates, 2, axis=-1)            # [B,S,H]
    log_f = -jax.nn.softplus(-f_pre)                       # log sigmoid
    log_i = i_pre                                          # exponential input gate
    chunk = min(256, s)
    if s % chunk:
        chunk = int(np.gcd(s, chunk)) or 1
    hidden = _mlstm_chunked(q, k, v, log_f, log_i, chunk)
    hidden = hidden.reshape(b, s, d_up).astype(x.dtype)
    hidden = L.rmsnorm(p["out_norm"], hidden, cfg.norm_eps)
    hidden = hidden * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return L.matmul(hidden, p["down_proj"])


def mlstm_init_state(cfg, batch):
    d_up, n_heads, head_dim = mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, n_heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, n_heads, head_dim), jnp.float32),
        # m starts at 0 (not -inf) to match the chunked-parallel stabilizer
        # initialization and avoid -inf - -inf NaNs; only effect is the
        # denominator floor exp(-m) on the first steps.
        "m": jnp.zeros((batch, n_heads), jnp.float32),
        "conv": jnp.zeros((batch, cfg.xlstm.conv_kernel - 1, d_up),
                          jnp.bfloat16),
    }


def mlstm_decode(p, cfg, x, state):
    """x [B,1,d]; stabilized recurrent step."""
    b = x.shape[0]
    d_up, n_heads, head_dim = mlstm_dims(cfg)
    uz = L.matmul(x, p["up_proj"])[:, 0]
    u, z = jnp.split(uz, 2, axis=-1)
    win = jnp.concatenate([state["conv"].astype(u.dtype), u[:, None, :]],
                          axis=1)
    conv_out = (win * p["conv"]["w"][None]).sum(axis=1) + p["conv"]["b"]
    uc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)
    q = (uc @ p["wq"]).reshape(b, n_heads, head_dim).astype(jnp.float32)
    k = (uc @ p["wk"]).reshape(b, n_heads, head_dim).astype(jnp.float32)
    v = (u @ p["wv"]).reshape(b, n_heads, head_dim).astype(jnp.float32)
    gates = (uc.astype(jnp.float32) @ p["w_gates"]) + p["b_gates"]
    f_pre, i_pre = jnp.split(gates, 2, axis=-1)            # [B,H]
    log_f = -jax.nn.softplus(-f_pre)
    log_i = i_pre
    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_s = jnp.exp(log_f + state["m"] - m_new)              # stabilized gates
    i_s = jnp.exp(log_i - m_new)
    k_scaled = k / np.sqrt(head_dim)
    C = state["C"] * f_s[..., None, None] + \
        i_s[..., None, None] * jnp.einsum("bhp,bhq->bhpq", v, k_scaled)
    nvec = state["n"] * f_s[..., None] + i_s[..., None] * k_scaled
    num = jnp.einsum("bhpq,bhq->bhp", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhq,bhq->bh", nvec, q)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, 1, d_up).astype(x.dtype)
    h = L.rmsnorm(p["out_norm"], h, cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)[:, None, :]
    out = L.matmul(h, p["down_proj"])
    new_state = {"C": C, "n": nvec, "m": m_new, "conv": win[:, 1:, :].astype(jnp.bfloat16)}
    return out, new_state


# ===========================================================================
# sLSTM (xLSTM scalar-memory block) — sequential scan (inherent recurrence)
# ===========================================================================
def slstm_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    d_ff = int(4.0 / 3.0 * 2 * d)
    return {
        "norm": L.rmsnorm_init(d),
        "w": L.truncated_normal(ks[0], (d, 4 * d), 1.0 / np.sqrt(d),
                                jnp.float32),
        "r": L.truncated_normal(ks[1], (d, 4 * d), 1.0 / np.sqrt(d),
                                jnp.float32),
        "b": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": L.rmsnorm_init(d),
        "mlp": L.swiglu_init(ks[2], d, d_ff, dtype),
        "mlp_norm": L.rmsnorm_init(d),
    }


def slstm_init_state(cfg, batch):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, d), -jnp.inf, jnp.float32)}


def _slstm_cell(p, x_t, st):
    """One sLSTM step.  x_t [B,d] fp32; state dict of [B,d]."""
    pre = x_t @ p["w"] + st["h"] @ p["r"] + p["b"]
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    log_f = -jax.nn.softplus(-f_pre)
    m_new = jnp.maximum(log_f + st["m"], i_pre)
    i_s = jnp.exp(i_pre - m_new)
    f_s = jnp.exp(log_f + st["m"] - m_new)
    c = f_s * st["c"] + i_s * z
    n = jnp.maximum(f_s * st["n"] + i_s, 1e-6)
    h = o * (c / n)
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply(p, cfg, x):
    """x [B,S,d]; sequential lax.scan over time (sLSTM is not parallelizable)."""
    b, s, d = x.shape
    xf = x.astype(jnp.float32)

    def body(st, x_t):
        st = _slstm_cell(p, x_t, st)
        return st, st["h"]

    st0 = slstm_init_state(cfg, b)
    _, hs = lax.scan(body, st0, jnp.moveaxis(xf, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    h = L.rmsnorm(p["out_norm"], h, cfg.norm_eps)
    # post-MLP (sLSTM block carries its own small FFN)
    h = h + L.swiglu(p["mlp"], L.rmsnorm(p["mlp_norm"], h, cfg.norm_eps))
    return h


def slstm_decode(p, cfg, x, state):
    st = _slstm_cell(p, x[:, 0].astype(jnp.float32), state)
    h = st["h"][:, None, :].astype(x.dtype)
    h = L.rmsnorm(p["out_norm"], h, cfg.norm_eps)
    h = h + L.swiglu(p["mlp"], L.rmsnorm(p["mlp_norm"], h, cfg.norm_eps))
    return h, st
