"""End-to-end LM training driver with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 8 --seq 256 [--reduced] [--resume]

On this CPU container use ``--reduced`` (tiny same-family config); on a pod
the full config + production mesh applies unchanged.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamW, cosine_schedule
from repro.train.step import make_train_step, make_init_fn, TrainStepConfig
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_host_mesh
from repro.distributed.sharding import use_mesh, activation_dp_over_model
from repro.distributed import specs as SP
from repro.data.tokens import synthetic_lm_batch
from repro.models.model import param_count


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(remat="nothing" if args.reduced else cfg.remat)
    model = build_model(cfg)
    opt = AdamW()
    scfg = TrainStepConfig(learning_rate=args.lr,
                           microbatches=args.microbatches,
                           grad_compression=args.grad_compression)
    lr_fn = cosine_schedule(args.lr, warmup_steps=20, total_steps=args.steps)
    step_fn = make_train_step(model, opt, scfg, lr_fn)
    init_fn = make_init_fn(model, opt, scfg)
    mesh = make_host_mesh()
    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with use_mesh(mesh), activation_dp_over_model(cfg.dp_over_model):
        state = jax.jit(init_fn)(jax.random.PRNGKey(0))
        start = 0
        if ckpt and args.resume and ckpt.latest_step() is not None:
            state, start = ckpt.restore(jax.eval_shape(lambda: state))
            print(f"[resume] restored step {start}")
        print(f"[train] {cfg.arch_id} reduced={args.reduced} "
              f"params={param_count(state['params']):,}")
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        losses = []
        for i in range(start, args.steps):
            batch = synthetic_lm_batch(args.batch, args.seq, cfg.vocab_size,
                                       seed=i)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if cfg.n_image_patches:
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.n_image_patches, cfg.d_model),
                    jnp.bfloat16)
            if cfg.is_enc_dec:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder_seq_len, cfg.d_model),
                    jnp.bfloat16)
            state, metrics = jstep(state, batch)
            losses.append(float(metrics["loss"]))
            if i % args.log_every == 0 or i == args.steps - 1:
                dt = time.time() - t0
                print(f"step {i:5d} loss {losses[-1]:.4f} "
                      f"ce {float(metrics['ce']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"({dt:.1f}s)", flush=True)
            if ckpt and (i + 1) % args.ckpt_every == 0:
                ckpt.save(state, i + 1, async_=True)
        if ckpt:
            ckpt.save(state, args.steps, async_=True)
            ckpt.wait()
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"[done] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
