"""Horizontal-scaling study driver — the paper's experiment, end to end.

DIFET's Table 1 sweeps a fixed LandSat scene set over 1/2/4 Hadoop nodes
and reports wall-clock per algorithm.  This driver reproduces that shape
on the streaming ingest subsystem (docs/scaling.md): a fixed band-striped
scene set on disk, cut into fixed-shape tile batches by the streaming
pipeline (`data/pipeline.py`), with the worker axis swept 1→N.

Worker semantics: worker *i* of *W* owns the contiguous batch slice
``batch_slices(n_batches, W)[i]`` of the restart-deterministic manifest
order; it streams **only** its slice (scenes outside it are never read)
and extracts each batch with the same compiled program.  On a one-device
host the workers are *simulated* — each worker's slice is executed and
timed separately, and the reported t(W) is the slowest worker (the
straggler defines makespan, as in MapReduce).  On a multi-device host the
same batches are additionally sharded over the data mesh
(`DifetJob`-style ``batch_pspec`` inputs).

Every sweep verifies bit-parity: the per-batch results of every worker
count must equal the single-worker reference array-for-array — scaling is
a schedule change, never a numerics change.

    PYTHONPATH=src python -m repro.launch.scale --scenes 3 \
        --scene-size 512 --workers 1,2,4 --algorithms harris,sift
    PYTHONPATH=src python -m repro.launch.scale --smoke
"""
from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core.engine import extract_features_multi, normalize_algorithms
from repro.data.landsat import BandSceneReader, write_synthetic_scene_set
from repro.data.pipeline import (Prefetcher, batch_slices, count_batches,
                                 iter_tile_batches)


def build_scene_set(root, n_scenes: int, scene_hw: Tuple[int, int]):
    """Materialize (or reopen) the fixed band-striped scene set and return
    its readers in deterministic name order — the order the manifest, and
    therefore every worker count, sees."""
    root = Path(root)
    dirs = sorted(d for d in root.glob("scene_*") if d.is_dir())
    if len(dirs) < n_scenes:
        write_synthetic_scene_set(root, n_scenes, *scene_hw)
        dirs = sorted(d for d in root.glob("scene_*") if d.is_dir())
    return [BandSceneReader(d) for d in dirs[:n_scenes]]


def make_batch_extractor(algorithms, cfg: DifetConfig, mesh=None,
                         use_pallas: bool = False):
    """jit-compiled fixed-shape batch extractor (the per-worker program).

    Returns ``fn(tiles, headers) -> {algorithm: result}``; with ``mesh``
    set the batch inputs carry explicit data-axis shardings, so on a
    multi-device host each worker's batches also split across devices.
    """
    import jax
    fn = functools.partial(extract_features_multi,
                           algorithms=tuple(algorithms), cfg=cfg,
                           use_pallas=use_pallas)
    if mesh is None:
        return jax.jit(fn)
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import batch_pspec
    return jax.jit(fn, in_shardings=(
        NamedSharding(mesh, batch_pspec(mesh, 3)),
        NamedSharding(mesh, batch_pspec(mesh, 2))))


def run_worker(readers, cfg: DifetConfig, batch_tiles: int, fn,
               lo: int, hi: int, stripe_rows: Optional[int] = None,
               prefetch_depth: int = 2) -> Tuple[Dict[int, Dict], float]:
    """Execute one worker's contiguous batch slice ``[lo, hi)``.

    Streams the slice through the prefetch queue (tiling overlaps device
    compute), runs the compiled extractor per batch, and returns
    ``({batch_index: {algorithm: host result}}, wall_seconds)``.
    """
    import jax
    results: Dict[int, Dict] = {}
    t0 = time.perf_counter()
    with Prefetcher(iter_tile_batches(readers, cfg, batch_tiles,
                                      stripe_rows=stripe_rows,
                                      start=lo, stop=hi),
                    depth=prefetch_depth) as pf:
        for idx, bundle in pf:
            out = fn(bundle.tiles, bundle.headers)
            results[idx] = jax.device_get(out)
    return results, time.perf_counter() - t0


def _results_equal(a: Dict[int, Dict], b: Dict[int, Dict]) -> bool:
    """Bitwise comparison of two {batch: {alg: {key: array}}} result maps."""
    if a.keys() != b.keys():
        return False
    for idx in a:
        if a[idx].keys() != b[idx].keys():
            return False
        for alg in a[idx]:
            ra, rb = a[idx][alg], b[idx][alg]
            if ra.keys() != rb.keys():
                return False
            for k in ra:
                if not np.array_equal(np.asarray(ra[k]),
                                      np.asarray(rb[k])):
                    return False
    return True


def run_scaling(readers, cfg: DifetConfig, algorithms,
                workers: Sequence[int] = (1, 2, 4), batch_tiles: int = 8,
                mesh=None, use_pallas: bool = False,
                stripe_rows: Optional[int] = None, repeats: int = 1):
    """Sweep the worker count over a fixed scene set, one row per algorithm.

    For each algorithm: a single-worker reference pass establishes t(1)
    and the reference per-batch results; each worker count W partitions
    the batch manifest into W contiguous slices, executes and times every
    slice, and reports makespan t(W) = max over slices.  With
    ``repeats > 1`` every slice is executed that many times and its wall
    clock is the best of the repeats — the standard guard against
    one-off scheduler hiccups dominating short benchmark runs (parity is
    still checked on every repeat).  Returns a list of row dicts with
    ``t``/``speedup``/``efficiency`` per worker count, the grand total
    feature count, and ``parity`` (True iff every worker count's results
    were bit-identical to the reference).
    """
    algorithms = normalize_algorithms(algorithms)
    workers = tuple(workers)
    n_batches = count_batches([r.shape for r in readers], cfg, batch_tiles)
    if n_batches < max(workers):
        raise ValueError(
            f"{n_batches} batches cannot occupy {max(workers)} workers — "
            f"grow the scene set or shrink --batch-tiles")
    rows = []
    for alg in algorithms:
        fn = make_batch_extractor((alg,), cfg, mesh, use_pallas)
        # warm the one compiled program outside any timed region
        hw = cfg.tile + 2 * cfg.halo
        import jax
        jax.block_until_ready(fn(
            np.zeros((batch_tiles, hw, hw), np.float32),
            np.zeros((batch_tiles, 6), np.int32))[alg]["total_count"])
        times: Dict[int, float] = {}
        parity = True
        ref: Dict[int, Dict] = {}
        for w in workers:
            best_walls = None
            for _ in range(max(1, repeats)):
                worker_results: Dict[int, Dict] = {}
                walls = []
                for lo, hi in batch_slices(n_batches, w):
                    res, wall = run_worker(readers, cfg, batch_tiles, fn,
                                           lo, hi, stripe_rows)
                    worker_results.update(res)
                    walls.append(wall)
                best_walls = (walls if best_walls is None else
                              [min(a, b) for a, b in
                               zip(best_walls, walls)])
                if w == workers[0] and not ref:
                    ref = worker_results
                else:
                    parity = parity and _results_equal(ref, worker_results)
            times[w] = max(best_walls)     # straggler defines makespan
        t1 = times[workers[0]]
        total = int(np.sum([ref[i][alg]["total_count"]
                            for i in sorted(ref)]))
        rows.append({
            "algorithm": alg, "n_batches": n_batches,
            "t": times,
            "speedup": {w: t1 / times[w] for w in workers},
            "efficiency": {w: t1 / times[w] / w for w in workers},
            "total_count": total, "parity": parity,
        })
    return rows


def print_table(rows, workers) -> None:
    """Render the sweep as the paper's Table-1 shape (seconds + speedup)."""
    hdr = " ".join(f"t(w={w})" .rjust(9) for w in workers)
    spd = " ".join(f"s(w={w})".rjust(8) for w in workers)
    print(f"{'algorithm':12s} {hdr} {spd} {'count':>9s} parity")
    for r in rows:
        t = " ".join(f"{r['t'][w]:9.3f}" for w in workers)
        s = " ".join(f"{r['speedup'][w]:8.2f}" for w in workers)
        print(f"{r['algorithm']:12s} {t} {s} {r['total_count']:9d} "
              f"{r['parity']}")


def main(argv=None):
    """CLI entry point; ``--smoke`` is the CI gate (tiny set, parity must
    hold for every worker count)."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", type=int, default=3)
    ap.add_argument("--scene-size", type=int, default=512)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--halo", type=int, default=24)
    ap.add_argument("--batch-tiles", type=int, default=8)
    ap.add_argument("--workers", default="1,2,4")
    ap.add_argument("--algorithms", default="harris,fast,sift")
    ap.add_argument("--store", default="/tmp/difet_scale")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--json", default=None,
                    help="also write the rows to this JSON path")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI mode: 2 scenes, workers 1,2; exits "
                         "non-zero unless every sweep is bit-exact")
    args = ap.parse_args(argv)
    if args.smoke:
        args.scenes, args.scene_size = 2, 160
        args.tile, args.halo, args.batch_tiles = 64, 16, 4
        args.workers, args.algorithms = "1,2", "harris,fast"
    workers = tuple(int(w) for w in args.workers.split(","))
    try:
        algorithms = normalize_algorithms(args.algorithms)
    except ValueError as e:
        ap.error(str(e))
    cfg = DifetConfig(tile=args.tile, halo=args.halo,
                      max_keypoints_per_tile=128)
    readers = build_scene_set(
        Path(args.store) / f"scenes_{args.scene_size}",
        args.scenes, (args.scene_size, args.scene_size))
    # on a multi-device host the batches additionally shard over a data
    # mesh; a single device compiles the same (unsharded) program
    import jax
    from repro.distributed.sharding import data_mesh
    mesh = data_mesh() if len(jax.devices()) > 1 else None
    print(f"[scale] {len(readers)} scenes of {args.scene_size}^2, "
          f"tile={args.tile}, batch={args.batch_tiles}, "
          f"workers={workers}, algorithms={','.join(algorithms)}, "
          f"devices={len(jax.devices())}")
    rows = run_scaling(readers, cfg, algorithms, workers,
                       batch_tiles=args.batch_tiles, mesh=mesh,
                       use_pallas=args.use_pallas)
    print_table(rows, workers)
    if args.json:
        Path(args.json).write_text(json.dumps(rows, indent=1, default=str))
        print(f"# wrote {args.json}")
    if not all(r["parity"] for r in rows):
        print("!! parity FAILED: some worker count changed results")
        raise SystemExit(1)
    if args.smoke:
        assert all(r["total_count"] > 0 for r in rows), \
            "smoke: no features extracted"
        print("[scale] smoke OK: bit-parity across worker counts, "
              f"{sum(r['total_count'] for r in rows)} features")
    return rows


if __name__ == "__main__":
    main()
