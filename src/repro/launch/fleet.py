"""DIFET fleet driver: replica pool + router replaying a synthetic
trace (`serve/trace.py`) — the multi-replica analogue of
``launch/serve.py``.

Open-loop injection at the trace's arrival offsets through the router:
admission control sheds (typed: tenant quota vs fleet saturation), the
consistent-hash ring routes hot scenes to their affinity replicas, and
the shared disk cache tier turns cross-replica repeats into hits.
``--proc`` spawns replicas as OS processes (`serve/proc.py`) over the
spooled-file transport.  ``--autoscale`` runs the SLO-driven autoscaler
during the replay; ``--kill-after N`` kills a replica after N accepted
requests — for process replicas that is a raw ``kill -9`` detected only
via the stale lease (chaos: the run must still complete every accepted
request, bit-identically).

    PYTHONPATH=src python -m repro.launch.fleet --replicas 2 --requests 128
    PYTHONPATH=src python -m repro.launch.fleet --smoke      # CI gate
    PYTHONPATH=src python -m repro.launch.fleet \\
        --replicas 4 --proc --kill-after 16 --smoke          # chaos gate
"""
from __future__ import annotations

import argparse
import tempfile
import time

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.obs import metrics as obs_metrics
from repro.serve import (Fleet, FleetConfig, RouterConfig, ServeConfig,
                         Shed)
from repro.serve.trace import TraceConfig, make_trace, scene_key, tile_pool


def build_fleet(args) -> Fleet:
    halo = 8 if args.tile_size <= 32 else 16
    base = DifetConfig(tile=args.tile_size, halo=halo,
                       max_keypoints_per_tile=args.max_keypoints)
    serve = ServeConfig(base=base, buckets=(args.tile_size,),
                        max_batch=args.batch,
                        max_batch_delay_s=args.delay_ms * 1e-3,
                        max_pending=args.max_pending,
                        cache_entries=args.cache_entries)
    router = RouterConfig(max_global_pending=args.max_global_pending,
                          spill_queue_threshold=args.spill_threshold,
                          tenant_rate=args.tenant_rate,
                          tenant_burst=args.tenant_burst)
    cfg = FleetConfig(serve=serve, router=router,
                      initial_replicas=args.replicas,
                      min_replicas=max(1, args.replicas // 2),
                      max_replicas=max(args.replicas, args.max_replicas),
                      warm_algorithm_sets=(("harris",),
                                           ("harris", "shi_tomasi")),
                      cache_dir=args.cache_dir
                      or tempfile.mkdtemp(prefix="difet-fleet-cache-"),
                      lease_ttl_s=args.lease_ttl,
                      proc=args.proc,
                      # proc fleets run the telemetry plane: workers ship
                      # metric deltas + spans, the parent aggregates
                      # (repro/obs/{ship,agg,slo}.py)
                      telemetry=args.proc,
                      slo_p99_s=args.slo_ms * 1e-3)
    return Fleet(cfg)


def trace_config(args) -> TraceConfig:
    return TraceConfig(n_requests=args.requests, seed=args.seed,
                       arrival=args.arrival, rate=args.rate,
                       tile_sizes=(args.tile_size,),
                       unique_scenes=args.unique_scenes,
                       algorithm_sets=(("harris",),
                                       ("harris", "shi_tomasi")),
                       algorithm_weights=(0.7, 0.3),
                       tenants=("tenant-a", "tenant-b"),
                       tenant_weights=(0.75, 0.25))


def replay(fleet, trace, pool, kill_after=0):
    """Open-loop replay through the router.  Returns (wall, responses,
    shed_by_reason, n_killed_readmitted, accepted_events) — the last is
    index-aligned with ``responses`` (shed events are absent from both).

    ``kill_after`` kills the deepest-queued replica once that many
    requests are accepted.  Thread fleets take the eager
    ``kill_replica`` path; process fleets get a raw ``kill -9``
    (`Fleet.sigkill_replica`) and the victim is *only* discovered by the
    maintenance tick noticing the stale lease — the tick runs inline
    with the injection loop here, standing in for the background
    autoscaler thread."""
    handles, accepted, sheds = [], [], {}
    killed = False
    sigkilled = None
    readmitted = 0
    t0 = time.perf_counter()
    for i, ev in enumerate(trace):
        target = t0 + ev.t
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        try:
            handles.append(fleet.submit(pool[ev.pool_key], ev.algorithms,
                                        tenant=ev.tenant,
                                        scene_key=scene_key(ev)))
            accepted.append(ev)
        except Shed as s:
            sheds[s.reason] = sheds.get(s.reason, 0) + 1
        if kill_after and not killed and len(handles) >= kill_after:
            ready = fleet.ready_replicas()
            victim = max(ready, key=lambda n: (
                fleet.replicas[n].service.scheduler.queue_depth, n))
            if fleet.cfg.proc:
                pid = fleet.sigkill_replica(victim)
                sigkilled = victim
                print(f"[chaos] kill -9 {victim} (pid {pid}) after "
                      f"{len(handles)} accepted; awaiting stale lease")
            else:
                readmitted = fleet.kill_replica(victim)
                print(f"[chaos] killed {victim} after {len(handles)} "
                      f"accepted ({readmitted} re-admitted)")
            killed = True
        if sigkilled is not None:
            # stand-in for the autoscaler thread: detect the stale lease
            if sigkilled in fleet.maintenance_tick():
                readmitted = fleet.router.readmitted
                print(f"[chaos] stale lease detected, {sigkilled} dead "
                      f"({readmitted} re-admitted)")
                sigkilled = None
    deadline = time.perf_counter() + 30.0
    while sigkilled is not None:          # trace ended before detection
        if sigkilled in fleet.maintenance_tick():
            readmitted = fleet.router.readmitted
            print(f"[chaos] stale lease detected, {sigkilled} dead "
                  f"({readmitted} re-admitted)")
            sigkilled = None
        elif time.perf_counter() > deadline:
            raise RuntimeError(f"stale lease for {sigkilled} never "
                               f"detected within 30s")
        else:
            time.sleep(0.05)
    responses = [h.result(120) for h in handles]
    return time.perf_counter() - t0, responses, sheds, readmitted, accepted


def report(label, wall, responses, sheds, fleet):
    lat = np.asarray([r.timing["latency_s"] for r in responses])
    s = fleet.stats()
    served, shed_n = len(lat), sum(sheds.values())
    print(f"[{label}] {served} served, {shed_n} shed in {wall:.2f}s "
          f"-> {served / wall:.1f} req/s over "
          f"{s['replica_count']} replica(s)")
    if served:
        print(f"  latency p50={np.percentile(lat, 50) * 1e3:.2f} ms  "
              f"p99={np.percentile(lat, 99) * 1e3:.2f} ms")
    print(f"  routing affinity={s['routed_affinity']} "
          f"spill={s['routed_spill']} readmitted={s['readmitted']}")
    print(f"  sheds={sheds}  tenants={s['tenants']}")
    print(f"  cache hits={s['total_cache_hits']} "
          f"misses={s['total_cache_misses']}  "
          f"busy={s['total_busy_s']:.2f}s")
    for name, r in sorted(s["replicas"].items()):
        print(f"  {name}: submitted={r['submitted']} "
              f"batches={r['batches']} occ={r['batch_occupancy']:.2f} "
              f"p99q={r['p99_queue_ms']:.1f}ms state="
              f"{s['states'].get(name, '?')}")
    return s


def chaos_summary(fleet, sheds) -> None:
    """Post-run summary after a ``--kill-after`` chaos run, answered
    from the metrics registry (`repro/obs/metrics.py`): sheds by reason,
    re-admissions, replica deaths, and the shared disk tier's hit rate
    — the 'did the fleet absorb the kill' digest.  With the telemetry
    plane on (proc fleets), the digest extends with rows only the
    *aggregated* fleet registry can answer: per-worker execution counts
    shipped from inside the worker processes, the workers' own disk-tier
    hit counters merged under ``difet.fleet.*``, and each worker
    flight-recorder dump correlated with the parent death/shed events
    recorded around it (`repro/obs/agg.py`)."""
    m = obs_metrics.registry().snapshot()
    s = fleet.stats()
    print("chaos summary (metrics registry):")
    shed_counters = {k.rsplit(".", 1)[1]: v for k, v in m.items()
                     if k.startswith("difet.router.shed.")}
    print(f"  sheds by reason: {shed_counters or dict(sheds) or '{}'}")
    print(f"  re-admissions: {int(m.get('difet.router.readmitted', 0))}  "
          f"replicas dead: {int(m.get('difet.fleet.replicas_dead', 0))}  "
          f"stale-lease deaths: "
          f"{int(m.get('difet.fleet.stale_lease_deaths', 0))}")
    dh = m.get("difet.cache.disk_hits", 0)
    dm = m.get("difet.cache.disk_misses", 0)
    rate = dh / (dh + dm) if (dh + dm) else 0.0
    print(f"  disk tier: {int(dh)} hits / {int(dm)} misses "
          f"({rate:.1%} hit rate)")
    print(f"  outstanding after drain: {s['outstanding']}")
    agg = getattr(fleet, "telemetry", None)
    if agg is None:
        return
    fleet.poll_telemetry()                # sweep any last shipments
    m = obs_metrics.registry().snapshot()
    print("  fleet telemetry (aggregated worker shipments, "
          f"{agg.ingested} applied / {agg.dropped} dropped):")
    for w in sorted(agg.worker_counts):
        execs = agg.worker_counts[w].get("difet.scheduler.queue_s", 0)
        state = "retired" if agg.worker_final.get(w) else "live/killed"
        print(f"    {w} (pid {agg.worker_pids.get(w, 0)}, {state}): "
              f"{execs} requests executed in-worker")
    wdh = m.get("difet.fleet.cache.disk_hits", 0)
    wdm = m.get("difet.fleet.cache.disk_misses", 0)
    print(f"    worker-side disk tier: {int(wdh)} hits / {int(wdm)} "
          f"misses (from inside the worker processes)")
    for row in agg.correlate_dumps():
        kinds = sorted({str(e.get('kind')) for e in row["parent_events"]})
        print(f"    dump {row['worker']}[{row['reason']}] -> "
              f"{row['path']}  parent events nearby: {kinds or ['none']}")


def smoke(args) -> int:
    """CI smoke: short trace with a mid-trace replica kill; assert zero
    accepted-request loss, bounded shed rate, and bit-parity of *every*
    served response against a direct (unrouted) oracle service — which
    is exactly "bit-identical to a no-kill run", since the oracle never
    sees the kill.  With ``--proc`` the kill is a raw ``kill -9``
    detected via the stale lease, and the smoke additionally asserts
    the stale-lease path (not the cooperative kill) did the detection.
    Non-zero exit on failure."""
    import dataclasses

    from repro.serve.api import FeatureService

    args.requests = max(32, min(args.requests, 64))
    if args.proc:
        # tight lease so stale detection lands inside the smoke window
        args.lease_ttl = min(args.lease_ttl, 1.0)
    fleet = build_fleet(args)
    tcfg = trace_config(args)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    failures = []

    kill_after = args.kill_after or args.requests // 2
    wall, responses, sheds, readmitted, accepted = replay(
        fleet, trace, pool, kill_after=kill_after)
    served, shed_n = len(responses), sum(sheds.values())
    if served + shed_n != len(trace):
        failures.append(f"lost requests: {served} served + {shed_n} shed "
                        f"!= {len(trace)} injected")
    if served < 0.9 * len(trace):
        failures.append(f"shed rate {shed_n / len(trace):.2%} > 10%")
    if args.proc:
        m = obs_metrics.registry().snapshot()
        if int(m.get("difet.fleet.stale_lease_deaths", 0)) < 1:
            failures.append("kill -9 was not detected via the stale "
                            "lease path")

    # parity: every served response == the direct (no-kill) oracle,
    # bit-identical — accepted requests survived the kill unchanged
    oracle = FeatureService(
        dataclasses.replace(fleet.cfg.serve, cache_dir=None),
        name="smoke-oracle")
    checked = 0
    for ev, resp in zip(accepted, responses):
        want = oracle.submit(pool[ev.pool_key], resp.algorithms,
                             block=True).result(60).results
        for alg in resp.algorithms:
            for k, v in want[alg].items():
                b = resp.results[alg][k]
                if np.asarray(v).shape != b.shape \
                        or not np.array_equal(v, b):
                    failures.append(f"parity mismatch req={resp.request_id}"
                                    f" {alg}/{k}")
        checked += 1
        if checked >= 16:                 # bounded oracle cost
            break
    oracle.close()

    report("fleet-smoke", wall, responses, sheds, fleet)
    chaos_summary(fleet, sheds)
    fleet.close()
    if failures:
        print("FLEET SMOKE FAILED:", "; ".join(failures))
        return 1
    print(f"fleet smoke ok ({'proc' if args.proc else 'thread'} mode, "
          f"{served} served, {readmitted} re-admitted, "
          f"{checked} parity-checked)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--arrival", choices=("uniform", "poisson", "burst"),
                    default="burst")
    ap.add_argument("--tile-size", type=int, default=32)
    ap.add_argument("--unique-scenes", type=int, default=16)
    ap.add_argument("--max-keypoints", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--delay-ms", type=float, default=2.0)
    ap.add_argument("--max-pending", type=int, default=256)
    ap.add_argument("--max-global-pending", type=int, default=1024)
    ap.add_argument("--spill-threshold", type=int, default=16)
    ap.add_argument("--tenant-rate", type=float, default=float("inf"))
    ap.add_argument("--tenant-burst", type=float, default=64.0)
    ap.add_argument("--cache-entries", type=int, default=1024)
    ap.add_argument("--cache-dir", default=None,
                    help="shared disk cache tier (temp dir by default)")
    ap.add_argument("--lease-ttl", type=float, default=5.0)
    ap.add_argument("--proc", action="store_true",
                    help="spawn replicas as OS processes (serve/proc.py)")
    ap.add_argument("--slo-ms", type=float, default=500.0,
                    help="p99 admission-to-completion SLO for the autoscaler")
    ap.add_argument("--autoscale", action="store_true",
                    help="run the SLO-driven autoscaler during replay")
    ap.add_argument("--kill-after", type=int, default=0,
                    help="chaos: kill one replica after N accepted requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: assertions + non-zero exit")
    args = ap.parse_args(argv)

    if args.smoke:
        raise SystemExit(smoke(args))

    fleet = build_fleet(args)
    if args.autoscale:
        fleet.start_autoscaler()
    tcfg = trace_config(args)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    wall, responses, sheds, _, _ = replay(fleet, trace, pool,
                                          kill_after=args.kill_after)
    stats = report("fleet", wall, responses, sheds, fleet)
    if args.kill_after:
        chaos_summary(fleet, sheds)
    fleet.close()
    return stats


if __name__ == "__main__":
    main()
