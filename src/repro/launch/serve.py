"""DIFET serving driver: in-process feature service + synthetic load
generator (the online analogue of ``launch/extract.py``'s batch job).

The workload itself — arrival process, hot-scene skew, tile/algorithm
mix — comes from `serve/trace.py`, the same generator the fleet driver
(`launch/fleet.py`) and fleet benchmark (`benchmarks/bench_fleet.py`)
replay, so single-service and fleet numbers describe the same traffic.

Closed loop: ``--concurrency`` client threads each submit a request and
wait for it — models downstream consumers like the stitching pipeline
(arrival offsets ignored; the clients are completion-clocked).
Open loop: requests are injected at the trace's arrival offsets
regardless of completions — models public traffic; queue overflow is
load-shed (:class:`ServiceOverloaded` counted as rejected, the
backpressure knob).  ``--arrival burst`` replays Markov-modulated spikes
instead of a fixed period.

The trace cycles ``--unique-tiles`` distinct scenes over ``--requests``
requests with hot-set skew, so repeats exercise the content-hash result
cache exactly the way recurring LandSat granules would.

    PYTHONPATH=src python -m repro.launch.serve --requests 96 --batch 8
    PYTHONPATH=src python -m repro.launch.serve --mode open --rate 500 \
        --arrival burst
    PYTHONPATH=src python -m repro.launch.serve --smoke      # CI gate
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core.engine import normalize_algorithms
from repro.serve import FeatureService, ServeConfig, ServiceOverloaded
from repro.serve.trace import TraceConfig, make_trace, tile_pool


def build_service(args) -> FeatureService:
    halo = 8 if args.tile_size <= 32 else 16
    base = DifetConfig(tile=args.tile_size, halo=halo,
                       max_keypoints_per_tile=args.max_keypoints)
    cfg = ServeConfig(base=base, buckets=(args.tile_size,),
                      max_batch=args.batch,
                      max_batch_delay_s=args.delay_ms * 1e-3,
                      max_pending=args.max_pending,
                      cache_entries=args.cache_entries)
    return FeatureService(cfg)


def trace_config(args, algs) -> TraceConfig:
    """Map the driver CLI onto one shared `serve/trace.py::TraceConfig`."""
    return TraceConfig(n_requests=args.requests, seed=args.seed,
                       arrival=args.arrival, rate=args.rate,
                       tile_sizes=(args.tile_size,),
                       unique_scenes=args.unique_tiles,
                       algorithm_sets=(tuple(algs),))


def make_pool(args):
    """Tile list for the smoke path: the trace generator's pool, indexed
    by scene (single tile size)."""
    cfg = TraceConfig(n_requests=1, seed=args.seed,
                      tile_sizes=(args.tile_size,),
                      unique_scenes=args.unique_tiles)
    tp = tile_pool(cfg)
    return [tp[(s, args.tile_size)] for s in range(args.unique_tiles)]


def run_closed(svc, trace, pool, concurrency):
    """Closed-loop: each worker submits, waits, repeats.  A failed request
    fails the run — a load generator must not mistake a dying service for
    a fast one."""
    n_requests = len(trace)
    latencies = [0.0] * n_requests
    it = iter(range(n_requests))
    lock = threading.Lock()
    errors = []

    def worker():
        while not errors:
            with lock:
                i = next(it, None)
            if i is None:
                return
            ev = trace[i]
            t0 = time.perf_counter()
            try:
                svc.submit(pool[ev.pool_key], ev.algorithms,
                           block=True).result(60)
            except Exception as e:  # noqa: BLE001 — surfaced after join
                errors.append((i, e))
                return
            latencies[i] = time.perf_counter() - t0

    threads = [threading.Thread(target=worker)
               for _ in range(min(concurrency, n_requests))]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        i, e = errors[0]
        raise RuntimeError(
            f"{len(errors)} request(s) failed (first: #{i}: {e!r})") from e
    return time.perf_counter() - t0, latencies, 0


def run_open(svc, trace, pool):
    """Open-loop: inject at the trace's arrival offsets; overload is
    shed, not queued.

    Latency is the service's own completion stamp
    (``timing["latency_s"]``: batch completion minus enqueue), NOT the
    handle-drain wall time — the drain loop below walks handles in submit
    order, so timing ``h.result()`` returns would add each handle's queue
    position behind its predecessors to its reported latency (at
    injection rates above service rate, that inflated every percentile
    toward the full run length)."""
    handles, rejected = [], 0
    t0 = time.perf_counter()
    for ev in trace:
        target = t0 + ev.t
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        try:
            handles.append(svc.submit(pool[ev.pool_key], ev.algorithms))
        except ServiceOverloaded:
            rejected += 1
    latencies = [h.result(60).timing["latency_s"] for h in handles]
    return time.perf_counter() - t0, latencies, rejected


def report(label, wall, latencies, rejected, svc):
    lat = np.asarray([l for l in latencies if l > 0.0])
    stats = svc.stats()
    served = len(lat)
    print(f"[{label}] {served} served, {rejected} rejected in {wall:.2f}s "
          f"-> {served / wall:.1f} req/s")
    if served:
        print(f"  latency p50={np.percentile(lat, 50) * 1e3:.2f} ms  "
              f"p99={np.percentile(lat, 99) * 1e3:.2f} ms")
    cache = stats["cache"]
    print(f"  cache hit-rate={cache['hit_rate']:.2f} "
          f"({cache['hits']} hits / {cache['misses']} misses, "
          f"{cache['entries']} entries)")
    print(f"  programs={stats['programs']} "
          f"batches={stats['scheduler']['batches']} "
          f"mean_batch={stats['scheduler']['mean_batch']:.1f} "
          f"hist={stats['scheduler']['batch_size_hist']}")
    return stats


def smoke(args) -> int:
    """CI smoke: in-process service, mixed-algorithm requests; assert
    responses, 100% cache hits on the repeat pass, and served-vs-direct
    parity.  Non-zero exit on any failure."""
    import functools
    import jax
    from repro.core import engine

    svc = build_service(args)
    algsets = [("harris",), ("harris", "shi_tomasi")]
    svc.warmup(algsets)
    pool = make_pool(args)
    failures = []

    # mixed-algorithm traffic
    t0 = time.perf_counter()
    handles = [svc.submit(pool[i % len(pool)], algsets[i % len(algsets)])
               for i in range(2 * len(pool))]
    resps = [h.result(60) for h in handles]
    wall = time.perf_counter() - t0
    if not all(int(r.results[a]["total_count"]) >= 0
               for r in resps for a in r.algorithms):
        failures.append("bad response payload")

    # repeat pass: every (tile, algorithm) pair must come from cache
    repeat = [svc.submit(pool[i % len(pool)], algsets[i % len(algsets)])
              .result(60) for i in range(2 * len(pool))]
    if not all(r.fully_cached for r in repeat):
        failures.append(f"repeat pass not fully cached: "
                        f"{[r.cached for r in repeat if not r.fully_cached]}")

    # parity: served == direct extract_features_multi, bit-identical
    bucket = svc.table.interiors[0]
    tile, header = svc.table.pad_to_bucket(pool[0], bucket)
    direct = jax.jit(functools.partial(
        engine.extract_features_multi, algorithms=algsets[1],
        cfg=svc.table.cfg_for(bucket)))(tile[None], header[None])
    served = svc.submit(pool[0], algsets[1]).result(60).results
    for alg in algsets[1]:
        for k, v in direct[alg].items():
            a, b = np.asarray(v), served[alg][k]
            if a.shape != b.shape or not np.array_equal(a, b):
                failures.append(f"parity mismatch {alg}/{k}")

    report("smoke", wall, [r.timing["latency_s"] for r in resps], 0, svc)
    svc.close()
    if failures:
        print("SMOKE FAILED:", "; ".join(failures))
        return 1
    print("smoke ok")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithms", default="harris,shi_tomasi")
    ap.add_argument("--mode", choices=("closed", "open"), default="closed")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--rate", type=float, default=500.0,
                    help="open-loop mean injection rate (req/s)")
    ap.add_argument("--arrival", choices=("uniform", "poisson", "burst"),
                    default="uniform",
                    help="open-loop arrival process (serve/trace.py)")
    ap.add_argument("--tile-size", type=int, default=32)
    ap.add_argument("--unique-tiles", type=int, default=16,
                    help="distinct scenes in the pool; repeats hit the cache")
    ap.add_argument("--max-keypoints", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--delay-ms", type=float, default=2.0)
    ap.add_argument("--max-pending", type=int, default=256)
    ap.add_argument("--cache-entries", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: assertions + non-zero exit")
    args = ap.parse_args(argv)

    if args.smoke:
        raise SystemExit(smoke(args))

    try:
        algs = normalize_algorithms(args.algorithms)
    except ValueError as e:
        ap.error(str(e))
    svc = build_service(args)
    print(f"[serve] warmup: {svc.warmup([algs])} program(s) "
          f"(bucket {args.tile_size}, batch {args.batch})")
    tcfg = trace_config(args, algs)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    if args.mode == "closed":
        wall, lat, rej = run_closed(svc, trace, pool, args.concurrency)
    else:
        wall, lat, rej = run_open(svc, trace, pool)
    stats = report(args.mode, wall, lat, rej, svc)
    svc.close()
    return stats


if __name__ == "__main__":
    main()
