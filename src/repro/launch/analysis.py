"""Compiled-artifact analysis: collective-byte parsing + roofline terms.

TPU v5e hardware model (per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  The dry-run is single-controller with placeholder
devices, so wall-clock is meaningless — the roofline terms below are the
perf report (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (one direction)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "ragged-all-to-all")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# e.g.:  %ag = bf16[2,512]{1,0} all-gather(...)   or tuple results
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op, by kind.

    ``-done`` ops are skipped (their ``-start`` was counted); convention:
    payload == result bytes (documented in EXPERIMENTS.md §Roofline).
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, kind, _ = m.groups()
        out[kind] = out.get(kind, 0) + _shape_bytes(type_str)
    return out


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   n_chips: int) -> Dict[str, float]:
    """The three roofline terms in seconds.

    IMPORTANT convention: ``compiled.cost_analysis()`` on an SPMD-partitioned
    module reports PER-DEVICE flops/bytes (verified against 6·N·D/chips), and
    the collective shapes in the partitioned HLO are per-device payloads —
    so every term is per-chip work over per-chip capability; n_chips is only
    used for reporting.
    """
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    total = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = compute_s / total if total > 0 else 0.0
    return terms


def active_param_count(cfg, n_params: int) -> int:
    """MoE: subtract un-routed expert params (6·N_active·D convention)."""
    if getattr(cfg, "moe", None) is None:
        return n_params
    m = cfg.moe
    n_moe_layers = cfg.n_layers - m.n_dense_layers
    inactive = n_moe_layers * 3 * cfg.d_model * m.d_ff_expert \
        * (m.n_experts - m.n_experts_per_tok)
    return n_params - inactive


def model_flops(n_params: int, n_tokens: int, kind: str = "train") -> float:
    """6·N·D for train, 2·N·D for inference forward (N = active params)."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params * n_tokens


def cost_analysis_terms(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):   # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    return {"hlo_flops": flops, "hlo_bytes": bytes_accessed}
