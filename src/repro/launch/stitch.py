"""Scene-stitching driver: extraction → pairwise registration → mosaic
layout — the companion stitching pipeline (arXiv:1808.08522) as a second
end-to-end workload next to `launch/extract.py`.

Synthetic mode (default) cuts overlapping views out of one wide LandSat-
like scene at known offsets, so the recovered registrations can be checked
against ground truth (reported as ``max_err``; the acceptance bar is
sub-pixel on integer shifts).  Both phases are checkpointed ManifestJobs:
kill the process at any point and the same command resumes.

    PYTHONPATH=src python -m repro.launch.stitch --scenes 3 \
        --scene-size 384 --overlap 160 --algorithm orb --store /tmp/difet_stitch
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core import mosaic
from repro.core.bundle import BundleStore, bundle_scenes
from repro.core.job import DifetJob
from repro.data.landsat import synthetic_scene

DESCRIPTOR_ALGORITHMS = ("sift", "surf", "brief", "orb")


def build_overlapping_store(store_path, n_scenes: int, scene: int,
                            overlap: int, cfg: DifetConfig, seed: int = 0,
                            density: float = 4.0):
    """Synthetic overlapping scenes: crops of one wide base scene at known
    integer offsets (x strides of ``scene - overlap``, alternating y jitter
    so the registration is genuinely 2-D).  Pure crops — the overlap pixels
    are bit-identical across scenes, the realistic best case for LandSat
    row-adjacent products.  Dense structure (``density``) so every overlap
    holds enough corners to verify a registration.  Ground truth goes to
    ``truth.json``."""
    store = BundleStore(store_path)
    truth_path = store.root / "truth.json"
    step = scene - overlap
    if step <= 0:
        raise ValueError("overlap must be smaller than scene size")
    params = {"n_scenes": n_scenes, "scene": scene, "overlap": overlap,
              "seed": seed, "density": density, "tile": cfg.tile,
              "max_keypoints": cfg.max_keypoints_per_tile,
              "fast_threshold": cfg.fast_threshold}
    jitter = 16
    truth = {f"scene_{i:02d}": [jitter * (i % 2), step * i]
             for i in range(n_scenes)}
    if store.list() or truth_path.exists():
        meta = json.loads(truth_path.read_text()) if truth_path.exists() \
            else {}
        if meta.get("params") != params:
            raise SystemExit(
                f"store {store.root} was built with {meta.get('params')}, "
                f"current args are {params} — pick a fresh --store (or "
                "delete the old one) instead of silently mixing geometries")
    else:
        # commit the build plan before any scene data so a killed build is
        # resumable (scene contents are deterministic from the params)
        truth_path.write_text(json.dumps({"params": params,
                                          "offsets": truth}))
    missing = [n for n in truth if n not in set(store.list())]
    if missing:
        base = synthetic_scene(scene + jitter,
                               scene + step * (n_scenes - 1),
                               seed, density=density)
        for name in missing:
            oy, ox = truth[name]
            store.put(name, bundle_scenes(
                [base[oy:oy + scene, ox:ox + scene]], cfg))
    return store, truth


def truth_errors(positions, truth):
    """Per-scene |estimated - true| offset, both anchored on the first
    placed scene (layout positions are relative, truth is absolute)."""
    anchor = next(iter(positions))
    errs = {}
    for name, pos in positions.items():
        true_rel = (np.asarray(truth[name], np.float64)
                    - np.asarray(truth[anchor], np.float64))
        errs[name] = float(np.abs(pos - true_rel).max())
    return errs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="orb",
                    choices=DESCRIPTOR_ALGORITHMS)
    ap.add_argument("--scenes", type=int, default=3)
    ap.add_argument("--scene-size", type=int, default=384)
    ap.add_argument("--overlap", type=int, default=160)
    ap.add_argument("--tile", type=int, default=128)
    ap.add_argument("--max-keypoints", type=int, default=256)
    ap.add_argument("--store", default="/tmp/difet_stitch")
    ap.add_argument("--ratio", type=float, default=0.8)
    ap.add_argument("--tol", type=float, default=2.0)
    ap.add_argument("--iters", type=int, default=128)
    ap.add_argument("--min-inliers", type=int, default=8)
    ap.add_argument("--pairs-per-step", type=int, default=8)
    ap.add_argument("--all-pairs", action="store_true",
                    help="register every scene pair, not just neighbours")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--mesh", default="host", choices=("host", "none"),
                    help="shard the pair batch over a device mesh")
    ap.add_argument("--fail-after", type=int, default=None,
                    help="simulate worker failure after N match chunks")
    args = ap.parse_args(argv)

    # lower FAST threshold than the extraction default: registration wants
    # *many* verifiable corners, not just the strongest (Table-2) ones
    cfg = DifetConfig(tile=args.tile, halo=24,
                      max_keypoints_per_tile=args.max_keypoints,
                      fast_threshold=0.08)
    store, truth = build_overlapping_store(
        args.store, args.scenes, args.scene_size, args.overlap, cfg)
    scenes = store.list()
    print(f"[stitch] {args.algorithm} over {len(scenes)} scenes "
          f"({args.scene_size}^2, overlap {args.overlap}, tile {args.tile})")

    t0 = time.time()
    extract_job = DifetJob(store, args.algorithm)
    extract_job.run(progress=lambda n: print(f"  extracted {n}", flush=True))

    if args.all_pairs:
        pairs = [(scenes[i], scenes[j]) for i in range(len(scenes))
                 for j in range(i + 1, len(scenes))]
    else:
        pairs = list(zip(scenes, scenes[1:]))
    mesh = None
    if args.mesh == "host":
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
    phase = mosaic.MatchPhase(
        store, pairs, args.algorithm, ratio=args.ratio, tol=args.tol,
        iters=args.iters, pairs_per_step=args.pairs_per_step, mesh=mesh,
        use_pallas=args.use_pallas)
    try:
        phase.run(simulate_failure_after=args.fail_after,
                  progress=lambda n: print(f"  matched {n}", flush=True))
    except RuntimeError as e:
        print(f"  !! {e} — restart with the same command to resume")
        raise SystemExit(2)

    results = phase.results()
    for (a, b), r in results.items():
        t = np.asarray(r["t"])
        print(f"  {a} -> {b}: dy={t[0]:+7.2f} dx={t[1]:+7.2f} "
              f"inliers={int(r['n_inliers'])}/{int(r['n_matches'])} "
              f"rms={float(r['rms']):.3f}")
    positions, dropped = mosaic.solve_layout(scenes, results,
                                             args.min_inliers)
    summary = mosaic.mosaic_summary(
        positions, (args.scene_size, args.scene_size))
    dt = time.time() - t0
    print(f"[mosaic] placed {summary['n_scenes']}/{len(scenes)} scenes, "
          f"canvas {summary['mosaic_hw'][0]}x{summary['mosaic_hw'][1]}, "
          f"{len(dropped)} pair(s) dropped, {dt:.1f}s")
    max_err = None
    if truth and len(positions) > 1:
        errs = truth_errors(positions, truth)
        max_err = max(errs.values())
        print(f"[verify] max |offset error| vs ground truth: "
              f"{max_err:.3f} px")
    return {"positions": {k: (float(v[0]), float(v[1]))
                          for k, v in positions.items()},
            "pairs": {f"{a}->{b}": (float(r['t'][0]), float(r['t'][1]))
                      for (a, b), r in results.items()},
            "summary": summary, "dropped": dropped, "max_err": max_err}


if __name__ == "__main__":
    main()
