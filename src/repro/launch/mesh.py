"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — device count is
locked on first jax init, and smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

import jax

try:                                        # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:                         # older jax: axes are Auto-typed
    AxisType = None


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod ('data' x 'model'); 2 pods stack a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate mesh over however many devices the host actually has —
    used by smoke tests and the CPU examples."""
    n = len(jax.devices())
    if AxisType is None:
        return jax.make_mesh((n, 1), ("data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
