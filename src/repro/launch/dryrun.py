import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks device
# count on first init).  This module is the ONLY place the 512 placeholder
# devices exist — smoke tests and benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, record roofline inputs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from pathlib import Path  # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    get_config, all_arch_ids, applicable_shapes, SHAPES)
from repro.models import build_model  # noqa: E402
from repro.optim import AdamW  # noqa: E402
from repro.train.step import make_train_step, TrainStepConfig  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.analysis import (  # noqa: E402
    parse_collectives, roofline_terms, cost_analysis_terms, model_flops,
    active_param_count)
from repro.distributed.sharding import (  # noqa: E402
    use_mesh, activation_dp_over_model)
from repro.distributed import specs as SP  # noqa: E402
from repro.models.model import param_count  # noqa: E402


def lower_cell(cfg, shape, mesh, microbatches: int = 1):
    """Lower + compile one (arch, shape, mesh) cell.  Returns result dict."""
    model = build_model(cfg)
    t0 = time.time()
    import contextlib
    with use_mesh(mesh), activation_dp_over_model(cfg.dp_over_model):
        if shape.kind == "train":
            opt = AdamW()
            scfg = TrainStepConfig(microbatches=microbatches)
            step = make_train_step(model, opt, scfg)
            state_shapes = SP.state_abstract(model, opt, scfg)
            state_sh = SP.to_named(SP.state_pspecs(state_shapes, mesh), mesh)
            batch_shapes = model.input_specs(shape)
            batch_sh = SP.to_named(SP.batch_pspecs(batch_shapes, mesh), mesh)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            params_shapes = SP.params_abstract(model)
            params_sh = SP.to_named(
                SP.params_pspecs(params_shapes, mesh, serving=True), mesh)
            batch_shapes = model.input_specs(shape)
            batch_sh = SP.to_named(SP.batch_pspecs(batch_shapes, mesh), mesh)
            out_shapes = jax.eval_shape(model.prefill, params_shapes,
                                        batch_shapes)
            cache_sh = SP.to_named(
                SP.cache_pspecs(out_shapes[1], mesh,
                                batch_size=shape.global_batch,
                                max_seq=shape.seq_len, cfg=cfg), mesh)
            lowered = jax.jit(
                model.prefill,
                in_shardings=(params_sh, batch_sh),
                out_shardings=(None, cache_sh),
            ).lower(params_shapes, batch_shapes)
        else:  # decode
            params_shapes = SP.params_abstract(model)
            params_sh = SP.to_named(
                SP.params_pspecs(params_shapes, mesh, serving=True), mesh)
            b = shape.global_batch
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(b, shape.seq_len))
            cache_sh = SP.to_named(
                SP.cache_pspecs(cache_shapes, mesh, batch_size=b,
                                max_seq=shape.seq_len, cfg=cfg), mesh)
            tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                model.decode_step,
                in_shardings=(params_sh, cache_sh, None, None),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            ).lower(params_shapes, cache_shapes, tok, pos)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_terms(compiled)
    coll = parse_collectives(compiled.as_text())
    n_chips = mesh.size
    terms = roofline_terms(cost["hlo_flops"], cost["hlo_bytes"],
                           sum(coll.values()), n_chips)
    n_params = param_count(SP.params_abstract(model))
    n_active = active_param_count(cfg, n_params)
    n_tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
    mflops = model_flops(n_active, n_tokens,
                         "train" if shape.kind == "train" else "serve")
    result = {
        "arch": cfg.arch_id, "shape": shape.name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": n_chips,
        "n_params": n_params,
        "n_active_params": n_active,
        "microbatches": microbatches,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes),
        },
        "cost": cost,
        "collective_bytes": coll,
        "collective_bytes_total": sum(coll.values()),
        "model_flops": mflops,
        # hlo_flops is per-device; global = x n_chips
        "useful_flops_ratio": (mflops / (cost["hlo_flops"] * n_chips)
                               if cost["hlo_flops"] else 0.0),
        "roofline": terms,
    }
    return result


# Per-arch gradient-accumulation defaults for train_4k (1M tokens global):
# sized so activation peak fits HBM after remat (§Perf iterations 2-3).
TRAIN_MICROBATCHES = {
    "deepseek-v3-671b": 16, "dbrx-132b": 32, "qwen1.5-110b": 8,
    "glm4-9b": 8, "internvl2-2b": 8, "whisper-large-v3": 1,
    "internlm2-1.8b": 2, "smollm-135m": 1, "xlstm-350m": 1,
    "zamba2-2.7b": 4,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0,
                    help="0 = per-arch default (train shapes)")
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(multi_pod=False),
                  make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = []
    if args.all:
        for arch in all_arch_ids():
            cfg = get_config(arch)
            for sname in applicable_shapes(cfg):
                cells.append((arch, sname))
    else:
        cells = [(args.arch, args.shape)]

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for mesh in meshes:
        mesh_tag = "x".join(str(s) for s in mesh.devices.shape)
        for arch, sname in cells:
            cfg = get_config(arch)
            shape = SHAPES[sname]
            path = outdir / f"{mesh_tag}__{arch}__{sname}.json"
            if path.exists() and not args.force:
                print(f"[skip] {path.name} (cached)")
                continue
            print(f"[dryrun] {arch} × {sname} on mesh {mesh_tag} ...",
                  flush=True)
            mb = 1
            if shape.kind == "train":
                mb = args.microbatches or TRAIN_MICROBATCHES.get(arch, 1)
            try:
                res = lower_cell(cfg, shape, mesh, microbatches=mb)
                path.write_text(json.dumps(res, indent=1))
                r = res["roofline"]
                print(f"  ok: compile={res['compile_s']}s "
                      f"peak/dev={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
                      f"compute={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s "
                      f"coll={r['collective_s']:.2e}s dom={r['dominant']}",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((mesh_tag, arch, sname, repr(e)))
                print(f"  FAIL: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-run cells compiled successfully.")


if __name__ == "__main__":
    main()
