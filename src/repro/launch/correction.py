import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# Must precede all other imports (see dryrun.py).

"""Scan-trip-count correction for the roofline analysis.

``compiled.cost_analysis()`` counts a ``lax.scan`` (while-loop) body ONCE,
not x trip-count (verified empirically — see EXPERIMENTS.md §Roofline
methodology).  Since every layer stack here is scanned, per-cell flops/bytes
/collective-bytes are undercounted by ~the layer count.

Correction: for each (arch, shape, mesh) cell, lower tiny VARIANT configs
that change each stack's depth by one (e.g. dense LM at n_layers=1 and 2)
and solve the linear model

    cost(n_1..n_k) = base + sum_i n_i * per_layer_i

then extrapolate to the full depths.  Scan bodies are depth-independent, so
the model is exact (up to XLA fusion differences between variant and full
compiles, which are small — the body HLO is identical).

Peak memory is NOT corrected (the scanned executable's memory_analysis is
already the truth).  Results are written back into the dry-run JSONs under
``corrected``.
"""
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
from pathlib import Path  # noqa: E402

import numpy as np   # noqa: E402

from repro.configs import get_config, SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.analysis import roofline_terms  # noqa: E402


def stack_knobs(cfg):
    """Returns (knob_names, full_counts, variant_cfg_fn).

    knobs = independent scanned-stack trip counts of this arch.
    variant_cfg_fn(counts) -> a config with those trip counts.
    """
    if cfg.family == "audio":
        full = (cfg.n_encoder_layers, cfg.n_layers)
        make = lambda c: cfg.replace(n_encoder_layers=c[0], n_layers=c[1])
        return ("enc", "dec"), full, make
    if cfg.moe is not None and cfg.moe.n_dense_layers:
        nd = cfg.moe.n_dense_layers
        full = (nd, cfg.n_layers - nd)
        make = lambda c: cfg.replace(
            n_layers=c[0] + c[1],
            moe=dataclasses.replace(cfg.moe, n_dense_layers=c[0]))
        return ("dense", "moe"), full, make
    if cfg.xlstm is not None:
        g = cfg.n_layers // cfg.xlstm.slstm_every
        full = (g,)
        make = lambda c: cfg.replace(n_layers=c[0] * cfg.xlstm.slstm_every)
        return ("super",), full, make
    if cfg.shared_attn_every:
        g = cfg.n_layers // cfg.shared_attn_every
        full = (g,)
        make = lambda c: cfg.replace(n_layers=c[0] * cfg.shared_attn_every)
        return ("super",), full, make
    full = (cfg.n_layers,)
    return ("layers",), full, lambda c: cfg.replace(n_layers=c[0])


def variant_points(n_knobs):
    """Probe points: all-ones plus one +1 per knob (k+1 lowers)."""
    pts = [tuple([1] * n_knobs)]
    for i in range(n_knobs):
        p = [1] * n_knobs
        p[i] = 2
        pts.append(tuple(p))
    return pts


def measure(cfg, shape, mesh):
    from repro.launch.dryrun import lower_cell
    from repro.models.analysis_flags import single_chunk
    with single_chunk():
        # prefill_chunks=1: lax.map is a while loop (counted once) — the
        # chunked production numbers are chunk-count x the per-chunk cost,
        # which equals the unchunked cost measured here.
        r = lower_cell(cfg.replace(unroll_stacks=True, prefill_chunks=1),
                       shape, mesh)
    return np.array([r["cost"]["hlo_flops"], r["cost"]["hlo_bytes"],
                     r["collective_bytes_total"]], dtype=np.float64)


def slstm_addon(cfg, shape, mesh_axes_prod) -> np.ndarray:
    """sLSTM's time scan is inherently sequential (cannot be single-chunked);
    its body is counted once instead of S times.  Analytic add-on for the
    missing (S-1) steps: per step/device ~ 16·B_loc·d² flops (W and R
    matmuls, fwd), x3 for train (bwd); bytes ~ weight reads 32·d²·4."""
    if cfg.xlstm is None or shape.is_decode:
        return np.zeros(3)
    g = cfg.n_layers // cfg.xlstm.slstm_every
    d = cfg.d_model
    b_loc = max(shape.global_batch // mesh_axes_prod, 1)
    s = shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    flops = g * (s - 1) * mult * 16.0 * b_loc * d * d
    bytes_ = g * (s - 1) * mult * (32.0 * d * d)
    return np.array([flops, bytes_, 0.0])


def correct_cell(path: Path, force: bool = False):
    d = json.loads(path.read_text())
    if "corrected" in d and not force:
        print(f"[skip] {path.name}")
        return
    cfg = get_config(d["arch"])
    shape = SHAPES[d["shape"]]
    mesh = make_production_mesh(multi_pod=(d["mesh"].count("x") == 2))
    knobs, full, make = stack_knobs(cfg)
    pts = variant_points(len(knobs))
    print(f"[correct] {path.name}: knobs={knobs} full={full} "
          f"probes={pts}", flush=True)
    ys = [measure(make(p), shape, mesh) for p in pts]
    base_pt = np.array(pts[0], np.float64)
    y0 = ys[0]
    per_layer = np.stack([ys[i + 1] - y0 for i in range(len(knobs))])  # [k,3]
    base = y0 - base_pt @ per_layer
    fullv = np.array(full, np.float64)
    corrected = base + fullv @ per_layer
    corrected = np.maximum(corrected, y0)      # monotone guard
    dp_total = 32 if d["mesh"].count("x") == 2 else 16
    corrected = corrected + slstm_addon(cfg, shape, dp_total)
    flops, hbm, coll = [float(v) for v in corrected]
    d["corrected"] = {
        "hlo_flops": flops, "hlo_bytes": hbm, "collective_bytes_total": coll,
        "per_layer": per_layer.tolist(), "base": base.tolist(),
        "knobs": list(knobs), "full": list(full),
        "roofline": roofline_terms(flops, hbm, coll, d["n_chips"]),
    }
    d["corrected"]["useful_flops_ratio"] = (
        d["model_flops"] / (flops * d["n_chips"]) if flops else 0.0)
    path.write_text(json.dumps(d, indent=1))
    r = d["corrected"]["roofline"]
    print(f"  corrected: compute={r['compute_s']:.3e}s "
          f"mem={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s "
          f"dom={r['dominant']} frac={r['roofline_fraction']*100:.1f}%",
          flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--only", default=None, help="substring filter")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    files = sorted(Path(args.dir).glob("*.json"))
    if args.only:
        files = [f for f in files if f.name.startswith(args.only)]
    for f in files:
        try:
            correct_cell(f, force=args.force)
        except Exception as e:  # noqa: BLE001
            print(f"  FAIL {f.name}: {e!r}", flush=True)


if __name__ == "__main__":
    main()
