"""Observed fleet run: the `launch/fleet.py` replay with the full
observability stack switched on — flight recorder, kernel profiler, and
the two per-run artifacts (`repro/obs/export.py`):

    PYTHONPATH=src python -m repro.launch.obs --requests 64 --report \\
        --chrome-trace trace.json --metrics metrics.json
    PYTHONPATH=src python -m repro.launch.obs --explain-dispatch
    PYTHONPATH=src python -m repro.launch.obs --smoke          # CI gate
    PYTHONPATH=src python -m repro.launch.obs --fleet --smoke  # CI gate

``--report`` prints the per-layer latency-breakdown table (queue /
compile / kernel / disk-tier, p50/p95/p99 from the bounded histograms);
``--chrome-trace`` writes the span timeline for ``chrome://tracing`` /
Perfetto; ``--explain-dispatch`` decodes the matcher dispatch cache
(winner, margin, loser timings per shape bucket) without running
anything; ``--smoke`` runs a short traced replay with a mid-trace
replica kill and exits non-zero unless the exported trace passes the
schema validator with spans from every serving layer, the re-admitted
requests' spans share their original trace id, and the flight recorder
dumped a ``replica_died`` artifact.

``--fleet --smoke`` is the telemetry-plane gate: a ``--proc`` fleet of
process replicas with a mid-replay ``kill -9``, asserting the
cross-process guarantees of `repro/obs/{ship,agg,slo}.py` — one
schema-valid *stitched* Chrome trace with spans from >=2 distinct
worker processes, admission-minted trace ids joining parent admit spans
to worker-side exec spans (including across the kill, via readmit),
merged ``difet.fleet.*`` histogram totals exactly equal to the summed
per-worker observation counts, and a forced SLO burn-rate breach taking
exactly one deduped flight-recorder dump.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace

# every serving layer a traced fleet replay must produce spans from
REQUIRED_LAYERS = ("router", "scheduler", "batch", "kernel", "cache")


def explain_dispatch() -> int:
    """Render the matcher dispatch cache (`kernels/dispatch.py::explain`)
    as a table: per shape bucket, the winning path, its margin over the
    runner-up, and every candidate's measured microseconds."""
    from repro.kernels import dispatch

    rows = dispatch.explain()
    print(f"dispatch cache: {dispatch.cache_path()}")
    if not rows:
        print("  (empty — no buckets measured yet; run a matcher "
              "workload or benchmarks/bench_matcher.py first)")
        return 0
    for key, row in rows.items():
        margin = row.get("margin")
        margin_s = f"{margin:.2f}x" if margin else "only candidate"
        print(f"  {key}")
        print(f"    winner: {row['path']} ({margin_s} over runner-up)  "
              f"backend={row['backend']} probe={row['probe']}")
        for cand, us in sorted(row["us"].items(), key=lambda kv: kv[1]):
            mark = "->" if cand == row["path"] else "  "
            print(f"     {mark} {cand:<16} {us:>10.1f} us")
    return 0


def observed_replay(args, dump_dir: str):
    """Run the `launch/fleet.py` replay with recorder + profiler
    installed; returns ``(fleet_stats, spans, flight_recorder,
    kernel_profile_snapshot)``."""
    from repro.launch import fleet as fleet_mod

    rec = obs_trace.FlightRecorder(capacity=args.ring, dump_dir=dump_dir)
    prof = obs_profile.KernelProfiler()
    prev_rec = obs_trace.set_recorder(rec)
    prev_prof = obs_profile.set_profiler(prof)
    try:
        # the recorder must be live BEFORE the fleet spawns: warm-up
        # compiles are the 'compile' layer's spans
        fleet = fleet_mod.build_fleet(args)
        tcfg = fleet_mod.trace_config(args)
        trace = fleet_mod.make_trace(tcfg)
        pool = fleet_mod.tile_pool(tcfg)
        with obs_profile.capture(args.profile_dir):
            wall, lat, sheds, readmitted, _accepted = fleet_mod.replay(
                fleet, trace, pool, kill_after=args.kill_after)
        stats = fleet_mod.report("obs", wall, lat, sheds, fleet)
        stats["readmitted_during_replay"] = readmitted
        spans = rec.spans()
        fleet.close()
        return stats, spans, rec, prof.snapshot()
    finally:
        obs_trace.set_recorder(prev_rec)
        obs_profile.set_profiler(prev_prof)


def smoke(args) -> int:
    """CI smoke: traced replay + chaos kill, then gate on (1) the
    exported Chrome trace passing the schema validator with >=1 span
    from every serving layer, (2) trace-id continuity across the kill
    (a ``readmit`` span sharing an admitted request's trace id), and
    (3) the flight recorder having dumped a ``replica_died`` artifact."""
    failures = []
    args.replicas = 2
    args.requests = max(32, min(args.requests, 48))
    args.kill_after = args.kill_after or args.requests // 2
    with tempfile.TemporaryDirectory(prefix="difet-obs-smoke-") as tmp:
        stats, spans, rec, prof = observed_replay(args, dump_dir=tmp)

        doc = obs_export.spans_to_chrome(spans)
        problems = obs_export.validate_chrome_trace(
            doc, required_layers=REQUIRED_LAYERS)
        failures += [f"chrome trace: {p}" for p in problems]

        readmits = [s for s in spans if s.name == "readmit"]
        if not readmits:
            failures.append("no readmit span after the chaos kill")
        admitted_tids = {s.trace_id for s in spans if s.name == "admit"}
        for s in readmits:
            if s.trace_id not in admitted_tids:
                failures.append(f"readmit span trace id {s.trace_id!r} "
                                f"matches no admitted request")
        dumps = rec.dumps
        if "replica_died" not in dumps:
            failures.append(f"flight recorder did not dump on the kill "
                            f"(dumps: {sorted(dumps)})")
        elif not os.path.exists(dumps["replica_died"]):
            failures.append("replica_died dump artifact missing on disk")

        # the metrics artifact must carry the layer breakdown the report
        # renders — queue + kernel at minimum saw traffic
        payload = obs_export.metrics_payload(
            extra={"kernel_profile": prof,
                   "fleet": {"readmitted": stats["readmitted"]}})
        stages = {r["stage"] for r in
                  obs_export.latency_breakdown(payload["metrics"])}
        for want in ("queue", "kernel"):
            if want not in stages:
                failures.append(f"breakdown table missing {want!r} stage "
                                f"(saw {sorted(stages)})")

    print(f"[obs-smoke] {len(spans)} spans, "
          f"layers={sorted({s.layer for s in spans})}, "
          f"readmit_spans={len(readmits)}")
    if failures:
        print("OBS SMOKE FAILED:", "; ".join(failures))
        return 1
    print("obs smoke ok")
    return 0


def fleet_smoke(args) -> int:
    """CI gate for the fleet telemetry plane (module docstring): a
    ``--proc`` fleet of >=2 process replicas, a mid-replay ``kill -9``
    detected via the stale lease, and a deliberately unmeetable SLO.
    Gates on:

    1. the *stitched* fleet Chrome trace (parent spans + every worker's
       shipped spans on one rebased timeline) passes the schema
       validator with spans from every serving layer and from >=2
       distinct worker processes;
    2. >=1 admission-minted trace id appears in both a parent ``admit``
       span and a worker-side ``exec`` span — and >=1 *readmitted*
       trace id re-executed worker-side, proving the id survived the
       kill across the process boundary;
    3. every merged ``difet.fleet.*`` histogram's total count equals
       the sum of the per-worker shipped observation counts (the merge
       is exact, not approximate);
    4. the forced SLO burn-rate breach alerts and takes exactly one
       deduped ``slo-burn-rate`` flight-recorder dump.
    """
    from repro.launch import fleet as fleet_mod
    from repro.obs import agg as obs_agg

    failures = []
    args.proc = True
    args.replicas = 2
    args.requests = max(24, min(args.requests, 32))
    args.kill_after = args.kill_after or args.requests // 2
    # tight lease so the kill -9 is declared inside the smoke window
    args.lease_ttl = min(args.lease_ttl, 1.0)
    # unmeetable SLO (1 microsecond p99): every served request burns
    # error budget, so the burn-rate monitor must alert
    args.slo_ms = 1e-3
    with tempfile.TemporaryDirectory(prefix="difet-fleet-tel-smoke-") as tmp:
        rec = obs_trace.FlightRecorder(capacity=args.ring, dump_dir=tmp)
        prev_rec = obs_trace.set_recorder(rec)
        try:
            fleet = fleet_mod.build_fleet(args)
            if fleet.telemetry is None or fleet.slo_monitor is None:
                print("FLEET TELEMETRY SMOKE FAILED: telemetry plane "
                      "not enabled on a --proc fleet")
                return 1
            tcfg = fleet_mod.trace_config(args)
            trace = fleet_mod.make_trace(tcfg)
            pool = fleet_mod.tile_pool(tcfg)
            wall, responses, sheds, readmitted, _accepted = fleet_mod.replay(
                fleet, trace, pool, kill_after=args.kill_after)
            # two monitor ticks against the microsecond SLO: the first
            # must alert + dump, the second must alert *without* a
            # second dump (dedup per reason)
            tick1 = fleet.slo_monitor.tick()
            tick2 = fleet.slo_monitor.tick()
            fleet_mod.report("fleet-telemetry-smoke", wall, responses,
                             sheds, fleet)
            fleet.close()    # drains workers -> final telemetry flushes
            fleet_mod.chaos_summary(fleet, sheds)
            agg = fleet.telemetry

            # (1) stitched cross-process trace
            stitched = agg.stitched_spans(rec.spans())
            doc = obs_export.spans_to_chrome(stitched)
            problems = obs_export.validate_chrome_trace(
                doc, required_layers=REQUIRED_LAYERS)
            failures += [f"stitched trace: {p}" for p in problems]
            worker_pids = ({s.pid for s in agg.spans}
                           - {0, os.getpid()})
            if len(worker_pids) < 2:
                failures.append(
                    f"stitched spans cover {len(worker_pids)} worker "
                    f"process(es), need >=2 (pids {sorted(worker_pids)})")

            # (2) trace-id continuity across the process boundary
            parent_spans = rec.spans()
            admit_tids = {s.trace_id for s in parent_spans
                          if s.name == "admit" and s.trace_id}
            exec_tids = {s.trace_id for s in agg.spans
                         if s.name == "exec" and s.trace_id}
            if not (admit_tids & exec_tids):
                failures.append("no trace id joins a parent admit span "
                                "to a worker-side exec span")
            readmit_tids = {s.trace_id for s in parent_spans
                            if s.name == "readmit" and s.trace_id}
            if not readmit_tids:
                failures.append("no readmit span after the chaos kill")
            elif not (readmit_tids & exec_tids):
                failures.append("no readmitted trace id re-executed "
                                "worker-side (kill survival unproven)")

            # (3) exact histogram merge: fleet totals == worker ledgers
            ledger = agg.fleet_counts()
            if not ledger:
                failures.append("no worker histograms were aggregated")
            if len(agg.worker_pids) < 2:
                failures.append(f"telemetry arrived from "
                                f"{len(agg.worker_pids)} worker(s), "
                                f"need >=2")
            reg_metrics = obs_metrics.registry().metrics()
            for name, total in sorted(ledger.items()):
                fleet_h = reg_metrics.get(obs_agg.fleet_metric_name(name))
                if fleet_h is None:
                    failures.append(f"no merged fleet histogram for "
                                    f"{name!r}")
                elif fleet_h.count != total:
                    failures.append(
                        f"fleet {name}: merged count {fleet_h.count} != "
                        f"summed per-worker counts {total}")

            # (4) forced burn-rate breach -> exactly one deduped dump
            if not tick1["alerting"]:
                failures.append(f"unmeetable SLO did not alert "
                                f"(burn_fast={tick1['burn_fast']:.2f}, "
                                f"burn_slow={tick1['burn_slow']:.2f})")
            if not tick1["dump"]:
                failures.append("first alerting tick took no "
                                "flight-recorder dump")
            if tick2["dump"]:
                failures.append("second alerting tick took a second "
                                "dump (per-reason dedup broken)")
            slo_dump = rec.dumps.get("slo-burn-rate")
            if not slo_dump:
                failures.append(f"no slo-burn-rate dump recorded "
                                f"(dumps: {sorted(rec.dumps)})")
            elif not os.path.exists(slo_dump):
                failures.append("slo-burn-rate dump artifact missing "
                                "on disk")

            print(f"[fleet-telemetry-smoke] {len(stitched)} stitched "
                  f"spans across pids {sorted(worker_pids)} + parent, "
                  f"{agg.ingested} shipments, "
                  f"{readmitted} re-admitted, "
                  f"burn_fast={tick1['burn_fast']:.1f}")
        finally:
            obs_trace.set_recorder(prev_rec)
    if failures:
        print("FLEET TELEMETRY SMOKE FAILED:", "; ".join(failures))
        return 1
    print("fleet telemetry smoke ok")
    return 0


def main(argv=None):
    """CLI: observed fleet replay (or ``--explain-dispatch`` /
    ``--smoke``); writes the requested artifacts and returns the fleet
    stats dict."""
    ap = argparse.ArgumentParser()
    # replay knobs (mirrors launch/fleet.py)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--max-replicas", type=int, default=4)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--arrival", choices=("uniform", "poisson", "burst"),
                    default="burst")
    ap.add_argument("--tile-size", type=int, default=32)
    ap.add_argument("--unique-scenes", type=int, default=16)
    ap.add_argument("--max-keypoints", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--delay-ms", type=float, default=2.0)
    ap.add_argument("--max-pending", type=int, default=256)
    ap.add_argument("--max-global-pending", type=int, default=1024)
    ap.add_argument("--spill-threshold", type=int, default=16)
    ap.add_argument("--tenant-rate", type=float, default=float("inf"))
    ap.add_argument("--tenant-burst", type=float, default=64.0)
    ap.add_argument("--cache-entries", type=int, default=1024)
    ap.add_argument("--cache-dir", default=None)
    ap.add_argument("--lease-ttl", type=float, default=5.0)
    ap.add_argument("--proc", action="store_true",
                    help="spawn replicas as OS processes (serve/proc.py)")
    ap.add_argument("--slo-ms", type=float, default=500.0,
                    help="p99 admission-to-completion SLO "
                         "(autoscaler + burn-rate monitor)")
    ap.add_argument("--kill-after", type=int, default=0,
                    help="chaos: kill one replica after N accepted requests")
    ap.add_argument("--seed", type=int, default=0)
    # observability surface
    ap.add_argument("--ring", type=int, default=8192,
                    help="flight-recorder span capacity")
    ap.add_argument("--chrome-trace", default=None, metavar="OUT.json",
                    help="write the span timeline as Chrome-trace JSON")
    ap.add_argument("--metrics", default=None, metavar="OUT.json",
                    help="write the flat metrics + kernel-profile JSON")
    ap.add_argument("--dump-dir", default=None,
                    help="flight-recorder crash/shed artifact directory")
    ap.add_argument("--profile-dir", default=None,
                    help="jax.profiler trace capture directory (optional)")
    ap.add_argument("--report", action="store_true",
                    help="print the per-layer latency-breakdown table")
    ap.add_argument("--explain-dispatch", action="store_true",
                    help="decode the matcher dispatch cache and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode: assertions + non-zero exit")
    ap.add_argument("--fleet", action="store_true",
                    help="with --smoke: the fleet telemetry-plane gate "
                         "(--proc replicas, stitched trace, SLO burn)")
    args = ap.parse_args(argv)

    if args.explain_dispatch:
        raise SystemExit(explain_dispatch())
    if args.fleet:
        if not args.smoke:
            ap.error("--fleet requires --smoke (telemetry-plane CI gate)")
        raise SystemExit(fleet_smoke(args))
    if args.smoke:
        raise SystemExit(smoke(args))

    dump_dir = args.dump_dir or tempfile.mkdtemp(prefix="difet-obs-dumps-")
    stats, spans, rec, prof = observed_replay(args, dump_dir=dump_dir)
    payload = obs_export.metrics_payload(extra={
        "kernel_profile": prof,
        "fleet": {k: stats[k] for k in ("submitted", "readmitted", "shed",
                                        "replica_count", "total_cache_hits",
                                        "total_cache_misses")}})
    if args.chrome_trace:
        obs_export.write_chrome_trace(args.chrome_trace, spans,
                                      metadata={"requests": args.requests})
        print(f"chrome trace -> {args.chrome_trace} ({len(spans)} spans)")
    if args.metrics:
        with open(args.metrics, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True, default=str)
        print(f"metrics -> {args.metrics}")
    if rec.dumps:
        for reason, path in sorted(rec.dumps.items()):
            print(f"flight-recorder dump [{reason}] -> {path}")
    if args.report:
        print(obs_export.render_report(payload))
    return stats


if __name__ == "__main__":
    main()
