"""DIFET driver: distributed feature extraction over a bundle store —
the paper's end-to-end workload (scenes → HIB-analogue bundles → map/
shuffle/reduce → per-algorithm results), with checkpointed restart.

    PYTHONPATH=src python -m repro.launch.extract --algorithm harris \
        --scenes 3 --scene-size 768 --store /tmp/difet_store
"""
from __future__ import annotations

import argparse
import time

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.core.bundle import BundleStore, bundle_scenes
from repro.core.engine import normalize_algorithms
from repro.core.job import DifetJob
from repro.data.landsat import synthetic_scene


def build_store(store_path, n_scenes, scene_hw, cfg, scenes_per_bundle=1,
                stream: bool = False, batch_tiles: int = 64):
    """Populate (or reopen) a BundleStore with synthetic scenes.

    ``stream=False`` materializes each scene in memory
    (`bundle_scenes`); ``stream=True`` writes the scene set band-striped
    to disk and cuts fixed-shape bundles through the streaming ingest
    pipeline (`data/pipeline.py`) — one bundle per ``batch_tiles`` tile
    batch, host memory bounded by the tiler's row window.
    """
    store = BundleStore(store_path)
    existing = store.list()
    if existing:
        return store
    if stream:
        from pathlib import Path
        from repro.data.landsat import BandSceneReader, \
            write_synthetic_scene_set
        from repro.data.pipeline import iter_tile_batches
        dirs = write_synthetic_scene_set(Path(store_path) / "scenes",
                                         n_scenes, *scene_hw)
        readers = [BandSceneReader(d) for d in dirs]
        for idx, bundle in iter_tile_batches(readers, cfg, batch_tiles):
            store.put(f"bundle_{idx:04d}", bundle)
        return store
    for i in range(0, n_scenes, scenes_per_bundle):
        scenes = [synthetic_scene(*scene_hw, seed=i + j)
                  for j in range(min(scenes_per_bundle, n_scenes - i))]
        store.put(f"bundle_{i:04d}", bundle_scenes(scenes, cfg))
    return store


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithm", default="harris",
                    choices=list(PAPER_ALGORITHMS))
    ap.add_argument("--algorithms", default=None,
                    help="comma-separated multi-algorithm mode "
                         "(e.g. fast,brief,orb): one pass through "
                         "extract_features_multi, algorithms sharing a "
                         "response map compute it once per tile")
    ap.add_argument("--scenes", type=int, default=3)
    ap.add_argument("--scene-size", type=int, default=768)
    ap.add_argument("--tile", type=int, default=256)
    ap.add_argument("--store", default="/tmp/difet_store")
    ap.add_argument("--stream", action="store_true",
                    help="build bundles through the streaming ingest "
                         "pipeline (band-striped scenes on disk, bounded "
                         "host memory) instead of in-memory scenes")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--fail-after", type=int, default=None,
                    help="simulate worker failure after N bundles")
    args = ap.parse_args(argv)

    # canonicalize: strip whitespace, drop repeats (first occurrence wins),
    # reject unknown names with the valid choices listed
    try:
        algorithm = ",".join(normalize_algorithms(args.algorithms
                                                  or args.algorithm))
    except ValueError as e:
        ap.error(str(e))
    cfg = DifetConfig(tile=args.tile, halo=24, max_keypoints_per_tile=256)
    store = build_store(args.store, args.scenes,
                        (args.scene_size, args.scene_size), cfg,
                        stream=args.stream)
    job = DifetJob(store, algorithm, use_pallas=args.use_pallas)
    print(f"[difet] {algorithm} over {len(store.list())} bundles "
          f"({args.scenes} scenes of {args.scene_size}^2, tile={args.tile})")
    t0 = time.time()
    try:
        summary = job.run(simulate_failure_after=args.fail_after,
                          progress=lambda n: print(f"  done {n}", flush=True))
    except RuntimeError as e:
        print(f"  !! {e} — restart with the same command to resume")
        raise SystemExit(2)
    dt = time.time() - t0
    if "per_algorithm" in summary:
        for alg, s in summary["per_algorithm"].items():
            print(f"  {alg}: {s['grand_total']} features")
    print(f"[done] {summary['bundles_done']}/{summary['bundles_total']} "
          f"bundles, {summary['grand_total']} features, {dt:.1f}s")
    return summary


if __name__ == "__main__":
    main()
