"""DIFET feature-extraction serving subsystem (DESIGN.md §8).

``FeatureService`` is the facade: request/response model in ``api.py``,
continuous-batching scheduler in ``scheduler.py``, shape buckets + the
per-(bucket, algorithm-set) compile cache in ``buckets.py``, and the
content-hash result caches (in-process LRU + shared disk tier) in
``cache.py``.  The fleet layer replicates the service: consistent-hash
router with admission control in ``router.py``, replica pool + lifecycle
+ SLO-driven autoscaling in ``fleet.py``, and the shared synthetic
trace generator in ``trace.py``.  Cross-process replicas live in
``proc.py`` (worker + parent-side client) over the spooled-file
transport in ``transport.py``; deterministic fault injection for both
tests and launch drivers in ``chaos.py``.  The LM-substrate decode
helpers live in ``serve/lm.py``.
"""
from repro.serve.api import (FeatureService, ServeConfig, ExtractResponse,  # noqa: F401
                             ResponseHandle, ServiceOverloaded, tile_digest,
                             config_digest, encode_tile, decode_tile)
from repro.serve.buckets import BucketTable, CompileCache, warmup  # noqa: F401
from repro.serve.cache import (ResultCache, DiskCacheTier,  # noqa: F401
                               TieredResultCache)
from repro.serve.chaos import ChaosPlan, cache_partition, sigkill, tear_file  # noqa: F401
from repro.serve.fleet import Fleet, FleetConfig  # noqa: F401
from repro.serve.proc import ProcReplicaClient, ProcHandle  # noqa: F401
from repro.serve.router import (Router, RouterConfig, Shed, FleetHandle,  # noqa: F401
                                HashRing, TokenBucket)
from repro.serve.scheduler import (BatchScheduler, WorkItem, ServiceClosed,  # noqa: F401
                                   ReplicaDied)
from repro.serve.trace import (TraceConfig, TraceEvent, make_trace,  # noqa: F401
                               tile_pool, scene_key)
from repro.serve.transport import WorkerMailbox  # noqa: F401
