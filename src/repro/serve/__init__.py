"""DIFET feature-extraction serving subsystem (DESIGN.md §8).

``FeatureService`` is the facade: request/response model in ``api.py``,
continuous-batching scheduler in ``scheduler.py``, shape buckets + the
per-(bucket, algorithm-set) compile cache in ``buckets.py``, and the
content-hash LRU result cache in ``cache.py``.  The LM-substrate decode
helpers live in ``serve/lm.py``.
"""
from repro.serve.api import (FeatureService, ServeConfig, ExtractResponse,  # noqa: F401
                             ResponseHandle, ServiceOverloaded, tile_digest,
                             config_digest, encode_tile, decode_tile)
from repro.serve.buckets import BucketTable, CompileCache, warmup  # noqa: F401
from repro.serve.cache import ResultCache  # noqa: F401
from repro.serve.scheduler import BatchScheduler, WorkItem  # noqa: F401
