from repro.serve.step import make_prefill_fn, make_decode_fn, greedy_generate  # noqa: F401
