"""Request/response model + facade for the DIFET feature service.

DIFET is feature extraction *as a service*: downstream consumers (the
companion stitching pipeline, arXiv:1808.08522; siftservice.com-style
online clients, arXiv:1504.02840) submit a tile — raw pixels, ``.npy``
bytes, or a registered scene id — plus an algorithm list, and get back
keypoints + descriptors + timing metadata.  ``FeatureService`` composes
the serving subsystem:

    submit(tile, algorithms)
      → normalize algorithms (`core/engine.py::normalize_algorithms`)
      → grayscale + bucket-pad (`serve/buckets.py`), or split oversize
        scenes into bucket tiles
      → per-(tile digest + grid position, algorithm, config digest)
        result-cache probe (`serve/cache.py`; position is in the key
        because results carry scene-global coordinates); fully-cached
        requests return without touching the device
      → misses coalesce with identical in-flight work, else enqueue on
        the continuous-batching scheduler (`serve/scheduler.py`)
      → the runner pads the batch into the bucket's fixed device shape
        and runs the (bucket, algorithm-set) program — compiled exactly
        once (`serve/buckets.py::CompileCache`) — through the engine's
        ``extract_request_features`` path (shared response maps, Pallas
        kernels under the VMEM gate)
      → results are frozen into the cache and the response assembled.

Served results are bit-identical to direct ``extract_features_multi``
calls on the same padded tile (engine batch-invariance; gated in
``benchmarks/bench_serve.py``), so caching and batching are pure
performance — never a numerics fork.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import json
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core.bundle import rgba_to_gray, tile_scene
from repro.core.engine import normalize_algorithms
from repro.core.job import DifetJob
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.buckets import BucketTable, CompileCache, warmup
from repro.serve.cache import ResultCache, TieredResultCache
from repro.serve.scheduler import (BatchScheduler, ServiceClosed,
                                   ServiceOverloaded, WorkItem)

__all__ = ["ServeConfig", "FeatureService", "ExtractResponse",
           "ResponseHandle", "ServiceClosed", "ServiceOverloaded",
           "tile_digest", "config_digest", "encode_tile", "decode_tile"]


# ---- wire helpers ----------------------------------------------------------

def encode_tile(arr: np.ndarray) -> bytes:
    """Serialize a tile to ``.npy`` bytes (the service's wire format)."""
    buf = io.BytesIO()
    np.save(buf, np.asarray(arr), allow_pickle=False)
    return buf.getvalue()


def decode_tile(data: bytes) -> np.ndarray:
    """Inverse of `encode_tile`: ``.npy`` bytes back to the tile array."""
    return np.load(io.BytesIO(data), allow_pickle=False)


def tile_digest(arr: np.ndarray) -> str:
    """Content hash of a tile: sha256 over dtype + shape + exact bytes."""
    a = np.ascontiguousarray(arr)
    h = hashlib.sha256()
    h.update(str(a.dtype).encode())
    h.update(np.asarray(a.shape, np.int64).tobytes())
    h.update(a.tobytes())
    return h.hexdigest()


def config_digest(cfg: DifetConfig, use_pallas: bool = False) -> str:
    """Digest of every extraction-relevant config field (+ backend flag):
    part of the cache key, so a config change is always a cache miss."""
    payload = json.dumps({**dataclasses.asdict(cfg),
                          "use_pallas": bool(use_pallas)}, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


# ---- request / response model ---------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service knobs.  ``base`` is the extraction config; its ``tile``
    field is replaced per shape bucket.  ``cache_dir`` (optional) backs
    the in-memory LRU with a shared on-disk tier
    (`serve/cache.py::TieredResultCache`) — fleet replicas pointing at the
    same directory warm each other."""
    base: DifetConfig = DifetConfig(tile=64, halo=16,
                                    max_keypoints_per_tile=128)
    buckets: Tuple[int, ...] = (32, 64, 128, 256)
    max_batch: int = 8
    max_batch_delay_s: float = 0.002      # latency/throughput knob
    max_pending: int = 1024               # backpressure knob
    cache_entries: int = 4096             # 0 disables the result cache
    cache_dir: Optional[str] = None       # shared disk tier (fleet mode)
    use_pallas: bool = False


@dataclasses.dataclass
class ExtractResponse:
    """What a client gets back: per-algorithm features + timing metadata.

    ``results[alg]`` holds the per-request reduced features
    (``total_count``, ``top_ys/top_xs/top_scores/top_valid``,
    ``top_desc`` for descriptor algorithms, …) as read-only numpy arrays;
    multi-tile scene requests are merged across their tiles with the same
    reduce the batch job uses (`core/job.py::DifetJob._merge`)."""
    request_id: str
    algorithms: Tuple[str, ...]
    results: Dict[str, Dict[str, np.ndarray]]
    n_tiles: int
    bucket: int
    cached: Dict[str, float]       # per algorithm: fraction of tiles cached
    timing: Dict[str, object]      # enqueued_at/completed_at/latency_s/...

    @property
    def fully_cached(self) -> bool:
        """True iff every (tile, algorithm) of this request was served
        from the result cache — the device was never touched."""
        return all(v >= 1.0 for v in self.cached.values())


class _TilePart:
    """One bucket tile of a request: cached per-algorithm results plus an
    optional future for the algorithms that still need the device."""

    def __init__(self, cached: Dict[str, Dict[str, np.ndarray]],
                 missing: Tuple[str, ...], future):
        self.cached = cached
        self.missing = missing
        self.future = future


class ResponseHandle:
    """Deferred response: ``result()`` blocks until every tile of the
    request has been served, then assembles the :class:`ExtractResponse`."""

    def __init__(self, request_id: str,
                 algorithms: Tuple[str, ...], parts: List[_TilePart],
                 bucket: int, enqueued_at: float):
        self.request_id = request_id
        self.algorithms = algorithms
        self._parts = parts
        self._bucket = bucket
        self._enqueued_at = enqueued_at

    def done(self) -> bool:
        """Non-blocking readiness probe: True once every tile of the
        request has a result (``result()`` will not block)."""
        return all(p.future is None or p.future.done() for p in self._parts)

    def result(self, timeout: Optional[float] = None) -> ExtractResponse:
        """Assemble the response; ``timeout`` is a total deadline across
        every tile of the request, not per tile.

        ``timing["completed_at"]`` is when the request's *work* finished —
        the latest device-batch completion stamp across its tiles (a
        fully-cached request completes at submit time) — NOT when
        ``result()`` happened to be called.  An open-loop client that
        drains handles in submit order therefore measures true service
        latency, not its own drain position (``latency_s`` used to be
        inflated by exactly that drain wait)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        per_tile: List[Dict[str, Dict[str, np.ndarray]]] = []
        batch_sizes: List[int] = []
        completed_at = self._enqueued_at       # fully-cached: no device wait
        for p in self._parts:
            if p.future is None:
                per_tile.append(dict(p.cached))
                continue
            rem = None if deadline is None else deadline - time.monotonic()
            computed, batch_size, part_done = p.future.result(rem)
            batch_sizes.append(batch_size)
            completed_at = max(completed_at, part_done)
            if not p.cached:
                per_tile.append(computed)
                continue
            tile_res = dict(p.cached)
            for alg in p.missing:
                tile_res[alg] = computed[alg]
            per_tile.append(tile_res)
        if len(per_tile) == 1:
            results = {alg: per_tile[0][alg] for alg in self.algorithms}
        else:
            results = {alg: DifetJob._merge([t[alg] for t in per_tile])
                       for alg in self.algorithms}
        cached = {alg: sum(1.0 for p in self._parts if alg not in p.missing)
                  / len(self._parts) for alg in self.algorithms}
        return ExtractResponse(
            request_id=self.request_id, algorithms=self.algorithms,
            results=results, n_tiles=len(self._parts), bucket=self._bucket,
            cached=cached,
            timing={"enqueued_at": self._enqueued_at,
                    "completed_at": completed_at,
                    "latency_s": completed_at - self._enqueued_at,
                    "batch_sizes": tuple(batch_sizes)})


# ---- the service -----------------------------------------------------------

class FeatureService:
    """In-process DIFET feature-extraction service (the unit a fleet of
    workers would replicate behind a load balancer)."""

    def __init__(self, cfg: Optional[ServeConfig] = None, *,
                 name: str = "difet-serve",
                 step_lock: Optional[threading.Lock] = None):
        self.cfg = cfg or ServeConfig()
        self.name = name
        self.table = BucketTable(self.cfg.buckets, self.cfg.base)
        self.compile_cache = CompileCache(self.table, self.cfg.max_batch,
                                          self.cfg.use_pallas)
        if self.cfg.cache_dir:
            self.cache = TieredResultCache(self.cfg.cache_entries,
                                           self.cfg.cache_dir)
        else:
            self.cache = ResultCache(self.cfg.cache_entries)
        # benchmark hook: a lock shared across replicas serializes device
        # steps, so per-replica ``busy_s`` is uncontended wall time and a
        # fleet makespan on a shared CI host is the straggler's busy time
        # (the table1 simulated-worker idiom) — None in production
        self._step_lock = step_lock
        self.busy_s = 0.0                 # runner-thread-only accumulator
        self.steps = 0
        # process-wide per-layer histograms (obs/export.py breakdown
        # table aggregates across replicas); handles cached here so the
        # runner's per-item path is one bounded observe, no registry lock
        _reg = obs_metrics.registry()
        self._m_queue_s = _reg.histogram("difet.scheduler.queue_s")
        self._m_step_s = _reg.histogram("difet.kernel.step_s")
        self.requests = 0                 # accepted submit() calls
        self.shed = 0                     # submit() calls shed on overload
        self.scheduler = BatchScheduler(
            self._run_batch, max_batch=self.cfg.max_batch,
            max_batch_delay_s=self.cfg.max_batch_delay_s,
            max_pending=self.cfg.max_pending, name=name)
        self._lock = threading.Lock()
        self._inflight: Dict[tuple, object] = {}
        self._canvases: Dict[int, tuple] = {}
        self._cfg_digests: Dict[int, str] = {}
        self._scenes: Dict[str, np.ndarray] = {}
        self._req_counter = 0

    # -- config/scene plumbing ----------------------------------------------
    def _cfg_digest(self, bucket: int) -> str:
        if bucket not in self._cfg_digests:
            self._cfg_digests[bucket] = config_digest(
                self.table.cfg_for(bucket), self.cfg.use_pallas)
        return self._cfg_digests[bucket]

    def register_scene(self, name: str, image: np.ndarray) -> None:
        """Make ``submit(name, ...)`` work by scene id."""
        self._scenes[name] = np.asarray(image)

    def _resolve(self, image) -> np.ndarray:
        if isinstance(image, str):
            if image not in self._scenes:
                raise KeyError(f"unknown scene id {image!r} "
                               f"(registered: {sorted(self._scenes)})")
            image = self._scenes[image]
        elif isinstance(image, (bytes, bytearray)):
            image = decode_tile(bytes(image))
        arr = np.asarray(image)
        if arr.ndim == 3:
            return rgba_to_gray(arr)
        if arr.dtype == np.uint8:
            return arr.astype(np.float32) / 255.0
        return np.asarray(arr, np.float32)      # no copy when already f32

    # -- submission ----------------------------------------------------------
    def submit(self, image: Union[np.ndarray, bytes, str], algorithms,
               request_id: Optional[str] = None,
               block: bool = False,
               trace_id: Optional[str] = None) -> ResponseHandle:
        """Enqueue one request.  ``image`` is a grayscale/RGBA array,
        ``.npy`` bytes, or a registered scene id; oversize images are split
        into largest-bucket tiles and merged on completion.  Raises
        :class:`ServiceOverloaded` when the queue is full (``block=True``
        waits instead).  ``trace_id`` ties the request's spans to a
        router-minted trace (`obs/trace.py`); direct callers get one
        minted here when tracing is on."""
        tracing = obs_trace.enabled()
        tid = trace_id or (obs_trace.new_trace_id() if tracing else "")
        algs = normalize_algorithms(algorithms)
        # device/group/coalescing keys use the sorted set (per-algorithm
        # results are order-independent), so permuted algorithm lists share
        # one compiled program, one batch group, and one in-flight entry;
        # the response keeps the request's order
        canonical = tuple(sorted(algs))
        gray = self._resolve(image)
        enqueued_at = time.time()
        with self._lock:
            self._req_counter += 1
            rid = request_id or f"req-{self._req_counter:06d}"
        bucket = self.table.bucket_for(*gray.shape)
        if bucket is None:                      # oversize → multi-tile scene
            bucket = self.table.interiors[-1]
            b = tile_scene(gray, self.table.cfg_for(bucket))
            tiles = [(b.tiles[i], b.headers[i]) for i in range(len(b))]
        else:
            tiles = [self.table.pad_to_bucket(gray, bucket)]
        cfg_dig = self._cfg_digest(bucket)
        # NOTE: a multi-tile submit hitting backpressure mid-loop raises
        # with its earlier tiles already queued; they complete into the
        # result cache, so a retry reuses rather than recomputes them
        try:
            # the ambient trace id lets un-threaded layers underneath
            # (the cache tiers' disk I/O) tag their spans with this
            # request's trace (obs/trace.py contextvar)
            with obs_trace.use_trace(tid):
                parts = [self._submit_tile(tile, header, bucket, canonical,
                                           cfg_dig, block, tid)
                         for tile, header in tiles]
        except ServiceOverloaded:
            with self._lock:
                self.shed += 1
            raise
        with self._lock:
            self.requests += 1
        return ResponseHandle(rid, algs, parts, bucket, enqueued_at)

    def _submit_tile(self, tile, header, bucket, algs, cfg_dig,
                     block, trace_id="") -> _TilePart:
        if self.cache.capacity <= 0:
            # cache disabled: digest/probe/in-flight coalescing can't pay
            # for themselves — straight to the queue (zero-copy responses)
            fut = self.scheduler.submit(tile, header, bucket, algs,
                                        block=block, trace_id=trace_id)
            return _TilePart({}, algs, fut)
        # the key must fold the header's grid position + valid extent:
        # results carry scene-GLOBAL coordinates (ys = ty*tile + ...), so
        # two pixel-identical tiles at different (ty, tx) — e.g. a
        # recurring granule in an oversize scene split — have different
        # correct outputs and must never alias (scene_id itself doesn't
        # enter the compute, so it stays out of the key)
        digest = (tile_digest(tile)
                  + ":" + ",".join(str(int(v)) for v in header[1:]))
        cached = {}
        for alg in algs:
            hit = self.cache.get((digest, alg, cfg_dig))
            if hit is not None:
                cached[alg] = hit
        missing = tuple(a for a in algs if a not in cached)
        if not missing:
            return _TilePart(cached, (), None)
        # coalesce concurrent identical work before queueing new work.
        # scheduler.submit may BLOCK on backpressure, so it must run
        # outside the service lock — a stalled submitter must not wedge
        # every other request.  The tiny race window (two threads both
        # missing the in-flight map) only duplicates work, never corrupts.
        with self._lock:
            fut = self._inflight.get(key := (digest, missing, cfg_dig,
                                             bucket))
        if fut is None:
            fut = self.scheduler.submit(tile, header, bucket, missing,
                                        digest=digest,
                                        cfg_digest=cfg_dig, block=block,
                                        trace_id=trace_id)
            with self._lock:
                if key not in self._inflight:
                    self._inflight[key] = fut
                    fut.add_done_callback(
                        lambda _f, k=key: self._inflight.pop(k, None))
        return _TilePart(cached, missing, fut)

    def extract(self, image, algorithms, timeout: Optional[float] = None,
                block: bool = True) -> ExtractResponse:
        """Synchronous convenience: submit + wait."""
        return self.submit(image, algorithms, block=block).result(timeout)

    # -- device step ---------------------------------------------------------
    def _run_batch(self, bucket: int, algorithms: Tuple[str, ...],
                   items: Sequence[WorkItem]) -> None:
        """Scheduler runner: scatter items into the bucket's fixed-shape
        batch (padded rows carry the pad flag), run the compiled program,
        freeze + cache per-item results, resolve futures."""
        if self._step_lock is not None:
            with self._step_lock:
                return self._run_batch_locked(bucket, algorithms, items)
        return self._run_batch_locked(bucket, algorithms, items)

    def _run_batch_locked(self, bucket, algorithms, items) -> None:
        t_start = time.monotonic()
        tracing = obs_trace.enabled()
        if tracing:
            # queue-wait spans: enqueue → batch formation, one per item,
            # carrying the item's trace id (stamps already taken — no
            # extra clock reads on the untraced path)
            for it in items:
                obs_trace.emit_span("queue_wait", "scheduler",
                                    it.enqueued_at, t_start,
                                    trace_id=it.trace_id,
                                    replica=self.name, bucket=bucket)
        # per-bucket scratch canvas, reused across steps (runner thread is
        # the only writer).  Rows beyond the batch keep stale-but-finite
        # tile data; their headers are re-marked pad, so the engine masks
        # them out — only the zeroing is skipped.
        canvas = self._canvases.get(bucket)
        if canvas is None:
            canvas = self._canvases[bucket] = \
                self.compile_cache.empty_batch(bucket)
        tiles, headers = canvas
        headers[:, :] = 0
        headers[:, 5] = 1
        for i, it in enumerate(items):
            tiles[i] = it.tile
            headers[i] = it.header
        fn = self.compile_cache.get(bucket, algorithms)
        t_kernel = time.monotonic()
        out = jax.device_get(fn(tiles, headers))   # one host transfer
        t_kernel_done = time.monotonic()
        self._m_step_s.observe(t_kernel_done - t_kernel)
        batch_span = None
        if tracing:
            batch_span = obs_trace.emit_span(
                "device_step", "kernel", t_kernel, t_kernel_done,
                trace_id="", replica=self.name, bucket=bucket,
                batch_size=len(items), algorithms=",".join(algorithms))
        for res in out.values():
            for v in res.values():
                v.setflags(write=False)            # responses are read-only
        caching = self.cache.capacity > 0
        # service-time stamp: the device step for this batch is done NOW.
        # It rides in the future payload so ResponseHandle can report the
        # completion time of the work itself — result() may be called
        # arbitrarily late (an open-loop client draining handles in submit
        # order), and stamping at assembly would bill that drain wait as
        # service latency.
        completed_at = time.time()
        now_mono = time.monotonic()
        for i, it in enumerate(items):
            it.completed_at = completed_at
            dt = now_mono - it.enqueued_at
            self.scheduler.queue_hist.observe(dt)
            self._m_queue_s.observe(dt)
            res = {}
            # ambient trace for the cache tiers' disk-write spans
            with obs_trace.use_trace(it.trace_id):
                for alg in algorithms:
                    sliced = {k: v[i] for k, v in out[alg].items()}
                    if caching:
                        # freeze = an owned copy, so a cache entry never
                        # pins the whole batch buffer it was sliced from
                        sliced = self.cache.put(
                            (it.digest, alg, it.cfg_digest), sliced)
                    res[alg] = sliced
            if tracing:
                obs_trace.emit_span("exec", "batch", t_kernel, now_mono,
                                    trace_id=it.trace_id,
                                    parent_id=batch_span or "",
                                    replica=self.name, bucket=bucket,
                                    batch_size=len(items))
            # first-wins settle: a concurrent kill() may have failed this
            # item already (serve/scheduler.py::WorkItem.resolve)
            it.resolve((res, it.batch_size, completed_at))
        self.busy_s += time.monotonic() - t_start
        self.steps += 1

    # -- ops -----------------------------------------------------------------
    def warmup(self, algorithm_sets: Sequence,
               buckets: Optional[Sequence[int]] = None) -> int:
        """Pre-compile every (bucket, algorithm-set) pair (see
        `serve/buckets.py::warmup`).  Call before taking traffic."""
        sets = [tuple(sorted(normalize_algorithms(a)))
                for a in algorithm_sets]
        return warmup(self.compile_cache, sets, buckets)

    def stats(self) -> Dict[str, object]:
        """Operational counters, cheap enough for an autoscaler to poll:
        nested result-cache / scheduler detail plus a flat per-replica
        snapshot (``submitted``/``shed`` requests, cache hit/miss, batch
        occupancy, p50/p99 queue latency, device busy seconds) that
        `serve/router.py::Router.stats` aggregates across the fleet."""
        sched = self.scheduler.stats()
        cache = self.cache.stats()
        return {"cache": cache,
                "scheduler": sched,
                "programs": self.compile_cache.programs,
                "program_keys": self.compile_cache.keys(),
                # flat per-replica counters (the fleet aggregation surface)
                "name": self.name,
                "submitted": self.requests,
                "shed": self.shed,
                "cache_hits": cache["hits"],
                "cache_misses": cache["misses"],
                "queue_depth": sched["queue_depth"],
                "batches": sched["batches"],
                "batch_occupancy": sched["occupancy"],
                "p50_queue_ms": sched["p50_queue_ms"],
                "p99_queue_ms": sched["p99_queue_ms"],
                "busy_s": self.busy_s,
                "steps": self.steps}

    def drain(self, timeout: Optional[float] = 30.0) -> None:
        """Stop accepting work and process everything already queued:
        new ``submit`` calls raise :class:`ServiceClosed`, every accepted
        item's future resolves (zero dropped responses), then the runner
        thread exits.  The drain half of the fleet's drain → retire
        lifecycle (`serve/fleet.py`)."""
        self.scheduler.stop(timeout)

    def kill(self, exc: Optional[BaseException] = None) -> None:
        """Chaos hook: crash the replica *without* draining — queued and
        on-device items fail with :class:`serve.scheduler.ReplicaDied` so
        a router can re-admit them (`serve/router.py::Router`)."""
        self.scheduler.kill(exc)

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Drain and stop the scheduler runner thread (idempotent);
        pending futures resolve before shutdown or time out."""
        self.scheduler.stop(timeout)
