"""Fault-injection primitives for the cross-process fleet.

The chaos surface the ROADMAP asks for — kill -9 a replica process,
partition the cache directory, tear an ``.npz`` mid-write, stall a
heartbeat — lives here as a small library that both the test harness
(`tests/chaos.py` → `tests/test_proc_fleet.py`) and the launch driver
(`launch/fleet.py --kill-after`) drive, so a fault exercised in CI is
the *same code path* a human reproduces from the command line.

Two delivery channels:

* **In-band plans** (:class:`ChaosPlan`): a JSON file dropped into a
  worker's mailbox directory.  The `serve/proc.py` worker re-reads it
  every loop iteration, so a test can make a *live* worker stop
  heartbeating (stale-lease detection with the process still running),
  sit on finished responses (keeping work outstanding across a kill),
  or ``os._exit(137)`` itself after serving N requests (a self-inflicted
  ``kill -9`` at a deterministic point in the request stream).
* **Out-of-band faults**: :func:`sigkill` (real ``SIGKILL``, no atexit,
  no cleanup), :func:`cache_partition` (make the shared cache dir
  unreachable for a block), :func:`tear_file` (truncate a committed
  file to simulate a torn write that somehow became visible).

Everything here is deterministic — no random fault schedules; tests
choose the exact span at which a fault lands.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from contextlib import contextmanager
from pathlib import Path
from typing import Optional

__all__ = ["ChaosPlan", "write_plan", "read_plan", "clear_plan",
           "sigkill", "cache_partition", "tear_file"]

PLAN_FILE = "chaos.json"


@dataclasses.dataclass
class ChaosPlan:
    """One worker's fault-injection plan (all faults off by default).

    ``heartbeat_stall_s``: skip lease heartbeats for this many seconds
    after the plan lands (the worker otherwise runs normally — this is
    how tests exercise stale-lease detection on a *live* process).
    ``hold_responses_s``: finish work but withhold the response files
    for this many seconds (keeps requests outstanding at a chosen span,
    e.g. across a concurrent ``kill -9``).
    ``exit_after_requests``: ``os._exit(137)`` immediately after the
    N-th response is written — a deterministic self-``kill -9`` leaving
    claimed-but-unanswered requests behind.
    ``plan_time`` is stamped by `read_plan` from the file's mtime; the
    stall windows are measured from it.
    """
    heartbeat_stall_s: float = 0.0
    hold_responses_s: float = 0.0
    exit_after_requests: int = 0
    plan_time: float = 0.0

    def heartbeat_stalled(self, now: Optional[float] = None) -> bool:
        """Is the heartbeat stall window active at ``now``?"""
        if self.heartbeat_stall_s <= 0:
            return False
        now = time.time() if now is None else now
        return now - self.plan_time < self.heartbeat_stall_s

    def responses_held(self, now: Optional[float] = None) -> bool:
        """Is the response-withholding window active at ``now``?"""
        if self.hold_responses_s <= 0:
            return False
        now = time.time() if now is None else now
        return now - self.plan_time < self.hold_responses_s


def write_plan(mailbox_root, plan: ChaosPlan) -> None:
    """Drop ``plan`` into a worker's mailbox (atomic rename, so the
    worker never reads a torn plan)."""
    root = Path(mailbox_root)
    root.mkdir(parents=True, exist_ok=True)
    fields = {k: v for k, v in dataclasses.asdict(plan).items()
              if k != "plan_time"}
    tmp = root / f".{PLAN_FILE}.tmp.{os.getpid()}"
    tmp.write_text(json.dumps(fields))
    tmp.replace(root / PLAN_FILE)


def read_plan(mailbox_root) -> ChaosPlan:
    """The active plan for a mailbox (an all-off plan when absent or
    unreadable — chaos must never take a worker down by accident)."""
    path = Path(mailbox_root) / PLAN_FILE
    try:
        raw = json.loads(path.read_text())
        mtime = path.stat().st_mtime
    except (OSError, ValueError):
        return ChaosPlan()
    known = {f.name for f in dataclasses.fields(ChaosPlan)}
    fields = {k: v for k, v in raw.items() if k in known and k != "plan_time"}
    return ChaosPlan(plan_time=mtime, **fields)


def clear_plan(mailbox_root) -> None:
    """Remove any active plan (faults off)."""
    try:
        (Path(mailbox_root) / PLAN_FILE).unlink()
    except OSError:
        pass


def sigkill(pid: int) -> None:
    """``kill -9`` — no Python-level cleanup, no atexit, no flush.  The
    process gets no chance to release leases or finish writes; already
    dead is fine."""
    try:
        os.kill(pid, signal.SIGKILL)
    except ProcessLookupError:
        pass


@contextmanager
def cache_partition(path):
    """Make a directory unreachable for the block's duration — the
    "partitioned cache directory" fault.  Replicas must degrade to
    recomputing (disk tier counts errors/misses) rather than crash.

    Implementation note: the directory is moved aside and replaced by a
    plain *file*, so every mkdir/write/read beneath it fails with an
    ``OSError`` — unlike a chmod-000 fault, this holds even when tests
    run as root (root bypasses permission bits entirely)."""
    p = Path(path)
    p.mkdir(parents=True, exist_ok=True)
    moved = p.with_name(p.name + ".partitioned")
    p.rename(moved)
    p.touch()
    try:
        yield p
    finally:
        p.unlink()
        moved.rename(p)


def tear_file(path, keep: int = 64) -> Path:
    """Truncate a committed file to its first ``keep`` bytes in place —
    the "torn write became visible" fault (e.g. a non-atomic writer or
    a filesystem that lied about rename durability).  Readers must treat
    the result as absent/corrupt, never as data."""
    p = Path(path)
    data = p.read_bytes()[:keep]
    p.write_bytes(data)
    return p
