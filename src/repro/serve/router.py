"""Fleet front-end: consistent-hash affinity routing, admission control,
per-tenant rate limits, and crash re-admission.

One :class:`Router` fronts N `serve/api.py::FeatureService` replicas (the
pool is managed by `serve/fleet.py::Fleet`; the router only needs a
name → replica map).  A request flows:

    submit(image, algorithms, tenant, scene_key)
      → admission control: per-tenant token bucket, then the bounded
        *global* queue (sum of replica queue depths) — violations raise a
        typed :class:`Shed` (reason + retry-after) instead of a raw
        ``ServiceOverloaded``, so clients can tell "slow down" from
        "you specifically are over quota"
      → routing: consistent-hash on the scene/content key picks the
        *affinity* replica — repeats of a hot scene land on the replica
        whose result cache and batch groups already hold it; when that
        replica's queue is deep (hot-scene hotspot) the router spills to
        the least-pending replica instead (affinity is a cache
        optimization, never a correctness constraint — extraction is
        deterministic, so any replica computes the same bits)
      → the request is registered in the outstanding table, submitted to
        the replica, and a :class:`FleetHandle` returned.

Crash handling: when a replica dies (`Fleet.kill_replica`, or a stale
liveness lease), every outstanding request routed to it is *re-admitted*
— re-submitted to a surviving replica, bypassing admission (it was
already accepted; accepted work is never shed).  The dead replica's
futures carry `serve/scheduler.py::ReplicaDied`; `FleetHandle.result`
swallows that and waits for the re-dispatch, so callers just see the
request complete — bit-identically, because extraction is deterministic
and the result cache keys on content.  Both halves of the race (batch
completed vs kill won) deliver the same bits.
"""
from __future__ import annotations

import bisect
import dataclasses
import hashlib
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serve.api import ExtractResponse, FeatureService
from repro.serve.scheduler import (ReplicaDied, ServiceClosed,
                                   ServiceOverloaded)

__all__ = ["RouterConfig", "Router", "FleetHandle", "Shed", "TokenBucket",
           "HashRing", "SHED_TENANT_THROTTLED", "SHED_FLEET_SATURATED",
           "SHED_NO_REPLICA", "SHED_CLOSED"]

# typed shed reasons (the admission/shed policy table in docs/fleet.md)
SHED_TENANT_THROTTLED = "tenant_throttled"   # this tenant is over quota
SHED_FLEET_SATURATED = "fleet_saturated"     # global queue bound hit
SHED_NO_REPLICA = "no_ready_replica"         # pool empty / all draining
SHED_CLOSED = "closed"                       # router shut down


class Shed(ServiceOverloaded):
    """Typed load-shed response.  Subclasses ``ServiceOverloaded`` so
    single-service callers keep working, but carries *why* the request
    was shed (``reason``), *who* was shedding (``tenant`` for quota
    sheds) and a ``retry_after_s`` hint."""

    def __init__(self, reason: str, detail: str = "",
                 tenant: Optional[str] = None,
                 retry_after_s: float = 0.0):
        super().__init__(detail or reason)
        self.reason = reason
        self.tenant = tenant
        self.retry_after_s = retry_after_s


class TokenBucket:
    """Thread-safe token bucket: ``rate`` tokens/s refill up to ``burst``
    capacity; ``take()`` spends one.  ``rate=inf`` never throttles."""

    def __init__(self, rate: float, burst: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = self.burst
        self._last = time.monotonic()
        self._lock = threading.Lock()

    def take(self) -> Tuple[bool, float]:
        """Try to spend one token.  Returns ``(ok, retry_after_s)`` —
        on refusal, how long until one token refills."""
        if self.rate == float("inf"):
            return True, 0.0
        with self._lock:
            now = time.monotonic()
            self._tokens = min(self.burst,
                               self._tokens + (now - self._last) * self.rate)
            self._last = now
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return True, 0.0
            return False, (1.0 - self._tokens) / max(self.rate, 1e-9)


def _hash64(s: str) -> int:
    return int.from_bytes(hashlib.sha256(s.encode()).digest()[:8], "big")


class HashRing:
    """Consistent-hash ring with virtual nodes.  Adding/removing one
    replica only remaps the keys that hashed to it — every other key
    keeps its replica (and therefore its warm caches), which is the whole
    point of consistent hashing for cache-affinity routing (tested)."""

    def __init__(self, vnodes: int = 64):
        self.vnodes = int(vnodes)
        self._ring: List[Tuple[int, str]] = []   # sorted (position, name)
        self._names: set = set()

    def add(self, name: str) -> None:
        """Insert ``vnodes`` virtual nodes for a replica (idempotent)."""
        if name in self._names:
            return
        self._names.add(name)
        for v in range(self.vnodes):
            bisect.insort(self._ring, (_hash64(f"{name}#{v}"), name))

    def remove(self, name: str) -> None:
        """Drop a replica's virtual nodes (idempotent)."""
        if name not in self._names:
            return
        self._names.discard(name)
        self._ring = [(p, n) for p, n in self._ring if n != name]

    @property
    def names(self) -> Tuple[str, ...]:
        """Replica names currently on the ring, sorted."""
        return tuple(sorted(self._names))

    def lookup(self, key: str) -> Optional[str]:
        """The replica owning ``key`` (first vnode clockwise), or None on
        an empty ring."""
        if not self._ring:
            return None
        i = bisect.bisect_left(self._ring, (_hash64(key), ""))
        if i == len(self._ring):
            i = 0
        return self._ring[i][1]


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Admission + routing knobs.

    ``max_global_pending`` bounds the *fleet-wide* queue (sum of replica
    queue depths) — beyond it requests shed with
    :data:`SHED_FLEET_SATURATED`.  ``spill_queue_threshold`` is the
    affinity replica's queue depth beyond which the router abandons
    affinity for the least-pending replica (hot-scene hotspot relief).
    ``tenant_rate``/``tenant_burst`` are the default per-tenant token
    bucket (``inf`` = unthrottled); ``tenant_limits`` overrides specific
    tenants with ``{tenant: (rate, burst)}``."""
    max_global_pending: int = 4096
    spill_queue_threshold: int = 16
    vnodes: int = 64
    tenant_rate: float = float("inf")
    tenant_burst: float = 64.0
    tenant_limits: Optional[Dict[str, Tuple[float, float]]] = None


class _Slot:
    """Router-side view of one replica: the service + whether the router
    may send it new work (False while draining)."""

    def __init__(self, service: FeatureService):
        self.service = service
        self.accepting = True


class _FleetRequest:
    """Outstanding-table entry: enough payload to re-admit the request if
    its replica dies, plus the live inner handle + a generation counter
    bumped on every re-dispatch."""

    def __init__(self, rid: str, image, algorithms, tenant: str,
                 route_key: str, replica: str, handle,
                 trace_id: str = "", admitted_at: float = 0.0):
        self.rid = rid
        self.image = image
        self.algorithms = algorithms
        self.tenant = tenant
        self.route_key = route_key
        self.replica = replica
        self.handle = handle
        self.trace_id = trace_id
        self.admitted_at = admitted_at   # wall clock at admission (SLO base)
        self.latency_observed = False    # each request counts once
        self.generation = 0
        self.error: Optional[BaseException] = None


class FleetHandle:
    """Deferred fleet response.  ``result()`` delegates to the current
    replica-level handle; if that replica died mid-flight it waits for
    the router's re-admission (generation bump) and retries — the caller
    never sees :class:`ReplicaDied`."""

    def __init__(self, router: "Router", req: _FleetRequest):
        self._router = router
        self._req = req

    @property
    def request_id(self) -> str:
        """The fleet-assigned request id (stable across re-admissions)."""
        return self._req.rid

    def done(self) -> bool:
        """Non-blocking readiness probe (False while a re-admitted
        request is still recomputing)."""
        with self._router._cv:
            if self._req.error is not None:
                return True
            return self._req.handle.done()

    def result(self, timeout: Optional[float] = None) -> ExtractResponse:
        """Wait for the request across any number of re-admissions."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._router._cv:
                if self._req.error is not None:
                    raise self._req.error
                gen, inner = self._req.generation, self._req.handle
            rem = None if deadline is None else deadline - time.monotonic()
            if rem is not None and rem <= 0:
                raise TimeoutError(
                    f"request {self._req.rid} timed out")
            try:
                resp = inner.result(rem)
            except ReplicaDied:
                # our replica was killed: wait for the router to re-admit
                # (the fleet's maintenance tick does so as soon as the
                # stale lease is detected, so this is one TTL at worst)
                with self._router._cv:
                    while (self._req.generation == gen
                           and self._req.error is None):
                        rem = (None if deadline is None
                               else deadline - time.monotonic())
                        if rem is not None and rem <= 0:
                            raise TimeoutError(
                                f"request {self._req.rid} timed out "
                                f"waiting for re-admission")
                        self._router._cv.wait(rem)
                continue
            self._router._observe_latency(self._req, resp)
            self._router._complete(self._req.rid)
            return resp


class Router:
    """The fleet front-end (see module docstring).  Thread-safe: any
    number of client threads may ``submit`` while `serve/fleet.py` adds,
    drains, or removes replicas."""

    def __init__(self, cfg: Optional[RouterConfig] = None):
        self.cfg = cfg or RouterConfig()
        self._cv = threading.Condition()
        self._slots: Dict[str, _Slot] = {}
        self._ring = HashRing(self.cfg.vnodes)
        self._outstanding: Dict[str, _FleetRequest] = {}
        self._buckets: Dict[str, TokenBucket] = {}
        self._closed = False
        self._rid = 0
        # counters
        self.submitted = 0
        self.readmitted = 0
        self.routed_affinity = 0
        self.routed_spill = 0
        self.shed_by_reason: Dict[str, int] = {}
        self.tenant_counts: Dict[str, Dict[str, int]] = {}
        # bounded shed/readmit event feed: the fleet drains this into
        # the telemetry aggregator's correlation log (repro/obs/agg.py)
        # so worker flight-recorder dumps can be joined with the parent
        # admission decisions taken around them
        self._events: List[Dict[str, object]] = []
        # registry mirrors (difet.router.*) for the per-run metrics JSON
        _reg = obs_metrics.registry()
        self._m_admitted = _reg.counter("difet.router.admitted")
        self._m_readmitted = _reg.counter("difet.router.readmitted")
        self._m_affinity = _reg.counter("difet.router.routed_affinity")
        self._m_spill = _reg.counter("difet.router.routed_spill")
        # admission → work-completion latency, the SLO the fleet
        # autoscaler controls on (`serve/fleet.py::Fleet.autoscale_tick`)
        self._m_latency = _reg.histogram("difet.fleet.request_latency_s")

    # ---- pool membership (called by Fleet) ---------------------------------
    def add_replica(self, name: str, service: FeatureService) -> None:
        """Add a READY replica to the routable pool + hash ring."""
        with self._cv:
            self._slots[name] = _Slot(service)
            self._ring.add(name)
            self._cv.notify_all()

    def set_accepting(self, name: str, accepting: bool) -> None:
        """Drain gate: ``False`` removes the replica from the ring (no new
        work routes to it) while its queued work finishes."""
        with self._cv:
            slot = self._slots.get(name)
            if slot is None:
                return
            slot.accepting = accepting
            (self._ring.add if accepting else self._ring.remove)(name)

    def remove_replica(self, name: str, died: bool = False) -> None:
        """Drop a replica; ``died=True`` re-admits its outstanding
        requests to the survivors (crash path)."""
        with self._cv:
            self._slots.pop(name, None)
            self._ring.remove(name)
        if died:
            self.readmit(name)

    def replica_names(self) -> Tuple[str, ...]:
        """Names of every replica the router can currently reach."""
        with self._cv:
            return tuple(sorted(self._slots))

    def drain_events(self) -> List[Dict[str, object]]:
        """Hand over (and clear) the bounded shed/readmit event feed —
        consumed by `serve/fleet.py::Fleet.poll_telemetry` into the
        telemetry aggregator's dump-correlation log."""
        with self._cv:
            out, self._events = self._events, []
        return out

    # ---- admission + routing ----------------------------------------------
    def _bucket(self, tenant: str) -> TokenBucket:
        b = self._buckets.get(tenant)
        if b is None:
            rate, burst = (self.cfg.tenant_limits or {}).get(
                tenant, (self.cfg.tenant_rate, self.cfg.tenant_burst))
            b = self._buckets.setdefault(tenant, TokenBucket(rate, burst))
        return b

    def _shed(self, reason: str, tenant: str, detail: str = "",
              retry_after_s: float = 0.0):
        with self._cv:
            self.shed_by_reason[reason] = \
                self.shed_by_reason.get(reason, 0) + 1
            t = self.tenant_counts.setdefault(
                tenant, {"admitted": 0, "shed": 0})
            t["shed"] += 1
            self._events.append({"kind": "shed", "reason": reason,
                                 "tenant": tenant, "t": time.monotonic()})
            del self._events[:-256]
        obs_metrics.registry().counter(f"difet.router.shed.{reason}").inc()
        rec = obs_trace.get_recorder()
        if rec.enabled:
            # a shed is an operator-actionable event: snapshot what the
            # fleet was doing when it happened (deduped per reason)
            getattr(rec, "dump_on", lambda _r: None)(f"shed-{reason}")
        raise Shed(reason, detail, tenant=tenant,
                   retry_after_s=retry_after_s)

    def _route_key(self, image, scene_key: Optional[str]) -> str:
        if scene_key is not None:
            return scene_key
        if isinstance(image, str):
            return image                     # registered scene id
        if isinstance(image, (bytes, bytearray)):
            return hashlib.sha256(bytes(image)).hexdigest()
        a = np.ascontiguousarray(image)
        return hashlib.sha256(a.tobytes()).hexdigest()

    def _pick(self, key: str) -> Tuple[Optional[str], bool]:
        """(replica name, spilled?) under the lock: affinity target unless
        its queue is past the spill threshold and someone is shallower."""
        target = self._ring.lookup(key)
        if target is None:
            return None, False
        depth = self._slots[target].service.scheduler.queue_depth
        if depth < self.cfg.spill_queue_threshold:
            return target, False
        best, best_depth = target, depth
        for name, slot in self._slots.items():
            if not slot.accepting:
                continue
            d = slot.service.scheduler.queue_depth
            if d < best_depth:
                best, best_depth = name, d
        return best, best != target

    def total_pending(self) -> int:
        """Fleet-wide queue depth (the bounded global queue)."""
        with self._cv:
            slots = list(self._slots.values())
        return sum(s.service.scheduler.queue_depth for s in slots)

    def submit(self, image, algorithms, tenant: str = "default",
               scene_key: Optional[str] = None,
               request_id: Optional[str] = None) -> FleetHandle:
        """Admit + route one request; returns a :class:`FleetHandle`.

        Raises :class:`Shed` (typed: reason/tenant/retry-after) when the
        tenant is over its token bucket, the fleet-wide queue is at
        ``max_global_pending``, or no replica is accepting work.  Never
        blocks the caller on backpressure — shedding at the edge is the
        contract."""
        if self._closed:
            self._shed(SHED_CLOSED, tenant, "router is closed")
        ok, retry = self._bucket(tenant).take()
        if not ok:
            self._shed(SHED_TENANT_THROTTLED, tenant,
                       f"tenant {tenant!r} over rate limit",
                       retry_after_s=retry)
        if self.total_pending() >= self.cfg.max_global_pending:
            self._shed(SHED_FLEET_SATURATED, tenant,
                       f"fleet queue at max_global_pending="
                       f"{self.cfg.max_global_pending}")
        key = self._route_key(image, scene_key)
        with self._cv:
            name, spilled = self._pick(key)
            if name is None:
                # release the lock before raising (shed takes it again)
                pass
            else:
                slot = self._slots[name]
        if name is None:
            self._shed(SHED_NO_REPLICA, tenant, "no replica accepting work")
        # trace id minted at admission (the request passed every gate):
        # it follows the request through the replica scheduler, batch
        # execution, the cache tiers, and crash re-admission
        tracing = obs_trace.enabled()
        tid = obs_trace.new_trace_id() if tracing else ""
        t_admit = time.monotonic() if tracing else 0.0
        admitted_at = time.time()        # SLO latency base (wall clock,
        #                                  comparable to timing["completed_at"])
        try:
            handle = slot.service.submit(image, algorithms,
                                         request_id=request_id, block=False,
                                         trace_id=tid)
        except (ServiceOverloaded, ServiceClosed):
            # the chosen replica itself refused (its local queue bound is
            # tighter than the global one, or it closed under us): one
            # retry on the least-pending other replica, then shed
            alt = self._least_pending(exclude=name)
            if alt is None:
                self._shed(SHED_FLEET_SATURATED, tenant,
                           f"replica {name} overloaded, no alternative")
            try:
                handle = self._slots[alt].service.submit(
                    image, algorithms, request_id=request_id, block=False,
                    trace_id=tid)
                name, spilled = alt, True
            except (ServiceOverloaded, ServiceClosed):
                self._shed(SHED_FLEET_SATURATED, tenant,
                           "all replicas overloaded")
        with self._cv:
            self._rid += 1
            rid = request_id or f"fleet-{self._rid:08d}"
            req = _FleetRequest(rid, image, tuple(algorithms) if
                                not isinstance(algorithms, str)
                                else algorithms, tenant, key, name, handle,
                                trace_id=tid, admitted_at=admitted_at)
            self._outstanding[rid] = req
            self.submitted += 1
            if spilled:
                self.routed_spill += 1
            else:
                self.routed_affinity += 1
            t = self.tenant_counts.setdefault(
                tenant, {"admitted": 0, "shed": 0})
            t["admitted"] += 1
        self._m_admitted.inc()
        (self._m_spill if spilled else self._m_affinity).inc()
        if tracing:
            obs_trace.emit_span("admit", "router", t_admit, time.monotonic(),
                                trace_id=tid, rid=rid, tenant=tenant,
                                replica=name, spilled=spilled)
        return FleetHandle(self, req)

    def extract(self, image, algorithms, tenant: str = "default",
                scene_key: Optional[str] = None,
                timeout: Optional[float] = None) -> ExtractResponse:
        """Synchronous convenience: submit + wait."""
        return self.submit(image, algorithms, tenant=tenant,
                           scene_key=scene_key).result(timeout)

    def _least_pending(self, exclude: Optional[str] = None) -> Optional[str]:
        with self._cv:
            cands = [(s.service.scheduler.queue_depth, n)
                     for n, s in self._slots.items()
                     if s.accepting and n != exclude]
        return min(cands)[1] if cands else None

    # ---- crash re-admission -------------------------------------------------
    def readmit(self, dead_replica: str) -> int:
        """Re-dispatch every outstanding request routed to a dead replica
        onto the survivors.  Accepted work is never shed: re-admission
        bypasses admission control (the request already passed it) and
        blocks for queue room if it must.  Returns the number of requests
        re-admitted."""
        with self._cv:
            victims = [r for r in self._outstanding.values()
                       if r.replica == dead_replica]
        n = 0
        for req in victims:
            if req.handle.done():
                # finished before (or racing) the crash: either a real
                # result (deliverable — determinism makes it correct) or
                # ReplicaDied (handled below on the next loop)
                try:
                    if not self._handle_failed(req.handle):
                        continue
                except Exception:  # noqa: BLE001 — treat as failed
                    pass
            target = self._least_pending(exclude=dead_replica)
            if target is None:
                with self._cv:
                    req.error = Shed(SHED_NO_REPLICA,
                                     "replica died and no survivor "
                                     "accepts work", tenant=req.tenant)
                    self._cv.notify_all()
                continue
            t0 = time.monotonic()
            try:
                new_handle = self._slots[target].service.submit(
                    req.image, req.algorithms, request_id=req.rid,
                    block=True, trace_id=req.trace_id)
            except (ServiceOverloaded, ServiceClosed) as e:
                with self._cv:
                    req.error = e
                    self._cv.notify_all()
                continue
            with self._cv:
                req.replica = target
                req.handle = new_handle
                req.generation += 1
                self.readmitted += 1
                self._events.append(
                    {"kind": "readmit", "rid": req.rid,
                     "from": dead_replica, "to": target,
                     "t": time.monotonic()})
                del self._events[:-256]
                self._cv.notify_all()
            self._m_readmitted.inc()
            if obs_trace.enabled():
                # links the dead replica's spans to the recompute: same
                # trace id as the original admission, old/new replica
                # named in the attrs (chaos-tested)
                obs_trace.emit_span("readmit", "router", t0,
                                    time.monotonic(),
                                    trace_id=req.trace_id, rid=req.rid,
                                    old_replica=dead_replica,
                                    new_replica=target)
            n += 1
        return n

    @staticmethod
    def _handle_failed(handle) -> bool:
        """True iff a done replica-handle holds a died-without-result
        failure (probe without blocking).  Duck-typed over both replica
        kinds: process handles (`serve/proc.py::ProcHandle`) expose
        ``failed()`` directly; thread handles are probed through their
        per-part futures."""
        probe = getattr(handle, "failed", None)
        if callable(probe):
            return bool(probe())
        for p in handle._parts:
            if p.future is not None and p.future.done():
                if p.future.exception() is not None:
                    return True
        return False

    # ---- SLO latency ---------------------------------------------------------
    def _observe_latency(self, req: _FleetRequest,
                         resp: ExtractResponse) -> None:
        """Record one admission→work-completion latency into the fleet
        SLO histogram (idempotent per request — ``result()`` can be
        called repeatedly and `harvest_latencies` races it benignly)."""
        with self._cv:
            if req.latency_observed or not req.admitted_at:
                return
            req.latency_observed = True
        completed = resp.timing.get("completed_at") or time.time()
        self._m_latency.observe(max(0.0, completed - req.admitted_at))

    def harvest_latencies(self) -> int:
        """Observe the latency of every *done but uncollected* request —
        the autoscaler's view under open-loop clients that submit fast
        and collect late (without this, p99 would only reflect requests
        whose callers already drained them).  Returns how many were
        harvested this call."""
        with self._cv:
            todo = [r for r in self._outstanding.values()
                    if not r.latency_observed and r.error is None]
        n = 0
        for req in todo:
            try:
                if not req.handle.done() or self._handle_failed(req.handle):
                    continue
                resp = req.handle.result(0.05)
            except Exception:  # noqa: BLE001 — died/raced: its turn comes later
                continue
            self._observe_latency(req, resp)
            n += 1
        return n

    def _complete(self, rid: str) -> None:
        with self._cv:
            self._outstanding.pop(rid, None)

    @property
    def outstanding(self) -> int:
        """Accepted requests not yet collected by their callers."""
        with self._cv:
            return len(self._outstanding)

    # ---- ops ----------------------------------------------------------------
    def stats(self) -> Dict[str, object]:
        """Fleet-aggregated counters: router admission/routing totals,
        per-tenant admit/shed, and the per-replica ``FeatureService``
        snapshots (plus their summed cache/queue totals)."""
        with self._cv:
            slots = dict(self._slots)
            snap = {
                "submitted": self.submitted,
                "shed": dict(self.shed_by_reason),
                "shed_total": sum(self.shed_by_reason.values()),
                "routed_affinity": self.routed_affinity,
                "routed_spill": self.routed_spill,
                "readmitted": self.readmitted,
                "outstanding": len(self._outstanding),
                "tenants": {t: dict(c)
                            for t, c in self.tenant_counts.items()},
            }
        per_replica = {n: s.service.stats() for n, s in slots.items()}
        snap["replicas"] = per_replica
        snap["replica_count"] = len(per_replica)
        snap["total_queue_depth"] = sum(r["queue_depth"]
                                        for r in per_replica.values())
        snap["total_cache_hits"] = sum(r["cache_hits"]
                                       for r in per_replica.values())
        snap["total_cache_misses"] = sum(r["cache_misses"]
                                         for r in per_replica.values())
        snap["total_busy_s"] = sum(r["busy_s"]
                                   for r in per_replica.values())
        qs = [r["p99_queue_ms"] for r in per_replica.values()
              if r["batches"]]
        snap["max_p99_queue_ms"] = max(qs) if qs else 0.0
        return snap

    def close(self) -> None:
        """Stop admitting (subsequent submits shed with ``closed``)."""
        self._closed = True
