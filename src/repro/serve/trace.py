"""Synthetic serving traces: one workload definition shared by the
single-service load driver (`launch/serve.py`), the fleet driver
(`launch/fleet.py`) and the fleet benchmark (`benchmarks/bench_fleet.py`).

A trace is a deterministic list of :class:`TraceEvent` — *when* a request
arrives (``t`` seconds from trace start), *what* it asks for (scene,
tile size, algorithm set) and *who* asks (tenant).  The generator models
the load shapes a public feature-extraction service actually sees:

* **arrival processes** — ``uniform`` (fixed inter-arrival), ``poisson``
  (exponential inter-arrival at the same mean rate), and ``burst``
  (Markov-modulated: the rate alternates between a calm baseline and
  ``burst_factor``× spikes — the pattern that stresses admission
  control);
* **hot-scene skew** — a small hot set of scenes receives most of the
  probability mass (recurring LandSat granules / popular map areas), the
  regime content-hash caches and scene-affinity routing are built for;
* **mixed tile sizes** — requests spread over several shape buckets, so
  batches can't all share one compiled program;
* **multi-tenant mix** — weighted tenants, so per-tenant token buckets
  have someone to throttle.

Everything is driven by one ``numpy`` RNG seeded from ``TraceConfig.seed``
— the same config always yields byte-identical traces, which is what lets
the fleet benchmark replay *the same* trace against 1 and N replicas and
call the throughput ratio a speedup.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data.landsat import synthetic_scene

__all__ = ["TraceConfig", "TraceEvent", "make_trace", "tile_pool",
           "scene_key"]


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs for one synthetic trace (all sampling is seeded).

    ``rate`` is the *mean* arrival rate in req/s across every process;
    ``burst`` mode alternates calm (``rate``·(1-burst_amplitude·…)) and
    spike segments so the long-run mean stays ``rate``.  ``hot_weight``
    of the scene-choice mass lands on the first ``ceil(hot_fraction ·
    unique_scenes)`` scenes (the hot set); the rest is uniform over the
    cold set."""
    n_requests: int = 256
    seed: int = 0
    # arrival process
    arrival: str = "uniform"              # uniform | poisson | burst
    rate: float = 500.0                   # mean req/s
    burst_factor: float = 4.0             # spike rate multiplier (burst)
    burst_fraction: float = 0.25          # fraction of requests in spikes
    # workload mix
    tile_sizes: Tuple[int, ...] = (32,)
    tile_size_weights: Optional[Tuple[float, ...]] = None
    unique_scenes: int = 32
    hot_fraction: float = 0.125           # |hot set| / unique_scenes
    hot_weight: float = 0.7               # P(request hits the hot set)
    algorithm_sets: Tuple[Tuple[str, ...], ...] = (("harris",),)
    algorithm_weights: Optional[Tuple[float, ...]] = None
    tenants: Tuple[str, ...] = ("tenant-a",)
    tenant_weights: Optional[Tuple[float, ...]] = None


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One request of a trace: arrival offset + workload coordinates.
    ``scene`` indexes the trace's tile pool (see `tile_pool`)."""
    t: float                              # seconds from trace start
    scene: int
    tile_hw: int
    tenant: str
    algorithms: Tuple[str, ...]

    @property
    def pool_key(self) -> Tuple[int, int]:
        """Key into the `tile_pool` dict for this event's tile."""
        return (self.scene, self.tile_hw)


def scene_key(event: TraceEvent) -> str:
    """The affinity-routing key for an event: same scene (any tile size)
    → same key → same replica under consistent-hash routing."""
    return f"scene-{event.scene}"


def _weights(n: int, w: Optional[Sequence[float]]) -> np.ndarray:
    if w is None:
        return np.full((n,), 1.0 / n)
    w = np.asarray(w, np.float64)
    if w.shape != (n,):
        raise ValueError(f"need {n} weights, got {w.shape}")
    return w / w.sum()


def _arrival_offsets(cfg: TraceConfig, rng: np.random.RandomState
                     ) -> np.ndarray:
    """Cumulative arrival times (seconds) for ``n_requests`` events."""
    n, mean_gap = cfg.n_requests, 1.0 / cfg.rate
    if cfg.arrival == "uniform":
        gaps = np.full((n,), mean_gap)
    elif cfg.arrival == "poisson":
        gaps = rng.exponential(mean_gap, size=n)
    elif cfg.arrival == "burst":
        # Markov-modulated: ``burst_fraction`` of requests arrive at
        # ``burst_factor``× the base rate, the rest slower, so the
        # long-run mean rate stays cfg.rate:
        #   f/r_spike + (1-f)/r_calm = 1/rate
        f, k = cfg.burst_fraction, cfg.burst_factor
        calm_gap = mean_gap * (1.0 - f / k) / max(1.0 - f, 1e-9)
        spike = rng.rand(n) < f
        gaps = np.where(spike, mean_gap / k, calm_gap)
        # arrivals cluster: sort spike membership into runs of ~8 so a
        # spike is a sustained burst, not isolated fast gaps
        run = 8
        for i in range(0, n - run, run):
            if spike[i]:
                gaps[i:i + run] = mean_gap / k
    else:
        raise ValueError(f"unknown arrival process {cfg.arrival!r} "
                         f"(uniform | poisson | burst)")
    return np.cumsum(gaps)


def make_trace(cfg: TraceConfig) -> List[TraceEvent]:
    """Generate the trace: deterministic in ``cfg`` (same config ⇒ same
    events, byte for byte)."""
    rng = np.random.RandomState(cfg.seed)
    t = _arrival_offsets(cfg, rng)
    n = cfg.n_requests
    # hot-scene skew: hot set gets hot_weight of the mass
    n_hot = max(1, int(np.ceil(cfg.hot_fraction * cfg.unique_scenes)))
    n_hot = min(n_hot, cfg.unique_scenes)
    p = np.empty((cfg.unique_scenes,))
    p[:n_hot] = cfg.hot_weight / n_hot
    if cfg.unique_scenes > n_hot:
        p[n_hot:] = (1.0 - cfg.hot_weight) / (cfg.unique_scenes - n_hot)
    else:
        p[:n_hot] = 1.0 / n_hot
    scenes = rng.choice(cfg.unique_scenes, size=n, p=p / p.sum())
    sizes = rng.choice(len(cfg.tile_sizes), size=n,
                       p=_weights(len(cfg.tile_sizes),
                                  cfg.tile_size_weights))
    algs = rng.choice(len(cfg.algorithm_sets), size=n,
                      p=_weights(len(cfg.algorithm_sets),
                                 cfg.algorithm_weights))
    tenants = rng.choice(len(cfg.tenants), size=n,
                         p=_weights(len(cfg.tenants), cfg.tenant_weights))
    return [TraceEvent(t=float(t[i]), scene=int(scenes[i]),
                       tile_hw=int(cfg.tile_sizes[sizes[i]]),
                       tenant=cfg.tenants[tenants[i]],
                       algorithms=tuple(cfg.algorithm_sets[algs[i]]))
            for i in range(n)]


def tile_pool(cfg: TraceConfig) -> Dict[Tuple[int, int], np.ndarray]:
    """The trace's tile inventory: one synthetic grayscale tile per
    (scene, tile size) the trace can reference.  Tile content depends on
    (trace seed, scene, size) only, so two traces with the same seed share
    bit-identical tiles — required for cross-run parity checks."""
    pool = {}
    for scene in range(cfg.unique_scenes):
        for hw in cfg.tile_sizes:
            pool[(scene, hw)] = synthetic_scene(
                hw, hw, seed=cfg.seed * 100003 + scene * 31 + hw)
    return pool
