"""Spooled-file request/response transport between the fleet router
process and its replica worker processes.

The cross-process fleet (`serve/proc.py`) needs a request channel with
the same failure discipline as the rest of the stack: a reader must see
either a *complete* message or no message, a writer crash (``kill -9``
mid-write) must leave nothing a peer could mistake for a message, and a
message that survived the writer's death must remain deliverable.  The
spooled-file transport gets all three from the filesystem primitives the
repo already trusts (`core/job.py::LeaseBoard`,
`serve/cache.py::DiskCacheTier`): every message is one ``.npz`` file
written tmp-then-atomic-rename, so the visible file *is* the commit.

Layout (one :class:`WorkerMailbox` directory per replica)::

    <root>/<replica-name>/
        req/    <rid>.npz      router → worker   (atomic rename)
        work/   <rid>.npz      claimed requests  (worker renames in)
        resp/   <rid>.npz      worker → router   (atomic rename)
        ctrl/   drain          control flags (empty marker files)
        telemetry/ <w>-<seq>.npz  worker → router telemetry shipments
                               (repro/obs/ship.py; parent consumes)
        chaos.json             fault-injection plan (serve/chaos.py)
        ready.npz              worker warm-up complete marker
        stats.npz              worker's latest stats() snapshot
        worker.log             worker stdout/stderr

Requests persist until the worker *claims* them (rename into ``work/``)
and responses persist until the router collects them — so a SIGKILL'd
worker leaves its unserved requests enumerable (the router re-admits
them to survivors) and its already-written responses deliverable (work
that finished before the crash is never recomputed).  A torn or corrupt
message (a fault-injection write, a partial tmp left by a dead writer)
is quarantined and skipped, never delivered.

Payloads are numpy trees + one JSON metadata dict, packed into a single
``.npz``: arrays keep dtype/shape bit-exactly (0-d leaves tagged
``__0d`` exactly like the disk cache tier), metadata rides as a
UTF-8-encoded ``uint8`` array under ``__meta__``.
"""
from __future__ import annotations

import io
import json
import os
import threading
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["encode_message", "decode_message", "write_message",
           "read_message", "read_snapshot", "WorkerMailbox"]

_META = "__meta__"


def encode_message(meta: Dict[str, object],
                   arrays: Optional[Dict[str, np.ndarray]] = None) -> bytes:
    """Pack one message (JSON-able ``meta`` + named numpy ``arrays``)
    into ``.npz`` bytes.  0-d arrays are tagged so decode restores exact
    shape; array names must not collide with the ``__meta__`` slot."""
    payload = {}
    for k, v in (arrays or {}).items():
        if k == _META:
            raise ValueError(f"array name {_META!r} is reserved")
        a = np.asarray(v)
        payload[k + "__0d" if a.ndim == 0 else k] = a
    payload[_META] = np.frombuffer(
        json.dumps(meta, sort_keys=True).encode(), np.uint8)
    buf = io.BytesIO()
    np.savez(buf, **payload)
    return buf.getvalue()


def decode_message(raw: bytes) -> Tuple[Dict[str, object],
                                        Dict[str, np.ndarray]]:
    """Inverse of `encode_message`: ``(meta, arrays)`` with every array
    frozen read-only.  Raises on a torn/corrupt payload (``ValueError``,
    ``KeyError``, ``zipfile.BadZipFile``) — callers quarantine."""
    with np.load(io.BytesIO(raw), allow_pickle=False) as z:
        meta = json.loads(bytes(z[_META]).decode())
        arrays = {}
        for k in z.files:
            if k == _META:
                continue
            a = z[k]
            if k.endswith("__0d"):
                k, a = k[:-4], a.reshape(())
            a.setflags(write=False)
            arrays[k] = a
    return meta, arrays


def write_message(path: Path, meta: Dict[str, object],
                  arrays: Optional[Dict[str, np.ndarray]] = None) -> None:
    """Atomically publish one message at ``path`` (tmp + rename, unique
    per-writer tmp name — the `DiskCacheTier` discipline, so a crash
    mid-write never exposes a torn message)."""
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(f".tmp.{os.getpid()}.{threading.get_ident()}")
    tmp.write_bytes(encode_message(meta, arrays))
    tmp.replace(path)


def read_message(path: Path) -> Optional[Tuple[Dict[str, object],
                                               Dict[str, np.ndarray]]]:
    """Read + decode one message; None when absent.  A corrupt file is
    quarantined (renamed ``*.corrupt``) and reads as absent — the
    torn-write chaos test drives this path."""
    try:
        raw = path.read_bytes()
    except OSError:
        return None
    try:
        return decode_message(raw)
    except Exception:  # noqa: BLE001 — any torn/corrupt payload; np.load
        # raises EOFError on an empty file and struct.error on a partial
        # zip header, beyond the documented ValueError/BadZipFile set
        try:
            path.rename(path.with_suffix(path.suffix + ".corrupt"))
        except OSError:
            pass
        return None


def read_snapshot(path: Path) -> Optional[Tuple[Dict[str, object],
                                                Dict[str, np.ndarray]]]:
    """Read a *republished* snapshot channel (``stats.npz``,
    ``ready.npz``): like `read_message`, but a torn/partial/corrupt file
    reads as "not yet" **without** quarantining — the writer overwrites
    the same path every interval, so renaming a torn read aside would
    discard the next perfectly good publish's landing spot and turn one
    torn write into a permanently missing channel.  Regression-tested
    against truncated stats files in ``tests/test_telemetry.py``."""
    try:
        raw = path.read_bytes()
    except OSError:
        return None
    try:
        return decode_message(raw)
    except Exception:  # noqa: BLE001 — torn mid-write/mid-rename read
        return None


class WorkerMailbox:
    """One replica's transport directory (see module docstring).

    Both sides construct it over the same path: the router uses
    `send_request` / `try_read_response` / `pending_requests`, the
    worker uses `claim_requests` / `send_response` plus the control
    helpers.  All operations are safe against the peer dying at any
    instruction boundary."""

    def __init__(self, root):
        self.root = Path(root)
        self.req = self.root / "req"
        self.work = self.root / "work"
        self.resp = self.root / "resp"
        self.ctrl = self.root / "ctrl"
        self.tele = self.root / "telemetry"
        for d in (self.req, self.work, self.resp, self.ctrl, self.tele):
            d.mkdir(parents=True, exist_ok=True)

    # ---- router side --------------------------------------------------------
    def send_request(self, rid: str, meta: Dict[str, object],
                     arrays: Dict[str, np.ndarray]) -> None:
        """Publish request ``rid`` into the worker's inbox."""
        write_message(self.req / f"{rid}.npz", meta, arrays)

    def try_read_response(self, rid: str) -> Optional[Tuple[Dict, Dict]]:
        """The worker's response to ``rid``, or None if not (yet)
        written.  Responses persist — a response written before the
        worker died is still deliverable."""
        return read_message(self.resp / f"{rid}.npz")

    def has_response(self, rid: str) -> bool:
        """Cheap readiness probe (one stat)."""
        return (self.resp / f"{rid}.npz").exists()

    def pending_requests(self) -> List[str]:
        """rids the worker has neither claimed nor answered — what a dead
        worker leaves behind for re-admission accounting."""
        claimed = {p.stem for p in self.work.glob("*.npz")}
        answered = {p.stem for p in self.resp.glob("*.npz")}
        out = []
        for p in self.req.glob("*.npz"):
            if p.stem not in answered:
                out.append(p.stem)
        out.extend(r for r in claimed if r not in answered)
        return sorted(set(out))

    def request_drain(self) -> None:
        """Raise the drain flag: the worker finishes every claimed +
        inbox request, answers them all, then exits cleanly."""
        (self.ctrl / "drain").touch()

    # ---- worker side --------------------------------------------------------
    def claim_requests(self) -> List[Tuple[str, Dict, Dict]]:
        """Claim every inbox request (atomic rename into ``work/`` —
        claim-then-read, so a crash after claim still shows the request
        as claimed-but-unanswered to `pending_requests`).  Corrupt
        requests are quarantined and skipped.  Returns
        ``[(rid, meta, arrays), ...]`` in rid order."""
        out = []
        for path in sorted(self.req.glob("*.npz")):
            claimed = self.work / path.name
            try:
                path.rename(claimed)
            except OSError:
                continue                       # raced / vanished: skip
            msg = read_message(claimed)
            if msg is None:
                continue                       # quarantined by read_message
            out.append((path.stem, msg[0], msg[1]))
        return out

    def send_response(self, rid: str, meta: Dict[str, object],
                      arrays: Dict[str, np.ndarray]) -> None:
        """Publish the response for ``rid`` and retire its claimed
        request file (response first — the commit point — so a crash
        between the two at worst leaves a claimed request *with* a
        response, which `pending_requests` already treats as done)."""
        write_message(self.resp / f"{rid}.npz", meta, arrays)
        try:
            (self.work / f"{rid}.npz").unlink()
        except OSError:
            pass

    def drain_requested(self) -> bool:
        """Has the router asked this worker to drain?"""
        return (self.ctrl / "drain").exists()

    # ---- telemetry channel (repro/obs/ship.py → repro/obs/agg.py) -----------
    def publish_telemetry(self, worker: str, seq: int,
                          meta: Dict[str, object]) -> None:
        """Worker: spool one sequenced telemetry shipment (atomic
        rename, like every other message; the parent consumes it)."""
        write_message(self.tele / f"{worker}-{seq:08d}.npz", meta)

    def collect_telemetry(self) -> List[Dict[str, object]]:
        """Router: drain every spooled telemetry shipment, in sequence
        order, deleting each file once read — the channel is a queue,
        not a snapshot.  Torn/corrupt shipments are quarantined by
        `read_message` and skipped (one lost interval of deltas, never a
        double-count)."""
        out = []
        for path in sorted(self.tele.glob("*.npz")):
            msg = read_message(path)
            if msg is not None:
                out.append(msg[0])
            try:
                path.unlink()
            except OSError:
                pass                      # quarantined or raced: gone either way
        return out

    # ---- shared markers -----------------------------------------------------
    def write_ready(self, info: Dict[str, object]) -> None:
        """Worker: publish the warm-up-complete marker (atomic)."""
        write_message(self.root / "ready.npz", info)

    def read_ready(self) -> Optional[Dict[str, object]]:
        """Router: the worker's ready marker — None while warming *or*
        on a torn/partial read (`read_snapshot`: a snapshot channel
        reads as "not yet", it is never quarantined)."""
        msg = read_snapshot(self.root / "ready.npz")
        return msg[0] if msg else None

    def write_stats(self, stats: Dict[str, object]) -> None:
        """Worker: publish the latest ``stats()`` snapshot."""
        write_message(self.root / "stats.npz", stats)

    def read_stats(self) -> Optional[Dict[str, object]]:
        """Router: the worker's last stats snapshot — None before the
        first publish or on a torn/partial read (`read_snapshot`; the
        next periodic publish repairs the channel)."""
        msg = read_snapshot(self.root / "stats.npz")
        return msg[0] if msg else None
