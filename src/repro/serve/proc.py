"""Cross-process replica worker + its parent-side client.

PR 6's fleet ran N `FeatureService` replicas as threads in one process
— crash re-admission and cache partitions were simulated.  This module
makes the replica an OS process, so ``kill -9`` is a *real* SIGKILL and
the only surviving channels are the ones the paper's architecture
actually grants a distributed worker: the spooled-file transport
(`serve/transport.py`), `LeaseBoard` lease files as the liveness
heartbeat, and the shared `DiskCacheTier`.

Two halves:

* :func:`run_worker` / ``python -m repro.serve.proc`` — the worker
  process.  It builds a normal in-process `FeatureService`, warms the
  requested compile programs, publishes a ready marker, then loops:
  heartbeat its own lease, claim requests from the mailbox, submit them
  to the service, publish responses (response file = commit point),
  republish stats, honour the drain flag.  Every loop iteration re-reads
  the mailbox's chaos plan (`serve/chaos.py`), so tests steer faults —
  stalled heartbeats, withheld responses, self-``kill -9`` — in-band.
* :class:`ProcReplicaClient` — the router-facing proxy.  It duck-types
  the slice of `FeatureService` that `serve/router.py` and
  `serve/fleet.py` touch (``submit``/``stats``/``register_scene``/
  ``drain``/``kill``/``warmup`` plus ``scheduler.queue_depth``), so the
  same `Router`/`Fleet` code drives thread and process replicas.
  :class:`ProcHandle` mirrors `ResponseHandle` and adds ``failed()`` —
  died-without-a-response — which the router's re-admission probe uses.

Liveness is worker-reported: the *worker* refreshes its lease; the
parent never touches it.  A SIGKILL therefore stops the heartbeat at
the same instant it stops the work, and the fleet's maintenance loop
discovers the death the way a distributed control plane would — by the
lease going stale — not by waiting on a child process handle.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core.job import LeaseBoard
from repro.serve import chaos
from repro.serve.api import (ExtractResponse, FeatureService, ServeConfig,
                             decode_tile)
from repro.serve.scheduler import ReplicaDied, ServiceClosed
from repro.serve.transport import WorkerMailbox

__all__ = ["ProcReplicaClient", "ProcHandle", "serve_config_to_json",
           "serve_config_from_json", "run_worker"]


# -- config over the wire ----------------------------------------------------

def serve_config_to_json(cfg: ServeConfig) -> Dict[str, object]:
    """`ServeConfig` → JSON-able dict (inverse of
    `serve_config_from_json`); shipped to the worker as a file."""
    return dataclasses.asdict(cfg)


def serve_config_from_json(d: Dict[str, object]) -> ServeConfig:
    """Rebuild a `ServeConfig` (tuples restored) from
    `serve_config_to_json` output."""
    d = dict(d)
    base = dict(d.pop("base"))
    base["scene_hw"] = tuple(base.get("scene_hw", (7681, 7831)))
    d["buckets"] = tuple(d.get("buckets", ()))
    return ServeConfig(base=DifetConfig(**base), **d)


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def _encode_response(resp: ExtractResponse) -> Tuple[Dict, Dict]:
    """`ExtractResponse` → (meta, arrays) for the transport; per-
    algorithm arrays are flattened to ``"<alg>/<key>"`` names so the one
    ``.npz`` keeps every leaf bit-exact."""
    arrays = {f"{alg}/{k}": v
              for alg, res in resp.results.items() for k, v in res.items()}
    meta = {"status": "ok",
            "request_id": resp.request_id,
            "algorithms": list(resp.algorithms),
            "n_tiles": int(resp.n_tiles),
            "bucket": int(resp.bucket),
            "cached": {k: float(v) for k, v in resp.cached.items()},
            "timing": _jsonable(resp.timing)}
    return meta, arrays


def _decode_response(meta: Dict, arrays: Dict) -> ExtractResponse:
    results: Dict[str, Dict[str, np.ndarray]] = {}
    for name, arr in arrays.items():
        alg, _, key = name.partition("/")
        results.setdefault(alg, {})[key] = arr
    return ExtractResponse(request_id=meta["request_id"],
                           algorithms=tuple(meta["algorithms"]),
                           results=results,
                           n_tiles=int(meta["n_tiles"]),
                           bucket=int(meta["bucket"]),
                           cached=dict(meta["cached"]),
                           timing=dict(meta["timing"]))


# -- the worker process ------------------------------------------------------

def run_worker(name: str, mailbox_dir: str, lease_dir: str, *,
               lease_ttl_s: float, heartbeat_interval_s: float,
               serve_config_path: str, warm_sets: List[List[str]],
               poll_interval_s: float = 0.003,
               telemetry_interval_s: float = 0.0) -> int:
    """Worker main loop (see module docstring).  Returns the process
    exit code: 0 on a clean drain.  Faults from the mailbox's chaos plan
    are honoured *every* iteration — a live worker can stop
    heartbeating, sit on finished responses, or ``os._exit(137)``
    after its N-th response.

    ``telemetry_interval_s > 0`` turns the fleet telemetry plane on for
    this worker: a `FlightRecorder` is installed (so the scheduler/batch
    spans carry the parent-minted trace ids), and a
    `repro/obs/ship.py::TelemetryShipper` spools metric deltas + span
    batches onto the mailbox's ``telemetry/`` channel every interval,
    with one forced flush on drain so no tail telemetry is lost."""
    mbox = WorkerMailbox(mailbox_dir)
    leases = LeaseBoard(lease_dir, ttl_s=lease_ttl_s)
    cfg = serve_config_from_json(
        json.loads(Path(serve_config_path).read_text()))
    shipper = None
    if telemetry_interval_s > 0:
        from repro.obs import trace as obs_trace
        from repro.obs.ship import TelemetryShipper
        dump_dir = os.environ.get("DIFET_CHAOS_DUMP_DIR") \
            or str(mbox.root / "dumps")
        Path(dump_dir).mkdir(parents=True, exist_ok=True)
        obs_trace.set_recorder(
            obs_trace.FlightRecorder(capacity=8192, dump_dir=dump_dir))
        shipper = TelemetryShipper(
            mbox, name, recorder=obs_trace.get_recorder(),
            interval_s=telemetry_interval_s)
    svc = FeatureService(cfg, name=name)
    if warm_sets:
        svc.warmup([tuple(s) for s in warm_sets])
    leases.acquire(name, name)
    mbox.write_ready({"name": name, "pid": os.getpid(),
                      "programs": svc.compile_cache.programs})
    pending: Dict[str, object] = {}        # rid -> ResponseHandle
    served = 0
    last_hb = time.time()
    last_stats = 0.0
    while True:
        now = time.time()
        plan = chaos.read_plan(mbox.root)
        if (not plan.heartbeat_stalled(now)
                and now - last_hb >= heartbeat_interval_s):
            leases.acquire(name, name)     # refresh own lease
            last_hb = now
        for rid, meta, arrays in mbox.claim_requests():
            try:
                h = svc.submit(arrays["image"],
                               tuple(meta.get("algorithms", ())),
                               request_id=rid, block=True,
                               trace_id=meta.get("trace_id") or None)
                pending[rid] = h
            except Exception as e:  # noqa: BLE001 — report, don't die
                mbox.send_response(rid, {"status": "error",
                                         "request_id": rid,
                                         "error": repr(e)}, {})
        if not plan.responses_held(now):
            for rid in list(pending):
                h = pending[rid]
                if not h.done():
                    continue
                try:
                    rmeta, rarrays = _encode_response(h.result(10.0))
                except Exception as e:  # noqa: BLE001
                    rmeta, rarrays = {"status": "error", "request_id": rid,
                                      "error": repr(e)}, {}
                mbox.send_response(rid, rmeta, rarrays)
                del pending[rid]
                served += 1
                if (plan.exit_after_requests
                        and served >= plan.exit_after_requests):
                    os._exit(137)          # self-inflicted kill -9
        if now - last_stats >= 0.25:
            mbox.write_stats(_jsonable(svc.stats()))
            last_stats = now
        if shipper is not None:
            shipper.maybe_ship()
        if (mbox.drain_requested() and not pending
                and not mbox.claim_requests()):
            mbox.write_stats(_jsonable(svc.stats()))
            svc.close()
            if shipper is not None:
                shipper.ship(final=True)   # retire flush: no tail loss
            leases.release(name, name)
            return 0
        time.sleep(poll_interval_s)


def _worker_main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.serve.proc")
    ap.add_argument("--name", required=True)
    ap.add_argument("--dir", required=True)
    ap.add_argument("--lease-dir", required=True)
    ap.add_argument("--lease-ttl", type=float, default=5.0)
    ap.add_argument("--heartbeat-interval", type=float, default=0.2)
    ap.add_argument("--serve-config", required=True)
    ap.add_argument("--warm-sets", default="[]")
    ap.add_argument("--poll-interval", type=float, default=0.003)
    ap.add_argument("--telemetry-interval", type=float, default=0.0)
    a = ap.parse_args(argv)
    return run_worker(a.name, a.dir, a.lease_dir,
                      lease_ttl_s=a.lease_ttl,
                      heartbeat_interval_s=a.heartbeat_interval,
                      serve_config_path=a.serve_config,
                      warm_sets=json.loads(a.warm_sets),
                      poll_interval_s=a.poll_interval,
                      telemetry_interval_s=a.telemetry_interval)


# -- parent-side proxy -------------------------------------------------------

class ProcHandle:
    """Parent-side handle for one request to a process replica; mirrors
    `serve/api.py::ResponseHandle` (``done()``/``result()``) and adds
    ``failed()`` for the router's re-admission probe.  The response file
    is checked *before* the dead flag everywhere, so work the replica
    finished before dying is still delivered, never recomputed."""

    def __init__(self, client: "ProcReplicaClient", rid: str):
        self._client = client
        self.request_id = rid
        self._resp: Optional[ExtractResponse] = None

    def _load(self) -> Optional[ExtractResponse]:
        if self._resp is not None:
            return self._resp
        msg = self._client.mailbox.try_read_response(self.request_id)
        if msg is None:
            return None
        meta, arrays = msg
        if meta.get("status") != "ok":
            raise RuntimeError(f"replica {self._client.name} failed "
                               f"{self.request_id}: {meta.get('error')}")
        self._resp = _decode_response(meta, arrays)
        self._client._settled(self.request_id)
        return self._resp

    def done(self) -> bool:
        """True once a response is published (or the replica died)."""
        return (self._resp is not None
                or self._client.mailbox.has_response(self.request_id)
                or self._client.dead.is_set())

    def failed(self) -> bool:
        """Replica died with no response published — the request needs
        re-admission to a survivor."""
        return (self._resp is None
                and self._client.dead.is_set()
                and not self._client.mailbox.has_response(self.request_id))

    @property
    def completed_at(self) -> Optional[float]:
        """Worker-stamped work-finish time (None before the response
        lands) — the fleet's SLO latency histogram reads this."""
        return (None if self._resp is None
                else self._resp.timing.get("completed_at"))

    def result(self, timeout: Optional[float] = None) -> ExtractResponse:
        """Block for the response; raises
        `serve/scheduler.py::ReplicaDied` if the replica died without
        publishing one (a persisted response always wins over death)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            resp = self._load()
            if resp is not None:
                return resp
            if self._client.dead.is_set():
                raise ReplicaDied(
                    f"replica {self._client.name} died before answering "
                    f"{self.request_id}")
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"no response for {self.request_id} after {timeout}s")
            time.sleep(self._client.poll_interval_s)


class _SchedulerView:
    """The one scheduler attribute the router touches on a replica:
    ``queue_depth`` (here: requests sent but not yet answered)."""

    def __init__(self, client: "ProcReplicaClient"):
        self._client = client

    @property
    def queue_depth(self) -> int:
        return self._client.outstanding()


class ProcReplicaClient:
    """Router-facing proxy for one worker process (see module
    docstring).  Construct via :meth:`spawn`, then :meth:`wait_ready`
    before routing traffic."""

    def __init__(self, name: str, root, proc: subprocess.Popen,
                 poll_interval_s: float = 0.002):
        self.name = name
        self.root = Path(root)
        self.proc = proc
        self.poll_interval_s = poll_interval_s
        self.mailbox = WorkerMailbox(self.root)
        self.dead = threading.Event()
        self.scheduler = _SchedulerView(self)
        self._scenes: Dict[str, np.ndarray] = {}
        self._inflight: set = set()
        self._lock = threading.Lock()
        self._rid = 0

    # -- lifecycle -----------------------------------------------------------
    @classmethod
    def spawn(cls, name: str, root, serve_cfg: ServeConfig, lease_dir, *,
              lease_ttl_s: float = 5.0, heartbeat_interval_s: float = 0.2,
              warm_algorithm_sets=(), poll_interval_s: float = 0.002,
              worker_poll_s: float = 0.003,
              telemetry_interval_s: float = 0.0) -> "ProcReplicaClient":
        """Launch the worker process (``python -m repro.serve.proc``)
        with its mailbox under ``root``; returns immediately — pair with
        :meth:`wait_ready`.  stdout/stderr land in
        ``<root>/worker.log``."""
        root = Path(root)
        root.mkdir(parents=True, exist_ok=True)
        cfg_path = root / "serve_config.json"
        cfg_path.write_text(json.dumps(serve_config_to_json(serve_cfg)))
        src_dir = Path(__file__).resolve().parent.parent.parent
        env = dict(os.environ)
        env["PYTHONPATH"] = (f"{src_dir}{os.pathsep}{env['PYTHONPATH']}"
                             if env.get("PYTHONPATH") else str(src_dir))
        env.setdefault("JAX_PLATFORMS", "cpu")
        cmd = [sys.executable, "-m", "repro.serve.proc",
               "--name", name, "--dir", str(root),
               "--lease-dir", str(lease_dir),
               "--lease-ttl", str(lease_ttl_s),
               "--heartbeat-interval", str(heartbeat_interval_s),
               "--serve-config", str(cfg_path),
               "--warm-sets",
               json.dumps([list(s) for s in warm_algorithm_sets]),
               "--poll-interval", str(worker_poll_s),
               "--telemetry-interval", str(telemetry_interval_s)]
        with open(root / "worker.log", "ab") as log:
            proc = subprocess.Popen(cmd, stdout=log,
                                    stderr=subprocess.STDOUT, env=env)
        return cls(name, root, proc, poll_interval_s)

    def wait_ready(self, timeout: float = 120.0) -> Dict[str, object]:
        """Block until the worker publishes its ready marker (warm-up
        complete); raises with the tail of ``worker.log`` if the process
        exits first."""
        deadline = time.monotonic() + timeout
        while True:
            info = self.mailbox.read_ready()
            if info is not None:
                return info
            if self.proc.poll() is not None:
                raise RuntimeError(
                    f"worker {self.name} exited rc={self.proc.returncode} "
                    f"before ready:\n{self._log_tail()}")
            if time.monotonic() > deadline:
                raise TimeoutError(f"worker {self.name} not ready "
                                   f"after {timeout}s")
            time.sleep(0.02)

    def _log_tail(self, n: int = 20) -> str:
        try:
            lines = (self.root / "worker.log").read_text().splitlines()
            return "\n".join(lines[-n:])
        except OSError:
            return "<no worker.log>"

    def alive(self) -> bool:
        """Is the worker process itself still running?  (Liveness for
        fleet decisions is the *lease*; this is the process-table
        ground truth used to reap zombies.)"""
        return self.proc.poll() is None

    @property
    def pid(self) -> int:
        """Worker process id (the ``kill -9`` target)."""
        return self.proc.pid

    def mark_dead(self) -> None:
        """Flip every outstanding handle to the died path (persisted
        responses still deliver).  Called by the fleet once the lease
        goes stale, or by :meth:`kill`."""
        self.dead.set()

    def kill(self, exc: Optional[BaseException] = None) -> None:
        """Chaos hook mirroring `FeatureService.kill`: SIGKILL the
        worker and mark it dead — no drain, no cleanup."""
        chaos.sigkill(self.proc.pid)
        try:
            self.proc.wait(timeout=10.0)
        except subprocess.TimeoutExpired:
            pass
        self.mark_dead()

    def drain(self, timeout: Optional[float] = 30.0) -> None:
        """Raise the drain flag and wait for the worker to answer every
        accepted request and exit 0; a worker that overruns ``timeout``
        is killed (and marked dead) rather than leaked."""
        self.mailbox.request_drain()
        try:
            self.proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            self.kill()

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Alias for :meth:`drain` (the `FeatureService` surface)."""
        self.drain(timeout)

    # -- the FeatureService surface the router drives ------------------------
    def warmup(self, algorithm_sets, buckets=None) -> int:
        """No-op: the worker warms itself before publishing ready."""
        return 0

    def register_scene(self, name: str, image: np.ndarray) -> None:
        """Scene ids resolve parent-side; requests always ship resolved
        pixel arrays so the worker needs no scene registry."""
        self._scenes[name] = np.asarray(image)

    def _resolve(self, image) -> np.ndarray:
        if isinstance(image, str):
            if image not in self._scenes:
                raise KeyError(f"unknown scene id {image!r}")
            return self._scenes[image]
        if isinstance(image, (bytes, bytearray)):
            return decode_tile(bytes(image))
        return np.asarray(image)

    def submit(self, image, algorithms, request_id: Optional[str] = None,
               block: bool = False,
               trace_id: Optional[str] = None) -> ProcHandle:
        """Publish one request into the worker's mailbox and return a
        :class:`ProcHandle`.  Raises `ServiceClosed` when the replica is
        already known dead (the router's retry path picks a survivor)."""
        if self.dead.is_set():
            raise ServiceClosed(f"replica {self.name} is dead")
        with self._lock:
            self._rid += 1
            rid = request_id or f"{self.name}-r{self._rid:06d}"
            self._inflight.add(rid)
        self.mailbox.send_request(
            rid, {"algorithms": [str(a) for a in algorithms],
                  "trace_id": trace_id or ""},
            {"image": self._resolve(image)})
        return ProcHandle(self, rid)

    def _settled(self, rid: str) -> None:
        with self._lock:
            self._inflight.discard(rid)

    def outstanding(self) -> int:
        """Requests sent but not yet answered (the router's queue-depth
        signal for this replica); prunes answered rids as it scans."""
        with self._lock:
            inflight = list(self._inflight)
        depth = 0
        for rid in inflight:
            if self.mailbox.has_response(rid):
                self._settled(rid)
            else:
                depth += 1
        return depth

    def stats(self) -> Dict[str, object]:
        """The worker's last published ``stats()`` snapshot, with the
        parent-side queue depth (more current than the snapshot) and
        zeroed defaults before the first publish."""
        base = self.mailbox.read_stats() or {}
        out = {"name": self.name, "submitted": 0, "shed": 0,
               "cache_hits": 0, "cache_misses": 0, "batches": 0,
               "batch_occupancy": 0.0, "p50_queue_ms": 0.0,
               "p99_queue_ms": 0.0, "busy_s": 0.0, "steps": 0,
               "cache": {"hits": 0, "misses": 0},
               "scheduler": {}, "programs": 0, "program_keys": []}
        out.update(base)
        out["queue_depth"] = self.outstanding()
        out["pid"] = self.proc.pid
        out["alive"] = self.alive()
        return out


if __name__ == "__main__":
    sys.exit(_worker_main())
