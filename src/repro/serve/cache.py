"""Content-hash LRU result cache for the feature service.

LandSat tiles recur across scenes and across requests (overlapping scene
footprints, re-submitted work, mosaics sharing source granules), and
feature extraction is deterministic — so repeated extraction is pure
waste.  The cache is keyed by ``(tile_digest, algorithm, config_digest)``
(`serve/api.py::tile_digest` / `config_digest`):

* the tile digest hashes the exact padded pixel bytes + shape + dtype, so
  any content change is a miss;
* the algorithm is part of the key, so one tile's SIFT and FAST results
  are independent entries (a request for a superset of algorithms reuses
  the per-algorithm entries it already has);
* the config digest folds every ``DifetConfig`` field plus the
  ``use_pallas`` flag, so a threshold/geometry/backend change can never
  alias a stale result (collision-safety is tested).

Values are per-request feature dicts (numpy leaves) frozen read-only on
insert: cache hits hand out the stored arrays without copying, and the
freeze guarantees no consumer can corrupt a shared entry.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np


def freeze(tree: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Own + freeze a feature dict: contiguous copies (detached from any
    batch buffer the scheduler will reuse) marked non-writeable."""
    out = {}
    for k, v in tree.items():
        # NOT ascontiguousarray: that silently promotes 0-d leaves
        # (total_count, keypoint_count) to shape (1,)
        a = np.array(v, order="C")       # always an owned copy
        a.setflags(write=False)
        out[k] = a
    return out


class ResultCache:
    """Thread-safe LRU over feature-result dicts.

    ``capacity`` counts entries (one per (tile, algorithm, config) key);
    0 disables the cache entirely (every get is a miss, puts are dropped)
    — the throughput benchmark uses that to measure honest batching wins.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: "OrderedDict[tuple, Dict[str, np.ndarray]]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserts = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def get(self, key) -> Optional[Dict[str, np.ndarray]]:
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key, value: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Insert (refreshing recency) and return the frozen stored value."""
        frozen = freeze(value)
        if self.capacity <= 0:
            return frozen
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
            self._d[key] = frozen
            self.inserts += 1
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)      # evict least-recently-used
                self.evictions += 1
            return frozen

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def keys(self):
        with self._lock:
            return list(self._d)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"entries": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "inserts": self.inserts,
                    "hit_rate": self.hit_rate}
