"""Content-hash result caches for the feature service: in-process LRU +
a shared on-disk tier for fleets.

LandSat tiles recur across scenes and across requests (overlapping scene
footprints, re-submitted work, mosaics sharing source granules), and
feature extraction is deterministic — so repeated extraction is pure
waste.  The cache is keyed by ``(tile_digest, algorithm, config_digest)``
(`serve/api.py::tile_digest` / `config_digest`):

* the tile digest hashes the exact padded pixel bytes + shape + dtype, so
  any content change is a miss;
* the algorithm is part of the key, so one tile's SIFT and FAST results
  are independent entries (a request for a superset of algorithms reuses
  the per-algorithm entries it already has);
* the config digest folds every ``DifetConfig`` field plus the
  ``use_pallas`` flag, so a threshold/geometry/backend change can never
  alias a stale result (collision-safety is tested).

Values are per-request feature dicts (numpy leaves) frozen read-only on
insert: cache hits hand out the stored arrays without copying, and the
freeze guarantees no consumer can corrupt a shared entry.

Fleets layer the tiers (`TieredResultCache`): each replica keeps its own
in-memory LRU, backed by one ``DiskCacheTier`` directory shared by every
replica — a write-through on any replica warms the whole fleet, and a
local miss that hits disk is promoted into the local LRU.  Disk entries
are ``.npz`` files named by the sha256 of the cache key, written
tmp-then-rename (the same atomicity `core/job.py` relies on), so
concurrent replica writers never expose a torn entry, and the round trip
is bit-exact (``np.savez`` preserves dtype/shape, 0-d leaves included).
"""
from __future__ import annotations

import hashlib
import io
import os
import threading
import time
import zipfile
from collections import OrderedDict
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


def freeze(tree: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Own + freeze a feature dict: contiguous copies (detached from any
    batch buffer the scheduler will reuse) marked non-writeable."""
    out = {}
    for k, v in tree.items():
        # NOT ascontiguousarray: that silently promotes 0-d leaves
        # (total_count, keypoint_count) to shape (1,)
        a = np.array(v, order="C")       # always an owned copy
        a.setflags(write=False)
        out[k] = a
    return out


class ResultCache:
    """Thread-safe LRU over feature-result dicts.

    ``capacity`` counts entries (one per (tile, algorithm, config) key);
    0 disables the cache entirely (every get is a miss, puts are dropped)
    — the throughput benchmark uses that to measure honest batching wins.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._d: "OrderedDict[tuple, Dict[str, np.ndarray]]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.inserts = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def get(self, key) -> Optional[Dict[str, np.ndarray]]:
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
                self.hits += 1
                return self._d[key]
            self.misses += 1
            return None

    def put(self, key, value: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Insert (refreshing recency) and return the frozen stored value."""
        frozen = freeze(value)
        if self.capacity <= 0:
            return frozen
        with self._lock:
            if key in self._d:
                self._d.move_to_end(key)
            self._d[key] = frozen
            self.inserts += 1
            while len(self._d) > self.capacity:
                self._d.popitem(last=False)      # evict least-recently-used
                self.evictions += 1
            return frozen

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def keys(self):
        with self._lock:
            return list(self._d)

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"entries": len(self._d), "capacity": self.capacity,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "inserts": self.inserts,
                    "hit_rate": self.hit_rate}


class DiskCacheTier:
    """Shared on-disk result tier: one directory, one ``.npz`` per cache
    key (filename = sha256 of the key tuple, two-level fan-out so huge
    fleets don't make one giant directory).

    Writes are tmp-then-atomic-rename with a per-writer tmp name, so any
    number of replica processes/threads can write concurrently; a reader
    either sees a complete entry or none.  A corrupt/truncated file (a
    crashed writer on a non-atomic filesystem) reads as a miss and is
    removed.  Values round-trip bit-exactly: dtype, shape and 0-d leaves
    are preserved, and loaded arrays come back frozen read-only."""

    def __init__(self, root):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.inserts = 0
        self.errors = 0            # failed writes (partitioned/full disk)
        self._lock = threading.Lock()
        # disk-tier I/O latency histograms (bounded; shared across every
        # tier instance so the per-run breakdown aggregates the fleet)
        _reg = obs_metrics.registry()
        self._m_read_s = _reg.histogram("difet.cache.disk_read_s")
        self._m_write_s = _reg.histogram("difet.cache.disk_write_s")
        self._m_hits = _reg.counter("difet.cache.disk_hits")
        self._m_misses = _reg.counter("difet.cache.disk_misses")
        self._m_errors = _reg.counter("difet.cache.disk_errors")

    def path_for(self, key) -> Path:
        """Deterministic entry path for a cache key (any tuple of
        str/bytes-able parts)."""
        h = hashlib.sha256(repr(key).encode()).hexdigest()
        return self.root / h[:2] / f"{h[2:]}.npz"

    def get(self, key) -> Optional[Dict[str, np.ndarray]]:
        """Load + freeze the entry, or None (miss / torn entry)."""
        path = self.path_for(key)
        t0 = time.monotonic()
        try:
            raw = path.read_bytes()
            with np.load(io.BytesIO(raw), allow_pickle=False) as z:
                out = {}
                for k in z.files:
                    a = z[k]
                    if k.endswith("__0d"):      # un-promote 0-d leaves
                        k, a = k[:-4], a.reshape(())
                    a.setflags(write=False)
                    out[k] = a
        except FileNotFoundError:
            with self._lock:
                self.misses += 1
            self._m_misses.inc()
            return None
        except (OSError, ValueError, KeyError, zipfile.BadZipFile):
            try:
                path.unlink()                   # torn entry: drop + miss
            except OSError:
                pass
            with self._lock:
                self.misses += 1
            self._m_misses.inc()
            return None
        t1 = time.monotonic()
        with self._lock:
            self.hits += 1
        self._m_hits.inc()
        self._m_read_s.observe(t1 - t0)
        if obs_trace.enabled():                 # ambient trace id (if any)
            obs_trace.emit_span("disk_get", "cache", t0, t1,
                                bytes=len(raw))
        return out

    def put(self, key, value: Dict[str, np.ndarray]) -> None:
        """Write-through one frozen feature dict (atomic rename).

        A failed write — partitioned/unwritable directory, full disk —
        is *absorbed*, not raised: the tier is a performance layer, and
        a replica that can't reach it must degrade to recomputing, never
        crash mid-request (the cache-partition chaos test drives this).
        Failures count in ``errors`` / ``difet.cache.disk_errors``."""
        t0 = time.monotonic()
        path = self.path_for(key)
        buf = io.BytesIO()
        # savez silently promotes 0-d arrays on round trip via indexing
        # conventions elsewhere; tag them so get() restores exact shape
        np.savez(buf, **{(k + "__0d" if np.ndim(v) == 0 else k):
                         np.asarray(v) for k, v in value.items()})
        tmp = path.with_suffix(
            f".tmp.{os.getpid()}.{threading.get_ident()}")
        try:
            path.parent.mkdir(parents=True, exist_ok=True)
            tmp.write_bytes(buf.getvalue())
            tmp.replace(path)
        except OSError:
            with self._lock:
                self.errors += 1
            self._m_errors.inc()
            try:
                tmp.unlink()                    # never leave a torn tmp
            except OSError:
                pass
            return
        with self._lock:
            self.inserts += 1
        t1 = time.monotonic()
        self._m_write_s.observe(t1 - t0)
        if obs_trace.enabled():
            obs_trace.emit_span("disk_put", "cache", t0, t1,
                                bytes=buf.getbuffer().nbytes)

    def __len__(self) -> int:
        return sum(1 for _ in self.root.glob("*/*.npz"))

    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "inserts": self.inserts, "errors": self.errors}


class TieredResultCache:
    """Per-replica LRU backed by a shared :class:`DiskCacheTier`.

    ``get`` probes the local LRU first, then the disk tier (a disk hit is
    promoted into the LRU so the replica's next probe is memory-speed);
    ``put`` inserts locally and writes through to disk — so one replica's
    computation warms every replica sharing the directory.  Duck-types
    :class:`ResultCache` (``get``/``put``/``capacity``/``stats``…), so
    `serve/api.py::FeatureService` uses either interchangeably."""

    def __init__(self, capacity: int, root):
        self.local = ResultCache(capacity)
        self.disk = DiskCacheTier(root)

    @property
    def capacity(self) -> int:
        return self.local.capacity

    @property
    def hits(self) -> int:
        """Total hits across tiers (local + disk-promoted)."""
        return self.local.hits + self.disk.hits

    @property
    def misses(self) -> int:
        """True fleet-level misses: missed locally AND on disk."""
        return self.disk.misses

    def __len__(self) -> int:
        return len(self.local)

    def get(self, key) -> Optional[Dict[str, np.ndarray]]:
        hit = self.local.get(key)
        if hit is not None:
            return hit
        hit = self.disk.get(key)
        if hit is not None:
            return self.local.put(key, hit)     # promote (re-frozen copy)
        return None

    def put(self, key, value: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        frozen = self.local.put(key, value)
        self.disk.put(key, frozen)
        return frozen

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def keys(self):
        return self.local.keys()

    def stats(self) -> Dict[str, float]:
        s = self.local.stats()
        d = self.disk.stats()
        s["local_misses"] = s["misses"]
        s["misses"] = d["misses"]             # fleet-level miss definition
        s["disk_hits"] = d["hits"]
        s["disk_inserts"] = d["inserts"]
        s["hit_rate"] = self.hit_rate
        return s
