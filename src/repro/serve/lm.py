"""LM-substrate serving helpers: prefill + single-token decode steps
(KV-cache donation) and a simple batched greedy generation loop for the
example drivers.  (Moved out of ``serve/step.py`` — ``repro.serve`` proper
is the DIFET tile-serving subsystem, see ``serve/api.py``.)"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_fn(model):
    def prefill(params, batch):
        return model.prefill(params, batch)
    return prefill


def make_decode_fn(model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)
    return decode_step


def greedy_generate(model, params, prompt_tokens, n_steps, cache_len=None):
    """prompt_tokens [B, S0] -> generated [B, n_steps] (greedy, batched)."""
    b, s0 = prompt_tokens.shape
    cache_len = cache_len or (s0 + n_steps)
    cache = model.init_cache(b, cache_len)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    # feed the prompt token-by-token (cache warm-up), then generate
    logits = None
    for i in range(s0):
        logits, cache = decode(params, cache, prompt_tokens[:, i:i + 1],
                               jnp.int32(i))
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(n_steps):
        out.append(tok)
        logits, cache = decode(params, cache, tok, jnp.int32(s0 + i))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)
