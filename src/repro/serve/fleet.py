"""Replica pool + autoscaler: N ``FeatureService`` replicas behind one
:class:`serve/router.py::Router`.

This is the layer the ROADMAP calls "the fleet a load balancer would
replicate".  Replicas come in two kinds:

* **thread** (default): an in-process `serve/api.py::FeatureService`
  (its own continuous-batching scheduler, compile cache, local result
  LRU) — cheap, shares the heap, the unit-test and benchmark workhorse.
* **process** (``FleetConfig.proc=True``): a `serve/proc.py` worker
  spawned as an OS process, driven through the spooled-file transport
  (`serve/transport.py`).  Nothing is shared but what a distributed
  worker would actually share: the on-disk result tier
  (`serve/cache.py::DiskCacheTier`), `LeaseBoard` lease files, and the
  mailbox directory.  ``kill -9`` is a real SIGKILL.

Replica lifecycle::

    SPAWNING → WARMING → READY → DRAINING → RETIRED
                   │        │
                   │        └─ kill / stale lease → DEAD (chaos path)
                   └─ warm-up pre-compiles every (bucket, algorithm-set)
                      program before the replica joins the ring — a new
                      replica never serves a compile stall to traffic.

Liveness rides `core/job.py::LeaseBoard` leases under each replica's
name.  Thread replicas are heartbeaten by the fleet's maintenance tick
*only while their runner thread is alive*; process replicas heartbeat
**themselves** — the parent never refreshes a worker's lease, so a
SIGKILL stops the heartbeat at the same instant it stops the work and
the next maintenance tick past the TTL declares the replica DEAD and
re-admits its outstanding requests through `Router.readmit`
(bit-identically — extraction is deterministic).

Autoscaling is SLO-driven: the controller reads the windowed p99 of
``difet.fleet.request_latency_s`` (admission → work completion, the
histogram `serve/router.py` feeds) between ticks and scales **up** when
it breaches ``slo_p99_s``; fleet queue depth per replica is kept as a
fast-path up-trigger (a saturated queue predicts the breach before
enough completions exist to measure it).  Scale **down** only happens
when the window's p99 is comfortably under the SLO *and* queues are
shallow for ``scale_down_grace_ticks`` consecutive ticks, and only by
*draining*: the replica leaves the ring, finishes its queue, retires
with zero dropped responses.  Every decision is recorded in
``Fleet.scale_events`` (trigger metric, value, before/after replica
count) — `benchmarks/bench_fleet.py` copies them into the
``BENCH_<rev>.json`` snapshot.
"""
from __future__ import annotations

import dataclasses
import tempfile
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.job import LeaseBoard
from repro.obs import metrics as obs_metrics
from repro.obs.agg import TelemetryAggregator
from repro.obs.slo import BurnRateMonitor, SloPolicy
from repro.serve import chaos
from repro.serve.api import FeatureService, ServeConfig
from repro.serve.proc import ProcReplicaClient
from repro.serve.router import Router, RouterConfig

__all__ = ["FleetConfig", "Fleet", "Replica",
           "SPAWNING", "WARMING", "READY", "DRAINING", "RETIRED", "DEAD"]

# replica lifecycle states
SPAWNING = "spawning"
WARMING = "warming"
READY = "ready"
DRAINING = "draining"
RETIRED = "retired"
DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet knobs.  ``serve`` configures every replica (its
    ``cache_dir`` is overridden with the fleet's shared ``cache_dir``
    when set); ``router`` configures admission + routing.

    ``proc=True`` spawns replicas as OS processes (`serve/proc.py`)
    with mailboxes under ``transport_dir``; workers heartbeat their own
    leases every ``heartbeat_interval_s``.  ``lease_ttl_s`` bounds
    crash-detection latency: a replica that stops heartbeating is
    declared DEAD once its lease is this stale.

    SLO autoscaling: scale up when the windowed p99 of
    ``difet.fleet.request_latency_s`` exceeds ``slo_p99_s`` (or, fast
    path, when fleet queue depth per READY replica exceeds
    ``scale_up_queue_per_replica``); scale down — by draining — after
    ``scale_down_grace_ticks`` consecutive ticks with p99 below
    ``slo_p99_s * slo_scale_down_factor`` (an empty window counts as
    satisfied) and queues below ``scale_down_queue_per_replica``."""
    serve: ServeConfig = ServeConfig()
    router: RouterConfig = RouterConfig()
    initial_replicas: int = 2
    min_replicas: int = 1
    max_replicas: int = 8
    warm_algorithm_sets: Tuple[Tuple[str, ...], ...] = (("harris",),)
    cache_dir: Optional[str] = None       # shared result tier (all replicas)
    lease_dir: Optional[str] = None       # liveness leases (temp dir default)
    lease_ttl_s: float = 5.0
    # process-mode knobs
    proc: bool = False
    transport_dir: Optional[str] = None   # worker mailboxes (temp dir default)
    heartbeat_interval_s: float = 0.2
    worker_ready_timeout_s: float = 180.0
    # fleet telemetry plane (proc mode only): workers ship metric deltas
    # + span batches every interval (repro/obs/ship.py), the parent
    # merges them into difet.fleet.* (repro/obs/agg.py) and runs the SLO
    # burn-rate monitor over the aggregate (repro/obs/slo.py)
    telemetry: bool = False
    telemetry_interval_s: float = 0.25
    # SLO autoscaler policy
    slo_p99_s: float = 0.5
    slo_scale_down_factor: float = 0.5
    scale_up_queue_per_replica: float = 16.0
    scale_down_queue_per_replica: float = 2.0
    scale_down_grace_ticks: int = 3
    autoscale_interval_s: float = 0.5


class Replica:
    """One pool member: the service (or process-replica client) plus its
    lifecycle state and kind (``"thread"`` | ``"proc"``)."""

    def __init__(self, name: str, service, kind: str = "thread"):
        self.name = name
        self.service = service
        self.kind = kind
        self.state = SPAWNING

    def runner_alive(self) -> bool:
        """Is the replica's execution vehicle still running — the
        scheduler runner thread (thread kind) or the worker process
        (proc kind)?  Thread replicas are heartbeaten by the fleet only
        while this holds; proc replicas heartbeat themselves, so for
        them this is zombie-reaping ground truth, not liveness."""
        if self.kind == "proc":
            return self.service.alive()
        return self.service.scheduler._thread.is_alive()


class Fleet:
    """The replica pool (see module docstring).  ``fleet.router`` is the
    client-facing submit surface; the fleet itself manages membership.

    ``scale_events`` is the audit log of every autoscale decision:
    ``{"action", "trigger", "value", "slo_p99_s", "before", "after"}``
    dicts in decision order (bounded; benchmarks snapshot it into
    ``BENCH_<rev>.json``)."""

    MAX_SCALE_EVENTS = 256

    def __init__(self, cfg: Optional[FleetConfig] = None, *,
                 step_lock: Optional[threading.Lock] = None):
        self.cfg = cfg or FleetConfig()
        self.router = Router(self.cfg.router)
        lease_dir = self.cfg.lease_dir or tempfile.mkdtemp(
            prefix="difet-fleet-leases-")
        self.lease_dir = Path(lease_dir)
        self.leases = LeaseBoard(lease_dir, ttl_s=self.cfg.lease_ttl_s)
        self.transport_dir = Path(
            self.cfg.transport_dir or tempfile.mkdtemp(
                prefix="difet-fleet-mbox-")) if self.cfg.proc else None
        self._step_lock = step_lock
        self._lock = threading.RLock()
        self.replicas: Dict[str, Replica] = {}
        self.scale_events: List[Dict[str, object]] = []
        self._counter = 0
        self._idle_ticks = 0
        self._scenes: Dict[str, object] = {}
        self._autoscaler: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # fleet lifecycle counters + pool-size gauge (difet.fleet.*)
        _reg = obs_metrics.registry()
        self._m_scale_up = _reg.counter("difet.fleet.scale_up")
        self._m_scale_down = _reg.counter("difet.fleet.scale_down")
        self._m_dead = _reg.counter("difet.fleet.replicas_dead")
        self._m_stale = _reg.counter("difet.fleet.stale_lease_deaths")
        self._g_ready = _reg.gauge("difet.fleet.ready_replicas")
        # SLO controller state: windowed p99 over the router-fed
        # admission→completion histogram, baselined each tick
        self._lat_hist = _reg.histogram("difet.fleet.request_latency_s")
        self._lat_baseline = self._lat_hist.counts()
        # fleet telemetry plane: aggregator + SLO burn-rate monitor over
        # the *aggregated* latency histogram and typed shed counters —
        # the autoscaler's p99 becomes fleet-wide, not parent-only
        self.telemetry: Optional[TelemetryAggregator] = None
        self.slo_monitor: Optional[BurnRateMonitor] = None
        if self.cfg.proc and self.cfg.telemetry:
            self.telemetry = TelemetryAggregator(_reg)
            self.slo_monitor = BurnRateMonitor(
                self._lat_hist,
                shed_counters=self._shed_counters,
                policy=SloPolicy(latency_slo_s=self.cfg.slo_p99_s))
        if self.cfg.proc:
            # parallel spawn: launch every worker first (they warm
            # concurrently — jax import + compile dominates), then wait
            reps = [self._launch_proc()
                    for _ in range(self.cfg.initial_replicas)]
            for rep in reps:
                self._finalize_proc(rep)
        else:
            for _ in range(self.cfg.initial_replicas):
                self.spawn_replica()

    # ---- lifecycle ----------------------------------------------------------
    def _serve_cfg(self) -> ServeConfig:
        if self.cfg.cache_dir:
            return dataclasses.replace(self.cfg.serve,
                                       cache_dir=self.cfg.cache_dir)
        return self.cfg.serve

    def _launch_proc(self) -> Replica:
        with self._lock:
            self._counter += 1
            name = f"replica-{self._counter}"
            client = ProcReplicaClient.spawn(
                name, self.transport_dir / name, self._serve_cfg(),
                self.lease_dir,
                lease_ttl_s=self.cfg.lease_ttl_s,
                heartbeat_interval_s=self.cfg.heartbeat_interval_s,
                warm_algorithm_sets=self.cfg.warm_algorithm_sets,
                telemetry_interval_s=(self.cfg.telemetry_interval_s
                                      if self.cfg.telemetry else 0.0))
            rep = Replica(name, client, kind="proc")
            self.replicas[name] = rep
        rep.state = WARMING
        return rep

    def _finalize_proc(self, rep: Replica) -> str:
        rep.service.wait_ready(self.cfg.worker_ready_timeout_s)
        for scene_name, image in self._scenes.items():
            rep.service.register_scene(scene_name, image)
        rep.state = READY
        self.router.add_replica(rep.name, rep.service)
        self._g_ready.set(len(self.ready_replicas()))
        return rep.name

    def spawn_replica(self) -> str:
        """SPAWNING → WARMING → READY: build a service (or launch a
        worker process), pre-compile its programs, establish its
        liveness lease, join the ring.  Returns the replica name
        (``replica-N``)."""
        if self.cfg.proc:
            return self._finalize_proc(self._launch_proc())
        with self._lock:
            self._counter += 1
            name = f"replica-{self._counter}"
            svc = FeatureService(self._serve_cfg(), name=name,
                                 step_lock=self._step_lock)
            rep = Replica(name, svc)
            self.replicas[name] = rep
        rep.state = WARMING
        svc.warmup(self.cfg.warm_algorithm_sets)
        for scene_name, image in self._scenes.items():
            svc.register_scene(scene_name, image)
        self.leases.acquire(name, name)
        rep.state = READY
        self.router.add_replica(name, svc)
        self._g_ready.set(len(self.ready_replicas()))
        return name

    def drain_replica(self, name: str, timeout: float = 60.0) -> None:
        """READY → DRAINING → RETIRED: leave the ring, finish every queued
        item (zero dropped responses — tested), release the lease."""
        with self._lock:
            rep = self.replicas.get(name)
            if rep is None or rep.state not in (READY, DRAINING):
                return
            rep.state = DRAINING
        self.router.set_accepting(name, False)
        rep.service.drain(timeout)
        self.poll_telemetry()     # the worker's retire flush, if any
        self.router.remove_replica(name)
        self.leases.release(name, name)
        rep.state = RETIRED
        self._g_ready.set(len(self.ready_replicas()))

    def kill_replica(self, name: str) -> int:
        """Chaos: crash a replica mid-flight (thread: fail its futures;
        proc: real SIGKILL).  Its in-flight work is immediately
        re-admitted to the survivors; returns the router's cumulative
        re-admission count."""
        with self._lock:
            rep = self.replicas.get(name)
            if rep is None or rep.state in (RETIRED, DEAD):
                return 0
            rep.state = DEAD
        rep.service.kill()
        self.leases.release(name, name)
        self.router.remove_replica(name, died=True)
        self._m_dead.inc()
        self._g_ready.set(len(self.ready_replicas()))
        if self.telemetry is not None:
            self.telemetry.record_event("replica_died", replica=name,
                                        cause="kill")
        return self.router.readmitted

    def sigkill_replica(self, name: str) -> int:
        """Chaos, the *uncooperative* variant for process replicas: raw
        ``kill -9`` to the worker pid and nothing else — no state change,
        no router removal, no lease release.  Detection is entirely the
        maintenance tick's job (stale lease after ``lease_ttl_s``), which
        is the path a real worker crash takes.  Returns the pid killed."""
        with self._lock:
            rep = self.replicas.get(name)
        if rep is None or rep.kind != "proc":
            raise ValueError(f"{name} is not a process replica")
        pid = rep.service.pid
        chaos.sigkill(pid)
        return pid

    # ---- fleet telemetry ----------------------------------------------------
    def _shed_counters(self):
        reg = obs_metrics.registry()
        return [m for name, m in reg.metrics().items()
                if name.startswith("difet.router.shed.")
                and isinstance(m, obs_metrics.Counter)]

    def poll_telemetry(self) -> int:
        """Drain every worker mailbox's ``telemetry/`` channel into the
        aggregator (`repro/obs/agg.py`); returns shipments applied.
        No-op (0) when the telemetry plane is off."""
        if self.telemetry is None:
            return 0
        for ev in self.router.drain_events():
            self.telemetry.record_event(**ev)
        with self._lock:
            reps = [r for r in self.replicas.values() if r.kind == "proc"]
        applied = 0
        for rep in reps:
            payloads = rep.service.mailbox.collect_telemetry()
            if payloads:
                applied += self.telemetry.ingest(payloads)
        return applied

    # ---- liveness + autoscaling ---------------------------------------------
    def ready_replicas(self) -> Tuple[str, ...]:
        """Names of replicas currently in the READY state."""
        with self._lock:
            return tuple(n for n, r in self.replicas.items()
                         if r.state == READY)

    def maintenance_tick(self) -> Sequence[str]:
        """Liveness pass.  Thread replicas: heartbeat their lease while
        the runner thread lives; declare DEAD when the runner died *and*
        the lease went stale.  Process replicas: never heartbeaten here
        (the worker refreshes its own lease), so a stale lease alone —
        SIGKILL, hung worker, stalled heartbeat — declares them DEAD,
        reaps any zombie process, and re-admits their outstanding work.
        Returns the names declared dead this tick."""
        self.poll_telemetry()
        died = []
        with self._lock:
            candidates = [(n, r) for n, r in self.replicas.items()
                          if r.state in (READY, DRAINING)]
        for name, rep in candidates:
            if rep.kind == "proc":
                if self.leases.fresh(name):
                    continue
                with self._lock:
                    if rep.state == DEAD:
                        continue
                    rep.state = DEAD
                rep.service.mark_dead()
                if rep.service.alive():
                    chaos.sigkill(rep.service.pid)   # reap the zombie
                self.router.remove_replica(name, died=True)
                self.leases.release(name, name)
                self._m_dead.inc()
                self._m_stale.inc()
                if self.telemetry is not None:
                    self.telemetry.record_event(
                        "replica_died", replica=name, cause="stale_lease")
                died.append(name)
            elif rep.runner_alive():
                self.leases.acquire(name, name)      # refresh own lease
            elif not self.leases.fresh(name):
                with self._lock:
                    if rep.state == DEAD:
                        continue
                    rep.state = DEAD
                self.router.remove_replica(name, died=True)
                self.leases.release(name, name)
                self._m_dead.inc()
                died.append(name)
        if died:
            self._g_ready.set(len(self.ready_replicas()))
        return died

    def _record_scale(self, action: str, trigger: str, value: float,
                      before: int, after: int) -> None:
        event = {"action": action, "trigger": trigger,
                 "value": float(value), "slo_p99_s": self.cfg.slo_p99_s,
                 "before": int(before), "after": int(after),
                 "t": time.time()}
        with self._lock:
            self.scale_events.append(event)
            del self.scale_events[:-self.MAX_SCALE_EVENTS]
        obs_metrics.registry().counter(
            f"difet.fleet.{action}.{trigger}").inc()

    def autoscale_tick(self) -> str:
        """One SLO-controller decision (pure policy — the background
        loop and the tests both call this).  Reads the windowed p99 of
        admission→completion latency since the previous tick (harvesting
        done-but-uncollected requests first so open-loop clients count),
        plus queue depth as the fast-path up-trigger.  Returns the action
        taken: ``"scale_up:<name>"``, ``"scale_down:<name>"``, or
        ``"hold"`` — and records non-hold decisions in
        ``scale_events``.

        With the telemetry plane on, the p99 comes from the SLO
        burn-rate monitor's fast window over the *fleet-aggregated*
        latency histogram (worker shipments merged first) instead of the
        parent-only baseline — and a sustained burn-rate breach takes
        one deduped flight-recorder dump (`repro/obs/slo.py`)."""
        self.router.harvest_latencies()
        if self.slo_monitor is not None:
            self.poll_telemetry()
            p99 = self.slo_monitor.tick().get("p99_fast")
            self._lat_baseline = self._lat_hist.counts()
        else:
            p99 = self._lat_hist.quantile_since(self._lat_baseline, 0.99)
            self._lat_baseline = self._lat_hist.counts()
        ready = self.ready_replicas()
        if not ready:
            if len(self.replicas) < self.cfg.max_replicas:
                before = 0
                name = self.spawn_replica()
                self._m_scale_up.inc()
                self._record_scale("scale_up", "no_ready_replica", 0.0,
                                   before, len(self.ready_replicas()))
                return f"scale_up:{name}"
            return "hold"
        depth = self.router.total_pending()
        per_replica = depth / len(ready)
        if len(ready) < self.cfg.max_replicas:
            # SLO breach: measured p99 over the SLO target
            if p99 is not None and p99 > self.cfg.slo_p99_s:
                self._idle_ticks = 0
                before = len(ready)
                name = self.spawn_replica()
                self._m_scale_up.inc()
                self._record_scale("scale_up", "p99_latency", p99,
                                   before, len(self.ready_replicas()))
                return f"scale_up:{name}"
            # fast path: a deep queue predicts the breach before enough
            # completions exist to measure it
            if per_replica > self.cfg.scale_up_queue_per_replica:
                self._idle_ticks = 0
                before = len(ready)
                name = self.spawn_replica()
                self._m_scale_up.inc()
                self._record_scale("scale_up", "queue_depth", per_replica,
                                   before, len(self.ready_replicas()))
                return f"scale_up:{name}"
        slo_ok = (p99 is None
                  or p99 < self.cfg.slo_p99_s * self.cfg.slo_scale_down_factor)
        if slo_ok and per_replica < self.cfg.scale_down_queue_per_replica:
            self._idle_ticks += 1
            if (self._idle_ticks >= self.cfg.scale_down_grace_ticks
                    and len(ready) > self.cfg.min_replicas):
                self._idle_ticks = 0
                # retire the replica with the shallowest queue (cheapest
                # drain); ties break on name for determinism
                name = min(ready, key=lambda n: (
                    self.replicas[n].service.scheduler.queue_depth, n))
                before = len(ready)
                self.drain_replica(name)
                self._m_scale_down.inc()
                self._record_scale("scale_down", "slo_satisfied",
                                   p99 if p99 is not None else 0.0,
                                   before, len(self.ready_replicas()))
                return f"scale_down:{name}"
        else:
            self._idle_ticks = 0
        return "hold"

    def start_autoscaler(self) -> None:
        """Run maintenance + autoscale ticks on a daemon thread every
        ``autoscale_interval_s`` until ``close()``."""
        if self._autoscaler is not None:
            return

        def loop():
            while not self._stop.wait(self.cfg.autoscale_interval_s):
                try:
                    self.maintenance_tick()
                    self.autoscale_tick()
                except Exception:  # noqa: BLE001 — scaling must not crash serving
                    pass

        self._autoscaler = threading.Thread(
            target=loop, daemon=True, name="difet-fleet-autoscaler")
        self._autoscaler.start()

    # ---- client surface -----------------------------------------------------
    def submit(self, image, algorithms, tenant: str = "default",
               scene_key: Optional[str] = None,
               request_id: Optional[str] = None):
        """Router passthrough (see `serve/router.py::Router.submit`)."""
        return self.router.submit(image, algorithms, tenant=tenant,
                                  scene_key=scene_key,
                                  request_id=request_id)

    def extract(self, image, algorithms, tenant: str = "default",
                scene_key: Optional[str] = None,
                timeout: Optional[float] = None):
        """Synchronous convenience: submit + wait."""
        return self.submit(image, algorithms, tenant=tenant,
                           scene_key=scene_key).result(timeout)

    def register_scene(self, name: str, image) -> None:
        """Broadcast a scene id to every replica (current and future), so
        ``submit(name, ...)`` works wherever the request routes."""
        self._scenes[name] = image
        with self._lock:
            reps = list(self.replicas.values())
        for rep in reps:
            if rep.state in (READY, WARMING, DRAINING):
                rep.service.register_scene(name, image)

    def stats(self) -> Dict[str, object]:
        """Router aggregate + per-replica lifecycle states + the
        autoscaler's decision log."""
        s = self.router.stats()
        with self._lock:
            s["states"] = {n: r.state for n, r in self.replicas.items()}
            s["scale_events"] = [dict(e) for e in self.scale_events]
        s["ready"] = sum(1 for v in s["states"].values() if v == READY)
        return s

    def close(self, timeout: float = 60.0) -> None:
        """Shut the fleet down: stop the autoscaler, stop admitting,
        drain every live replica (accepted work completes), and reap any
        dead worker processes."""
        self._stop.set()
        if self._autoscaler is not None:
            self._autoscaler.join(self.cfg.autoscale_interval_s + 5.0)
            self._autoscaler = None
        self.router.close()
        for name in list(self.replicas):
            self.drain_replica(name, timeout)
        self.poll_telemetry()     # sweep any last shipments
        with self._lock:
            reps = list(self.replicas.values())
        for rep in reps:
            if rep.kind == "proc" and rep.service.alive():
                chaos.sigkill(rep.service.pid)
