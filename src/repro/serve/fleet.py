"""Replica pool + autoscaler: N ``FeatureService`` replicas behind one
:class:`serve/router.py::Router`.

This is the layer the ROADMAP calls "the fleet a load balancer would
replicate": each replica is an independent `serve/api.py::FeatureService`
(its own continuous-batching scheduler, compile cache, and local result
LRU), all sharing one on-disk result tier (``cache_dir`` →
`serve/cache.py::TieredResultCache`, so a computation on any replica
warms every replica) and one scene registry (broadcast on
``register_scene``).

Replica lifecycle::

    SPAWNING → WARMING → READY → DRAINING → RETIRED
                   │        │
                   │        └─ kill / stale lease → DEAD (chaos path)
                   └─ warm-up pre-compiles every (bucket, algorithm-set)
                      program (`serve/buckets.py::warmup` via
                      ``FeatureService.warmup``) before the replica joins
                      the ring — a new replica never serves a compile
                      stall to live traffic.

Liveness rides the elastic-job machinery from `core/job.py`: every
replica holds a :class:`LeaseBoard` lease under its own name, refreshed
by the fleet's maintenance tick *only while the replica's runner thread
is alive* — a crashed runner stops refreshing, the lease goes stale, and
the next tick declares the replica DEAD and re-admits its in-flight work
through the router (`Router.readmit`).  ``kill_replica`` is the same
path taken eagerly (chaos tests).

Autoscaling is queue-driven: each ``autoscale_tick`` compares the
fleet-wide pending depth per READY replica against high/low watermarks —
scale *up* immediately (spawn + warm + join), scale *down* only after
``scale_down_grace_ticks`` consecutive idle ticks (hysteresis), and only
by *draining*: the replica leaves the ring, finishes its queue, retires
with zero dropped responses.
"""
from __future__ import annotations

import dataclasses
import tempfile
import threading
from typing import Dict, Optional, Sequence, Tuple

from repro.core.job import LeaseBoard
from repro.obs import metrics as obs_metrics
from repro.serve.api import FeatureService, ServeConfig
from repro.serve.router import Router, RouterConfig

__all__ = ["FleetConfig", "Fleet", "Replica",
           "SPAWNING", "WARMING", "READY", "DRAINING", "RETIRED", "DEAD"]

# replica lifecycle states
SPAWNING = "spawning"
WARMING = "warming"
READY = "ready"
DRAINING = "draining"
RETIRED = "retired"
DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Fleet knobs.  ``serve`` configures every replica (its
    ``cache_dir`` is overridden with the fleet's shared ``cache_dir``
    when set); ``router`` configures admission + routing.

    Autoscaling: scale up when fleet queue depth per READY replica
    exceeds ``scale_up_queue_per_replica`` (and the pool is below
    ``max_replicas``); scale down after ``scale_down_grace_ticks``
    consecutive ticks below ``scale_down_queue_per_replica`` (and above
    ``min_replicas``).  ``lease_ttl_s`` bounds crash-detection latency:
    a replica whose runner died is declared DEAD once its lease is this
    stale."""
    serve: ServeConfig = ServeConfig()
    router: RouterConfig = RouterConfig()
    initial_replicas: int = 2
    min_replicas: int = 1
    max_replicas: int = 8
    warm_algorithm_sets: Tuple[Tuple[str, ...], ...] = (("harris",),)
    cache_dir: Optional[str] = None       # shared result tier (all replicas)
    lease_dir: Optional[str] = None       # liveness leases (temp dir default)
    lease_ttl_s: float = 5.0
    scale_up_queue_per_replica: float = 16.0
    scale_down_queue_per_replica: float = 2.0
    scale_down_grace_ticks: int = 3
    autoscale_interval_s: float = 0.5


class Replica:
    """One pool member: the service plus its lifecycle state."""

    def __init__(self, name: str, service: FeatureService):
        self.name = name
        self.service = service
        self.state = SPAWNING

    def runner_alive(self) -> bool:
        """Is the replica's scheduler runner thread still running?  The
        signal the maintenance tick gates heartbeats on — a dead runner
        stops heartbeating and the lease goes stale."""
        return self.service.scheduler._thread.is_alive()


class Fleet:
    """The replica pool (see module docstring).  ``fleet.router`` is the
    client-facing submit surface; the fleet itself manages membership."""

    def __init__(self, cfg: Optional[FleetConfig] = None, *,
                 step_lock: Optional[threading.Lock] = None):
        self.cfg = cfg or FleetConfig()
        self.router = Router(self.cfg.router)
        lease_dir = self.cfg.lease_dir or tempfile.mkdtemp(
            prefix="difet-fleet-leases-")
        self.leases = LeaseBoard(lease_dir, ttl_s=self.cfg.lease_ttl_s)
        self._step_lock = step_lock
        self._lock = threading.RLock()
        self.replicas: Dict[str, Replica] = {}
        self._counter = 0
        self._idle_ticks = 0
        self._scenes: Dict[str, object] = {}
        self._autoscaler: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # fleet lifecycle counters + pool-size gauge (difet.fleet.*)
        _reg = obs_metrics.registry()
        self._m_scale_up = _reg.counter("difet.fleet.scale_up")
        self._m_scale_down = _reg.counter("difet.fleet.scale_down")
        self._m_dead = _reg.counter("difet.fleet.replicas_dead")
        self._g_ready = _reg.gauge("difet.fleet.ready_replicas")
        for _ in range(self.cfg.initial_replicas):
            self.spawn_replica()

    # ---- lifecycle ----------------------------------------------------------
    def _serve_cfg(self) -> ServeConfig:
        if self.cfg.cache_dir:
            return dataclasses.replace(self.cfg.serve,
                                       cache_dir=self.cfg.cache_dir)
        return self.cfg.serve

    def spawn_replica(self) -> str:
        """SPAWNING → WARMING → READY: build a service, pre-compile its
        programs, take a liveness lease, join the ring.  Returns the
        replica name (``replica-N``)."""
        with self._lock:
            self._counter += 1
            name = f"replica-{self._counter}"
            svc = FeatureService(self._serve_cfg(), name=name,
                                 step_lock=self._step_lock)
            rep = Replica(name, svc)
            self.replicas[name] = rep
        rep.state = WARMING
        svc.warmup(self.cfg.warm_algorithm_sets)
        for scene_name, image in self._scenes.items():
            svc.register_scene(scene_name, image)
        self.leases.acquire(name, name)
        rep.state = READY
        self.router.add_replica(name, svc)
        self._g_ready.set(len(self.ready_replicas()))
        return name

    def drain_replica(self, name: str, timeout: float = 60.0) -> None:
        """READY → DRAINING → RETIRED: leave the ring, finish every queued
        item (zero dropped responses — tested), release the lease."""
        with self._lock:
            rep = self.replicas.get(name)
            if rep is None or rep.state not in (READY, DRAINING):
                return
            rep.state = DRAINING
        self.router.set_accepting(name, False)
        rep.service.drain(timeout)
        self.router.remove_replica(name)
        self.leases.release(name, name)
        rep.state = RETIRED
        self._g_ready.set(len(self.ready_replicas()))

    def kill_replica(self, name: str) -> int:
        """Chaos: crash a replica mid-flight.  Its queued + on-device
        items fail with ``ReplicaDied`` and are immediately re-admitted to
        the survivors; returns how many requests were re-admitted."""
        with self._lock:
            rep = self.replicas.get(name)
            if rep is None or rep.state in (RETIRED, DEAD):
                return 0
            rep.state = DEAD
        rep.service.kill()
        self.leases.release(name, name)
        self.router.remove_replica(name, died=True)
        self._m_dead.inc()
        self._g_ready.set(len(self.ready_replicas()))
        return self.router.readmitted

    # ---- liveness + autoscaling ---------------------------------------------
    def ready_replicas(self) -> Tuple[str, ...]:
        """Names of replicas currently in the READY state."""
        with self._lock:
            return tuple(n for n, r in self.replicas.items()
                         if r.state == READY)

    def maintenance_tick(self) -> Sequence[str]:
        """Heartbeat live replicas; declare DEAD (and re-admit the work
        of) any READY replica whose runner died and lease went stale.
        Returns the names declared dead this tick."""
        died = []
        with self._lock:
            candidates = [(n, r) for n, r in self.replicas.items()
                          if r.state in (READY, DRAINING)]
        for name, rep in candidates:
            if rep.runner_alive():
                self.leases.acquire(name, name)      # refresh own lease
            elif not self.leases.fresh(name):
                with self._lock:
                    if rep.state == DEAD:
                        continue
                    rep.state = DEAD
                self.router.remove_replica(name, died=True)
                self.leases.release(name, name)
                self._m_dead.inc()
                died.append(name)
        if died:
            self._g_ready.set(len(self.ready_replicas()))
        return died

    def autoscale_tick(self) -> str:
        """One scaling decision from live queue stats (pure policy — the
        background loop and the tests both call this).  Returns the action
        taken: ``"scale_up:<name>"``, ``"scale_down:<name>"``, or
        ``"hold"``."""
        ready = self.ready_replicas()
        if not ready:
            if len(self.replicas) < self.cfg.max_replicas:
                self._m_scale_up.inc()
                return f"scale_up:{self.spawn_replica()}"
            return "hold"
        depth = self.router.total_pending()
        per_replica = depth / len(ready)
        if (per_replica > self.cfg.scale_up_queue_per_replica
                and len(ready) < self.cfg.max_replicas):
            self._idle_ticks = 0
            self._m_scale_up.inc()
            return f"scale_up:{self.spawn_replica()}"
        if per_replica < self.cfg.scale_down_queue_per_replica:
            self._idle_ticks += 1
            if (self._idle_ticks >= self.cfg.scale_down_grace_ticks
                    and len(ready) > self.cfg.min_replicas):
                self._idle_ticks = 0
                # retire the replica with the shallowest queue (cheapest
                # drain); ties break on name for determinism
                name = min(ready, key=lambda n: (
                    self.replicas[n].service.scheduler.queue_depth, n))
                self.drain_replica(name)
                self._m_scale_down.inc()
                return f"scale_down:{name}"
        else:
            self._idle_ticks = 0
        return "hold"

    def start_autoscaler(self) -> None:
        """Run maintenance + autoscale ticks on a daemon thread every
        ``autoscale_interval_s`` until ``close()``."""
        if self._autoscaler is not None:
            return

        def loop():
            while not self._stop.wait(self.cfg.autoscale_interval_s):
                try:
                    self.maintenance_tick()
                    self.autoscale_tick()
                except Exception:  # noqa: BLE001 — scaling must not crash serving
                    pass

        self._autoscaler = threading.Thread(
            target=loop, daemon=True, name="difet-fleet-autoscaler")
        self._autoscaler.start()

    # ---- client surface -----------------------------------------------------
    def submit(self, image, algorithms, tenant: str = "default",
               scene_key: Optional[str] = None,
               request_id: Optional[str] = None):
        """Router passthrough (see `serve/router.py::Router.submit`)."""
        return self.router.submit(image, algorithms, tenant=tenant,
                                  scene_key=scene_key,
                                  request_id=request_id)

    def extract(self, image, algorithms, tenant: str = "default",
                scene_key: Optional[str] = None,
                timeout: Optional[float] = None):
        """Synchronous convenience: submit + wait."""
        return self.submit(image, algorithms, tenant=tenant,
                           scene_key=scene_key).result(timeout)

    def register_scene(self, name: str, image) -> None:
        """Broadcast a scene id to every replica (current and future), so
        ``submit(name, ...)`` works wherever the request routes."""
        self._scenes[name] = image
        with self._lock:
            reps = list(self.replicas.values())
        for rep in reps:
            if rep.state in (READY, WARMING, DRAINING):
                rep.service.register_scene(name, image)

    def stats(self) -> Dict[str, object]:
        """Router aggregate + per-replica lifecycle states."""
        s = self.router.stats()
        with self._lock:
            s["states"] = {n: r.state for n, r in self.replicas.items()}
        s["ready"] = sum(1 for v in s["states"].values() if v == READY)
        return s

    def close(self, timeout: float = 60.0) -> None:
        """Shut the fleet down: stop the autoscaler, stop admitting, and
        drain every replica (accepted work completes)."""
        self._stop.set()
        if self._autoscaler is not None:
            self._autoscaler.join(self.cfg.autoscale_interval_s + 5.0)
            self._autoscaler = None
        self.router.close()
        for name in list(self.replicas):
            self.drain_replica(name, timeout)
