"""Continuous-batching scheduler for the feature service.

Requests arrive one tile at a time; the device wants full batches.  The
scheduler keeps a FIFO of pending work items, and a single runner thread
repeatedly forms the next batch: it takes the *oldest* pending item, whose
``(bucket, algorithm-set)`` group keys the step, waits until either
``max_batch`` same-group items are pending or the head item has aged past
``max_batch_delay_s`` (the latency/throughput knob), then pops up to
``max_batch`` group members in arrival order and hands them to the runner
callback — which pads the batch to the fixed device shape and runs the
bucket's compiled program.  While a device step executes, new arrivals
keep queueing, so the next batch forms the moment the step returns:
continuous batching, no generation barriers.

Backpressure: at most ``max_pending`` items may be queued; beyond that
``submit`` raises :class:`ServiceOverloaded` (or blocks when asked to),
so a slow device surfaces as load-shedding at the edge instead of an
unbounded queue.

Determinism: batches are formed in arrival (seq) order, and per-request
results are batch-invariant (`core/engine.py::extract_request_features`),
so the *same request set in any arrival order yields bit-identical
per-request results* — tested in ``tests/test_serve.py``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace


class ServiceOverloaded(RuntimeError):
    """Raised by ``submit`` when the pending queue is at ``max_pending``."""


class ServiceClosed(RuntimeError):
    """Raised by ``submit`` once the scheduler is stopping or stopped —
    including for submitters already *blocked* on backpressure when
    ``stop()``/``kill()`` arrives: shutdown wakes them and raises this
    instead of leaving them parked on the condition variable."""


class ReplicaDied(RuntimeError):
    """Set on every unresolved future when a replica is ``kill()``-ed —
    the fleet router catches it and re-admits the work elsewhere
    (`serve/router.py`); extraction is deterministic, so re-execution is
    bit-identical."""


@dataclasses.dataclass
class WorkItem:
    """One tile awaiting a device step.  ``future`` resolves to the
    per-algorithm feature dict for this tile; ``digest``/``cfg_digest``
    ride along so the runner can insert results into the result cache.

    Future resolution goes through :meth:`resolve`/:meth:`fail` only —
    ``stop()``/``kill()`` race the in-flight ``_run_batch`` by design
    (the kill path fails every active item while the runner may be
    setting its result), and the old ad-hoc ``done()``-then-set guards
    at each call site still allowed both sides to believe they won.
    The settle flag makes first-wins explicit and auditable
    (regression-tested in ``tests/test_serve.py``)."""
    seq: int
    tile: np.ndarray                 # [hw, hw] float32, bucket-padded
    header: np.ndarray               # [6] int32
    bucket: int
    algorithms: Tuple[str, ...]
    digest: str
    cfg_digest: str
    future: Future
    enqueued_at: float = 0.0
    batch_size: int = 0              # filled by the runner
    completed_at: float = 0.0        # wall clock at batch completion (runner)
    trace_id: str = ""               # minted at router admission (obs/trace)
    settled: bool = False            # first resolve/fail wins; rest no-op
    _settle_lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False)

    @property
    def group_key(self) -> tuple:
        return (self.bucket, self.algorithms)

    def _claim(self) -> bool:
        with self._settle_lock:
            if self.settled:
                return False
            self.settled = True
            return True

    def resolve(self, value) -> bool:
        """Idempotently complete the item's future with ``value``;
        returns True iff this call won the settle race (a concurrent
        `fail` — e.g. ``kill()`` vs batch completion — is benign:
        exactly one side wins)."""
        if not self._claim():
            return False
        try:
            self.future.set_result(value)
        except InvalidStateError:      # future cancelled/settled externally
            return False
        return True

    def fail(self, exc: BaseException) -> bool:
        """Idempotently fail the item's future with ``exc``; returns
        True iff this call won the settle race."""
        if not self._claim():
            return False
        try:
            self.future.set_exception(exc)
        except InvalidStateError:
            return False
        return True


class BatchScheduler:
    """Single-runner continuous batcher over :class:`WorkItem` queues."""

    def __init__(self, run_batch: Callable[[int, Tuple[str, ...],
                                            Sequence[WorkItem]], None],
                 *, max_batch: int = 8, max_batch_delay_s: float = 0.002,
                 max_pending: int = 1024, name: str = "difet-serve"):
        self._run_batch = run_batch
        self.max_batch = int(max_batch)
        self.max_batch_delay_s = float(max_batch_delay_s)
        self.max_pending = int(max_pending)
        self._cv = threading.Condition()
        self._pending: List[WorkItem] = []
        self._active: List[WorkItem] = []   # the batch currently on-device
        self._seq = 0
        self._stopping = False
        self._killed = False
        self.batches = 0
        self.items = 0
        self.rejected = 0
        self.batch_size_hist: Dict[int, int] = {}
        # queue latency (enqueue → batch completion, seconds) — observed
        # by the service runner into a fixed-bucket histogram: bounded
        # memory forever (the old per-request deque grew with traffic and
        # its np.percentile sorted on every stats() poll), quantiles
        # answered by interpolated bucket walk (obs/metrics.py)
        self.queue_hist = obs_metrics.Histogram(f"{name}.queue_s")
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name=name)
        self._thread.start()

    # ---- client side -------------------------------------------------------
    def submit(self, tile, header, bucket, algorithms, digest="",
               cfg_digest="", block: bool = False,
               timeout: Optional[float] = None,
               trace_id: str = "") -> Future:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            if self._stopping:
                raise ServiceClosed("scheduler is stopped")
            while len(self._pending) >= self.max_pending:
                if not block:
                    self.rejected += 1
                    raise ServiceOverloaded(
                        f"{len(self._pending)} tiles pending "
                        f"(max_pending={self.max_pending})")
                rem = None if deadline is None else deadline - time.monotonic()
                if rem is not None and rem <= 0:
                    self.rejected += 1
                    raise ServiceOverloaded("timed out waiting for queue room")
                self._cv.wait(rem)
                # shutdown must wake blocked submitters: without this
                # re-check a submitter parked on backpressure would hang
                # across stop()/kill() (regression-tested)
                if self._stopping:
                    raise ServiceClosed("scheduler stopped while waiting "
                                        "for queue room")
            item = WorkItem(seq=self._seq, tile=np.asarray(tile, np.float32),
                            header=np.asarray(header, np.int32),
                            bucket=int(bucket),
                            algorithms=tuple(algorithms), digest=digest,
                            cfg_digest=cfg_digest, future=Future(),
                            enqueued_at=time.monotonic(),
                            trace_id=trace_id)
            self._seq += 1
            self._pending.append(item)
            self._cv.notify_all()
            return item.future

    @property
    def queue_depth(self) -> int:
        with self._cv:
            return len(self._pending)

    # ---- runner side -------------------------------------------------------
    def _take_batch(self) -> Tuple[tuple, List[WorkItem]]:
        """Form the next batch (called with the lock held, queue non-empty):
        oldest item keys the group; wait for fill or the head's deadline."""
        head = self._pending[0]
        key = head.group_key
        deadline = head.enqueued_at + self.max_batch_delay_s
        while not self._stopping:
            group = [it for it in self._pending if it.group_key == key]
            if len(group) >= self.max_batch:
                break
            rem = deadline - time.monotonic()
            if rem <= 0:
                break
            self._cv.wait(rem)
        group = [it for it in self._pending
                 if it.group_key == key][:self.max_batch]
        taken = {it.seq for it in group}
        self._pending = [it for it in self._pending if it.seq not in taken]
        return key, group

    def _loop(self):
        while True:
            with self._cv:
                while not self._pending and not self._stopping:
                    self._cv.wait()
                if not self._pending and self._stopping:
                    return
                (bucket, algorithms), batch = self._take_batch()
                if not batch:                  # kill() raced the take
                    continue
                self.batches += 1
                self.items += len(batch)
                self.batch_size_hist[len(batch)] = \
                    self.batch_size_hist.get(len(batch), 0) + 1
                self._active = list(batch)
                self._cv.notify_all()          # wake backpressure waiters
            for it in batch:
                it.batch_size = len(batch)
            try:
                self._run_batch(bucket, algorithms, batch)
            except BaseException as e:  # noqa: BLE001 — fail the batch, not the service
                for it in batch:
                    it.fail(e)                 # no-op if kill() already won
            finally:
                with self._cv:
                    self._active = []
                    if self._killed:
                        return

    def stop(self, timeout: Optional[float] = None):
        """Drain the queue, then stop the runner thread.  Submitters
        blocked on backpressure are woken and raise :class:`ServiceClosed`
        instead of hanging."""
        with self._cv:
            self._stopping = True
            self._cv.notify_all()
        self._thread.join(timeout)

    def kill(self, exc: Optional[BaseException] = None):
        """Crash the scheduler *without* draining (chaos path): every
        pending and in-flight (on-device) item's future fails with ``exc``
        (default :class:`ReplicaDied`) so a fleet router can re-admit the
        work; blocked submitters wake with :class:`ServiceClosed`.  An
        in-flight batch that completes concurrently wins the future race
        benignly — extraction is deterministic, so either outcome carries
        the same bits."""
        exc = exc or ReplicaDied("replica killed")
        with self._cv:
            self._stopping = True
            self._killed = True
            victims = self._pending + self._active
            self._pending = []
            self._cv.notify_all()
        rec = obs_trace.get_recorder()
        if rec.enabled:
            now = time.monotonic()
            for it in victims:                 # mark the orphaned work
                obs_trace.emit_span("killed", "scheduler", it.enqueued_at,
                                    now, trace_id=it.trace_id,
                                    scheduler=self._thread.name,
                                    exc=type(exc).__name__)
            # flight-recorder artifact: what the replica was doing when
            # it died (deduped per reason inside dump_on)
            getattr(rec, "dump_on", lambda _r: None)("replica_died")
        for it in victims:
            it.fail(exc)                       # no-op if the batch finished first

    def stats(self) -> Dict[str, object]:
        """Counter snapshot: totals, queue depth, batch-size histogram /
        mean occupancy, and p50/p99 queue latency (enqueue → batch
        completion) estimated from the bounded fixed-bucket histogram
        (`obs/metrics.py::Histogram` — constant memory at any traffic
        volume, interpolated quantiles)."""
        with self._cv:
            snap = {"batches": self.batches, "items": self.items,
                    "submitted": self._seq,
                    "rejected": self.rejected,
                    "queue_depth": len(self._pending),
                    "inflight": len(self._active),
                    "batch_size_hist": dict(sorted(
                        self.batch_size_hist.items())),
                    "mean_batch": (self.items / self.batches
                                   if self.batches else 0.0)}
        snap["occupancy"] = snap["mean_batch"] / self.max_batch
        snap["p50_queue_ms"] = self.queue_hist.quantile(0.50) * 1e3
        snap["p99_queue_ms"] = self.queue_hist.quantile(0.99) * 1e3
        return snap
