"""Shape buckets + the per-(bucket, algorithm-set) compile cache.

jit recompiles per input shape, and a public tile service sees arbitrary
tile sizes — unbounded shapes would mean unbounded compiles.  Incoming
tiles are therefore padded into a small static table of interior sizes
(the *buckets*); batches are always padded to the scheduler's fixed
``max_batch``; and the algorithm set is canonicalized — so the number of
compiled programs is exactly ``len(buckets) × len(distinct algorithm
sets)``, each compiled once (``CompileCache``), and ``warmup`` pre-pays
all of them before traffic arrives.

Padding reuses the engine's own convention: a request tile is treated as
a one-tile scene (`core/bundle.py::tile_scene`), giving a reflect-padded
halo ring and a header whose ``valid_h/valid_w`` confine detection to the
request's real pixels — bucket padding can never emit keypoints
(`nms.interior_mask`), so results are independent of which bucket a tile
landed in beyond the documented tile-size semantics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.core.bundle import tile_scene
from repro.core.engine import make_serve_step
from repro.obs import metrics as obs_metrics
from repro.obs import profile as obs_profile
from repro.obs import trace as obs_trace


class BucketTable:
    """Static table of interior sizes; ``bucket_for`` picks the smallest
    bucket that holds a tile (None = bigger than every bucket, the caller
    splits it into a multi-tile scene request)."""

    def __init__(self, interiors: Sequence[int], base: DifetConfig):
        self.interiors: Tuple[int, ...] = tuple(sorted(set(int(i)
                                                           for i in interiors)))
        if not self.interiors:
            raise ValueError("bucket table needs at least one interior size")
        self.base = base
        self._cfgs: Dict[int, DifetConfig] = {}

    @property
    def halo(self) -> int:
        return self.base.halo

    def bucket_for(self, h: int, w: int) -> Optional[int]:
        side = max(int(h), int(w))
        for interior in self.interiors:
            if side <= interior:
                return interior
        return None

    def cfg_for(self, bucket: int) -> DifetConfig:
        if bucket not in self._cfgs:
            if bucket not in self.interiors:
                raise KeyError(f"{bucket} is not a bucket "
                               f"(table: {self.interiors})")
            self._cfgs[bucket] = dataclasses.replace(self.base, tile=bucket)
        return self._cfgs[bucket]

    def pad_to_bucket(self, gray: np.ndarray, bucket: int):
        """Pad one grayscale tile into its bucket canvas.  Returns
        ``(tile [hw, hw] float32, header [6] int32)`` with hw =
        bucket + 2*halo; the header's valid extent is the tile's own
        shape, so detection ignores the padding.  Output is bit-identical
        to ``tile_scene`` on the same tile (tested) — the fast path just
        skips ``np.pad``'s generic machinery, which dominated the
        per-request submit cost."""
        gray = np.asarray(gray, np.float32)
        h, w = gray.shape
        if min(h, w) < 2:
            raise ValueError(f"tile {h}x{w} too small: reflect padding "
                             f"needs at least 2 pixels per side")
        tile = _reflect_pad_fast(gray, bucket, self.halo)
        if tile is None:    # pad needs numpy's multi-bounce reflection
            b = tile_scene(gray, self.cfg_for(bucket))
            assert len(b) == 1, "tile exceeded its bucket"
            return b.tiles[0], b.headers[0]
        header = np.array([0, 0, 0, h, w, 0], np.int32)
        return tile, header


def _reflect_pad_fast(gray: np.ndarray, t: int, halo: int):
    """Single-bounce reflect pad of one tile to ``(t+2h) x (t+2h)`` —
    exactly ``np.pad(gray, ((h, h+t-H), (h, h+t-W)), 'reflect')`` (the
    ``tile_scene`` convention: axis 0 first, then axis 1 over the padded
    rows), hand-rolled as six slice copies.  Returns None when any pad
    width needs numpy's multi-bounce reflection (tiny tiles in big
    buckets) and the caller falls back to ``tile_scene``."""
    h, w = gray.shape
    pb, pr = halo + t - h, halo + t - w          # bottom / right pad widths
    if max(halo, pb) > h - 1 or max(halo, pr) > w - 1:
        return None
    hw = t + 2 * halo
    rows = np.empty((hw, w), np.float32)
    rows[halo:halo + h] = gray
    rows[:halo] = gray[halo:0:-1]
    rows[halo + h:] = gray[h - 2::-1][:pb]
    out = np.empty((hw, hw), np.float32)
    out[:, halo:halo + w] = rows
    out[:, :halo] = rows[:, halo:0:-1]
    out[:, halo + w:] = rows[:, w - 2::-1][:, :pr]
    return out


class CompileCache:
    """(bucket, algorithm-set) → jitted serving step; one program each.

    The scheduler pads every batch to ``max_batch`` rows, so each program
    sees exactly one input shape and jit-compiles exactly once.
    ``programs`` counts distinct programs built — the serving metric the
    benchmark reports as compile-cache size."""

    def __init__(self, table: BucketTable, max_batch: int,
                 use_pallas: bool = False):
        self.table = table
        self.max_batch = int(max_batch)
        self.use_pallas = use_pallas
        self._fns: Dict[tuple, object] = {}

    @property
    def programs(self) -> int:
        return len(self._fns)

    def keys(self):
        return sorted(self._fns)

    def get(self, bucket: int, algorithms: Tuple[str, ...]):
        key = (int(bucket), tuple(algorithms))
        fn = self._fns.get(key)
        if fn is None:
            fn = make_serve_step(key[1], self.table.cfg_for(key[0]),
                                 use_pallas=self.use_pallas)
            self._fns[key] = fn
        return fn

    def empty_batch(self, bucket: int):
        """An all-padding batch at this bucket's device shape (header pad
        flag set, so nothing detects) — the warm-up input, also used by the
        scheduler runner as the canvas real tiles are scattered into."""
        hw = bucket + 2 * self.table.halo
        tiles = np.zeros((self.max_batch, hw, hw), np.float32)
        headers = np.zeros((self.max_batch, 6), np.int32)
        headers[:, 5] = 1
        return tiles, headers


def warmup(compile_cache: CompileCache,
           algorithm_sets: Sequence[Tuple[str, ...]],
           buckets: Optional[Sequence[int]] = None) -> int:
    """Warm-up driver: compile every (bucket, algorithm-set) pair by
    pushing one all-padding batch through each program, so no live request
    ever pays a compile.  Returns the number of compiled programs."""
    hist = obs_metrics.registry().histogram("difet.compile.program_s")
    for bucket in (buckets if buckets is not None
                   else compile_cache.table.interiors):
        tiles, headers = compile_cache.empty_batch(bucket)
        for algs in algorithm_sets:
            key = (int(bucket), tuple(algs))
            fresh = key not in compile_cache._fns
            fn = compile_cache.get(bucket, tuple(algs))
            t0 = time.monotonic()
            jax.block_until_ready(fn(tiles, headers))
            t1 = time.monotonic()
            if fresh:                          # first call = trace + compile
                hist.observe(t1 - t0)
                obs_profile.record_compile(
                    f"serve:{bucket}:{'+'.join(algs)}", t1 - t0)
                if obs_trace.enabled():
                    obs_trace.emit_span(
                        "compile_program", "compile", t0, t1, trace_id="",
                        bucket=bucket, algorithms=",".join(algs))
    return compile_cache.programs
