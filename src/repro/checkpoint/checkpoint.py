"""Mesh-agnostic checkpointing with async save, integrity manifest, pruning,
and elastic restore.

Checkpoints store *logical* (unsharded) tensors keyed by tree path, so a
restart may use a different mesh shape / worker count: restore re-applies
the current sharding rules via ``device_put`` (elastic scaling).  Saves are
atomic (write to tmp dir, rename) and a JSON manifest records step + per-
tensor checksums for integrity; a half-written checkpoint is never visible,
so node failure during save costs at most one checkpoint interval — the
JobTracker-commit analogue of DESIGN.md §2.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Optional

import jax
import ml_dtypes
import numpy as np
from jax import tree_util as jtu


def _restore_dtype(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    """npz round-trips ml_dtypes (bf16 etc.) as raw void — view them back."""
    if str(arr.dtype) == dtype_str:
        return arr
    target = np.dtype(getattr(ml_dtypes, dtype_str, dtype_str))
    if arr.dtype.kind == "V" and arr.dtype.itemsize == target.itemsize:
        return arr.view(target)
    return arr.astype(target)


def _flatten(tree):
    leaves = []
    for path, leaf in jtu.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(k.key) if isinstance(k, jtu.DictKey) else str(getattr(k, "idx", k))
            for k in path)
        leaves.append((key, leaf))
    return leaves


class CheckpointManager:
    def __init__(self, root, keep_n: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: Optional[threading.Thread] = None

    # ---------------- save ---------------------------------------------------
    def save(self, state, step: int, async_: bool = False):
        # materialize on host *now* (so training can proceed under async)
        host = {k: np.asarray(v) for k, v in _flatten(state)}
        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(host, step), daemon=True)
            self._thread.start()
        else:
            self._write(host, step)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host, step: int):
        tmp = self.root / f".tmp_step_{step}"
        final = self.root / f"step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "time": time.time(), "tensors": {}}
        np.savez(tmp / "tensors.npz", **host)
        for k, v in host.items():
            manifest["tensors"][k] = {
                "shape": list(v.shape), "dtype": str(v.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(v).tobytes()) & 0xffffffff,
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.replace(final)                      # atomic publish
        self._prune()

    def _prune(self):
        steps = self.list_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(self.root / f"step_{s:010d}", ignore_errors=True)

    # ---------------- restore ------------------------------------------------
    def list_steps(self):
        return sorted(int(p.name.split("_")[1])
                      for p in self.root.glob("step_*"))

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: Optional[int] = None, shardings=None,
                verify: bool = True):
        """Restore into the structure of ``target`` (an abstract or concrete
        state tree).  ``shardings``: optional matching tree of NamedSharding
        for elastic restore onto the current mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        d = self.root / f"step_{step:010d}"
        manifest = json.loads((d / "manifest.json").read_text())
        z = np.load(d / "tensors.npz")
        if verify:
            for k, meta in manifest["tensors"].items():
                crc = zlib.crc32(np.ascontiguousarray(z[k]).tobytes()) & 0xffffffff
                if crc != meta["crc32"]:
                    raise IOError(f"checkpoint corruption in tensor {k!r}")
        flat_target = _flatten(target)
        treedef = jtu.tree_structure(target)
        sh_leaves = (jtu.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else [None] * len(flat_target))
        leaves = []
        for (key, ref_leaf), sh in zip(flat_target, sh_leaves):
            arr = _restore_dtype(z[key], manifest["tensors"][key]["dtype"])
            if tuple(arr.shape) != tuple(ref_leaf.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"target {ref_leaf.shape}")
            if sh is not None:
                leaves.append(jax.device_put(arr, sh))
            else:
                leaves.append(jax.numpy.asarray(
                    arr, dtype=getattr(ref_leaf, "dtype", None)))
        return jtu.tree_unflatten(treedef, leaves), step
