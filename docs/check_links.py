#!/usr/bin/env python
"""Intra-repo link check over docs/ + the top-level markdown pages.

Scans every ``[text](target)`` in the checked pages and fails (non-zero
exit) when a *relative* target does not resolve to a file in the repo —
broken cross-page links are how a docs tree rots.  ``#anchor`` fragments
on markdown targets are verified against the target page's headings
(GitHub slug rules: lowercase, spaces → dashes, punctuation dropped).
External ``http(s)://`` links are not fetched (CI must not depend on the
network); they are only counted.

    python docs/check_links.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

PAGES = sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("*.md"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    s = heading.strip().lower()
    s = re.sub(r"[^\w\s§-]", "", s, flags=re.UNICODE)
    return re.sub(r"\s+", "-", s)


def prose_lines(path: Path) -> list:
    """The page's lines with fenced code blocks removed — code samples
    are neither links to check nor headings that define anchors."""
    out = []
    fenced = False
    for line in path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if not fenced:
            out.append(line)
    return out


def page_anchors(path: Path) -> set:
    """The set of anchor slugs a markdown page exposes."""
    anchors = set()
    for line in prose_lines(path):
        if line.startswith("#"):
            anchors.add(slugify(line.lstrip("#")))
    return anchors


def check_page(path: Path) -> list:
    """Return a list of broken-link descriptions for one page."""
    errors = []
    text = "\n".join(prose_lines(path))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        base, _, anchor = target.partition("#")
        dest = (path.parent / base).resolve() if base else path
        if base and not dest.exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link "
                          f"-> {target}")
            continue
        if anchor and dest.suffix == ".md" and dest.exists():
            if anchor.lower() not in page_anchors(dest):
                errors.append(f"{path.relative_to(ROOT)}: missing anchor "
                              f"-> {target}")
    return errors


def main() -> int:
    errors = []
    n_links = 0
    for page in PAGES:
        n_links += len(LINK_RE.findall("\n".join(prose_lines(page))))
        errors.extend(check_page(page))
    if errors:
        print(f"link check FAILED ({len(errors)} broken):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"link check OK: {len(PAGES)} pages, {n_links} links")
    return 0


if __name__ == "__main__":
    sys.exit(main())
