"""Roofline report: reads the dry-run JSONs, (re)computes the three terms
under the per-device convention, and renders the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.analysis import (
    roofline_terms, model_flops, active_param_count)


def reprocess(path: Path) -> dict:
    d = json.loads(path.read_text())
    cfg = get_config(d["arch"])
    n_active = active_param_count(cfg, d["n_params"])
    kind = "train" if d["shape"].startswith("train") else "serve"
    d["n_active_params"] = n_active
    d["roofline"] = roofline_terms(
        d["cost"]["hlo_flops"], d["cost"]["hlo_bytes"],
        d["collective_bytes_total"], d["n_chips"])
    shape_tokens = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                    "decode_32k": 128, "long_500k": 1}
    d["model_flops"] = model_flops(n_active, shape_tokens[d["shape"]], kind)
    d["useful_flops_ratio"] = d["model_flops"] / (
        d["cost"]["hlo_flops"] * d["n_chips"]) if d["cost"]["hlo_flops"] else 0.0
    path.write_text(json.dumps(d, indent=1))
    return d


def render_table(results, mesh_tag: str) -> str:
    lines = [
        f"### Mesh {mesh_tag} ({results[0]['n_chips']} chips) — "
        "scan-corrected terms where available (* = uncorrected)",
        "",
        "| arch | shape | GiB/dev | compute (s) | memory (s) | collective (s)"
        " | dominant | roofline frac | useful FLOPs |",
        "|---|---|---:|---:|---:|---:|---|---:|---:|",
    ]
    for d in results:
        corr = d.get("corrected")
        r = corr["roofline"] if corr else d["roofline"]
        useful = (corr or d)["useful_flops_ratio"]
        star = "" if corr else "*"
        lines.append(
            f"| {d['arch']}{star} | {d['shape']} "
            f"| {d['memory']['peak_bytes_per_device']/2**30:.2f} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].replace('_s','')} "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {useful*100:.1f}% |")
    return "\n".join(lines)


def scalespace_hbm_table(tile_hw=(176, 304), scales_per_octave=3,
                         sigma0=1.6) -> str:
    """Analytic HBM traffic per tile per octave: the seed's level-by-level
    SIFT path vs the fused scale-space kernel.

    Counting convention (fp32 = 4 B/px): the seed path writes + re-reads
    every materialized intermediate — each Gaussian level's two separable
    passes, each DoG level, the 26-neighbour extrema stack over the mid
    scales, and the response; the fused kernel DMAs the padded tile in
    ONCE and writes only the response and the next-octave seed (no
    per-level Gaussian materialization in the measured ratio).
    """
    from repro.kernels.ops import (scalespace_pad, scalespace_vmem_bytes,
                                   scalespace_fits_vmem)
    n_levels = scales_per_octave + 3
    n_dogs = n_levels - 1
    n_mid = n_dogs - 2
    lines = [
        "### Fused scale-space: HBM bytes per tile per octave "
        f"(S={scales_per_octave}, sigma0={sigma0})",
        "",
        "| tile extent | seed level-by-level | fused kernel | ratio "
        "| VMEM est. | fused on TPU? |",
        "|---|---:|---:|---:|---:|---|",
    ]
    for hw in tile_hw:
        px = hw * hw * 4
        # seed: each blur pass writes+reads its output; DoG reads 2 levels,
        # writes 1; the extrema stack materializes 26 neighbour maps per
        # mid scale (read+write), then the response.
        seed_b = px * (n_levels * 2 * 2      # 2 passes x (write + read)
                       + n_dogs * 3          # DoG: 2 reads + 1 write
                       + n_mid * 26 * 2      # neighbour stack
                       + n_mid * 2 + 1)      # |mid|/threshold + response
        p = scalespace_pad(scales_per_octave, sigma0)
        fused_b = (hw + 2 * p) * (hw + 2 * p) * 4 + 2 * px   # in + 2 outs
        vmem = scalespace_vmem_bytes(hw, hw, scales_per_octave, sigma0)
        fits = scalespace_fits_vmem(hw, hw, scales_per_octave, sigma0)
        lines.append(
            f"| {hw}x{hw} | {seed_b / 2**20:.1f} MiB | "
            f"{fused_b / 2**20:.2f} MiB | {seed_b / fused_b:.1f}x | "
            f"{vmem / 2**20:.1f} MiB | {'yes' if fits else 'no (jnp path)'} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown-out", default=None)
    args = ap.parse_args()
    by_mesh = {}
    for f in sorted(glob.glob(f"{args.dir}/*.json")):
        d = reprocess(Path(f))
        by_mesh.setdefault(d["mesh"], []).append(d)
    out = []
    for mesh_tag, results in sorted(by_mesh.items()):
        out.append(render_table(results, mesh_tag))
        out.append("")
    out.append(scalespace_hbm_table())
    out.append("")
    text = "\n".join(out)
    print(text)
    if args.markdown_out:
        Path(args.markdown_out).write_text(text)


if __name__ == "__main__":
    main()
