"""Roofline report: reads the dry-run JSONs, (re)computes the three terms
under the per-device convention, and renders the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

from repro.configs import get_config
from repro.launch.analysis import (
    roofline_terms, model_flops, active_param_count)


def reprocess(path: Path) -> dict:
    d = json.loads(path.read_text())
    cfg = get_config(d["arch"])
    n_active = active_param_count(cfg, d["n_params"])
    kind = "train" if d["shape"].startswith("train") else "serve"
    d["n_active_params"] = n_active
    d["roofline"] = roofline_terms(
        d["cost"]["hlo_flops"], d["cost"]["hlo_bytes"],
        d["collective_bytes_total"], d["n_chips"])
    shape_tokens = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
                    "decode_32k": 128, "long_500k": 1}
    d["model_flops"] = model_flops(n_active, shape_tokens[d["shape"]], kind)
    d["useful_flops_ratio"] = d["model_flops"] / (
        d["cost"]["hlo_flops"] * d["n_chips"]) if d["cost"]["hlo_flops"] else 0.0
    path.write_text(json.dumps(d, indent=1))
    return d


def render_table(results, mesh_tag: str) -> str:
    lines = [
        f"### Mesh {mesh_tag} ({results[0]['n_chips']} chips) — "
        "scan-corrected terms where available (* = uncorrected)",
        "",
        "| arch | shape | GiB/dev | compute (s) | memory (s) | collective (s)"
        " | dominant | roofline frac | useful FLOPs |",
        "|---|---|---:|---:|---:|---:|---|---:|---:|",
    ]
    for d in results:
        corr = d.get("corrected")
        r = corr["roofline"] if corr else d["roofline"]
        useful = (corr or d)["useful_flops_ratio"]
        star = "" if corr else "*"
        lines.append(
            f"| {d['arch']}{star} | {d['shape']} "
            f"| {d['memory']['peak_bytes_per_device']/2**30:.2f} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | {r['dominant'].replace('_s','')} "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {useful*100:.1f}% |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown-out", default=None)
    args = ap.parse_args()
    by_mesh = {}
    for f in sorted(glob.glob(f"{args.dir}/*.json")):
        d = reprocess(Path(f))
        by_mesh.setdefault(d["mesh"], []).append(d)
    out = []
    for mesh_tag, results in sorted(by_mesh.items()):
        out.append(render_table(results, mesh_tag))
        out.append("")
    text = "\n".join(out)
    print(text)
    if args.markdown_out:
        Path(args.markdown_out).write_text(text)


if __name__ == "__main__":
    main()
