"""Serving-subsystem benchmark + parity gate.

Four measurements on an in-process :class:`FeatureService`:

* **throughput** — the same unique-tile workload (cache disabled, so the
  win is honest batching, not memoization) through a one-request-at-a-time
  service (``max_batch=1``, the sequential baseline: a synchronous client,
  each request paying the full round trip) vs continuous-batched services
  at batch 8/16/32.  Deliverable: batched >= 3x sequential at batch 32 on
  a 2-core CPU host (batching amortizes the per-dispatch overhead that
  dominates small-tile extraction; on TPU the win is larger — one device
  step vs B).
* **latency** — closed-loop p50/p99 per batch setting.
* **cache** — a second pass over the same tiles must be served 100% from
  the content-hash result cache.
* **parity** — served results must be *bit-identical* to direct
  ``core/engine.py::extract_features_multi`` calls on the same padded
  tiles.

Parity and the 100%-hit-rate check are CI gates: ``main`` exits non-zero
on mismatch, and ``run(strict=True)`` (the ``benchmarks/run.py`` path)
raises so the harness marks the section failed.

    PYTHONPATH=src python -m benchmarks.run --quick       # CI entry
    PYTHONPATH=src python -m benchmarks.bench_serve       # standalone
"""
from __future__ import annotations

import argparse
import functools
import time

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.data.landsat import synthetic_scene
from repro.serve import FeatureService, ServeConfig

ALGS = ("harris", "shi_tomasi")
TILE, HALO, K = 32, 8, 32


class BenchGateError(AssertionError):
    """A serving CI gate (parity / cache hit-rate) failed."""


def _service(max_batch: int, cache_entries: int) -> FeatureService:
    base = DifetConfig(tile=TILE, halo=HALO, max_keypoints_per_tile=K)
    svc = FeatureService(ServeConfig(
        base=base, buckets=(TILE,), max_batch=max_batch,
        max_batch_delay_s=0.02, max_pending=4096,
        cache_entries=cache_entries))
    svc.warmup([ALGS])
    return svc


def _pool(n: int):
    return [synthetic_scene(TILE, TILE, seed) for seed in range(n)]


def _one_pass(svc: FeatureService, pool, sequential: bool):
    """One workload pass; seconds per request + latency percentiles.
    ``sequential`` is the one-request-at-a-time baseline: a synchronous
    client that waits for each response before sending the next (every
    request pays the full submit→step→respond round trip).  Otherwise an
    async client submits the whole workload and the scheduler batches
    continuously."""
    t0 = time.perf_counter()
    if sequential:
        resps = [svc.extract(tile, ALGS, timeout=120) for tile in pool]
    else:
        handles = [svc.submit(tile, ALGS, block=True) for tile in pool]
        resps = [h.result(120) for h in handles]
    dt = time.perf_counter() - t0
    lat = np.asarray([r.timing["latency_s"] for r in resps])
    return dt / len(pool), np.percentile(lat, 50), np.percentile(lat, 99)


def run(quick: bool = False, strict: bool = True):
    import jax
    from repro.core import engine

    n_unique = 64
    batches = (8, 32) if quick else (8, 16, 32)
    repeats = 3 if quick else 4
    pool = _pool(n_unique)
    rows = []

    # -- sequential baseline + batched throughput (cache off) ---------------
    # settings are measured round-robin (best-of across interleaved rounds)
    # so a noisy-CPU epoch can't land entirely on one setting and skew the
    # speedup ratio
    settings = [(1, True)] + [(b, False) for b in batches]
    services = {b: _service(max_batch=b, cache_entries=0)
                for b, _ in settings}
    best = {b: (np.inf, 0.0, 0.0) for b, _ in settings}
    for _ in range(repeats):
        for b, sequential in settings:
            t, p50, p99 = _one_pass(services[b], pool, sequential)
            if t < best[b][0]:
                best[b] = (t, p50, p99)
    t_seq, p50, p99 = best[1]
    rows.append(("serve/sequential_b1", t_seq * 1e6,
                 f"req_per_s={1.0 / t_seq:.1f};p50_ms={p50 * 1e3:.2f};"
                 f"p99_ms={p99 * 1e3:.2f}"))
    for b in batches:
        t_b, p50, p99 = best[b]
        sched = services[b].scheduler.stats()
        rows.append((f"serve/batched_b{b}", t_b * 1e6,
                     f"speedup_vs_seq={t_seq / t_b:.2f};"
                     f"req_per_s={1.0 / t_b:.1f};p50_ms={p50 * 1e3:.2f};"
                     f"p99_ms={p99 * 1e3:.2f};"
                     f"mean_batch={sched['mean_batch']:.1f}"))
    for svc in services.values():
        svc.close()

    # -- content-hash cache: repeated-tile workload -------------------------
    svc = _service(max_batch=8, cache_entries=4 * n_unique)
    for tile in pool:                       # cold pass: all misses
        svc.submit(tile, ALGS, block=True).result(120)
    cold = svc.cache.stats()
    t0 = time.perf_counter()
    repeat = [svc.submit(tile, ALGS, block=True).result(120)
              for tile in pool]             # warm pass: must be 100% hits
    t_hit = (time.perf_counter() - t0) / len(pool)
    all_cached = all(r.fully_cached for r in repeat)
    warm = svc.cache.stats()
    hit_rate_warm = ((warm["hits"] - cold["hits"])
                     / (len(ALGS) * len(pool)))  # warm-pass probes only
    rows.append(("serve/cache_repeat", t_hit * 1e6,
                 f"warm_hit_rate={hit_rate_warm:.2f};"
                 f"all_cached={all_cached};"
                 f"speedup_vs_seq={t_seq / t_hit:.1f}"))

    # -- parity gate: served == direct engine call, bit-identical -----------
    bucket = svc.table.interiors[0]
    direct_fn = jax.jit(functools.partial(
        engine.extract_features_multi, algorithms=ALGS,
        cfg=svc.table.cfg_for(bucket)))
    n_check = 8 if quick else 16
    mismatches = []
    for i in range(n_check):
        tile, header = svc.table.pad_to_bucket(pool[i], bucket)
        direct = direct_fn(tile[None], header[None])
        served = repeat[i].results
        for alg in ALGS:
            for key, v in direct[alg].items():
                a, b2 = np.asarray(v), served[alg][key]
                if a.shape != b2.shape or not np.array_equal(a, b2):
                    mismatches.append(f"{i}/{alg}/{key}")
    parity_ok = not mismatches
    rows.append(("serve/parity", 0.0,
                 f"parity_allclose={parity_ok};"
                 f"checked={n_check}x{len(ALGS)}alg"))
    rows.append(("serve/compile_cache", 0.0,
                 f"programs={svc.compile_cache.programs};"
                 f"keys={len(svc.compile_cache.keys())}"))
    svc.close()

    if strict:
        if not parity_ok:
            raise BenchGateError(
                f"served results diverged from direct engine calls: "
                f"{mismatches[:8]}")
        if not all_cached or hit_rate_warm < 1.0:
            raise BenchGateError(
                f"repeated-tile workload not fully cached "
                f"(warm hit rate {hit_rate_warm:.2f})")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    try:
        for name, us, derived in run(args.quick, strict=True):
            print(f"{name},{us:.1f},{derived}")
    except BenchGateError as e:
        print(f"serve/GATE,0,ERROR={e}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
