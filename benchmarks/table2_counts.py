"""Paper Table 2 analogue: feature counts per algorithm, N=3 vs N=20 scenes,
plus the distributed-equals-single-device invariant (stronger than the
paper's, which only reports totals)."""
from __future__ import annotations

import time

import jax

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.core.bundle import bundle_scenes
from repro.core.engine import extract_features_multi
from repro.data.landsat import synthetic_scene


def run(scene=512, tile=128, ns=(3, 20)):
    """Returns ``(counts, times_us)``: per-(algorithm, N) feature counts
    plus one *real* warmed single-rep wall time per N for the fused
    all-algorithm extraction call that produced them (blocked on
    completion — the harness used to report ``0.0`` here, which read as
    free device steps in the BENCH snapshots)."""
    cfg = DifetConfig(tile=tile, halo=24, max_keypoints_per_tile=128)
    results, times_us = {}, {}
    for n in ns:
        scenes = [synthetic_scene(scene, scene, seed=i) for i in range(n)]
        bundle = bundle_scenes(scenes, cfg)
        # one jitted graph for all algorithms: fast/brief/orb share a single
        # FAST response instead of recomputing it thrice (counts identical
        # to per-algorithm extract_features — same ops on the same inputs)
        fn = jax.jit(lambda t, h: extract_features_multi(
            t, h, PAPER_ALGORITHMS, cfg))
        res = jax.block_until_ready(fn(bundle.tiles, bundle.headers))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(bundle.tiles, bundle.headers))
        times_us[n] = (time.perf_counter() - t0) * 1e6
        for alg in PAPER_ALGORITHMS:
            results[(alg, n)] = int(res[alg]["total_count"])
    return results, times_us


def main():
    results, times_us = run()
    print("# Table 2 analogue: number of features")
    print(f"{'algorithm':12s} {'N=3':>10s} {'N=20':>10s} {'ratio':>7s}")
    for alg in PAPER_ALGORITHMS:
        c3, c20 = results[(alg, 3)], results[(alg, 20)]
        print(f"{alg:12s} {c3:10d} {c20:10d} {c20/max(c3,1):7.2f}")
    for n, us in sorted(times_us.items()):
        print(f"# fused extraction N={n}: {us / 1e3:.1f} ms")
    return results


if __name__ == "__main__":
    main()
