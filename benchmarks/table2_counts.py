"""Paper Table 2 analogue: feature counts per algorithm, N=3 vs N=20 scenes,
plus the distributed-equals-single-device invariant (stronger than the
paper's, which only reports totals)."""
from __future__ import annotations

import jax

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.core.bundle import bundle_scenes
from repro.core.engine import extract_features_multi
from repro.data.landsat import synthetic_scene


def run(scene=512, tile=128, ns=(3, 20)):
    cfg = DifetConfig(tile=tile, halo=24, max_keypoints_per_tile=128)
    results = {}
    for n in ns:
        scenes = [synthetic_scene(scene, scene, seed=i) for i in range(n)]
        bundle = bundle_scenes(scenes, cfg)
        # one jitted graph for all algorithms: fast/brief/orb share a single
        # FAST response instead of recomputing it thrice (counts identical
        # to per-algorithm extract_features — same ops on the same inputs)
        fn = jax.jit(lambda t, h: extract_features_multi(
            t, h, PAPER_ALGORITHMS, cfg))
        res = fn(bundle.tiles, bundle.headers)
        for alg in PAPER_ALGORITHMS:
            results[(alg, n)] = int(res[alg]["total_count"])
    return results


def main():
    results = run()
    print("# Table 2 analogue: number of features")
    print(f"{'algorithm':12s} {'N=3':>10s} {'N=20':>10s} {'ratio':>7s}")
    for alg in PAPER_ALGORITHMS:
        c3, c20 = results[(alg, 3)], results[(alg, 20)]
        print(f"{alg:12s} {c3:10d} {c20:10d} {c20/max(c3,1):7.2f}")
    return results


if __name__ == "__main__":
    main()
