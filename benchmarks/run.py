"""Benchmark harness — one entry per paper table + framework micro-benches.
Prints ``name,us_per_call,derived`` CSV rows (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, repeats=3):
    # the warm-up must BLOCK: without it, the warm-up call's compile and
    # async execution bleed into the timed region and the first-benched
    # function eats the whole backlog (this exact bug made the production
    # L2 matcher read 16x slower than its oracle in BENCH_61e2246 — the
    # regression was the harness, not the matcher)
    out = fn(*args)                             # compile/warm
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / repeats * 1e6


def bench_table1(quick):
    """Streaming-ingest worker sweep (one row per algorithm × worker
    count, so the whole speedup/efficiency curve lands in the BENCH
    snapshot) + the hard scalability gate: bit-parity at every worker
    count and ≥1.6x at 2 workers for the anchor algorithm — a gate
    failure raises (after one re-measure for CPU-quota noise), which
    fails this section and the CI step."""
    from benchmarks.table1_scalability import run_gated
    from repro.configs.difet_paper import PAPER_ALGORITHMS
    rows = run_gated(n_scenes=3, scene=256 if quick else 512,
                     workers=(1, 2) if quick else (1, 2, 4),
                     batch_tiles=2 if quick else 4,
                     algorithms=("harris", "fast", "sift") if quick
                     else PAPER_ALGORITHMS)
    out = []
    for r in rows:
        for w in sorted(r["t"]):
            out.append((
                f"table1/{r['algorithm']}/w{w}", r["t"][w] * 1e6,
                f"speedup={r['speedup'][w]:.2f};"
                f"efficiency={r['efficiency'][w]:.2f};"
                f"parity={r['parity']};count={r['total_count']}"))
    return out


def bench_table2(quick):
    from benchmarks.table2_counts import run
    results, times_us = run(scene=256 if quick else 512,
                            ns=(3,) if quick else (3, 20))
    out = []
    for (alg, n), c in sorted(results.items()):
        # counts per algorithm come from ONE fused all-algorithm call per
        # N, so the honest per-row timing is that shared call's warmed
        # single-rep wall time (rows used to claim us_per_call=0.0)
        out.append((f"table2/{alg}_N{n}", times_us[n],
                    f"count={c};fused_call=1"))
    return out


def bench_kernels(quick):
    from repro.kernels import ops, ref
    from repro.data.landsat import synthetic_scene
    img = jnp.asarray(np.stack([synthetic_scene(256, 256, i)
                                for i in range(2)]))
    out = []
    for name, pallas_fn, ref_fn in [
        ("harris", lambda x: ops.harris(x), lambda x: ref.harris(x)),
        ("blur", lambda x: ops.gaussian_blur(x, 1.6),
         lambda x: ref.gaussian_blur(x, 1.6)),
        ("fast", lambda x: ops.fast_score(x), lambda x: ref.fast_score(x)),
    ]:
        t_ref = _bench(jax.jit(ref_fn), img)
        # interpret-mode pallas timing is not meaningful perf; report the
        # ref wall time and allclose-verified status as the derived column
        a = np.asarray(pallas_fn(img))
        b = np.asarray(ref_fn(img))
        ok = bool(np.allclose(a, b, rtol=1e-4, atol=1e-5))
        out.append((f"kernel/{name}", t_ref, f"pallas_allclose={ok}"))
    return out


def bench_scalespace(quick):
    """Fused scale-space vs the seed's level-by-level gaussian_pyramid path
    (deliverable: >= 1.5x per tile, jit'd jnp on CPU), plus the Pallas
    kernel's interpret-mode parity against the jnp oracle (atol=1e-5)."""
    from repro.core import detectors as D
    from repro.core.pyramid import blur_separable
    from repro.data.landsat import synthetic_scene
    from repro.kernels import ops, ref
    n = 2 if quick else 4
    hw = 176     # the engine's tile extent: tile 128 + 2*24 halo
    img = jnp.asarray(np.stack([synthetic_scene(hw, hw, i)
                                for i in range(n)]))
    fused = jax.jit(lambda x: D.sift_dog_response(x)[0])
    seed = jax.jit(lambda x: D.sift_dog_response_levelwise(x)[0])
    t_fused = _bench(fused, img)
    t_seed = _bench(seed, img)
    # Pallas fused-octave kernel vs oracle (interpret mode on CPU)
    base = blur_separable(img, 1.6)
    ra, sa = ops.scalespace_octave(base, scales_per_octave=3,
                                   contrast_threshold=0.04 / 3)
    rb, sb = ref.scalespace_octave(base, scales_per_octave=3,
                                   contrast_threshold=0.04 / 3)
    ok = (bool(np.allclose(np.asarray(ra), np.asarray(rb), atol=1e-5))
          and bool(np.allclose(np.asarray(sa), np.asarray(sb), atol=1e-5)))
    return [
        ("scalespace/fused", t_fused,
         f"speedup_vs_seed={t_seed / t_fused:.2f};pallas_allclose={ok}"),
        ("scalespace/seed_levelwise", t_seed,
         f"us_per_tile={t_seed / n:.1f}"),
    ]


def bench_matcher(quick):
    """Matcher: production packed/dot path vs naive oracle + Pallas parity
    (non-zero exit on parity failure via the allclose gate below)."""
    from benchmarks.bench_matcher import run
    return run(quick)


def bench_serve(quick):
    """Serving subsystem: batched-vs-sequential throughput, cache hit-rate,
    and the served-vs-direct bit-parity gate (strict mode raises on
    mismatch, failing this section)."""
    from benchmarks.bench_serve import run
    return run(quick, strict=True)


def bench_fleet(quick):
    """Fleet serving: replica-scaling makespan (>= 2x at 4 replicas),
    fleet-warmed shared cache tier, shed rate at rated load, and the
    routed-vs-direct bit-parity gate (strict mode raises on any gate,
    failing this section)."""
    from benchmarks.bench_fleet import run
    return run(quick, strict=True)


def bench_lm_step(quick):
    from repro.configs import get_config
    from repro.models import build_model
    from repro.optim import AdamW
    from repro.train.step import (make_train_step, make_init_fn,
                                  TrainStepConfig)
    from repro.data.tokens import synthetic_lm_batch
    out = []
    for arch in (["smollm-135m"] if quick else
                 ["smollm-135m", "xlstm-350m", "zamba2-2.7b"]):
        cfg = get_config(arch).reduced().replace(remat="nothing")
        model = build_model(cfg)
        opt = AdamW()
        scfg = TrainStepConfig()
        state = jax.jit(make_init_fn(model, opt, scfg))(jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, opt, scfg))
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_lm_batch(2, 64, cfg.vocab_size).items()}
        us = _bench(lambda s, b: step(s, b)[1]["loss"], state, batch)
        out.append((f"train_step/{arch}_reduced", us, "tokens=128"))
    return out


def bench_roofline(quick):
    """Roofline terms come from the dry-run artifacts (separate pipeline —
    benchmarks/roofline.py); surface the headline cells here."""
    import glob
    import json
    out = []
    for f in sorted(glob.glob("experiments/dryrun/16x16__*.json")):
        d = json.load(open(f))
        r = (d["corrected"]["roofline"] if "corrected" in d
             else d["roofline"])
        out.append((f"roofline/{d['arch']}__{d['shape']}",
                    r["compute_s"] * 1e6,
                    f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}"))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true",
                    help="also write a BENCH_<rev>.json snapshot so the "
                         "perf trajectory is tracked per PR")
    args, _ = ap.parse_known_args()
    rows = []
    failed = False
    print("name,us_per_call,derived")
    for section in (bench_table2, bench_table1, bench_kernels,
                    bench_scalespace, bench_matcher, bench_serve,
                    bench_fleet, bench_lm_step, bench_roofline):
        try:
            for name, us, derived in section(args.quick):
                rows.append((name, us, derived))
                print(f"{name},{us:.1f},{derived}")
                if "allclose=False" in derived:
                    failed = True
        except Exception as e:  # noqa: BLE001
            rows.append((section.__name__, 0.0, f"ERROR={e!r}"))
            print(f"{section.__name__},0,ERROR={e!r}")
            failed = True
    if args.json:
        import json
        import subprocess
        try:
            rev = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                                 capture_output=True, text=True,
                                 check=True).stdout.strip()
        except Exception:  # noqa: BLE001
            rev = "unknown"
        from repro.obs import export as obs_export
        path = f"BENCH_{rev}.json"
        with open(path, "w") as f:
            # observability payload rides along: the registry histograms
            # and kernel-profile rows accumulated while the sections ran
            # (dispatch decisions, cache hit mix, layer latency quantiles)
            # give each benchmark row its provenance
            json.dump({"rev": rev, "quick": args.quick,
                       "rows": [{"name": n, "us_per_call": us,
                                 "derived": d} for n, us, d in rows],
                       "observability": obs_export.metrics_payload()},
                      f, indent=1, default=str)
        print(f"# wrote {path}")
    if failed:
        # a section crashed or a kernel-vs-oracle parity check came back
        # False — make the CI step actually fail
        raise SystemExit(1)


if __name__ == "__main__":
    main()
