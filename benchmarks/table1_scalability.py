"""Paper Table 1 analogue: horizontal scalability of every algorithm.

The paper measures wall-clock on 1/2/4 Hadoop nodes (N=3 and N=20 LandSat
scenes).  Here the worker axis is simulated by partitioning the same tile
bundle into w independent shards and executing them sequentially on the one
CPU device, measuring per-shard wall time; the reported t(w) is the MAX
shard time (the straggler defines makespan, as in MapReduce).  Speedup(w) =
t(1)/t(w).  The paper's qualitative claims to reproduce:

  * compute-heavy algorithms (SIFT) scale near-linearly,
  * tiny-kernel algorithms (FAST) scale sub-linearly (scheduling overhead —
    here: per-shard dispatch + compile amortization).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.core.bundle import bundle_scenes
from repro.core.engine import extract_features
from repro.data.landsat import synthetic_scene


def run(n_scenes=3, scene=512, tile=128, workers=(1, 2, 4), repeats=1):
    cfg = DifetConfig(tile=tile, halo=24, max_keypoints_per_tile=128)
    scenes = [synthetic_scene(scene, scene, seed=i) for i in range(n_scenes)]
    bundle = bundle_scenes(scenes, cfg)
    rows = []
    for alg in PAPER_ALGORITHMS:
        fn = jax.jit(lambda t, h, a=alg: extract_features(t, h, a, cfg))
        times = {}
        counts = {}
        for w in workers:
            splits = np.array_split(np.arange(len(bundle)), w)
            # warmup/compile once per shard shape
            for s in {len(s) for s in splits}:
                fn(bundle.tiles[:s], bundle.headers[:s])["total_count"].block_until_ready()
            shard_times = []
            total = 0
            for s in splits:
                t0 = time.perf_counter()
                for _ in range(repeats):
                    r = fn(bundle.tiles[s], bundle.headers[s])
                    r["total_count"].block_until_ready()
                shard_times.append((time.perf_counter() - t0) / repeats)
                total += int(r["total_count"])
            times[w] = max(shard_times)        # makespan = slowest shard
            counts[w] = total
        assert len(set(counts.values())) == 1, (alg, counts)
        rows.append((alg, times, counts[workers[0]]))
    return rows


def main():
    rows = run()
    print("# Table 1 analogue: simulated horizontal scalability "
          "(max-shard makespan, seconds)")
    print(f"{'algorithm':12s} {'w=1':>8s} {'w=2':>8s} {'w=4':>8s} "
          f"{'speedup4':>9s} {'count':>8s}")
    for alg, t, c in rows:
        print(f"{alg:12s} {t[1]:8.3f} {t[2]:8.3f} {t[4]:8.3f} "
              f"{t[1]/t[4]:9.2f} {c:8d}")
    return rows


if __name__ == "__main__":
    main()
