"""Paper Table 1 analogue: horizontal scalability over the streaming ingest.

The paper measures wall-clock on 1/2/4 Hadoop nodes over a fixed LandSat
scene set (N=3 and N=20).  This benchmark drives the same experiment
through the horizontal-scalability subsystem (`repro.launch.scale`): a
band-striped on-disk scene set, streamed into fixed-shape tile batches
(`data/pipeline.py`), with the worker count swept 1→N.  Worker *i* of *W*
streams only its contiguous slice of the batch manifest; t(W) is the
slowest worker's wall clock (the straggler defines makespan, as in
MapReduce) and speedup(W) = t(1)/t(W), efficiency(W) = speedup(W)/W.

Qualitative claims reproduced:
  * compute-heavy algorithms (SIFT) scale near-linearly,
  * tiny-kernel algorithms (FAST/Harris) scale sub-linearly — per-worker
    fixed costs (stream spin-up, dispatch) are a larger fraction of their
    makespan.

Hard gates (`gate()`, enforced by ``benchmarks/run.py`` and ``main()``):
  * every worker count's per-batch outputs are bit-identical to the
    single-worker reference (scaling never changes numerics), and
  * the heaviest algorithm in the sweep reaches ≥ 1.6x speedup at 2
    simulated workers.
"""
from __future__ import annotations

from pathlib import Path

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.launch.scale import build_scene_set, print_table, run_scaling

MIN_SPEEDUP_2W = 1.6
# the gate anchors on the most compute-heavy algorithm present (paper
# Table 1: SIFT dominates and scales near-linearly)
GATE_PREFERENCE = ("sift", "surf", "orb", "brief", "shi_tomasi", "harris",
                   "fast")


def run(n_scenes=3, scene=512, tile=128, workers=(1, 2, 4), batch_tiles=4,
        algorithms=PAPER_ALGORITHMS, store="/tmp/difet_table1",
        repeats=3):
    """Execute the sweep; returns `repro.launch.scale.run_scaling` rows.
    ``repeats``: best-of-R wall per worker slice (parity checked on every
    repeat), so a one-off scheduler hiccup can't fail the speedup gate."""
    cfg = DifetConfig(tile=tile, halo=24, max_keypoints_per_tile=128)
    readers = build_scene_set(Path(store) / f"scenes_{scene}",
                              n_scenes, (scene, scene))
    return run_scaling(readers, cfg, algorithms, workers,
                       batch_tiles=batch_tiles, repeats=repeats)


def gate_algorithm(rows) -> str:
    """The algorithm whose speedup the hard gate anchors on."""
    present = {r["algorithm"] for r in rows}
    for alg in GATE_PREFERENCE:
        if alg in present:
            return alg
    return rows[0]["algorithm"]


def run_gated(retries: int = 1, **kwargs):
    """`run()` + `gate()` with up to ``retries`` re-measurements when only
    the *speedup* gate trips: the CI hosts have bursty CPU quotas (a
    sustained throttle window during one worker's slice skews the ratio),
    so a spurious timing failure re-measures once while a real
    scalability regression — or any parity break, which never retries —
    still fails.  Returns the rows of the passing (or final) attempt."""
    while True:
        rows = run(**kwargs)
        try:
            gate(rows)
            return rows
        except RuntimeError as e:
            if retries <= 0 or "parity" in str(e):
                raise
            retries -= 1
            print(f"# speedup gate tripped ({e}); re-measuring "
                  f"({retries} retries left)")


def gate(rows) -> None:
    """Raise unless parity held everywhere and the anchor algorithm hit
    ≥ 1.6x at 2 workers — the scalability regression gate."""
    broken = [r["algorithm"] for r in rows if not r["parity"]]
    if broken:
        raise RuntimeError(
            f"table1 parity FAILED for {broken}: some worker count "
            f"produced different bits than the single-worker path")
    anchor = gate_algorithm(rows)
    row = next(r for r in rows if r["algorithm"] == anchor)
    s2 = row["speedup"].get(2)
    if s2 is None:
        raise RuntimeError("table1 sweep did not include 2 workers")
    if s2 < MIN_SPEEDUP_2W:
        raise RuntimeError(
            f"table1 speedup gate FAILED: {anchor} reached {s2:.2f}x at "
            f"2 workers (< {MIN_SPEEDUP_2W}x)")


def main():
    rows = run_gated()
    workers = sorted(rows[0]["t"])
    print("# Table 1 analogue: streaming-ingest horizontal scalability "
          "(max-worker makespan, seconds)")
    print_table(rows, workers)
    anchor = gate_algorithm(rows)
    print(f"# gate OK: bit-parity at every worker count; "
          f"{anchor} speedup(2)="
          f"{next(r for r in rows if r['algorithm'] == anchor)['speedup'][2]:.2f}x")
    return rows


if __name__ == "__main__":
    main()
