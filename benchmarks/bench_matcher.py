"""Descriptor-matcher benchmark + CI gates (dispatched vs oracle, streaming
scale smoke, approx-index recall).

Rows / gates (all raise on failure, which fails the CI bench step):

* ``matcher/{hamming,l2}`` — the *dispatched* `ops.match_best2` (whatever
  path `kernels/dispatch.py` picked for this host) timed against the naive
  jnp oracle (`kernels/ref.match_best2`).  **Gate: dispatched L2 must be
  >= 1.0x the oracle** (one re-measure allowed for CPU-quota noise) — the
  0.06x reading in BENCH_61e2246 would fail this build.  Parity of all
  four dispatch paths (jnp_full / jnp_stream / pallas_resident /
  pallas_stream, kernels in interpret mode on CPU) against the oracle is
  asserted on every run: Hamming bit-identical, L2 allclose + identical
  argbest.
* ``matcher/stream_1M`` — a 1,000,000-row packed-Hamming database scanned
  by the dispatched path.  **Gates: the dispatcher must resolve to a
  streaming path** (no materializing fallback — the old VMEM gate would
  have silently fallen back) **and the scan must agree bit-identically
  with the blocked oracle on a sampled query subset.**
* ``matcher/approx_recall`` — `core/matching.match_pair(mode="approx")`
  (multi-probe LSH + exact re-rank) on BRIEF descriptors extracted from
  two overlapping crops of a ``synthetic_scene``.  **Gate: >= 0.95 of the
  exact pipeline's accepted matches keep the same best index at default
  probes.**

    PYTHONPATH=src python -m benchmarks.run --quick      # CI entry
    PYTHONPATH=src python -m benchmarks.bench_matcher    # standalone
"""
from __future__ import annotations

import argparse
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.run import _bench

STREAM_DB_ROWS = 1_000_000
STREAM_QUERIES = 128
STREAM_SAMPLE = 16          # queries cross-checked against the blocked oracle
RECALL_FLOOR = 0.95
L2_SPEEDUP_FLOOR = 1.0


def make_descriptors(n: int, seed: int, metric: str):
    rng = np.random.RandomState(seed)
    if metric == "hamming":       # 256-bit BRIEF/ORB: 8 packed uint32 words
        return jnp.asarray(rng.randint(0, 2 ** 32, size=(n, 8),
                                       dtype=np.uint64).astype(np.uint32))
    d = rng.randn(n, 128).astype(np.float32)    # 128-d SIFT
    return jnp.asarray(d / np.linalg.norm(d, axis=-1, keepdims=True))


def _assert_paths_match_oracle(q, db, valid, metric):
    """Every dispatch path against the independent oracle formulation."""
    from repro.kernels import dispatch, ops, ref
    o = [np.asarray(x) for x in ref.match_best2(q, db, valid, metric=metric)]
    for path in dispatch.MATCH_PATHS:
        got = [np.asarray(x) for x in ops.match_best2(
            q, db, valid, metric=metric, path=path, interpret=True)]
        if metric == "hamming":   # integer distances: bit-identical
            ok = all(np.array_equal(a, b) for a, b in zip(got, o))
        else:
            ok = (np.allclose(got[0], o[0], rtol=1e-5, atol=1e-4)
                  and np.allclose(got[1], o[1], rtol=1e-5, atol=1e-4)
                  and np.array_equal(got[2], o[2]))
        if not ok:
            raise RuntimeError(
                f"matcher path {path!r} disagrees with the oracle "
                f"(metric={metric})")


def bench_dispatched(quick: bool):
    """Dispatched match_best2 vs oracle; the L2 >= 1.0x gate."""
    from repro.kernels import ops, ref
    n = 256 if quick else 512
    rows = []
    for metric in ("hamming", "l2"):
        q = make_descriptors(n, 0, metric)
        db = make_descriptors(n, 1, metric)
        valid = jnp.ones((n,), jnp.bool_)
        _assert_paths_match_oracle(q, db, valid, metric)
        path = ops.match_path(n, n, q.shape[1], metric=metric)
        prod = jax.jit(functools.partial(ops.match_best2, metric=metric))
        orac = jax.jit(functools.partial(ref.match_best2, metric=metric))
        t_prod = _bench(prod, q, db, valid)
        t_orac = _bench(orac, q, db, valid)
        if metric == "l2" and t_orac / t_prod < L2_SPEEDUP_FLOOR:
            # one re-measure: shared CI runners have CPU-quota noise
            t_prod = _bench(prod, q, db, valid)
            t_orac = _bench(orac, q, db, valid)
            if t_orac / t_prod < L2_SPEEDUP_FLOOR:
                raise RuntimeError(
                    f"dispatched L2 matcher is {t_orac / t_prod:.2f}x the "
                    f"jnp oracle (path={path}) — below the "
                    f"{L2_SPEEDUP_FLOOR:.1f}x gate")
        pairs_per_s = n * n / (t_prod * 1e-6)
        rows.append((f"matcher/{metric}", t_prod,
                     f"speedup_vs_oracle={t_orac / t_prod:.2f};path={path};"
                     f"pallas_allclose=True;pairs_per_s={pairs_per_s:.3e}"))
    return rows


def bench_stream_1m(quick: bool):
    """One query batch over a million-descriptor DB via the dispatched
    streaming path; sampled-query bit-parity against the blocked oracle."""
    from repro.kernels import ops, ref
    rng = np.random.RandomState(7)
    nk, nq = STREAM_DB_ROWS, STREAM_QUERIES
    db = jnp.asarray(rng.randint(0, 2 ** 32, size=(nk, 8),
                                 dtype=np.uint64).astype(np.uint32))
    valid = jnp.asarray(rng.rand(nk) > 0.05)
    q = make_descriptors(nq, 3, "hamming")
    path = ops.match_path(nq, nk, 8, metric="hamming")
    if "stream" not in path:
        raise RuntimeError(
            f"1M-row DB dispatched to {path!r} — expected a streaming "
            "path (materializing fallback would re-open the VMEM gate)")
    fn = jax.jit(functools.partial(ops.match_best2, metric="hamming"))
    t_us = _bench(fn, q, db, valid, repeats=1)
    best, second, idx = (np.asarray(x) for x in fn(q, db, valid))
    sample = np.sort(rng.choice(nq, STREAM_SAMPLE, replace=False))
    ob, os_, oi = (np.asarray(x) for x in ref.match_best2_blocked(
        q[sample], db, valid, metric="hamming", block=1 << 14))
    if not (np.array_equal(best[sample], ob)
            and np.array_equal(second[sample], os_)
            and np.array_equal(idx[sample], oi)):
        raise RuntimeError("streaming 1M-row scan disagrees with the "
                           "blocked oracle on sampled queries")
    pairs_per_s = nq * nk / (t_us * 1e-6)
    return [(f"matcher/stream_1M", t_us,
             f"path={path};rows={nk};sampled_parity=True;"
             f"pairs_per_s={pairs_per_s:.3e}")]


def _crop_features(scene, alg="brief", tile=64):
    from repro.configs.difet_paper import DifetConfig
    from repro.core.bundle import tile_scene
    from repro.core.engine import extract_features
    cfg = DifetConfig(tile=tile, halo=24, max_keypoints_per_tile=256,
                      fast_threshold=0.08)
    b = tile_scene(scene, cfg)
    r = jax.jit(lambda t, h: extract_features(t, h, alg, cfg))(
        b.tiles, b.headers)
    return (jnp.asarray(r["top_desc"]), jnp.asarray(r["top_valid"]))


def bench_approx_recall(quick: bool):
    """Approx-mode recall vs the exact pipeline on a synthetic scene pair
    (overlapping crops — the stitching workload's matching geometry)."""
    import time

    from repro.core import matching
    from repro.data.landsat import synthetic_scene
    base = synthetic_scene(200, 320, seed=5, density=4.0)
    da, va = _crop_features(base[:, :220])
    db_, vb = _crop_features(base[:, 100:])
    exact = matching.match_pair(da, va, db_, vb)
    t0 = time.perf_counter()
    approx = matching.match_pair(da, va, db_, vb, mode="approx")
    jax.block_until_ready(approx.idx_b)
    t_us = (time.perf_counter() - t0) * 1e6     # includes index build
    acc = np.asarray(exact.ok)
    if not acc.any():
        raise RuntimeError("no exact-accepted matches — scene too sparse")
    agree = np.asarray(approx.idx_b)[acc] == np.asarray(exact.idx_b)[acc]
    recall = float(agree.mean())
    if recall < RECALL_FLOOR:
        raise RuntimeError(
            f"approx match recall {recall:.3f} < {RECALL_FLOOR} at default "
            "probes (vs the exact pipeline's accepted matches)")
    return [("matcher/approx_recall", t_us,
             f"recall={recall:.3f};accepted={int(acc.sum())};"
             f"mode=lsh_multiprobe")]


def run(quick: bool = False):
    return (bench_dispatched(quick) + bench_stream_1m(quick)
            + bench_approx_recall(quick))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    try:
        rows = run(args.quick)
    except RuntimeError as e:     # a gate tripped: named failure, exit 1
        print(f"GATE FAILED: {e}")
        raise SystemExit(1)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
