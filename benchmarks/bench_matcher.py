"""Descriptor-matcher micro-benchmark (Hamming vs L2, production vs oracle).

Times the production matcher formulation (`kernels/matcher.best2_scan`: the
packed-word SWAR-popcount / dot-expansion chunked scan — exactly what the
Pallas kernel runs per query block) against the naive jnp oracle
(`kernels/ref.match_best2`: bit-unpacked Hamming / full-matrix L2), and
checks Pallas-kernel parity in interpret mode (Hamming must be
bit-identical; interpret-mode wall time itself is not meaningful perf,
same reporting convention as ``bench_scalespace``).

Default sizes are the extraction defaults: 256-bit packed BRIEF/ORB words
and 128-d SIFT floats over a scene's top-K set.

    PYTHONPATH=src python -m benchmarks.run --quick      # CI entry
    PYTHONPATH=src python -m benchmarks.bench_matcher    # standalone
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.run import _bench


def make_descriptors(n: int, seed: int, metric: str):
    rng = np.random.RandomState(seed)
    if metric == "hamming":       # 256-bit BRIEF/ORB: 8 packed uint32 words
        return jnp.asarray(rng.randint(0, 2 ** 32, size=(n, 8),
                                       dtype=np.uint64).astype(np.uint32))
    d = rng.randn(n, 128).astype(np.float32)    # 128-d SIFT
    return jnp.asarray(d / np.linalg.norm(d, axis=-1, keepdims=True))


def run(quick: bool = False):
    from repro.kernels import ops, ref
    n = 256 if quick else 512
    rows = []
    for metric in ("hamming", "l2"):
        q = make_descriptors(n, 0, metric)
        db = make_descriptors(n, 1, metric)
        valid = jnp.ones((n,), jnp.bool_)
        prod = jax.jit(lambda q, d, v, m=metric:
                       ops.match_best2(q, d, v, metric=m))
        orac = jax.jit(lambda q, d, v, m=metric:
                       ref.match_best2(q, d, v, metric=m))
        t_prod = _bench(prod, q, db, valid)
        t_orac = _bench(orac, q, db, valid)
        a = [np.asarray(x) for x in prod(q, db, valid)]
        b = [np.asarray(x) for x in orac(q, db, valid)]
        p = [np.asarray(x) for x in ops.match_best2(
            q, db, valid, metric=metric, use_pallas=True, interpret=True)]
        if metric == "hamming":   # integer distances: all three bit-identical
            ok = (all(np.array_equal(x, y) for x, y in zip(a, b))
                  and all(np.array_equal(x, y) for x, y in zip(p, b)))
        else:
            ok = (np.allclose(a[0], b[0], rtol=1e-5, atol=1e-4)
                  and np.allclose(p[0], b[0], rtol=1e-5, atol=1e-4)
                  and np.array_equal(a[2], b[2])
                  and np.array_equal(p[2], b[2]))
        pairs_per_s = n * n / (t_prod * 1e-6)
        rows.append((f"matcher/{metric}", t_prod,
                     f"speedup_vs_oracle={t_orac / t_prod:.2f};"
                     f"pallas_allclose={ok};pairs_per_s={pairs_per_s:.3e}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    failed = False
    print("name,us_per_call,derived")
    for name, us, derived in run(args.quick):
        print(f"{name},{us:.1f},{derived}")
        if "allclose=False" in derived:
            failed = True
    if failed:                    # kernel-vs-oracle parity is a CI gate
        raise SystemExit(1)


if __name__ == "__main__":
    main()
