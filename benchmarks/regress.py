"""Perf-regression sentry over the committed ``BENCH_<rev>.json``
snapshots (written by ``benchmarks/run.py --json``).

Diffs the two newest snapshots — "newest" by the commit time of the
``<rev>`` embedded in the filename (``git log -1 --format=%ct``), falling
back to file mtime for revs git no longer knows — over their *shared*
row keys: rows present in only one snapshot are listed but never judged
(a new benchmark is not a regression, a deleted one is not a win).

A row whose ``us_per_call`` grew by more than ``--warn``x is annotated
(GitHub ``::warning::`` lines, so the CI run surfaces them inline);
more than ``--fail``x exits non-zero.  Rows timing 0 (errored sections)
are skipped — ``run.py`` already fails the build on those.

    PYTHONPATH=src python benchmarks/regress.py
    PYTHONPATH=src python benchmarks/regress.py --warn 1.25 --fail 1.5

CI runs this as a *non-blocking* step (``continue-on-error``): shared
runners are noisy enough that a hard gate on wall-time ratios would
flake, but the annotations make a real cliff visible in review.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
from typing import Dict, List, Optional, Tuple


def _rev_time(path: str) -> float:
    """Commit time of the snapshot's embedded rev; file mtime when git
    does not recognise it (rebased-away rev, exported tree)."""
    rev = os.path.basename(path)[len("BENCH_"):-len(".json")]
    try:
        out = subprocess.run(
            ["git", "log", "-1", "--format=%ct", rev],
            capture_output=True, text=True, check=True,
            cwd=os.path.dirname(os.path.abspath(path)) or ".")
        return float(out.stdout.strip())
    except Exception:  # noqa: BLE001 — unknown rev / not a repo
        return os.path.getmtime(path)


def newest_snapshots(root: str = ".") -> List[str]:
    """Every ``BENCH_<rev>.json`` under ``root``, oldest → newest by
    commit time (mtime fallback)."""
    paths = glob.glob(os.path.join(root, "BENCH_*.json"))
    return sorted(paths, key=_rev_time)


def _rows(snapshot: dict) -> Dict[str, float]:
    out = {}
    for row in snapshot.get("rows", ()):
        us = float(row.get("us_per_call", 0.0))
        if us > 0:                       # errored sections time as 0
            out[str(row["name"])] = us
    return out


def diff_snapshots(old: dict, new: dict, *, warn: float = 1.25,
                   fail: float = 1.5) -> List[Dict[str, object]]:
    """Compare two snapshot dicts; one result row per benchmark with
    ``status`` in ``ok | warn | fail | added | removed``.  Only shared
    keys get a ratio/status judgement; ``warn``/``fail`` are growth
    ratios (new/old) on ``us_per_call``."""
    a, b = _rows(old), _rows(new)
    out: List[Dict[str, object]] = []
    for name in sorted(set(a) | set(b)):
        if name not in b:
            out.append({"name": name, "status": "removed",
                        "old_us": a[name], "new_us": None, "ratio": None})
            continue
        if name not in a:
            out.append({"name": name, "status": "added",
                        "old_us": None, "new_us": b[name], "ratio": None})
            continue
        ratio = b[name] / a[name]
        status = "ok"
        if ratio > fail:
            status = "fail"
        elif ratio > warn:
            status = "warn"
        out.append({"name": name, "status": status, "old_us": a[name],
                    "new_us": b[name], "ratio": ratio})
    return out


def render(results: List[Dict[str, object]], old_rev: str,
           new_rev: str) -> Tuple[int, int]:
    """Print the diff table + GitHub annotations; returns
    ``(n_warn, n_fail)``."""
    n_warn = n_fail = 0
    print(f"perf regress: {old_rev} -> {new_rev} "
          f"({sum(r['status'] not in ('added', 'removed') for r in results)}"
          f" shared rows)")
    for r in results:
        if r["status"] == "added":
            print(f"  + {r['name']:<40} (new: {r['new_us']:.1f} us)")
        elif r["status"] == "removed":
            print(f"  - {r['name']:<40} (was: {r['old_us']:.1f} us)")
        else:
            mark = {"ok": " ", "warn": "!", "fail": "X"}[r["status"]]
            print(f"  {mark} {r['name']:<40} {r['old_us']:>12.1f} -> "
                  f"{r['new_us']:>12.1f} us  ({r['ratio']:.2f}x)")
        if r["status"] == "warn":
            n_warn += 1
            print(f"::warning title=perf regression::{r['name']} "
                  f"slowed {r['ratio']:.2f}x "
                  f"({r['old_us']:.1f} -> {r['new_us']:.1f} us/call)")
        elif r["status"] == "fail":
            n_fail += 1
            print(f"::warning title=perf cliff::{r['name']} "
                  f"slowed {r['ratio']:.2f}x "
                  f"({r['old_us']:.1f} -> {r['new_us']:.1f} us/call)")
    return n_warn, n_fail


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=".",
                    help="directory holding the BENCH_<rev>.json snapshots")
    ap.add_argument("--warn", type=float, default=1.25,
                    help="growth ratio that annotates a warning")
    ap.add_argument("--fail", type=float, default=1.5,
                    help="growth ratio that fails the sentry (exit 1)")
    ap.add_argument("--old", default=None, metavar="PATH",
                    help="explicit old snapshot (default: 2nd-newest)")
    ap.add_argument("--new", default=None, metavar="PATH",
                    help="explicit new snapshot (default: newest)")
    args = ap.parse_args(argv)

    if args.old and args.new:
        old_path, new_path = args.old, args.new
    else:
        snaps = newest_snapshots(args.root)
        if len(snaps) < 2:
            print(f"perf regress: {len(snaps)} snapshot(s) under "
                  f"{args.root!r} — need 2 to diff; skipping")
            return 0
        old_path, new_path = snaps[-2], snaps[-1]

    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    results = diff_snapshots(old, new, warn=args.warn, fail=args.fail)
    n_warn, n_fail = render(results, old.get("rev", old_path),
                            new.get("rev", new_path))
    if n_fail:
        print(f"PERF REGRESS FAILED: {n_fail} row(s) beyond "
              f"{args.fail:.2f}x ({n_warn} warned)")
        return 1
    print(f"perf regress ok ({n_warn} warning(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
