"""Fleet-serving benchmark + gates: replica scaling, fleet-warmed cache,
shed rate at rated load, and routed-vs-direct bit-parity.

Four measurements on an in-process `serve/fleet.py::Fleet` replaying
`serve/trace.py` traces:

* **throughput scaling** — the same unique-tile closed-loop flood
  (result cache disabled, so the win is honest routing + batching)
  through a 1-replica and a 4-replica fleet.  This host has one CPU
  core, so wall-clock cannot show parallel speedup; instead every
  replica shares one ``step_lock`` serializing device steps and
  accounts uncontended per-replica ``busy_s`` — fleet makespan is the
  straggler's busy time, exactly the simulated-worker methodology of
  ``benchmarks/table1_scalability.py``.  Deliverable (gated): makespan
  speedup >= 2x at 4 replicas — a router that hotspots one replica
  fails this even though total work is unchanged.
* **fleet-warmed cache** — fleet A computes a hot-scene trace through a
  shared ``DiskCacheTier``; a *fresh* fleet B (empty local LRUs, same
  directory) must serve the replay >= 99% from cache with every hit
  coming off disk — one fleet's work warms the next (gated).
* **shed at rated load** — open-loop Poisson injection at 60% of the
  measured 4-replica capacity; admission control must shed <= 1%
  (gated) and the p99 latency is recorded.
* **parity** — fleet-B responses (which round-tripped through the disk
  tier) must be *bit-identical* to direct
  ``core/engine.py::extract_features_multi`` on the same padded tiles
  (gated; covers router, replica, and npz round trip in one check).
* **SLO autoscaler** — a 1-replica fleet with an unmeetable p99 SLO
  must scale up on the *measured* breach (``p99_latency`` trigger, not
  the queue fast path), then drain back down once the window is clean,
  losing nothing (gated); every decision — trigger, value, before/after
  replica count — is serialized into the row's ``derived`` field and so
  into the ``BENCH_<rev>.json`` snapshot.

Timing gates (speedup, shed) re-measure once before failing — CPU-quota
noise on shared CI hosts; parity and cache gates never retry.

    PYTHONPATH=src python -m benchmarks.run --quick       # CI entry
    PYTHONPATH=src python -m benchmarks.bench_fleet       # standalone
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import tempfile
import threading
import time

import numpy as np

from repro.configs.difet_paper import DifetConfig
from repro.serve import (Fleet, FleetConfig, RouterConfig, ServeConfig,
                         Shed)
from repro.serve.trace import TraceConfig, make_trace, scene_key, tile_pool

ALGS = ("harris", "shi_tomasi")
TILE, HALO, K = 32, 8, 32


class FleetGateError(AssertionError):
    """A fleet CI gate (scaling / cache / shed / parity) failed."""


def _fleet(n: int, *, cache_entries: int, cache_dir=None,
           step_lock=None, spill: int = 8,
           max_global_pending: int = 4096) -> Fleet:
    base = DifetConfig(tile=TILE, halo=HALO, max_keypoints_per_tile=K)
    cfg = FleetConfig(
        serve=ServeConfig(base=base, buckets=(TILE,), max_batch=8,
                          max_batch_delay_s=0.02, max_pending=4096,
                          cache_entries=cache_entries),
        router=RouterConfig(max_global_pending=max_global_pending,
                            spill_queue_threshold=spill),
        initial_replicas=n, min_replicas=n, max_replicas=n,
        warm_algorithm_sets=(ALGS,), cache_dir=cache_dir)
    return Fleet(cfg, step_lock=step_lock)


def _uniform_trace(n: int, seed: int = 0) -> TraceConfig:
    """One distinct scene per request (hot set = everything => uniform):
    no repeats, so a disabled cache measures pure routing + batching."""
    return TraceConfig(n_requests=n, seed=seed, unique_scenes=n,
                       hot_fraction=1.0, tile_sizes=(TILE,),
                       algorithm_sets=(ALGS,))


def _hot_trace(n: int, seed: int = 1) -> TraceConfig:
    """Hot-scene skew (the cache regime): 70% of requests over 2 scenes."""
    return TraceConfig(n_requests=n, seed=seed, unique_scenes=16,
                       hot_fraction=0.125, hot_weight=0.7,
                       tile_sizes=(TILE,), algorithm_sets=(ALGS,))


def _flood(fleet: Fleet, trace, pool):
    """Closed-loop flood: submit everything, then drain.  Returns
    (wall_s, responses)."""
    t0 = time.perf_counter()
    handles = [fleet.submit(pool[ev.pool_key], ev.algorithms,
                            tenant=ev.tenant, scene_key=scene_key(ev))
               for ev in trace]
    resps = [h.result(240) for h in handles]
    return time.perf_counter() - t0, resps


def _busy(fleet: Fleet):
    """(total busy_s, straggler busy_s) across replicas."""
    reps = fleet.stats()["replicas"].values()
    busy = [r["busy_s"] for r in reps]
    return sum(busy), max(busy)


def _measure_scaling(n_requests: int):
    """One r1-vs-r4 measurement; returns the row ingredients."""
    tcfg = _uniform_trace(n_requests)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    lock = threading.Lock()

    f1 = _fleet(1, cache_entries=0, step_lock=lock)
    wall1, _ = _flood(f1, trace, pool)
    busy1, _ = _busy(f1)
    f1.close()

    f4 = _fleet(4, cache_entries=0, step_lock=lock)
    wall4, _ = _flood(f4, trace, pool)
    busy4_total, busy4_max = _busy(f4)
    s4 = f4.stats()
    f4.close()
    speedup = busy1 / max(busy4_max, 1e-9)
    return {"wall1": wall1, "wall4": wall4, "busy1": busy1,
            "busy4_total": busy4_total, "busy4_max": busy4_max,
            "speedup": speedup, "spill": s4["routed_spill"],
            "affinity": s4["routed_affinity"]}


def run(quick: bool = False, strict: bool = True):
    import jax
    from repro.core import engine

    n_scale = 64 if quick else 160
    n_hot = 48 if quick else 96
    rows = []

    # -- replica scaling (gated: makespan speedup >= 2x at 4 replicas) ------
    m = _measure_scaling(n_scale)
    if m["speedup"] < 2.0:                 # timing gate: one re-measure
        m = _measure_scaling(n_scale)
    scaling_ok = m["speedup"] >= 2.0
    rows.append(("fleet/throughput_r1", m["wall1"] / n_scale * 1e6,
                 f"req_per_s={n_scale / m['wall1']:.1f};"
                 f"busy_s={m['busy1']:.3f}"))
    rows.append(("fleet/throughput_r4", m["wall4"] / n_scale * 1e6,
                 f"speedup_makespan={m['speedup']:.2f};"
                 f"busy_max={m['busy4_max']:.3f};"
                 f"busy_total={m['busy4_total']:.3f};"
                 f"affinity={m['affinity']};spill={m['spill']}"))

    # -- fleet-warmed cache: fresh replicas served from the shared tier -----
    tcfg = _hot_trace(n_hot)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    cache_dir = tempfile.mkdtemp(prefix="bench-fleet-cache-")
    fa = _fleet(2, cache_entries=1024, cache_dir=cache_dir)
    _flood(fa, trace, pool)                # fleet A computes + writes through
    fa.close()
    fb = _fleet(2, cache_entries=1024, cache_dir=cache_dir)   # empty LRUs
    t_b, resps = _flood(fb, trace, pool)
    cached_frac = np.mean([r.fully_cached for r in resps])
    disk_hits = sum(r["cache"]["disk_hits"]
                    for r in fb.stats()["replicas"].values())
    rows.append(("fleet/warm_cache", t_b / n_hot * 1e6,
                 f"cached_frac={cached_frac:.3f};disk_hits={disk_hits};"
                 f"replay_req_per_s={n_hot / t_b:.1f}"))
    cache_ok = cached_frac >= 0.99 and disk_hits > 0

    # -- parity (gated, no retry): fleet-B responses vs direct engine -------
    svc = next(iter(fb.router._slots.values())).service
    bucket = svc.table.interiors[0]
    direct_fn = jax.jit(functools.partial(
        engine.extract_features_multi, algorithms=ALGS,
        cfg=svc.table.cfg_for(bucket)))
    n_check = 8 if quick else 16
    mismatches = []
    for i in range(min(n_check, len(trace))):
        ev = trace[i]
        tile, header = svc.table.pad_to_bucket(pool[ev.pool_key], bucket)
        direct = direct_fn(tile[None], header[None])
        served = resps[i].results
        for alg in ALGS:
            for key, v in direct[alg].items():
                a, b = np.asarray(v), served[alg][key]
                if a.shape != b.shape or not np.array_equal(a, b):
                    mismatches.append(f"{i}/{alg}/{key}")
    parity_ok = not mismatches
    rows.append(("fleet/parity", 0.0,
                 f"parity_allclose={parity_ok};"
                 f"checked={n_check}x{len(ALGS)}alg;via=disk_tier"))
    fb.close()

    # -- shed at rated load (gated: <= 1% at 60% of measured capacity) ------
    capacity = n_scale / m["wall4"]        # serialized-step capacity
    rate = 0.6 * capacity
    shed_rate, p99_ms, t_open = _shed_phase(n_hot, rate)
    if shed_rate > 0.01:                   # timing gate: one re-measure
        shed_rate, p99_ms, t_open = _shed_phase(n_hot, rate)
    rows.append(("fleet/shed_rated", t_open / n_hot * 1e6,
                 f"rate_req_per_s={rate:.1f};shed_rate={shed_rate:.4f};"
                 f"p99_ms={p99_ms:.2f}"))
    shed_ok = shed_rate <= 0.01

    # -- SLO autoscaler (gated: p99-triggered up, drained down, 0 lost) -----
    a = _autoscale_phase(24 if quick else 48)
    ups = [e for e in a["events"]
           if e["action"] == "scale_up" and e["trigger"] == "p99_latency"]
    downs = [e for e in a["events"] if e["action"] == "scale_down"]
    autoscale_ok = (bool(ups) and bool(downs)
                    and a["served"] == a["expected"])
    rows.append(("fleet/slo_autoscaler", a["wall"] / a["served"] * 1e6,
                 f"served={a['served']}/{a['expected']};"
                 f"ready_final={a['ready']};"
                 f"decisions={_fmt_events(a['events'])}"))

    if strict:
        if not scaling_ok:
            raise FleetGateError(
                f"4-replica makespan speedup {m['speedup']:.2f} < 2.0 "
                f"(busy1={m['busy1']:.3f}s, straggler="
                f"{m['busy4_max']:.3f}s)")
        if not cache_ok:
            raise FleetGateError(
                f"fresh fleet not warmed by shared tier: cached_frac="
                f"{cached_frac:.3f}, disk_hits={disk_hits}")
        if not parity_ok:
            raise FleetGateError(
                f"routed results diverged from direct engine calls: "
                f"{mismatches[:8]}")
        if not shed_ok:
            raise FleetGateError(
                f"shed rate {shed_rate:.2%} > 1% at rated load "
                f"{rate:.1f} req/s")
        if not autoscale_ok:
            raise FleetGateError(
                f"SLO autoscaler gate: served={a['served']}/"
                f"{a['expected']}, decisions="
                f"{_fmt_events(a['events']) or 'none'} (need a "
                f"p99_latency scale-up and a drained scale-down)")
    return rows


def _autoscale_phase(n: int):
    """SLO-autoscaler lifecycle under load: a 1-replica fleet with a
    deliberately unmeetable p99 SLO must scale **up** on the measured
    breach (the ``p99_latency`` trigger, queue fast path disabled), then
    — once the latency window is clean — scale back **down** by
    draining, dropping nothing.  Returns (events, served, wall_s); every
    decision dict rides into the ``BENCH_<rev>.json`` row."""
    base = DifetConfig(tile=TILE, halo=HALO, max_keypoints_per_tile=K)
    cfg = FleetConfig(
        serve=ServeConfig(base=base, buckets=(TILE,), max_batch=8,
                          max_batch_delay_s=0.02, max_pending=4096,
                          cache_entries=0),
        initial_replicas=1, min_replicas=1, max_replicas=3,
        warm_algorithm_sets=(ALGS,),
        slo_p99_s=0.005,                   # any honest latency breaches
        scale_up_queue_per_replica=1e9,    # isolate the p99 trigger
        scale_down_queue_per_replica=2.0, scale_down_grace_ticks=2)
    fleet = Fleet(cfg)
    tcfg = _uniform_trace(n, seed=3)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    t0 = time.perf_counter()
    _, resps_a = _flood(fleet, trace, pool)
    fleet.autoscale_tick()                 # p99 breach → scale up
    # clean window + shallow queues: grace ticks, then drain one down
    for _ in range(cfg.scale_down_grace_ticks + 1):
        fleet.autoscale_tick()
    # the shrunk fleet still serves a full replay, nothing dropped
    _, resps_b = _flood(fleet, trace, pool)
    events = fleet.stats()["scale_events"]
    ready = len(fleet.ready_replicas())
    fleet.close()
    return {"events": events, "served": len(resps_a) + len(resps_b),
            "expected": 2 * len(trace), "ready": ready,
            "wall": time.perf_counter() - t0}


def _fmt_events(events) -> str:
    return "|".join(f"{e['action']}:{e['trigger']}:"
                    f"{e['before']}->{e['after']}" for e in events)


def _shed_phase(n: int, rate: float):
    """Open-loop Poisson injection at ``rate``; returns (shed_rate,
    p99_ms, wall_s)."""
    tcfg = dataclasses.replace(_hot_trace(n, seed=2), arrival="poisson",
                               rate=rate)
    trace, pool = make_trace(tcfg), tile_pool(tcfg)
    fleet = _fleet(2, cache_entries=0, max_global_pending=4096)
    handles, shed = [], 0
    t0 = time.perf_counter()
    for ev in trace:
        target = t0 + ev.t
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        try:
            handles.append(fleet.submit(pool[ev.pool_key], ev.algorithms,
                                        scene_key=scene_key(ev)))
        except Shed:
            shed += 1
    lat = np.asarray([h.result(240).timing["latency_s"] for h in handles])
    wall = time.perf_counter() - t0
    fleet.close()
    p99 = float(np.percentile(lat, 99) * 1e3) if len(lat) else 0.0
    return shed / len(trace), p99, wall


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    try:
        for name, us, derived in run(args.quick, strict=True):
            print(f"{name},{us:.1f},{derived}")
    except FleetGateError as e:
        print(f"fleet/GATE,0,ERROR={e}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
