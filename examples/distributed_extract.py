"""Distributed extraction with fault tolerance: build a bundle store, run a
checkpointed DIFET job, kill it mid-flight, and restart — the restarted job
resumes from the manifest and produces identical results.

    PYTHONPATH=src python examples/distributed_extract.py
"""
import shutil
import tempfile
from pathlib import Path

from repro.configs.difet_paper import DifetConfig
from repro.core import BundleStore, DifetJob, bundle_scenes
from repro.data.landsat import synthetic_scene

root = Path(tempfile.mkdtemp(prefix="difet_"))
cfg = DifetConfig(tile=128, halo=24, max_keypoints_per_tile=64)
store = BundleStore(root)
for i in range(4):
    store.put(f"bundle_{i}", bundle_scenes(
        [synthetic_scene(300, 300, seed=i)], cfg))
print(f"store: {store.list()}")

# --- first attempt: dies after 2 bundles (simulated node failure) ----------
job = DifetJob(store, "harris", shards_per_bundle=2)
try:
    job.run(simulate_failure_after=2,
            progress=lambda n: print(f"  [worker] finished {n}"))
except RuntimeError as e:
    print(f"!! {e}")

# --- restart: only the remaining bundles run -------------------------------
print("restarting job ...")
job2 = DifetJob(store, "harris", shards_per_bundle=2)
print(f"  remaining after restart: {job2.manifest.remaining}")
summary = job2.run(progress=lambda n: print(f"  [worker] finished {n}"))
print(f"done: {summary['bundles_done']}/{summary['bundles_total']} bundles, "
      f"{summary['grand_total']} features total")

# --- elastic scaling: rebalance outstanding work over a new worker set -----
job3 = DifetJob(store, "sift", shards_per_bundle=2)
for n_workers in (2, 3):
    parts = job3.rebalance(n_workers)
    print(f"elastic rebalance over {n_workers} workers: "
          f"{[len(p) for p in parts]} bundles each")
shutil.rmtree(root)
