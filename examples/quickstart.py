"""Quickstart: extract features from a synthetic LandSat-like scene with
every algorithm the paper implements (Harris, Shi-Tomasi, SIFT, SURF, FAST,
BRIEF, ORB) using the public API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.difet_paper import DifetConfig, PAPER_ALGORITHMS
from repro.core import bundle_scenes, extract_features
from repro.data.landsat import synthetic_scene_rgba

# 1. a scene in the paper's format (RGBA, 32-bit pixels)
scene = synthetic_scene_rgba(600, 800, seed=0)

# 2. tile it into a shardable bundle (the HipiImageBundle analogue)
cfg = DifetConfig(tile=256, halo=24, max_keypoints_per_tile=128)
bundle = bundle_scenes([scene], cfg)
print(f"scene 600x800 -> {len(bundle)} tiles of "
      f"{bundle.tile_hw}x{bundle.tile_hw} (halo={cfg.halo})")

# 3. run each detector/descriptor (the paper's map function)
for alg in PAPER_ALGORITHMS:
    run = jax.jit(lambda t, h, a=alg: extract_features(t, h, a, cfg))
    r = run(bundle.tiles, bundle.headers)
    n = int(r["total_count"])
    kp = int(r["keypoint_count"])
    desc = r.get("top_desc")
    dshape = "-" if desc is None else f"{desc.shape[1]}-d"
    print(f"  {alg:11s} features={n:6d} keypoints={kp:5d} desc={dshape}")

# 4. strongest keypoint in scene coordinates
r = jax.jit(lambda t, h: extract_features(t, h, "harris", cfg))(
    bundle.tiles, bundle.headers)
y, x = int(r["top_ys"][0]), int(r["top_xs"][0])
print(f"strongest Harris corner at (y={y}, x={x}) "
      f"score={float(r['top_scores'][0]):.4f}")
