"""Batched serving example: prefill-free greedy decode with a KV cache
(cache donation keeps decode memory flat), on a reduced config.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.lm import greedy_generate

for arch in ("smollm-135m", "xlstm-350m", "zamba2-2.7b"):
    cfg = get_config(arch).reduced().replace(remat="nothing")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (4, 8)), jnp.int32)           # batch of 4 requests
    t0 = time.time()
    out = greedy_generate(model, params, prompt, n_steps=16)
    dt = time.time() - t0
    print(f"{arch:14s} generated {out.shape} tokens in {dt:.1f}s "
          f"(batched greedy, KV/state cache)")
    print(f"   first request: {np.asarray(out[0]).tolist()}")
