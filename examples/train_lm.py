"""End-to-end training driver (deliverable b): train a reduced SmolLM for a
few hundred steps on CPU with checkpointing; loss must visibly decrease.
On a TPU pod, drop --reduced and the production mesh/sharding applies.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    args = ["--arch", "smollm-135m", "--reduced", "--steps", "300",
            "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_ckpt",
            "--ckpt-every", "100"]
    # pass-through overrides, e.g. --steps 50
    extra = sys.argv[1:]
    if "--steps" in extra:
        i = args.index("--steps")
        del args[i:i + 2]
    main(args + extra)
